"""AOT bridge: lower the L2 graphs to HLO text + write the manifest.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the published `xla` rust crate) rejects; the text
parser reassigns ids, so text round-trips cleanly. See
/opt/xla-example/README.md and rust/src/runtime/engine.rs.

Usage:
    cd python && python -m compile.aot --out ../artifacts
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile.model import em_estep_graph, perplexity_graph

# Compiled shape configurations. K covers the paper's sweep (20-80 fits in
# 128) and the web-scale run (1000 fits in 1024). VB/D are fixed block
# sizes the rust side pads to.
PERPLEXITY_CONFIGS = [
    # (batch D, padded K, vocab block VB)
    (64, 128, 2048),
    (64, 1024, 2048),
]
EM_CONFIGS = [
    (64, 128, 2048),
]


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (ids reassigned)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def scalar():
    return jax.ShapeDtypeStruct((), jnp.float32)


def lower_perplexity(d, k, vb, use_pallas):
    fn = functools.partial(perplexity_graph, use_pallas=use_pallas)
    return jax.jit(fn).lower(
        f32(d, k),      # n_dk
        f32(k, vb),     # n_wk_t
        f32(k),         # n_k
        f32(d, vb),     # counts
        scalar(),       # alpha
        scalar(),       # beta
        scalar(),       # vocab_size
        scalar(),       # k_real
    )


def lower_em(d, k, vb):
    return jax.jit(em_estep_graph).lower(
        f32(d, k), f32(k, vb), f32(k), f32(d, vb), scalar(), scalar(), scalar()
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {"version": 1, "artifacts": []}

    def emit(name, lowered, d, k, vb, pallas):
        fname = f"{name}_d{d}_k{k}_v{vb}.hlo.txt"
        text = to_hlo_text(lowered)
        path = os.path.join(args.out, fname)
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"].append(
            {
                "name": name,
                "file": fname,
                "batch": d,
                "k": k,
                "vblock": vb,
                "pallas": pallas,
            }
        )
        print(f"wrote {path} ({len(text)} chars)")

    for d, k, vb in PERPLEXITY_CONFIGS:
        emit("perplexity", lower_perplexity(d, k, vb, True), d, k, vb, True)
        emit("perplexity_ref", lower_perplexity(d, k, vb, False), d, k, vb, False)
    for d, k, vb in EM_CONFIGS:
        emit("em_estep", lower_em(d, k, vb), d, k, vb, False)

    mpath = os.path.join(args.out, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath} ({len(manifest['artifacts'])} artifacts)")


if __name__ == "__main__":
    main()
