"""Pure-jnp oracle for the doclik kernel — the CORE correctness signal.

Everything here is deliberately written in the most obvious way possible;
pytest asserts the Pallas kernel matches it across shapes and dtypes.
"""

import jax.numpy as jnp

EPS = 1e-30


def doc_loglik_ref(theta, phi, counts):
    """Reference per-document log-likelihood.

    loglik[d] = sum_v counts[d,v] * log(max(sum_k theta[d,k] phi[k,v], EPS))
    with zero-count entries contributing exactly 0 (not 0 * -inf).
    """
    p = theta.astype(jnp.float32) @ phi.astype(jnp.float32)
    counts = counts.astype(jnp.float32)
    contrib = jnp.where(counts > 0.0, counts * jnp.log(jnp.maximum(p, EPS)), 0.0)
    return jnp.sum(contrib, axis=1)


def theta_from_counts(n_dk, alpha):
    """theta = (n_dk + alpha) / (len_d + alpha * K), row-wise."""
    n_dk = n_dk.astype(jnp.float32)
    k = n_dk.shape[1]
    denom = jnp.sum(n_dk, axis=1, keepdims=True) + alpha * k
    return (n_dk + alpha) / denom


def phi_from_counts(n_wk_t, n_k, beta, vocab_size):
    """phi = (n_wk + beta) / (n_k + V beta); n_wk_t laid out (K, V_block).

    `vocab_size` is the FULL vocabulary size V (the denominator is global
    even when only a block of columns is materialized).
    """
    n_wk_t = n_wk_t.astype(jnp.float32)
    n_k = n_k.astype(jnp.float32)
    return (n_wk_t + beta) / (n_k[:, None] + vocab_size * beta)


def em_estep_ref(n_dk, n_wk_t, n_k, counts, alpha, beta, vocab_size):
    """Reference blockwise EM E-step (Asuncion et al. '09 / MLlib EM).

    For every (doc d, word v-in-block) pair:
        gamma_dvk ∝ (n_dk + alpha - 1)(n_wk + beta - 1)/(n_k + V(beta-1))
    normalized over k; returns
        new_nwk_t[k, v] = sum_d counts[d, v] gamma_dvk        (K, VB)
        new_ndk_partial[d, k] = sum_v counts[d, v] gamma_dvk  (D, K)
    """
    n_dk = n_dk.astype(jnp.float32)
    n_wk_t = n_wk_t.astype(jnp.float32)
    n_k = n_k.astype(jnp.float32)
    counts = counts.astype(jnp.float32)
    doc_f = jnp.maximum(n_dk + alpha - 1.0, 1e-10)  # (D, K)
    word_f = jnp.maximum(n_wk_t + beta - 1.0, 1e-10)  # (K, VB)
    topic_f = jnp.maximum(n_k + vocab_size * (beta - 1.0), 1e-10)  # (K,)
    # gamma[d, k, v] before normalization
    g = doc_f[:, :, None] * (word_f / topic_f[:, None])[None, :, :]
    g = g / jnp.sum(g, axis=1, keepdims=True)
    gw = g * counts[:, None, :]
    new_nwk_t = jnp.sum(gw, axis=0)  # (K, VB)
    new_ndk = jnp.sum(gw, axis=2)  # (D, K)
    return new_nwk_t, new_ndk
