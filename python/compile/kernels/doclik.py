"""L1 Pallas kernel: batched document log-likelihood.

The dense hot-spot of topic-model evaluation is, for a document batch and
a vocabulary block,

    loglik[d] = sum_v counts[d, v] * log( sum_k theta[d, k] * phi[k, v] )

i.e. a (D,K)x(K,V) matmul followed by a masked log-weighted reduction.
This kernel tiles the vocabulary dimension so each grid step computes a
(D, TV) tile of probabilities on the MXU and folds it into a per-document
accumulator held in VMEM.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's system
is CPU/JVM-bound, so there is no CUDA kernel to port; we instead map the
evaluation matmul onto the TPU programming model — MXU-shaped tiles
(lane dimension a multiple of 128), explicit HBM->VMEM schedule via
BlockSpec, single-pass accumulation to avoid rematerializing the (D, V)
probability matrix in HBM.

The kernel MUST be lowered with interpret=True in this environment: the
CPU PJRT plugin cannot execute Mosaic custom-calls (real-TPU lowering).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Probability floor: padded vocabulary columns have p == 0; the mask makes
# their contribution zero, but log() still needs a finite argument.
EPS = 1e-30


def _doclik_kernel(theta_ref, phi_ref, counts_ref, o_ref):
    """One vocabulary tile: o[d] += sum_v counts[d,v] * log(theta@phi)."""
    # (D, K) @ (K, TV) on the MXU; fp32 accumulation.
    p = jnp.dot(theta_ref[...], phi_ref[...], preferred_element_type=jnp.float32)
    counts = counts_ref[...]
    contrib = jnp.where(counts > 0.0, counts * jnp.log(jnp.maximum(p, EPS)), 0.0)
    partial = jnp.sum(contrib, axis=1)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = partial

    @pl.when(pl.program_id(0) > 0)
    def _acc():
        o_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("tile_v",))
def doc_loglik(theta, phi, counts, tile_v=256):
    """Per-document log-likelihood via the Pallas kernel.

    Args:
      theta:  (D, K) document-topic distributions.
      phi:    (K, V) topic-word distributions.
      counts: (D, V) bag-of-words counts (0 for padded columns).
      tile_v: vocabulary tile width (must divide V; multiple of 128 for
        MXU lane alignment).

    Returns:
      (D,) float32 log-likelihood per document.
    """
    d, k = theta.shape
    k2, v = phi.shape
    assert k == k2, f"theta K={k} vs phi K={k2}"
    assert counts.shape == (d, v), (counts.shape, (d, v))
    assert v % tile_v == 0, f"V={v} must be a multiple of tile_v={tile_v}"
    grid = (v // tile_v,)
    return pl.pallas_call(
        _doclik_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((d, k), lambda i: (0, 0)),        # theta: resident
            pl.BlockSpec((k, tile_v), lambda i: (0, i)),   # phi: streamed
            pl.BlockSpec((d, tile_v), lambda i: (0, i)),   # counts: streamed
        ],
        out_specs=pl.BlockSpec((d,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((d,), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(
        theta.astype(jnp.float32),
        phi.astype(jnp.float32),
        counts.astype(jnp.float32),
    )


def vmem_bytes(d, k, tile_v):
    """Estimated VMEM working set of one grid step (see DESIGN.md §Perf).

    theta (D,K) + phi tile (K,TV) + counts tile (D,TV) + prob tile (D,TV)
    + accumulator (D,), all fp32.
    """
    return 4 * (d * k + k * tile_v + 2 * d * tile_v + d)


def mxu_utilization_estimate(d, k, tile_v):
    """Fraction of MXU-issue slots doing useful work for one tile.

    The 128x128 systolic array processes ceil(D/128) x ceil(TV/128) x
    ceil(K/128) passes; useful fraction is the filled volume.
    """
    import math

    passes = (
        math.ceil(d / 128) * math.ceil(tile_v / 128) * math.ceil(k / 128)
    )
    useful = d * tile_v * k
    return useful / (passes * 128 * 128 * 128)
