"""L2: the JAX evaluation graphs, lowered once by aot.py.

Two graphs, both shaped for blockwise streaming from the rust coordinator
(documents arrive in batches of D, the vocabulary in blocks of VB):

- ``perplexity_graph`` — per-document log-likelihood of a (D, VB) count
  block given raw PS count tables; computes theta and the phi block, then
  calls the L1 Pallas kernel for the matmul/log/reduce hot-spot.
- ``em_estep_graph`` — one blockwise variational-EM E-step (the Spark
  MLlib EM baseline's inner loop) over the same layout.

All inputs are f32 tensors (counts are exact integers well below 2^24, so
f32 is lossless) plus f32 scalars for the hyper-parameters. Rust pads
D/K/VB up to the compiled sizes; padded topics use zero theta mass and
padded vocabulary columns carry zero counts, so they contribute nothing.
"""

import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.doclik import doc_loglik


def perplexity_graph(n_dk, n_wk_t, n_k, counts, alpha, beta, vocab_size,
                     k_real, use_pallas=True, tile_v=256):
    """Per-document log-likelihood for one (doc batch, vocab block).

    Args:
      n_dk:    (D, K)  document-topic counts of the batch.
      n_wk_t:  (K, VB) word-topic counts of the vocab block (transposed).
      n_k:     (K,)    global topic totals.
      counts:  (D, VB) bag-of-words counts of the batch on this block.
      alpha, beta: scalar hyper-parameters.
      vocab_size:  scalar FULL vocabulary size (phi denominator).
      k_real:  scalar number of REAL topics (<= compiled K). Topic slots
        >= k_real are padding: they are masked out of theta exactly, so a
        model with any K can run on a larger compiled K without error.
      use_pallas:  embed the Pallas kernel (True) or the pure-jnp
        reference (False — compiled as the `_ref` artifact variant used
        for cross-checking from rust).

    Returns:
      1-tuple of (D,) log-likelihood (tuple because the AOT bridge lowers
      with return_tuple=True).
    """
    k_pad = n_dk.shape[1]
    mask = (jnp.arange(k_pad, dtype=jnp.float32) < k_real).astype(jnp.float32)
    n_dk = n_dk.astype(jnp.float32) * mask[None, :]
    # theta over the real topics only: padded slots get exactly 0 mass.
    denom = jnp.sum(n_dk, axis=1, keepdims=True) + alpha * k_real
    theta = (n_dk + alpha * mask[None, :]) / denom
    phi = ref.phi_from_counts(n_wk_t, n_k, beta, vocab_size)
    if use_pallas:
        out = doc_loglik(theta, phi, counts, tile_v=tile_v)
    else:
        out = ref.doc_loglik_ref(theta, phi, counts)
    return (out,)


def em_estep_graph(n_dk, n_wk_t, n_k, counts, alpha, beta, vocab_size):
    """Blockwise EM E-step; see ref.em_estep_ref for the math.

    Returns:
      (new_nwk_t (K, VB), new_ndk_partial (D, K)).
    """
    return ref.em_estep_ref(n_dk, n_wk_t, n_k, counts, alpha, beta, vocab_size)
