"""AOT manifest consistency tests (run after `make artifacts`)."""

import json
import os

import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built; run `make artifacts`")
    with open(path) as f:
        return json.load(f)


def test_manifest_version_and_files_exist():
    m = manifest()
    assert m["version"] == 1
    assert len(m["artifacts"]) >= 3
    for a in m["artifacts"]:
        p = os.path.join(ART, a["file"])
        assert os.path.exists(p), a["file"]
        assert os.path.getsize(p) > 100


def test_manifest_covers_paper_k_range():
    m = manifest()
    ks = sorted(a["k"] for a in m["artifacts"] if a["name"] == "perplexity")
    assert any(k >= 80 for k in ks), "Table 1 K sweep needs K>=80"
    assert any(k >= 1000 for k in ks), "web-scale run needs K>=1000"


def test_hlo_text_is_parseable_shape():
    m = manifest()
    a = m["artifacts"][0]
    with open(os.path.join(ART, a["file"])) as f:
        text = f.read()
    assert "HloModule" in text
    assert "f32" in text

