"""L1 correctness: the Pallas doclik kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes and dtypes; numpy.testing asserts closeness.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels import ref
from compile.kernels.doclik import (
    doc_loglik,
    mxu_utilization_estimate,
    vmem_bytes,
)

hypothesis.settings.register_profile(
    "ci", settings(max_examples=25, deadline=None)
)
hypothesis.settings.load_profile("ci")


def random_case(rng, d, k, v, dtype=np.float32, sparsity=0.5):
    theta = rng.dirichlet(np.full(k, 0.3), size=d).astype(dtype)
    phi = rng.dirichlet(np.full(v, 0.1), size=k).astype(dtype)
    counts = rng.poisson(1.0, size=(d, v)).astype(dtype)
    counts *= (rng.random((d, v)) > sparsity).astype(dtype)
    return theta, phi, counts


@given(
    d=st.sampled_from([1, 3, 8, 64]),
    k=st.sampled_from([2, 8, 128]),
    vmul=st.sampled_from([1, 2, 4]),
    tile=st.sampled_from([128, 256]),
    seed=st.integers(0, 2**16),
)
def test_kernel_matches_ref_shapes(d, k, vmul, tile, seed):
    v = tile * vmul
    rng = np.random.default_rng(seed)
    theta, phi, counts = random_case(rng, d, k, v)
    got = np.asarray(doc_loglik(theta, phi, counts, tile_v=tile))
    want = np.asarray(ref.doc_loglik_ref(theta, phi, counts))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@given(
    dtype=st.sampled_from([np.float32, np.float64, np.int32]),
    seed=st.integers(0, 2**16),
)
def test_kernel_dtype_coercion(dtype, seed):
    rng = np.random.default_rng(seed)
    d, k, v = 4, 8, 256
    theta, phi, counts = random_case(rng, d, k, v)
    counts = counts.astype(dtype)
    got = np.asarray(doc_loglik(theta, phi, counts))
    want = np.asarray(ref.doc_loglik_ref(theta, phi, counts))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_zero_counts_give_zero():
    d, k, v = 8, 16, 512
    rng = np.random.default_rng(0)
    theta, phi, _ = random_case(rng, d, k, v)
    counts = np.zeros((d, v), np.float32)
    got = np.asarray(doc_loglik(theta, phi, counts))
    np.testing.assert_array_equal(got, np.zeros(d, np.float32))


def test_padded_columns_contribute_nothing():
    # Padding the vocab block with zero-count columns must not change the
    # result even though the padded probabilities are degenerate.
    d, k, v = 8, 16, 256
    rng = np.random.default_rng(1)
    theta, phi, counts = random_case(rng, d, k, v)
    base = np.asarray(doc_loglik(theta, phi, counts))
    phi_pad = np.concatenate([phi, np.zeros((k, 256), np.float32)], axis=1)
    counts_pad = np.concatenate([counts, np.zeros((d, 256), np.float32)], axis=1)
    padded = np.asarray(doc_loglik(theta, phi_pad, counts_pad))
    np.testing.assert_allclose(padded, base, rtol=1e-6)


def test_padded_topics_contribute_nothing():
    d, k, v = 8, 16, 256
    rng = np.random.default_rng(2)
    theta, phi, counts = random_case(rng, d, k, v)
    base = np.asarray(doc_loglik(theta, phi, counts))
    theta_pad = np.concatenate([theta, np.zeros((d, 16), np.float32)], axis=1)
    phi_pad = np.concatenate([phi, np.full((16, v), 1.0 / v, np.float32)], axis=0)
    padded = np.asarray(doc_loglik(theta_pad, phi_pad, counts))
    np.testing.assert_allclose(padded, base, rtol=1e-5)


def test_analytic_uniform_case():
    # theta uniform, phi uniform: p = 1/V for every word, so
    # loglik[d] = total_counts[d] * log(1/V).
    d, k, v = 4, 8, 512
    theta = np.full((d, k), 1.0 / k, np.float32)
    phi = np.full((k, v), 1.0 / v, np.float32)
    counts = np.zeros((d, v), np.float32)
    counts[:, :3] = 2.0
    got = np.asarray(doc_loglik(theta, phi, counts))
    want = 6.0 * np.log(1.0 / v)
    np.testing.assert_allclose(got, np.full(d, want, np.float32), rtol=1e-5)


def test_known_tiny_case():
    theta = np.array([[1.0, 0.0]], np.float32)
    phi = np.array(
        [[0.5] + [0.5 / 255] * 255, [1.0 / 256] * 256], np.float32
    )
    counts = np.zeros((1, 256), np.float32)
    counts[0, 0] = 3.0
    got = np.asarray(doc_loglik(theta, phi, counts, tile_v=128))
    np.testing.assert_allclose(got, [3.0 * np.log(0.5)], rtol=1e-6)


def test_tile_must_divide_v():
    theta = np.ones((2, 4), np.float32) / 4
    phi = np.ones((4, 300), np.float32) / 300
    counts = np.ones((2, 300), np.float32)
    with pytest.raises(AssertionError):
        doc_loglik(theta, phi, counts, tile_v=256)


def test_vmem_estimate_within_budget():
    # Default production shape must fit VMEM (16 MB/core).
    assert vmem_bytes(64, 1024, 256) < 16 * 1024 * 1024
    # And the MXU utilization estimate is sane.
    u = mxu_utilization_estimate(64, 128, 256)
    assert 0.0 < u <= 1.0


def test_jit_cache_stable_across_calls():
    rng = np.random.default_rng(3)
    theta, phi, counts = random_case(rng, 8, 16, 256)
    a = np.asarray(doc_loglik(theta, phi, counts))
    b = np.asarray(doc_loglik(theta, phi, counts))
    np.testing.assert_array_equal(a, b)
