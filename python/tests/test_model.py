"""L2 graph tests: perplexity graph (pallas vs ref paths) and EM E-step."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.model import em_estep_graph, perplexity_graph


def make_counts(rng, d, k, v):
    n_dk = rng.poisson(2.0, size=(d, k)).astype(np.float32)
    n_wk_t = rng.poisson(3.0, size=(k, v)).astype(np.float32)
    n_k = n_wk_t.sum(axis=1).astype(np.float32)
    counts = rng.poisson(0.5, size=(d, v)).astype(np.float32)
    return n_dk, n_wk_t, n_k, counts


@given(seed=st.integers(0, 2**16))
@settings(max_examples=10, deadline=None)
def test_pallas_and_ref_paths_agree(seed):
    rng = np.random.default_rng(seed)
    d, k, v = 8, 16, 512
    n_dk, n_wk_t, n_k, counts = make_counts(rng, d, k, v)
    (a,) = perplexity_graph(n_dk, n_wk_t, n_k, counts, 0.5, 0.01, float(v),
                            float(k), use_pallas=True)
    (b,) = perplexity_graph(n_dk, n_wk_t, n_k, counts, 0.5, 0.01, float(v),
                            float(k), use_pallas=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5)


def test_uniform_analytic_value():
    # Zero counts everywhere: theta, phi uniform; one token per doc.
    d, k, v = 4, 8, 256
    n_dk = np.zeros((d, k), np.float32)
    n_wk_t = np.zeros((k, v), np.float32)
    n_k = np.zeros(k, np.float32)
    counts = np.zeros((d, v), np.float32)
    counts[:, 0] = 1.0
    (ll,) = perplexity_graph(n_dk, n_wk_t, n_k, counts, 0.5, 1.0, float(v),
                             float(k))
    np.testing.assert_allclose(
        np.asarray(ll), np.full(d, np.log(1.0 / v), np.float32), rtol=1e-4
    )


def test_perplexity_improves_with_matching_model():
    # A model whose phi matches the docs' words should beat uniform.
    d, k, v = 4, 8, 256
    rng = np.random.default_rng(7)
    n_dk = np.zeros((d, k), np.float32)
    n_dk[:, 0] = 10.0  # all docs on topic 0
    n_wk_t = np.zeros((k, v), np.float32)
    n_wk_t[0, :16] = 100.0  # topic 0 concentrated on 16 words
    n_k = n_wk_t.sum(axis=1)
    counts = np.zeros((d, v), np.float32)
    counts[:, :16] = rng.poisson(2.0, size=(d, 16)).astype(np.float32)
    (good,) = perplexity_graph(n_dk, n_wk_t, n_k, counts, 0.1, 0.01, float(v),
                               float(k))
    (unif,) = perplexity_graph(
        np.zeros_like(n_dk), np.zeros_like(n_wk_t), np.zeros_like(n_k),
        counts, 0.1, 0.01, float(v), float(k))
    assert np.asarray(good).sum() > np.asarray(unif).sum()


def test_em_estep_conserves_token_mass():
    rng = np.random.default_rng(11)
    d, k, v = 8, 8, 128
    n_dk, n_wk_t, n_k, counts = make_counts(rng, d, k, v)
    new_nwk_t, new_ndk = em_estep_graph(
        n_dk, n_wk_t, n_k, counts, 1.5, 1.1, float(v))
    total = counts.sum()
    np.testing.assert_allclose(np.asarray(new_nwk_t).sum(), total, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(new_ndk).sum(), total, rtol=1e-5)


def test_em_estep_gamma_normalized_per_pair():
    # For a single (d, v) pair with count 1, the contributions over k sum
    # to exactly 1.
    d, k, v = 1, 4, 128
    n_dk = np.ones((d, k), np.float32)
    n_wk_t = np.ones((k, v), np.float32) * 2
    n_k = n_wk_t.sum(axis=1)
    counts = np.zeros((d, v), np.float32)
    counts[0, 5] = 1.0
    new_nwk_t, new_ndk = em_estep_graph(
        n_dk, n_wk_t, n_k, counts, 1.5, 1.1, float(v))
    np.testing.assert_allclose(np.asarray(new_nwk_t)[:, 5].sum(), 1.0, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(new_ndk).sum(), 1.0, rtol=1e-6)


def test_em_estep_matches_ref():
    rng = np.random.default_rng(13)
    d, k, v = 4, 8, 128
    n_dk, n_wk_t, n_k, counts = make_counts(rng, d, k, v)
    a = em_estep_graph(n_dk, n_wk_t, n_k, counts, 1.5, 1.1, float(v))
    b = ref.em_estep_ref(n_dk, n_wk_t, n_k, counts, 1.5, 1.1, float(v))
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-6)


def test_k_real_masking_is_exact():
    # A K=4 model evaluated on a K=16-compiled graph (padded slots) must
    # equal the same model on a K=4 graph exactly.
    rng = np.random.default_rng(21)
    d, k, k_pad, v = 4, 4, 16, 256
    n_dk, n_wk_t, n_k, counts = make_counts(rng, d, k, v)
    (small,) = perplexity_graph(n_dk, n_wk_t, n_k, counts, 0.7, 0.01,
                                float(v), float(k))
    n_dk_p = np.zeros((d, k_pad), np.float32)
    n_dk_p[:, :k] = n_dk
    n_wk_p = np.zeros((k_pad, v), np.float32)
    n_wk_p[:k] = n_wk_t
    n_k_p = np.zeros(k_pad, np.float32)
    n_k_p[:k] = n_k
    (padded,) = perplexity_graph(n_dk_p, n_wk_p, n_k_p, counts, 0.7, 0.01,
                                 float(v), float(k))
    np.testing.assert_allclose(np.asarray(padded), np.asarray(small),
                               rtol=1e-5, atol=1e-5)
