//! Pipelined model pulls (paper §3.4).
//!
//! Workers pull the word-topic matrix in fixed-size row blocks. While a
//! block is being resampled (compute-bound), the *next* block is already
//! being pulled on a separate network thread, so the sampler never waits
//! on the network once the pipeline is warm.

use std::sync::mpsc;

use crate::ps::client::BigMatrix;
use crate::util::error::Result;

/// A pulled model block: the block index, the global row ids, and their
/// values (row-major, `rows.len() x K`).
pub struct Block {
    /// Index into the block list.
    pub index: usize,
    /// Global row (word) ids.
    pub rows: Vec<u64>,
    /// Pulled values.
    pub values: Vec<i64>,
}

/// Iterator over model blocks, prefetched `depth` blocks ahead on a
/// background thread.
pub struct PullPipeline {
    rx: mpsc::Receiver<Result<Block>>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl PullPipeline {
    /// Start pulling `blocks` (each a list of global rows) from `matrix`.
    ///
    /// `depth = 0` disables prefetching (each `next()` pulls
    /// synchronously — the non-pipelined ablation); `depth >= 1` keeps
    /// that many blocks in flight.
    pub fn start(matrix: BigMatrix<i64>, blocks: Vec<Vec<u64>>, depth: usize) -> PullPipeline {
        let (tx, rx) = mpsc::sync_channel(depth.max(1) - 1 + 1);
        let handle = std::thread::Builder::new()
            .name("glint-pull-pipeline".into())
            .spawn(move || {
                for (index, rows) in blocks.into_iter().enumerate() {
                    let result = matrix.pull_rows(&rows).map(|values| Block {
                        index,
                        rows,
                        values,
                    });
                    let failed = result.is_err();
                    if tx.send(result).is_err() || failed {
                        return; // consumer gone or pull failed
                    }
                }
            })
            .expect("spawn pull pipeline");
        PullPipeline { rx, handle: Some(handle) }
    }

    /// Next block, in order. `None` when exhausted.
    pub fn next_block(&mut self) -> Option<Result<Block>> {
        self.rx.recv().ok()
    }
}

impl Drop for PullPipeline {
    fn drop(&mut self) {
        // Keep receiving until the producer exits (it stops at the end of
        // the block list or on pull failure); this guarantees it is never
        // left blocked on a full channel when we join.
        while self.rx.recv().is_ok() {}
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Split the words `0..v` that are *present* (per `present` bitmap) into
/// blocks of at most `block_size` rows.
pub fn word_blocks(present: &[bool], block_size: usize) -> Vec<Vec<u64>> {
    let mut blocks = Vec::new();
    let mut current = Vec::with_capacity(block_size);
    for (w, &p) in present.iter().enumerate() {
        if p {
            current.push(w as u64);
            if current.len() == block_size {
                blocks.push(std::mem::take(&mut current));
            }
        }
    }
    if !current.is_empty() {
        blocks.push(current);
    }
    blocks
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::FaultPlan;
    use crate::ps::client::{CoordDeltas, PsClient};
    use crate::ps::config::PsConfig;
    use crate::ps::server::ServerGroup;

    fn setup() -> (ServerGroup, BigMatrix<i64>) {
        let cfg = PsConfig::with_shards(3);
        let group = ServerGroup::start(cfg.clone(), FaultPlan::reliable(), 9);
        let client = PsClient::connect(&group.transport(), cfg);
        let m: BigMatrix<i64> = client.matrix(64, 4).unwrap();
        // Mark each row with its id in column 0.
        let deltas = CoordDeltas {
            rows: (0..64).collect(),
            cols: vec![0; 64],
            values: (0..64).map(|r| r as i64 + 1).collect(),
        };
        m.push_coords(&deltas).unwrap();
        (group, m)
    }

    #[test]
    fn word_blocks_partition_present_words() {
        let mut present = vec![false; 10];
        for i in [0usize, 2, 3, 7, 8, 9] {
            present[i] = true;
        }
        let blocks = word_blocks(&present, 4);
        assert_eq!(blocks, vec![vec![0, 2, 3, 7], vec![8, 9]]);
    }

    #[test]
    fn pipeline_yields_all_blocks_in_order() {
        let (_g, m) = setup();
        let blocks = vec![vec![0u64, 1, 2], vec![10, 20], vec![63]];
        let mut p = PullPipeline::start(m, blocks, 2);
        let mut seen = Vec::new();
        while let Some(b) = p.next_block() {
            let b = b.unwrap();
            seen.push(b.index);
            // Check pulled values match what we pushed.
            for (i, &r) in b.rows.iter().enumerate() {
                assert_eq!(b.values[i * 4], r as i64 + 1, "row {r}");
            }
        }
        assert_eq!(seen, vec![0, 1, 2]);
    }

    #[test]
    fn depth_zero_is_synchronous_but_complete() {
        let (_g, m) = setup();
        let blocks = vec![vec![5u64], vec![6]];
        let mut p = PullPipeline::start(m, blocks, 0);
        assert_eq!(p.next_block().unwrap().unwrap().rows, vec![5]);
        assert_eq!(p.next_block().unwrap().unwrap().rows, vec![6]);
        assert!(p.next_block().is_none());
    }

    #[test]
    fn early_drop_does_not_hang() {
        let (_g, m) = setup();
        let blocks: Vec<Vec<u64>> = (0..32).map(|i| vec![i as u64]).collect();
        let mut p = PullPipeline::start(m, blocks, 1);
        let _ = p.next_block();
        drop(p); // must not deadlock
    }
}
