//! Pipelined model pulls (paper §3.4).
//!
//! Workers pull the word-topic matrix in fixed-size row blocks. While a
//! block is being resampled (compute-bound), the next `depth` blocks are
//! already in flight as asynchronous tickets riding each shard's
//! bounded window, so the sampler never waits on the network once the
//! pipeline is warm.
//!
//! Blocks can be pulled **dense** (full `rows x K` slabs over a
//! `Ticket<Vec<i64>>`) or **sparse** (`(col, val)` pairs over a
//! `Ticket<Vec<SparseRow<i64>>>`, handed to the consumer
//! **as pair lists** — [`BlockData::Sparse`] — never densified here).
//! Sparse mode ships bytes *and block memory* proportional to row
//! occupancy: a block costs O(pairs) instead of `rows x K x 8` bytes,
//! and the sampler densifies at most one row at a time into its own
//! reused scratch slab. Consumers that genuinely need the slab (the
//! full-model pull) call [`Block::into_dense`].
//!
//! Shard errors propagate through the ticket into
//! [`PullPipeline::next_block`]'s `Result` — there is no background
//! thread left to panic; a transient failure surfaces to the sampling
//! loop, which abandons the iteration cleanly.

use std::collections::VecDeque;

use crate::ps::client::{BigMatrix, SparseRow, Ticket};
use crate::util::error::{Error, Result};

/// A pulled model block: the block index, the global row ids, and their
/// values in whichever shape the pull mode produced.
pub struct Block {
    /// Index into the block list.
    pub index: usize,
    /// Global row (word) ids.
    pub rows: Vec<u64>,
    /// Pulled values, dense or sparse per [`PullMode`].
    pub data: BlockData,
}

/// The values of one pulled block.
pub enum BlockData {
    /// Row-major `rows.len() x K` slab.
    Dense(Vec<i64>),
    /// One `(col, val)` pair list per row, in row order — exactly the
    /// wire shape of [`BigMatrix::pull_sparse_rows_async`], O(pairs)
    /// memory.
    Sparse(Vec<SparseRow<i64>>),
}

impl Block {
    /// The block's values as a dense row-major `rows.len() x k` slab,
    /// scattering pair lists when the block is sparse. A column id at
    /// or beyond `k` is a malformed reply and surfaces as a decode
    /// error rather than a panic.
    pub fn into_dense(self, k: usize) -> Result<Vec<i64>> {
        match self.data {
            BlockData::Dense(values) => Ok(values),
            BlockData::Sparse(pairs) => densify(pairs, k),
        }
    }
}

/// How the pipeline pulls its blocks off the parameter server.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PullMode {
    /// Full rows ([`BigMatrix::pull_rows_async`]).
    Dense,
    /// Sparse `(col, val)` pairs ([`BigMatrix::pull_sparse_rows_async`]),
    /// delivered as pair lists ([`BlockData::Sparse`]).
    Sparse,
}

/// An issued-but-not-consumed block pull, in either mode.
enum Inflight {
    Dense(Ticket<Vec<i64>>),
    Sparse(Ticket<Vec<SparseRow<i64>>>),
}

/// Scatter per-row pair lists into a dense row-major `rows x k` slab.
/// A column id at or beyond `k` is a malformed reply and surfaces as a
/// decode error rather than a panic on the sampling thread.
fn densify(pairs: Vec<SparseRow<i64>>, k: usize) -> Result<Vec<i64>> {
    let mut values = vec![0i64; pairs.len() * k];
    for (i, row) in pairs.into_iter().enumerate() {
        let base = i * k;
        for (c, v) in row {
            if c as usize >= k {
                return Err(Error::Decode(format!(
                    "sparse pull returned column {c} for a {k}-column matrix"
                )));
            }
            values[base + c as usize] = v;
        }
    }
    Ok(values)
}

/// Iterator over model blocks, prefetched `depth` blocks ahead through
/// asynchronous pull tickets.
pub struct PullPipeline {
    matrix: BigMatrix<i64>,
    mode: PullMode,
    /// Blocks not yet issued, front first.
    remaining: VecDeque<Vec<u64>>,
    /// Issued-but-not-consumed pulls, in issue order.
    inflight: VecDeque<(usize, Vec<u64>, Inflight)>,
    depth: usize,
    next_index: usize,
}

impl PullPipeline {
    /// Start pulling `blocks` (each a list of global rows) from `matrix`
    /// as dense slabs.
    ///
    /// `depth = 0` disables prefetching (each `next_block` pulls
    /// synchronously — the non-pipelined ablation); `depth >= 1` keeps
    /// that many block pulls in flight ahead of the consumer.
    pub fn start(matrix: BigMatrix<i64>, blocks: Vec<Vec<u64>>, depth: usize) -> PullPipeline {
        PullPipeline::start_with_mode(matrix, blocks, depth, PullMode::Dense)
    }

    /// Start pulling `blocks` with an explicit [`PullMode`].
    pub fn start_with_mode(
        matrix: BigMatrix<i64>,
        blocks: Vec<Vec<u64>>,
        depth: usize,
        mode: PullMode,
    ) -> PullPipeline {
        let mut pipeline = PullPipeline {
            matrix,
            mode,
            remaining: blocks.into(),
            inflight: VecDeque::new(),
            depth,
            next_index: 0,
        };
        pipeline.fill();
        pipeline
    }

    fn issue(&self, rows: &[u64]) -> Inflight {
        match self.mode {
            PullMode::Dense => Inflight::Dense(self.matrix.pull_rows_async(rows)),
            PullMode::Sparse => Inflight::Sparse(self.matrix.pull_sparse_rows_async(rows)),
        }
    }

    fn resolve(&self, ticket: Inflight) -> Result<BlockData> {
        match ticket {
            Inflight::Dense(t) => Ok(BlockData::Dense(t.wait()?)),
            Inflight::Sparse(t) => Ok(BlockData::Sparse(t.wait()?)),
        }
    }

    /// Issue pulls until `depth` tickets are in flight (or no blocks
    /// remain).
    fn fill(&mut self) {
        while self.inflight.len() < self.depth {
            let Some(rows) = self.remaining.pop_front() else {
                return;
            };
            let ticket = self.issue(&rows);
            self.inflight.push_back((self.next_index, rows, ticket));
            self.next_index += 1;
        }
    }

    /// Next block, in order. `None` when exhausted; a pull failure
    /// surfaces here as `Some(Err(..))` and leaves later blocks
    /// unconsumed.
    pub fn next_block(&mut self) -> Option<Result<Block>> {
        if self.depth == 0 {
            let rows = self.remaining.pop_front()?;
            let index = self.next_index;
            self.next_index += 1;
            let ticket = self.issue(&rows);
            return Some(self.resolve(ticket).map(|data| Block { index, rows, data }));
        }
        let (index, rows, ticket) = self.inflight.pop_front()?;
        let result = self.resolve(ticket).map(|data| Block { index, rows, data });
        // Keep the window full while the caller samples this block.
        self.fill();
        Some(result)
    }
}

/// Split the words `0..v` that are *present* (per `present` bitmap) into
/// blocks of at most `block_size` rows.
pub fn word_blocks(present: &[bool], block_size: usize) -> Vec<Vec<u64>> {
    let mut blocks = Vec::new();
    let mut current = Vec::with_capacity(block_size);
    for (w, &p) in present.iter().enumerate() {
        if p {
            current.push(w as u64);
            if current.len() == block_size {
                blocks.push(std::mem::take(&mut current));
            }
        }
    }
    if !current.is_empty() {
        blocks.push(current);
    }
    blocks
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::FaultPlan;
    use crate::ps::client::{CoordDeltas, PsClient};
    use crate::ps::config::PsConfig;
    use crate::ps::messages::Layout;
    use crate::ps::server::ServerGroup;

    fn setup_with_layout(layout: Layout) -> (ServerGroup, BigMatrix<i64>) {
        let cfg = PsConfig::with_shards(3);
        let group = ServerGroup::start(cfg.clone(), FaultPlan::reliable(), 9);
        let client = PsClient::connect(&group.transport(), cfg);
        let m: BigMatrix<i64> = client.matrix_with_layout(64, 4, layout).unwrap();
        // Mark each row with its id in column 0.
        let deltas = CoordDeltas {
            rows: (0..64).collect(),
            cols: vec![0; 64],
            values: (0..64).map(|r| r as i64 + 1).collect(),
        };
        m.push_coords(&deltas).expect("seed rows");
        (group, m)
    }

    fn setup() -> (ServerGroup, BigMatrix<i64>) {
        setup_with_layout(Layout::Dense)
    }

    #[test]
    fn word_blocks_partition_present_words() {
        let mut present = vec![false; 10];
        for i in [0usize, 2, 3, 7, 8, 9] {
            present[i] = true;
        }
        let blocks = word_blocks(&present, 4);
        assert_eq!(blocks, vec![vec![0, 2, 3, 7], vec![8, 9]]);
    }

    #[test]
    fn pipeline_yields_all_blocks_in_order() {
        let (_g, m) = setup();
        let blocks = vec![vec![0u64, 1, 2], vec![10, 20], vec![63]];
        let mut p = PullPipeline::start(m, blocks, 2);
        let mut seen = Vec::new();
        while let Some(b) = p.next_block() {
            let b = b.unwrap();
            seen.push(b.index);
            // Check pulled values match what we pushed.
            let rows = b.rows.clone();
            let values = b.into_dense(4).unwrap();
            for (i, &r) in rows.iter().enumerate() {
                assert_eq!(values[i * 4], r as i64 + 1, "row {r}");
            }
        }
        assert_eq!(seen, vec![0, 1, 2]);
    }

    #[test]
    fn sparse_mode_yields_identical_blocks() {
        for layout in [Layout::Dense, Layout::Sparse] {
            let (_g, m) = setup_with_layout(layout);
            let blocks = vec![vec![0u64, 1, 2], vec![10, 20], vec![63]];
            let mut dense_p =
                PullPipeline::start_with_mode(m.clone(), blocks.clone(), 2, PullMode::Dense);
            let mut sparse_p =
                PullPipeline::start_with_mode(m, blocks, 2, PullMode::Sparse);
            loop {
                match (dense_p.next_block(), sparse_p.next_block()) {
                    (None, None) => break,
                    (Some(d), Some(s)) => {
                        let (d, s) = (d.unwrap(), s.unwrap());
                        assert_eq!(d.index, s.index);
                        assert_eq!(d.rows, s.rows);
                        assert_eq!(
                            d.into_dense(4).unwrap(),
                            s.into_dense(4).unwrap(),
                            "layout {layout:?}"
                        );
                    }
                    (d, s) => panic!(
                        "pipelines diverged: dense ended={}, sparse ended={}",
                        d.is_none(),
                        s.is_none()
                    ),
                }
            }
        }
    }

    #[test]
    fn sparse_mode_hands_over_pair_lists_without_densify() {
        // The zero-densify contract: a sparse pull must surface as the
        // raw pair lists (O(pairs) memory), with exactly the nonzeros.
        let (_g, m) = setup_with_layout(Layout::Sparse);
        let mut p = PullPipeline::start_with_mode(m, vec![vec![3u64, 7]], 1, PullMode::Sparse);
        let b = p.next_block().unwrap().unwrap();
        match &b.data {
            BlockData::Sparse(rows) => {
                // Each seeded row holds its id+1 in column 0, nothing else.
                assert_eq!(rows.len(), 2);
                assert_eq!(rows[0], vec![(0u32, 4i64)]);
                assert_eq!(rows[1], vec![(0u32, 8i64)]);
            }
            BlockData::Dense(_) => panic!("sparse pull was densified in the pipeline"),
        }
        assert!(p.next_block().is_none());
    }

    #[test]
    fn depth_zero_is_synchronous_but_complete() {
        let (_g, m) = setup();
        let blocks = vec![vec![5u64], vec![6]];
        let mut p = PullPipeline::start_with_mode(m, blocks, 0, PullMode::Sparse);
        assert_eq!(p.next_block().unwrap().unwrap().rows, vec![5]);
        assert_eq!(p.next_block().unwrap().unwrap().rows, vec![6]);
        assert!(p.next_block().is_none());
    }

    #[test]
    fn deep_prefetch_outruns_consumption_safely() {
        // More depth than blocks, and more blocks than the per-shard
        // window: everything must still arrive exactly once, in order.
        let (_g, m) = setup();
        let blocks: Vec<Vec<u64>> = (0..16).map(|i| vec![i as u64 * 4]).collect();
        let mut p = PullPipeline::start_with_mode(m, blocks, 32, PullMode::Sparse);
        let mut count = 0;
        while let Some(b) = p.next_block() {
            let b = b.unwrap();
            assert_eq!(b.index, count);
            count += 1;
        }
        assert_eq!(count, 16);
    }

    #[test]
    fn early_drop_does_not_hang() {
        let (_g, m) = setup();
        let blocks: Vec<Vec<u64>> = (0..32).map(|i| vec![i as u64]).collect();
        let mut p = PullPipeline::start(m, blocks, 1);
        let _ = p.next_block();
        drop(p); // must not deadlock
    }
}
