//! Sparse document–topic counters.
//!
//! `n_dk` is document-local (paper §3: "the document-topic counts are
//! document-specific and thus local to the data and need not be shared").
//! A document touches at most `min(len, K)` topics, so counts are kept as
//! a small sorted-by-topic vec of `(topic, count)` pairs — cache-friendly
//! for the K≤1000 regime and far smaller than a dense `docs x K` matrix.

/// Sparse topic counts for one document.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct DocTopicCounts {
    /// `(topic, count)` pairs, sorted by topic, counts > 0.
    entries: Vec<(u32, u32)>,
}

impl DocTopicCounts {
    /// Empty counts.
    pub fn new() -> DocTopicCounts {
        DocTopicCounts::default()
    }

    /// Build from a document's topic assignments.
    pub fn from_assignments(z: &[u32]) -> DocTopicCounts {
        let mut c = DocTopicCounts::new();
        for &k in z {
            c.increment(k);
        }
        c
    }

    /// Rebuild counts from `(topic, count)` pairs (e.g. an inference
    /// reply off the wire). Pairs need not arrive sorted; duplicates
    /// accumulate and zero counts are dropped.
    pub fn from_pairs(pairs: &[(u32, u32)]) -> DocTopicCounts {
        let mut entries: Vec<(u32, u32)> =
            pairs.iter().copied().filter(|&(_, c)| c > 0).collect();
        entries.sort_by_key(|e| e.0);
        entries.dedup_by(|b, a| {
            if a.0 == b.0 {
                a.1 += b.1;
                true
            } else {
                false
            }
        });
        DocTopicCounts { entries }
    }

    /// Count for one topic.
    #[inline]
    pub fn get(&self, topic: u32) -> u32 {
        match self.entries.binary_search_by_key(&topic, |e| e.0) {
            Ok(i) => self.entries[i].1,
            Err(_) => 0,
        }
    }

    /// Add one to a topic's count.
    #[inline]
    pub fn increment(&mut self, topic: u32) {
        match self.entries.binary_search_by_key(&topic, |e| e.0) {
            Ok(i) => self.entries[i].1 += 1,
            Err(i) => self.entries.insert(i, (topic, 1)),
        }
    }

    /// Remove one from a topic's count. Panics in debug if absent.
    #[inline]
    pub fn decrement(&mut self, topic: u32) {
        match self.entries.binary_search_by_key(&topic, |e| e.0) {
            Ok(i) => {
                self.entries[i].1 -= 1;
                if self.entries[i].1 == 0 {
                    self.entries.remove(i);
                }
            }
            Err(_) => debug_assert!(false, "decrement of zero count for topic {topic}"),
        }
    }

    /// Number of distinct topics present.
    pub fn distinct(&self) -> usize {
        self.entries.len()
    }

    /// Sum of all counts (== document length while consistent).
    pub fn total(&self) -> u64 {
        self.entries.iter().map(|&(_, c)| c as u64).sum()
    }

    /// Iterate `(topic, count)` pairs.
    pub fn iter(&self) -> impl Iterator<Item = (u32, u32)> + '_ {
        self.entries.iter().copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;

    #[test]
    fn increment_decrement_roundtrip() {
        let mut c = DocTopicCounts::new();
        c.increment(5);
        c.increment(5);
        c.increment(2);
        assert_eq!(c.get(5), 2);
        assert_eq!(c.get(2), 1);
        assert_eq!(c.get(9), 0);
        c.decrement(5);
        assert_eq!(c.get(5), 1);
        c.decrement(5);
        assert_eq!(c.get(5), 0);
        assert_eq!(c.distinct(), 1);
    }

    #[test]
    fn from_assignments_matches_manual() {
        let z = [1u32, 3, 1, 1, 0];
        let c = DocTopicCounts::from_assignments(&z);
        assert_eq!(c.get(1), 3);
        assert_eq!(c.get(3), 1);
        assert_eq!(c.get(0), 1);
        assert_eq!(c.total(), 5);
    }

    #[test]
    fn stays_consistent_with_dense_reference_property() {
        forall(
            "sparse equals dense",
            200,
            |rng| {
                let k = 1 + rng.below(20);
                let ops: Vec<(bool, u32)> = (0..rng.below(300))
                    .map(|_| (rng.bernoulli(0.6), rng.below(k) as u32))
                    .collect();
                (k, ops)
            },
            |(k, ops)| {
                let mut dense = vec![0i64; *k];
                let mut sparse = DocTopicCounts::new();
                for &(inc, topic) in ops {
                    if inc {
                        dense[topic as usize] += 1;
                        sparse.increment(topic);
                    } else if dense[topic as usize] > 0 {
                        dense[topic as usize] -= 1;
                        sparse.decrement(topic);
                    }
                }
                (0..*k).all(|t| dense[t] == sparse.get(t as u32) as i64)
                    && sparse.total() == dense.iter().sum::<i64>() as u64
            },
        );
    }
}
