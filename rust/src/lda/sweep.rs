//! The per-partition sweep: one executor's full pass over its corpus
//! slice (paper §3.1–§3.4), shared by the in-process trainer and the
//! cluster worker.
//!
//! [`SweepRunner`] owns exactly one partition's sampler state — topic
//! assignments, doc-topic counts, the word → occurrence inverted index —
//! and knows how to (a) push the counts implied by its assignments to
//! the parameter server and (b) run one LightLDA sweep against a
//! [`BigMatrix`] through the prefetching [`PullPipeline`], streaming
//! updates out through the [`UpdateBuffer`] as fire-and-forget push
//! tickets. [`crate::lda::trainer::Trainer`] drives one runner per
//! worker thread inside a single process; [`crate::cluster::worker`]
//! drives a single runner in a remote process. Keeping this the *same
//! code path* is what makes the two deployment modes numerically
//! equivalent.
//!
//! The sweep's inner loop is sparse end to end: sparse blocks arrive as
//! `(col, val)` pair lists ([`crate::lda::pipeline::BlockData::Sparse`])
//! and only the *current* word's row is densified — into a reused
//! scratch slab cleared through a touched-column list, so per-word cost
//! is O(nnz_w + reassignments), not O(K). Word-proposal tables are
//! built through a reusable [`AliasBuilder`] (the LightLDA hybrid
//! mixture, O(nnz_w) for tail words, dense above
//! [`SamplerParams::alias_dense_threshold`] fill), and the runner owns
//! all scratch, so the steady-state loop performs **no heap
//! allocations** per word or per token.

use std::ops::Range;

use crate::corpus::dataset::{Corpus, Document};
use crate::eval::perplexity::{log_likelihood_docs, TopicModel};
use crate::lda::alias::AliasBuilder;
use crate::lda::buffer::UpdateBuffer;
use crate::lda::hyper::LdaHyper;
use crate::lda::lightlda::{resample_token, TokenView};
use crate::lda::pipeline::{word_blocks, BlockData, PullMode, PullPipeline};
use crate::lda::sparse_counts::DocTopicCounts;
use crate::ps::client::BigMatrix;
use crate::ps::messages::Layout;
use crate::util::error::{Error, Result};
use crate::util::rng::Pcg64;
use crate::util::timer::Stopwatch;

/// The sampler-performance knobs, the *single* source of truth shared
/// by [`crate::lda::trainer::TrainConfig`], [`SweepConfig`], and the
/// wire-side [`crate::cluster::protocol::SweepKnobs`]: each embeds this
/// struct instead of re-declaring the fields, so adding a knob is a
/// one-struct change. Model-level quantities (topic count,
/// hyper-parameters) deliberately stay out — these are *how* to sample,
/// not *what*.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SamplerParams {
    /// Metropolis–Hastings proposal cycles per token.
    pub mh_steps: u32,
    /// Words per pulled model block (§3.4).
    pub block_words: usize,
    /// Sparse push-buffer flush threshold (§3.3).
    pub buffer_cap: usize,
    /// Most-frequent words aggregated densely (§3.3).
    pub dense_top_words: u64,
    /// Prefetch depth for model pulls (0 = synchronous).
    pub pipeline_depth: usize,
    /// Row fill fraction (nnz/K) at or above which a word's proposal
    /// table is built dense instead of as the sparse hybrid mixture
    /// (0.0 = always dense — the ablation; > 1.0 = never).
    pub alias_dense_threshold: f64,
}

impl Default for SamplerParams {
    fn default() -> SamplerParams {
        SamplerParams {
            mh_steps: 2,
            block_words: 2048,
            buffer_cap: 100_000,
            dense_top_words: 2000,
            pipeline_depth: 1,
            alias_dense_threshold: 0.5,
        }
    }
}

/// The knobs a sweep needs, extracted from
/// [`crate::lda::trainer::TrainConfig`] (or a cluster
/// [`crate::cluster::protocol::JobSpec`]) so the kernel itself never
/// depends on how the run was deployed.
#[derive(Debug, Clone)]
pub struct SweepConfig {
    /// Number of topics K.
    pub num_topics: u32,
    /// Sampler-performance knobs.
    pub sampler: SamplerParams,
    /// Resolved hyper-parameters.
    pub hyper: LdaHyper,
    /// Vocabulary size V.
    pub vocab_size: u32,
}

/// Counters published by one sweep (or one training iteration when
/// aggregated over partitions).
#[derive(Debug, Clone, Copy, Default)]
pub struct IterStats {
    /// Tokens resampled.
    pub tokens: u64,
    /// Topic reassignments (z changed).
    pub changed: u64,
    /// Sparse delta messages pushed.
    pub sparse_batches: u64,
    /// Wall-clock seconds.
    pub seconds: f64,
    /// Seconds spent densifying rows and building word-proposal tables.
    pub alias_build_secs: f64,
    /// Seconds the sampler sat waiting on the pull pipeline for its
    /// next block (pipeline stalls; ~0 when prefetch keeps up).
    pub block_wait_secs: f64,
}

/// The deterministic per-partition RNG: partition `p` gets the `p`-th
/// fork of a parent generator seeded with `seed`, salted with the
/// partition's first document index.
///
/// [`Pcg64::fork`] advances the parent stream once per call, so the
/// remote worker for partition `p` can reconstruct *exactly* the stream
/// the in-process trainer would have handed its `p`-th worker thread
/// without knowing the other partitions' ranges.
pub fn partition_rng(seed: u64, partition: usize, doc_start: u64) -> Pcg64 {
    let mut parent = Pcg64::new(seed);
    for _ in 0..partition {
        parent.next_u64();
    }
    parent.fork(doc_start)
}

/// Single source of truth for how a storage layout is pulled.
pub fn pull_mode_for(layout: Layout) -> PullMode {
    match layout {
        Layout::Sparse => PullMode::Sparse,
        Layout::Dense => PullMode::Dense,
    }
}

/// Pull the full `v x k` model (plus the derived topic totals) off the
/// parameter server, in 8192-row chunks through the same bounded
/// prefetch pipeline the sampler uses (§3.4): later chunks are in
/// flight while earlier ones are copied out, and `depth == 0` keeps the
/// synchronous ablation truly synchronous. In sparse mode the Zipf tail
/// crosses the wire as pairs, not slabs; the model slab itself is dense,
/// so this is the one consumer that densifies whole blocks.
pub fn pull_full_model(
    n_wk: &BigMatrix<i64>,
    vocab_size: u32,
    depth: usize,
    hyper: LdaHyper,
) -> Result<TopicModel> {
    let k = n_wk.cols() as usize;
    let rows: Vec<u64> = (0..vocab_size as u64).collect();
    let chunks: Vec<Vec<u64>> = rows.chunks(8192).map(|c| c.to_vec()).collect();
    let mut pipeline = PullPipeline::start_with_mode(
        n_wk.clone(),
        chunks,
        depth,
        pull_mode_for(n_wk.layout()),
    );
    let mut values = Vec::with_capacity(vocab_size as usize * k);
    while let Some(block) = pipeline.next_block() {
        values.extend(block?.into_dense(k)?);
    }
    let n_k = n_wk.pull_col_sums()?;
    Ok(TopicModel { k: n_wk.cols(), v: vocab_size, n_wk: values, n_k, hyper })
}

/// Reusable one-row densification scratch: the live row of the word
/// currently being sampled, zero outside `touched`. Clearing walks the
/// touched list, so a Zipf-tail word costs O(nnz + reassignments) — the
/// slab itself is written once and never re-zeroed wholesale.
#[derive(Debug, Default)]
struct RowScratch {
    /// K-length slab (grown once, then reused).
    values: Vec<i64>,
    /// Columns of `values` that may be nonzero.
    touched: Vec<u32>,
}

impl RowScratch {
    /// Grow the slab to cover `k` columns (new columns are zero).
    fn ensure(&mut self, k: usize) {
        if self.values.len() < k {
            self.values.resize(k, 0);
        }
    }

    /// Zero everything the previous word wrote.
    fn clear(&mut self) {
        for &c in &self.touched {
            self.values[c as usize] = 0;
        }
        self.touched.clear();
    }

    /// Load a dense row (dense pull mode): records the nonzeros so the
    /// next clear stays proportional to occupancy.
    fn load_dense(&mut self, row: &[i64]) {
        self.clear();
        for (c, &v) in row.iter().enumerate() {
            if v != 0 {
                self.values[c] = v;
                self.touched.push(c as u32);
            }
        }
    }

    /// Scatter a sparse pair list (sparse pull mode). A column at or
    /// beyond `k` is a malformed reply and surfaces as a decode error
    /// rather than a panic on the sampling thread.
    fn load_sparse(&mut self, pairs: &[(u32, i64)], k: usize) -> Result<()> {
        self.clear();
        for &(c, v) in pairs {
            if c as usize >= k {
                return Err(Error::Decode(format!(
                    "sparse pull returned column {c} for a {k}-column matrix"
                )));
            }
            self.values[c as usize] = v;
            self.touched.push(c);
        }
        Ok(())
    }

    /// Apply a reassignment to the live row, keeping the touched list
    /// aware of both columns. `from` is normally already tracked (the
    /// token's inclusive count makes it nonzero in the pulled row), but
    /// re-pushing it is one cheap duplicate and keeps the clear exact
    /// even if a stale reply ever understates a count.
    #[inline]
    fn shift(&mut self, from: u32, to: u32) {
        self.values[from as usize] -= 1;
        self.values[to as usize] += 1;
        self.touched.push(from);
        self.touched.push(to);
    }
}

/// One partition's sampler state (the executor's slice of the RDD).
pub struct SweepRunner {
    /// Document index range in the corpus (absolute).
    doc_range: Range<usize>,
    /// Topic assignments for the partition's docs.
    assignments: Vec<Vec<u32>>,
    /// Doc-topic counts for the partition's docs.
    doc_counts: Vec<DocTopicCounts>,
    /// Inverted index: word -> occurrences as (local doc idx, position),
    /// grouped so all of a word's tokens are sampled while its alias
    /// table is fresh.
    occurrences: Vec<Vec<(u32, u32)>>,
    /// Which words occur in this partition at all.
    present: Vec<bool>,
    /// Worker RNG.
    rng: Pcg64,
    /// Reusable word-proposal construction workspace (zero per-word
    /// allocations in the steady state).
    builder: AliasBuilder,
    /// Reusable live-row scratch for the word under sampling.
    row: RowScratch,
}

impl SweepRunner {
    /// Build the partition state for `doc_range` of `corpus`, calling
    /// `init_doc` once per document (in range order) for its initial
    /// assignment vector.
    pub fn build(
        corpus: &Corpus,
        doc_range: Range<usize>,
        mut rng: Pcg64,
        mut init_doc: impl FnMut(&Document, &mut Pcg64) -> Vec<u32>,
    ) -> SweepRunner {
        let v = corpus.vocab_size as usize;
        let mut assignments = Vec::with_capacity(doc_range.len());
        let mut doc_counts = Vec::with_capacity(doc_range.len());
        let mut occurrences: Vec<Vec<(u32, u32)>> = vec![Vec::new(); v];
        let mut present = vec![false; v];
        for (local, d) in doc_range.clone().enumerate() {
            let doc = &corpus.docs[d];
            let z = init_doc(doc, &mut rng);
            debug_assert_eq!(z.len(), doc.tokens.len());
            for (pos, &w) in doc.tokens.iter().enumerate() {
                occurrences[w as usize].push((local as u32, pos as u32));
                present[w as usize] = true;
            }
            doc_counts.push(DocTopicCounts::from_assignments(&z));
            assignments.push(z);
        }
        SweepRunner {
            doc_range,
            assignments,
            doc_counts,
            occurrences,
            present,
            rng,
            builder: AliasBuilder::new(),
            row: RowScratch::default(),
        }
    }

    /// Fresh random initialization at iteration 0.
    pub fn build_random(
        corpus: &Corpus,
        doc_range: Range<usize>,
        num_topics: u32,
        rng: Pcg64,
    ) -> SweepRunner {
        SweepRunner::build(corpus, doc_range, rng, |doc, rng| {
            doc.tokens.iter().map(|_| rng.below(num_topics as usize) as u32).collect()
        })
    }

    /// Document range (absolute corpus indices).
    pub fn doc_range(&self) -> Range<usize> {
        self.doc_range.clone()
    }

    /// Replace the runner's RNG. Cluster workers reseed per sweep with
    /// an iteration-keyed [`partition_rng`] stream so the token→random
    /// sequence of iteration `t` of partition `p` is a pure function of
    /// `(seed, epoch, t, p)` — identical whether the partition ran
    /// uninterrupted, resumed from a checkpoint, or moved to another
    /// worker mid-run.
    pub fn reseed(&mut self, rng: Pcg64) {
        self.rng = rng;
    }

    /// Per-document topic assignments, in range order.
    pub fn assignments(&self) -> &[Vec<u32>] {
        &self.assignments
    }

    /// Per-document topic counts, in range order.
    pub fn doc_counts(&self) -> &[DocTopicCounts] {
        &self.doc_counts
    }

    /// Visit every `(word, topic)` pair implied by the current
    /// assignments, grouped by word (the inverted-index order used for
    /// count pushes and consistency checks).
    pub fn for_each_word_topic(&self, mut f: impl FnMut(u64, u32)) {
        for (w, occs) in self.occurrences.iter().enumerate() {
            for &(local, pos) in occs {
                f(w as u64, self.assignments[local as usize][pos as usize]);
            }
        }
    }

    /// Push the counts implied by this partition's current assignments
    /// to the parameter server (buffered fire-and-forget tickets, the
    /// same path as training updates). The caller owns the completion
    /// barrier: call `flush()` on the client afterwards.
    pub fn push_counts(&self, cfg: &SweepConfig, n_wk: &BigMatrix<i64>) {
        let mut buffer = UpdateBuffer::new(
            cfg.sampler.buffer_cap,
            cfg.sampler.dense_top_words,
            cfg.num_topics,
        );
        self.for_each_word_topic(|w, z| {
            if let Some(batch) = buffer.add(w, z, 1) {
                let _ = n_wk.push_coords_async(&batch);
            }
        });
        let rest = buffer.take_sparse();
        let _ = n_wk.push_coords_async(&rest);
        let (rows, values) = buffer.take_dense();
        let _ = n_wk.push_rows_async(&rows, &values);
    }

    /// Log-likelihood contribution of this partition under `model`;
    /// returns `(total_log_lik, token_count)`. `corpus` is the full
    /// corpus the runner was built over.
    pub fn log_likelihood(&self, model: &TopicModel, corpus: &Corpus) -> (f64, u64) {
        log_likelihood_docs(model, &corpus.docs[self.doc_range.clone()], &self.doc_counts)
    }

    /// One full sweep over the partition (§3.2–§3.4).
    ///
    /// `nk_local` is the iteration-start snapshot of the global topic
    /// totals; the runner maintains its own local drift copy (LightLDA's
    /// bounded-staleness model). Sparse batches leave as fire-and-forget
    /// push tickets the moment the buffer fills; the shard windows
    /// backpressure the sampler if the network falls behind, and the
    /// caller's iteration-end `flush` is where their errors surface.
    /// Topic totals need no pushes of their own: every reassignment is
    /// already in the `n_wk` deltas, and the next iteration's snapshot
    /// re-derives the totals as server-side column sums.
    ///
    /// Per word: the row is densified (sparse blocks: scattered from
    /// its pair list) into the runner's reused scratch slab, the
    /// proposal table is built through the reused [`AliasBuilder`]
    /// (hybrid for tail words, dense at/above
    /// [`SamplerParams::alias_dense_threshold`] fill), all occurrences
    /// are resampled against the scratch row, and the scratch is
    /// cleared through its touched-column list — no per-word or
    /// per-token heap allocation anywhere on this path.
    pub fn sweep(
        &mut self,
        cfg: &SweepConfig,
        mut nk_local: Vec<i64>,
        n_wk: &BigMatrix<i64>,
    ) -> Result<IterStats> {
        let k = cfg.num_topics;
        let kk = k as usize;
        let v = cfg.vocab_size;
        let hyper = cfg.hyper;
        let mut stats = IterStats::default();
        let mut buffer =
            UpdateBuffer::new(cfg.sampler.buffer_cap, cfg.sampler.dense_top_words, k);
        self.row.ensure(kk);

        let blocks = word_blocks(&self.present, cfg.sampler.block_words);
        let mut pipeline = PullPipeline::start_with_mode(
            n_wk.clone(),
            blocks,
            cfg.sampler.pipeline_depth,
            pull_mode_for(n_wk.layout()),
        );

        loop {
            // Attribute time blocked on the pipeline separately from
            // compute: nonzero wait means prefetch is not keeping up.
            let wait = Stopwatch::new();
            let Some(block) = pipeline.next_block() else {
                stats.block_wait_secs += wait.secs();
                break;
            };
            stats.block_wait_secs += wait.secs();
            let block = block?;
            // Sample all occurrences of each word in the block while its
            // proposal table (built from the just-pulled, stale row) is
            // fresh. The block itself is never mutated: the live row
            // lives in `self.row`, so no clone of the row list is
            // needed to appease the borrow checker.
            for (bi, &wu) in block.rows.iter().enumerate() {
                let w = wu as usize;
                let build = Stopwatch::new();
                let alias = match &block.data {
                    BlockData::Dense(values) => {
                        let src = &values[bi * kk..(bi + 1) * kk];
                        self.row.load_dense(src);
                        self.builder.build_dense(src, hyper.beta)
                    }
                    BlockData::Sparse(rows) => {
                        let pairs = &rows[bi];
                        self.row.load_sparse(pairs, kk)?;
                        self.builder.build_hybrid(
                            pairs,
                            k,
                            hyper.beta,
                            cfg.sampler.alias_dense_threshold,
                        )
                    }
                };
                stats.alias_build_secs += build.secs();
                for &(local, pos) in &self.occurrences[w] {
                    let (local, pos) = (local as usize, pos as usize);
                    let z_old = self.assignments[local][pos];
                    // Inclusive counts; the kernel excludes on the fly,
                    // so the no-change path below is entirely read-only.
                    let z_new = {
                        let view = TokenView {
                            word_row: &self.row.values[..kk],
                            n_k: &nk_local,
                            doc_counts: &self.doc_counts[local],
                            doc_assignments: &self.assignments[local],
                            word_alias: &alias,
                            v,
                            hyper,
                        };
                        resample_token(z_old, &view, k, cfg.sampler.mh_steps, &mut self.rng)
                    };
                    stats.tokens += 1;
                    if z_new != z_old {
                        self.doc_counts[local].decrement(z_old);
                        self.doc_counts[local].increment(z_new);
                        self.row.shift(z_old, z_new);
                        nk_local[z_old as usize] -= 1;
                        nk_local[z_new as usize] += 1;
                        self.assignments[local][pos] = z_new;
                        stats.changed += 1;
                        if let Some(batch) = buffer.add(wu, z_old, -1) {
                            let _ = n_wk.push_coords_async(&batch);
                            stats.sparse_batches += 1;
                        }
                        if let Some(batch) = buffer.add(wu, z_new, 1) {
                            let _ = n_wk.push_coords_async(&batch);
                            stats.sparse_batches += 1;
                        }
                    }
                }
            }
        }
        // Leave the scratch zeroed for the next sweep.
        self.row.clear();

        // End-of-sweep flushes: remaining sparse triples and the dense
        // hot-word aggregate (§3.3) — all fire-and-forget; the caller's
        // flush() barrier collects them.
        let rest = buffer.take_sparse();
        if !rest.is_empty() {
            let _ = n_wk.push_coords_async(&rest);
            stats.sparse_batches += 1;
        }
        let (rows, values) = buffer.take_dense();
        if !rows.is_empty() {
            let _ = n_wk.push_rows_async(&rows, &values);
        }
        Ok(stats)
    }

    /// One full sweep against a local model snapshot instead of live
    /// pipeline pulls (the cluster's snapshot/BSP mode).
    ///
    /// Every read — word rows and topic totals — comes from `model`,
    /// the iteration-start snapshot all partitions share behind the
    /// coordinator's fetch barrier; only the *deltas* go to the live
    /// table, as the usual fire-and-forget pushes (the caller's
    /// `flush()` barrier collects them). Deltas are additive and
    /// commutative, so the next iteration's snapshot — and therefore
    /// the whole trajectory — is a pure function of the previous one,
    /// bit-identical for any worker count or membership history.
    pub fn sweep_snapshot(
        &mut self,
        cfg: &SweepConfig,
        model: &TopicModel,
        n_wk: &BigMatrix<i64>,
    ) -> Result<IterStats> {
        let k = cfg.num_topics;
        let kk = k as usize;
        let v = cfg.vocab_size;
        let hyper = cfg.hyper;
        if model.n_wk.len() < self.present.len() * kk || model.n_k.len() != kk {
            return Err(Error::Decode(format!(
                "model snapshot shape {}x{} does not cover vocab {} x {k} topics",
                model.v, model.k, self.present.len()
            )));
        }
        let mut stats = IterStats::default();
        let mut buffer =
            UpdateBuffer::new(cfg.sampler.buffer_cap, cfg.sampler.dense_top_words, k);
        self.row.ensure(kk);
        let mut nk_local = model.n_k.clone();

        for w in 0..self.present.len() {
            if !self.present[w] {
                continue;
            }
            let build = Stopwatch::new();
            let src = &model.n_wk[w * kk..(w + 1) * kk];
            self.row.load_dense(src);
            let alias = self.builder.build_dense(src, hyper.beta);
            stats.alias_build_secs += build.secs();
            for &(local, pos) in &self.occurrences[w] {
                let (local, pos) = (local as usize, pos as usize);
                let z_old = self.assignments[local][pos];
                let z_new = {
                    let view = TokenView {
                        word_row: &self.row.values[..kk],
                        n_k: &nk_local,
                        doc_counts: &self.doc_counts[local],
                        doc_assignments: &self.assignments[local],
                        word_alias: &alias,
                        v,
                        hyper,
                    };
                    resample_token(z_old, &view, k, cfg.sampler.mh_steps, &mut self.rng)
                };
                stats.tokens += 1;
                if z_new != z_old {
                    self.doc_counts[local].decrement(z_old);
                    self.doc_counts[local].increment(z_new);
                    self.row.shift(z_old, z_new);
                    nk_local[z_old as usize] -= 1;
                    nk_local[z_new as usize] += 1;
                    self.assignments[local][pos] = z_new;
                    stats.changed += 1;
                    if let Some(batch) = buffer.add(w as u64, z_old, -1) {
                        let _ = n_wk.push_coords_async(&batch);
                        stats.sparse_batches += 1;
                    }
                    if let Some(batch) = buffer.add(w as u64, z_new, 1) {
                        let _ = n_wk.push_coords_async(&batch);
                        stats.sparse_batches += 1;
                    }
                }
            }
        }
        self.row.clear();
        let rest = buffer.take_sparse();
        if !rest.is_empty() {
            let _ = n_wk.push_coords_async(&rest);
            stats.sparse_batches += 1;
        }
        let (rows, values) = buffer.take_dense();
        if !rows.is_empty() {
            let _ = n_wk.push_rows_async(&rows, &values);
        }
        Ok(stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_rng_is_position_independent() {
        // The trainer's sequential fork pattern and the remote worker's
        // skip-ahead reconstruction must produce identical streams.
        let seed = 0x5eed;
        let starts = [0u64, 37, 120];
        let mut parent = Pcg64::new(seed);
        let sequential: Vec<Vec<u64>> = starts
            .iter()
            .map(|&s| {
                let mut r = parent.fork(s);
                (0..8).map(|_| r.next_u64()).collect()
            })
            .collect();
        for (p, &s) in starts.iter().enumerate() {
            let mut r = partition_rng(seed, p, s);
            let stream: Vec<u64> = (0..8).map(|_| r.next_u64()).collect();
            assert_eq!(stream, sequential[p], "partition {p}");
        }
    }

    #[test]
    fn runner_counts_match_assignments() {
        use crate::corpus::synth::{generate, SynthConfig};
        let corpus = generate(&SynthConfig {
            num_docs: 40,
            vocab_size: 100,
            num_topics: 4,
            avg_doc_len: 12.0,
            seed: 5,
            ..Default::default()
        });
        let runner =
            SweepRunner::build_random(&corpus, 10..30, 6, partition_rng(1, 0, 10));
        assert_eq!(runner.assignments().len(), 20);
        assert_eq!(runner.doc_counts().len(), 20);
        // Every token appears exactly once in the inverted index, with
        // the topic its assignment says.
        let mut total = 0u64;
        let mut by_topic = vec![0u64; 6];
        runner.for_each_word_topic(|_, z| {
            total += 1;
            by_topic[z as usize] += 1;
        });
        let expect: u64 =
            corpus.docs[10..30].iter().map(|d| d.tokens.len() as u64).sum();
        assert_eq!(total, expect);
        let from_docs: u64 = runner
            .doc_counts()
            .iter()
            .map(|c| (0..6).map(|k| c.get(k) as u64).sum::<u64>())
            .sum();
        assert_eq!(by_topic.iter().sum::<u64>(), from_docs);
    }

    #[test]
    fn row_scratch_clears_exactly_what_was_written() {
        let mut s = RowScratch::default();
        s.ensure(8);
        s.load_sparse(&[(1, 3), (6, 2)], 8).unwrap();
        assert_eq!(s.values[..8], [0, 3, 0, 0, 0, 0, 2, 0]);
        // A reassignment into a previously-zero column must survive the
        // touched-list bookkeeping.
        s.shift(1, 4);
        assert_eq!(s.values[..8], [0, 2, 0, 0, 1, 0, 2, 0]);
        s.clear();
        assert!(s.values.iter().all(|&x| x == 0));
        // Out-of-range columns surface as decode errors, not panics.
        assert!(s.load_sparse(&[(8, 1)], 8).is_err());
        // Dense loads track nonzeros precisely.
        s.load_dense(&[0, 5, 0, 0, 0, 0, 0, 1]);
        assert_eq!(s.values[1], 5);
        assert_eq!(s.values[7], 1);
        s.clear();
        assert!(s.values.iter().all(|&x| x == 0));
    }
}
