//! LDA hyper-parameters.

/// Dirichlet concentrations for LDA.
#[derive(Debug, Clone, Copy)]
pub struct LdaHyper {
    /// Document–topic concentration α (symmetric). The common default is
    /// `50 / K` (Griffiths & Steyvers, 2004).
    pub alpha: f64,
    /// Topic–word concentration β (symmetric); 0.01 is the standard
    /// web-corpus choice.
    pub beta: f64,
}

impl LdaHyper {
    /// Standard defaults for `k` topics: α = 50/K, β = 0.01.
    pub fn default_for(k: usize) -> LdaHyper {
        LdaHyper { alpha: 50.0 / k as f64, beta: 0.01 }
    }

    /// Validate positivity.
    pub fn validate(&self) -> crate::util::error::Result<()> {
        if self.alpha <= 0.0 || self.beta <= 0.0 {
            return Err(crate::util::error::Error::Config(format!(
                "alpha and beta must be positive (got alpha={}, beta={})",
                self.alpha, self.beta
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_scale_with_k() {
        let h = LdaHyper::default_for(50);
        assert!((h.alpha - 1.0).abs() < 1e-12);
        assert_eq!(h.beta, 0.01);
        assert!(h.validate().is_ok());
    }

    #[test]
    fn invalid_rejected() {
        assert!(LdaHyper { alpha: 0.0, beta: 0.1 }.validate().is_err());
        assert!(LdaHyper { alpha: 0.1, beta: -1.0 }.validate().is_err());
    }
}
