//! Distributed LDA (the paper's §3).
//!
//! The inference algorithm is **LightLDA** (Yuan et al., WWW'15): a
//! collapsed Gibbs sampler whose per-token resampling step is a
//! Metropolis–Hastings kernel alternating two cheap proposals —
//!
//! - the **word proposal** `q_w(k) ∝ n_wk + β`, drawn in amortized O(1)
//!   via Vose [`alias`] tables rebuilt once per word per iteration;
//! - the **document proposal** `q_d(k) ∝ n_dk + α`, drawn in O(1) by
//!   picking the topic of a uniformly random token of the document
//!   (plus an α-weighted uniform smoothing branch);
//!
//! each followed by its exact acceptance probability, so the chain's
//! stationary distribution is the true collapsed Gibbs posterior.
//!
//! The sampler runs data-parallel over corpus partitions: the
//! per-partition pass lives in [`sweep`] ([`sweep::SweepRunner`]) and is
//! driven either by in-process worker threads ([`trainer`]) or by remote
//! worker processes ([`crate::cluster`]) — one code path, two
//! deployment modes;
//! the shared state — the word-topic matrix `n_wk`, stored sparsely on
//! the shards by default — lives on the parameter server, and the topic
//! vector `n_k` is derived from it server-side (column sums) rather
//! than kept as a second table. Document-topic counts `n_dk` are local
//! to each partition ([`sparse_counts`]). Updates stream out through
//! [`buffer`] (≈100 k-reassignment messages, with a dense local
//! aggregate for the most frequent words, §3.3) while model rows are
//! pulled ahead of the sampler by [`pipeline`] (§3.4, sparse pulls for
//! the sparse layout). [`checkpoint`]
//! provides the §3.5 fault-tolerance path. [`gibbs`] is the exact O(K)
//! collapsed Gibbs baseline used for correctness and for the O(1)-vs-O(K)
//! scaling benchmark.

pub mod alias;
pub mod buffer;
pub mod checkpoint;
pub mod gibbs;
pub mod hyper;
pub mod infer;
pub mod lightlda;
pub mod pipeline;
pub mod sparse_counts;
pub mod sweep;
pub mod trainer;

pub use hyper::LdaHyper;
pub use sweep::SamplerParams;
pub use trainer::{TrainConfig, Trainer};
