//! Push buffering (paper §3.3).
//!
//! Pushing every topic reassignment individually is infeasible (billions
//! per iteration); pushing everything at once makes messages too large to
//! cheaply resend on failure. The paper buffers ≈100,000 reassignments
//! (~2 MB messages), and aggregates the reassignments of the most
//! frequent words (top 2000) in a local *dense* matrix that is pushed
//! once at the end of the iteration — those words are so hot that their
//! deltas collapse massively under aggregation.

use crate::ps::client::CoordDeltas;

/// Accumulates count deltas, splitting them between a dense aggregate for
/// hot rows and a bounded sparse triple buffer for the long tail.
#[derive(Debug)]
pub struct UpdateBuffer {
    /// Sparse triple capacity before a flush is requested.
    cap: usize,
    /// Rows `< dense_rows` aggregate densely.
    dense_rows: u64,
    /// Columns (topics).
    k: u32,
    /// Dense aggregate, `dense_rows x k`.
    dense: Vec<i64>,
    /// Rows of the dense aggregate that have been touched.
    dense_touched: Vec<bool>,
    /// Sparse triples.
    sparse: CoordDeltas<i64>,
}

impl UpdateBuffer {
    /// Create a buffer. `cap` is the sparse flush threshold (paper:
    /// 100,000), `dense_rows` the hot-row count (paper: 2,000).
    pub fn new(cap: usize, dense_rows: u64, k: u32) -> UpdateBuffer {
        UpdateBuffer {
            cap: cap.max(1),
            dense_rows,
            k,
            dense: vec![0; dense_rows as usize * k as usize],
            dense_touched: vec![false; dense_rows as usize],
            sparse: CoordDeltas::default(),
        }
    }

    /// Number of sparse triples currently buffered.
    pub fn sparse_len(&self) -> usize {
        self.sparse.len()
    }

    /// Add a delta. Returns a batch of sparse deltas when the sparse
    /// buffer reaches capacity (the caller pushes it to the parameter
    /// server, asynchronously if it likes).
    pub fn add(&mut self, row: u64, col: u32, delta: i64) -> Option<CoordDeltas<i64>> {
        if delta == 0 {
            return None;
        }
        if row < self.dense_rows {
            let idx = row as usize * self.k as usize + col as usize;
            self.dense[idx] += delta;
            self.dense_touched[row as usize] = true;
            return None;
        }
        self.sparse.rows.push(row);
        self.sparse.cols.push(col);
        self.sparse.values.push(delta);
        if self.sparse.len() >= self.cap {
            Some(self.take_sparse())
        } else {
            None
        }
    }

    /// Take whatever sparse triples are buffered (end-of-iteration flush).
    pub fn take_sparse(&mut self) -> CoordDeltas<i64> {
        std::mem::take(&mut self.sparse)
    }

    /// Drain the dense aggregate into `(rows, row_major_values)` for a
    /// `push_rows` call; only touched rows are emitted. Resets the
    /// aggregate.
    pub fn take_dense(&mut self) -> (Vec<u64>, Vec<i64>) {
        let kk = self.k as usize;
        let mut rows = Vec::new();
        let mut values = Vec::new();
        for r in 0..self.dense_rows as usize {
            if self.dense_touched[r] {
                rows.push(r as u64);
                values.extend_from_slice(&self.dense[r * kk..(r + 1) * kk]);
                self.dense[r * kk..(r + 1) * kk].fill(0);
                self.dense_touched[r] = false;
            }
        }
        (rows, values)
    }

    /// Sum of all buffered deltas (tests: conservation check).
    pub fn buffered_total(&self) -> i64 {
        self.dense.iter().sum::<i64>() + self.sparse.values.iter().sum::<i64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;

    #[test]
    fn dense_rows_aggregate() {
        let mut b = UpdateBuffer::new(10, 5, 3);
        assert!(b.add(0, 1, 1).is_none());
        assert!(b.add(0, 1, 1).is_none());
        assert!(b.add(4, 2, -1).is_none());
        assert_eq!(b.sparse_len(), 0);
        let (rows, vals) = b.take_dense();
        assert_eq!(rows, vec![0, 4]);
        assert_eq!(vals, vec![0, 2, 0, 0, 0, -1]);
        // Drained: next take is empty.
        let (rows, vals) = b.take_dense();
        assert!(rows.is_empty() && vals.is_empty());
    }

    #[test]
    fn sparse_flush_at_capacity() {
        let mut b = UpdateBuffer::new(3, 0, 2);
        assert!(b.add(10, 0, 1).is_none());
        assert!(b.add(11, 1, 1).is_none());
        let batch = b.add(12, 0, -1).expect("flush at cap");
        assert_eq!(batch.len(), 3);
        assert_eq!(b.sparse_len(), 0);
    }

    #[test]
    fn flush_fires_exactly_at_cap_not_before() {
        let mut b = UpdateBuffer::new(4, 0, 2);
        for i in 0..3 {
            assert!(b.add(10 + i, 0, 1).is_none(), "delta {i} is below cap");
        }
        // The 4th delta lands exactly at cap: the batch carries all 4 and
        // the buffer restarts empty.
        let batch = b.add(13, 1, -1).expect("flush at exactly cap");
        assert_eq!(batch.len(), 4);
        assert_eq!(batch.rows, vec![10, 11, 12, 13]);
        assert_eq!(b.sparse_len(), 0);
        // Refilling to cap flushes again at the same boundary.
        for i in 0..3 {
            assert!(b.add(20 + i, 0, 1).is_none());
        }
        assert_eq!(b.add(23, 0, 1).expect("second flush").len(), 4);
    }

    #[test]
    fn hot_rows_aggregate_dense_tail_rows_go_sparse() {
        let mut b = UpdateBuffer::new(100, 3, 2);
        // Rows strictly below dense_rows aggregate locally...
        assert!(b.add(0, 0, 1).is_none());
        assert!(b.add(2, 1, 5).is_none());
        assert_eq!(b.sparse_len(), 0);
        // ...the boundary row (row == dense_rows) is the first tail row.
        assert!(b.add(3, 0, 7).is_none());
        assert_eq!(b.sparse_len(), 1);
        let (rows, vals) = b.take_dense();
        assert_eq!(rows, vec![0, 2]);
        assert_eq!(vals, vec![1, 0, 0, 5]);
        let sparse = b.take_sparse();
        assert_eq!((sparse.rows, sparse.cols, sparse.values), (vec![3], vec![0], vec![7]));
    }

    #[test]
    fn drains_are_idempotent() {
        let mut b = UpdateBuffer::new(100, 2, 2);
        assert!(b.add(0, 1, 3).is_none());
        assert!(b.add(50, 0, -2).is_none());
        let first_sparse = b.take_sparse();
        let (first_rows, first_vals) = b.take_dense();
        assert_eq!(first_sparse.len(), 1);
        assert_eq!((first_rows, first_vals), (vec![0], vec![0, 3]));
        // Draining again yields nothing: the first drain reset both
        // halves...
        assert!(b.take_sparse().is_empty());
        let (rows, vals) = b.take_dense();
        assert!(rows.is_empty() && vals.is_empty());
        assert_eq!(b.buffered_total(), 0);
        // ...and the buffer stays usable afterwards.
        assert!(b.add(1, 0, 9).is_none());
        let (rows, vals) = b.take_dense();
        assert_eq!((rows, vals), (vec![1], vec![9, 0]));
    }

    #[test]
    fn zero_deltas_skipped() {
        let mut b = UpdateBuffer::new(10, 2, 2);
        assert!(b.add(0, 0, 0).is_none());
        assert!(b.add(5, 0, 0).is_none());
        assert_eq!(b.sparse_len(), 0);
        assert_eq!(b.buffered_total(), 0);
    }

    #[test]
    fn conservation_property() {
        // Sum of everything drained == sum of everything added.
        forall(
            "buffer conserves deltas",
            100,
            |rng| {
                let ops: Vec<(u64, u32, i64)> = (0..rng.below(500))
                    .map(|_| {
                        (
                            rng.below(100) as u64,
                            rng.below(4) as u32,
                            rng.below(5) as i64 - 2,
                        )
                    })
                    .collect();
                ops
            },
            |ops| {
                let mut b = UpdateBuffer::new(37, 20, 4);
                let mut flushed: i64 = 0;
                let mut added: i64 = 0;
                for &(r, c, d) in ops {
                    added += d;
                    if let Some(batch) = b.add(r, c, d) {
                        flushed += batch.values.iter().sum::<i64>();
                    }
                }
                let rest = b.take_sparse();
                flushed += rest.values.iter().sum::<i64>();
                let (_, dense_vals) = b.take_dense();
                flushed += dense_vals.iter().sum::<i64>();
                flushed == added
            },
        );
    }
}
