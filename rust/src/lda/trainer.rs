//! The distributed LightLDA trainer (paper §3.1, Figure 3).
//!
//! Plays the role of the Spark driver + executors: the corpus is split
//! into partitions (the RDD analogue); each partition is sampled by a
//! worker thread running the LightLDA Metropolis–Hastings kernel against
//! shared state on the parameter server:
//!
//! - `n_wk` — `V x K` word-topic counts, a [`BigMatrix<i64>`] stored
//!   `Layout::Sparse` by default (Zipf-shaped rows; see
//!   [`TrainConfig::wt_layout`]);
//! - `n_k`  — `K` topic totals, **derived server-side**: pulled as the
//!   column sums of `n_wk`
//!   ([`crate::ps::client::BigMatrix::pull_col_sums`]) instead of being
//!   maintained as a second table and double-pushed;
//! - `n_dk` — document-topic counts, local to each worker.
//!
//! Per iteration, each worker walks the model in word blocks: rows are
//! **pulled in fixed-size sets** with the next sets prefetched as
//! asynchronous pull tickets while the current one is being sampled
//! (§3.4, [`crate::lda::pipeline`]) — sparse `(col, val)` pulls when the
//! matrix layout is sparse, so bandwidth tracks row occupancy; alias
//! tables are built per pulled word; all of the partition's occurrences
//! of those words are resampled; updates stream out through the
//! [`crate::lda::buffer`] (§3.3) as **fire-and-forget push tickets**
//! riding each shard's bounded in-flight window while sampling
//! continues. The iteration barrier is
//! [`crate::ps::client::PsClient::flush`]: it drains every outstanding
//! push (exactly-once, §2.4) — and surfaces any push error — before the
//! next iteration pulls, before perplexity evaluation, and before
//! checkpointing.
//!
//! Fault tolerance (§3.5): assignments are checkpointed after each
//! iteration; [`Trainer::restore`] rebuilds the parameter-server count
//! tables from the latest checkpoint.

use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use crate::corpus::dataset::Corpus;
use crate::eval::perplexity::{log_likelihood, perplexity_from_loglik, TopicModel};
use crate::lda::checkpoint::Checkpoint;
use crate::lda::hyper::LdaHyper;
use crate::lda::sparse_counts::DocTopicCounts;
use crate::lda::sweep::{partition_rng, pull_full_model, SamplerParams, SweepConfig, SweepRunner};
use crate::log_info;
use crate::metrics::{Report, Row};
use crate::net::tcp::{resolve_addrs, TcpTransport};
use crate::net::{FaultPlan, Transport};
use crate::ps::client::{BigMatrix, PsClient};
use crate::ps::config::{PsConfig, TransportMode};
use crate::ps::messages::Layout;
use crate::ps::partition::PartitionScheme;
use crate::ps::server::ServerGroup;
use crate::util::error::{Error, Result};
use crate::util::rng::Pcg64;
use crate::util::timer::Stopwatch;

pub use crate::lda::sweep::IterStats;

/// Trainer configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Number of topics K.
    pub num_topics: u32,
    /// Gibbs iterations (full corpus sweeps).
    pub iterations: u32,
    /// Document-topic concentration; `<= 0` selects the 50/K default.
    pub alpha: f64,
    /// Topic-word concentration.
    pub beta: f64,
    /// Sampler-performance knobs (MH steps, block size, push buffering,
    /// prefetch depth, alias threshold) — shared verbatim with
    /// [`SweepConfig`] and the cluster wire protocol. `pipeline_depth`
    /// also sizes the parameter-server client's per-shard in-flight
    /// window ([`PsConfig::pipeline_depth`], floored at 2 so push
    /// flushes still overlap sampling).
    pub sampler: SamplerParams,
    /// Sampling worker threads ("executors").
    pub workers: usize,
    /// Parameter-server shards (paper cluster: 30).
    pub shards: usize,
    /// Row partitioning scheme on the servers (paper: cyclic).
    pub scheme: PartitionScheme,
    /// Storage layout of the word-topic matrix on the shards. `Sparse`
    /// (the default) stores rows as sorted `(col, val)` pairs and pulls
    /// them as pairs, so memory and bandwidth track the Zipfian row
    /// occupancy; `Dense` is the full-slab ablation.
    pub wt_layout: Layout,
    /// Transport between trainer and parameter servers. `Sim` and
    /// `TcpLoopback` start the servers in-process; `Connect` attaches to
    /// externally running `serve` processes (and overrides `shards` with
    /// the address count).
    pub transport: TransportMode,
    /// Simulated network faults (ignored by the TCP transports).
    pub fault: FaultPlan,
    /// RNG seed.
    pub seed: u64,
    /// Compute training perplexity every N iterations (0 = never).
    pub eval_every: u32,
    /// Checkpoint directory (None disables checkpointing).
    pub checkpoint_dir: Option<PathBuf>,
    /// Checkpoints retained per granularity (whole-corpus files for the
    /// in-process trainer, per-partition files for cluster workers);
    /// older snapshots are pruned after each save. `0` keeps everything.
    pub keep_checkpoints: usize,
    /// Cluster mode: how often workers heartbeat the coordinator.
    pub heartbeat_ms: u64,
    /// Cluster mode: a worker silent for this long (no heartbeat, poll
    /// or report) is declared dead; its partition is reassigned and the
    /// run rolls back to the last per-partition checkpoints.
    pub straggler_timeout_ms: u64,
    /// Cluster mode: the asynchronous barrier's staleness bound — a fast
    /// worker may run at most this many iterations ahead of the slowest
    /// partition (`0` = lockstep).
    pub max_staleness: u32,
    /// Cluster mode: backup replica addresses, one per shard and
    /// parallel to the `Connect` primaries (started with
    /// `serve --backup-of`). Empty disables replication: no client
    /// failover, no promotion on shard death.
    pub backups: Vec<String>,
    /// Cluster mode: elastic membership on the consistent-hash ring.
    /// Workers may join, drain and rejoin mid-run; partitions move
    /// between members via warm checkpoint handoffs. Requires
    /// `checkpoint_dir`. Off = the historical static partition table.
    pub elastic: bool,
    /// Cluster mode: micro-partitions per configured worker. The corpus
    /// splits into `workers * partition_factor` fixed partitions, so
    /// the ring can rebalance in units smaller than a whole worker's
    /// share. 1 (the default) reproduces the historical one-partition-
    /// per-worker layout.
    pub partition_factor: usize,
    /// Cluster mode, elastic only: straggler shedding factor. A
    /// partition lagging the staleness window by this factor with no
    /// progress for `shed_stall_ms` gets its owner's ring weight
    /// halved. `<= 0` disables shedding.
    pub shed_factor: f64,
    /// Cluster mode: stall window (and shed cool-down), milliseconds.
    pub shed_stall_ms: u64,
    /// Cluster mode: snapshot (BSP) sweeps — each iteration samples a
    /// full-model snapshot behind a coordinator fetch barrier. With
    /// `max_staleness = 0` the final count table is bit-identical for
    /// any membership history (the elasticity demo's exactness oracle).
    pub snapshot: bool,
    /// Cluster mode: planned shard hand-off. Once every partition has
    /// completed iteration `.0`, drain shard `.1` onto its most
    /// caught-up standby — a zero-epoch-roll promotion (clients retarget
    /// via the shared route; no rollback, no re-sampling). One-shot;
    /// `None` disables.
    pub drain_shard_at: Option<(u32, usize)>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            num_topics: 20,
            iterations: 50,
            alpha: 0.0,
            beta: 0.01,
            sampler: SamplerParams::default(),
            workers: 4,
            shards: 4,
            scheme: PartitionScheme::Cyclic,
            wt_layout: Layout::Sparse,
            transport: TransportMode::Sim,
            fault: FaultPlan::reliable(),
            seed: 0x1da,
            eval_every: 0,
            checkpoint_dir: None,
            keep_checkpoints: 3,
            heartbeat_ms: 1000,
            straggler_timeout_ms: 10_000,
            max_staleness: 1,
            backups: Vec::new(),
            elastic: false,
            partition_factor: 1,
            shed_factor: 0.0,
            shed_stall_ms: 3000,
            snapshot: false,
            drain_shard_at: None,
        }
    }
}

impl TrainConfig {
    /// Resolved hyper-parameters.
    pub fn hyper(&self) -> LdaHyper {
        let alpha = if self.alpha > 0.0 { self.alpha } else { 50.0 / self.num_topics as f64 };
        LdaHyper { alpha, beta: self.beta }
    }

    /// The sampling knobs a [`SweepRunner`] needs, for a corpus with
    /// `vocab_size` words.
    pub fn sweep_config(&self, vocab_size: u32) -> SweepConfig {
        SweepConfig {
            num_topics: self.num_topics,
            sampler: self.sampler,
            hyper: self.hyper(),
            vocab_size,
        }
    }
}

/// Bring up (or connect to) the parameter servers for a training run.
///
/// `Sim`/`TcpLoopback` start an in-process [`ServerGroup`]; `Connect`
/// attaches to externally running `serve` processes, one shard per
/// address (the address count wins over `cfg.shards`).
fn start_parameter_servers(
    cfg: &TrainConfig,
) -> Result<(Option<ServerGroup>, Arc<dyn Transport>, PsClient)> {
    match &cfg.transport {
        TransportMode::Connect(addrs) => {
            let resolved = resolve_addrs(addrs)?;
            if cfg.shards != resolved.len() {
                log_info!(
                    "using {} shards (one per --connect address; configured {})",
                    resolved.len(),
                    cfg.shards
                );
            }
            let mut ps_cfg = PsConfig::deployment(
                resolved.len(),
                cfg.scheme,
                cfg.transport.clone(),
                cfg.sampler.pipeline_depth,
            );
            ps_cfg.backups = cfg.backups.clone();
            let transport: Arc<dyn Transport> = Arc::new(TcpTransport::connect(&resolved));
            let client = PsClient::connect(&*transport, ps_cfg);
            // A shard-count / scheme / address-order mismatch against the
            // serve processes would silently route rows to wrong slots;
            // fail loudly before any state is created.
            client.validate_deployment()?;
            Ok((None, transport, client))
        }
        _ => {
            let ps_cfg = PsConfig::deployment(
                cfg.shards,
                cfg.scheme,
                cfg.transport.clone(),
                cfg.sampler.pipeline_depth,
            );
            let group = ServerGroup::start(ps_cfg.clone(), cfg.fault.clone(), cfg.seed ^ 0x9d);
            let transport = group.transport();
            let client = PsClient::connect(&*transport, ps_cfg);
            Ok((Some(group), transport, client))
        }
    }
}

/// Distributed LightLDA trainer bound to one corpus layout.
pub struct Trainer {
    cfg: TrainConfig,
    hyper: LdaHyper,
    /// In-process server group (`None` when connected to external
    /// `serve` processes).
    group: Option<ServerGroup>,
    transport: Arc<dyn Transport>,
    client: PsClient,
    n_wk: BigMatrix<i64>,
    workers: Vec<SweepRunner>,
    vocab_size: u32,
    completed_iterations: u32,
    /// Per-iteration report (perplexity curve, throughput).
    pub report: Report,
}

impl Trainer {
    /// Set up servers, allocate the distributed model, initialize topic
    /// assignments randomly and push the initial counts.
    pub fn new(cfg: TrainConfig, corpus: &Corpus) -> Result<Trainer> {
        cfg.hyper().validate()?;
        if corpus.num_docs() == 0 {
            return Err(Error::Config("empty corpus".into()));
        }
        let (group, transport, client) = start_parameter_servers(&cfg)?;
        let n_wk: BigMatrix<i64> =
            client.matrix_with_layout(corpus.vocab_size as u64, cfg.num_topics, cfg.wt_layout)?;

        let mut trainer = Trainer {
            hyper: cfg.hyper(),
            group,
            transport,
            client,
            n_wk,
            workers: Vec::new(),
            vocab_size: corpus.vocab_size,
            completed_iterations: 0,
            report: Report::new(),
            cfg,
        };
        let k = trainer.cfg.num_topics;
        let seed = trainer.cfg.seed;
        trainer.build_workers(corpus, seed, |doc, rng| {
            doc.tokens.iter().map(|_| rng.below(k as usize) as u32).collect::<Vec<u32>>()
        });
        trainer.push_initial_counts()?;
        Ok(trainer)
    }

    /// Restore from the latest checkpoint in `cfg.checkpoint_dir`:
    /// assignments come from the checkpoint and the parameter-server
    /// count tables are rebuilt from them (§3.5).
    pub fn restore(cfg: TrainConfig, corpus: &Corpus) -> Result<Trainer> {
        let dir = cfg
            .checkpoint_dir
            .clone()
            .ok_or_else(|| Error::Checkpoint("no checkpoint_dir configured".into()))?;
        let ckpt = Checkpoint::load_latest(&dir)?
            .ok_or_else(|| Error::Checkpoint(format!("no checkpoint found in {dir:?}")))?;
        if ckpt.num_topics != cfg.num_topics {
            return Err(Error::Checkpoint(format!(
                "checkpoint has K={}, config has K={}",
                ckpt.num_topics, cfg.num_topics
            )));
        }
        if ckpt.assignments.len() != corpus.num_docs() {
            return Err(Error::Checkpoint("checkpoint does not match corpus".into()));
        }
        for (d, doc) in corpus.docs.iter().enumerate() {
            if ckpt.assignments[d].len() != doc.tokens.len() {
                return Err(Error::Checkpoint(format!("doc {d} length mismatch")));
            }
        }

        let (group, transport, client) = start_parameter_servers(&cfg)?;
        let n_wk: BigMatrix<i64> =
            client.matrix_with_layout(corpus.vocab_size as u64, cfg.num_topics, cfg.wt_layout)?;
        let completed = ckpt.iteration;
        let assignments = std::cell::RefCell::new(ckpt.assignments);

        let mut trainer = Trainer {
            hyper: cfg.hyper(),
            group,
            transport,
            client,
            n_wk,
            workers: Vec::new(),
            vocab_size: corpus.vocab_size,
            completed_iterations: completed,
            report: Report::new(),
            cfg,
        };
        let seed = trainer.cfg.seed ^ 0xc4;
        // Hand each doc its checkpointed assignment. Docs are visited in
        // order, so drain front-to-back.
        let next = std::cell::Cell::new(0usize);
        trainer.build_workers(corpus, seed, |_, _| {
            let i = next.get();
            next.set(i + 1);
            assignments.borrow_mut()[i].clone()
        });
        trainer.push_initial_counts()?;
        log_info!(
            "restored from checkpoint at iteration {} ({} docs)",
            completed,
            corpus.num_docs()
        );
        Ok(trainer)
    }

    /// Iterations completed so far (nonzero after restore).
    pub fn completed_iterations(&self) -> u32 {
        self.completed_iterations
    }

    /// Server-side id of the word-topic count table — the freeze/attach
    /// handshake token a serving replica passes to
    /// [`crate::lda::infer::InferEngine::attach`] to reach this model on
    /// the same shards.
    pub fn matrix_id(&self) -> u32 {
        self.n_wk.id()
    }

    /// The in-process server group, when this trainer started one
    /// (`None` when attached to external `serve` processes).
    pub fn server_group(&self) -> Option<&ServerGroup> {
        self.group.as_ref()
    }

    /// One [`SweepRunner`] per worker thread, each over its contiguous
    /// corpus partition, with the deterministic per-partition RNG
    /// ([`partition_rng`]) — the same stream a remote cluster worker
    /// would reconstruct for the same partition index and seed.
    fn build_workers(
        &mut self,
        corpus: &Corpus,
        seed: u64,
        mut init_doc: impl FnMut(&crate::corpus::dataset::Document, &mut Pcg64) -> Vec<u32>,
    ) {
        for (p, range) in corpus.partitions(self.cfg.workers).into_iter().enumerate() {
            let rng = partition_rng(seed, p, range.start as u64);
            self.workers.push(SweepRunner::build(corpus, range, rng, &mut init_doc));
        }
    }

    /// Push every worker's initial counts to the parameter server
    /// (buffered fire-and-forget tickets, same path as training updates;
    /// the trailing `flush` is the completion barrier). Only `n_wk` is
    /// pushed — the topic totals are its column sums, aggregated
    /// server-side on demand.
    fn push_initial_counts(&mut self) -> Result<()> {
        let scfg = self.cfg.sweep_config(self.vocab_size);
        for ws in &self.workers {
            ws.push_counts(&scfg, &self.n_wk);
        }
        self.client.flush()
    }

    /// Run the configured number of iterations; returns the final model
    /// pulled off the parameter server.
    pub fn run(&mut self, corpus: &Corpus) -> Result<TopicModel> {
        let total = self.cfg.iterations;
        while self.completed_iterations < total {
            let stats = self.run_iteration()?;
            let iter = self.completed_iterations;
            let mut row = Row::new()
                .set("iter", iter as f64)
                .set("seconds", stats.seconds)
                .set("tokens", stats.tokens as f64)
                .set(
                    "tokens_per_sec",
                    if stats.seconds > 0.0 { stats.tokens as f64 / stats.seconds } else { 0.0 },
                )
                .set("changed_frac", stats.changed as f64 / stats.tokens.max(1) as f64)
                // Hot-path visibility: cumulative seconds (summed over
                // workers) spent building word-proposal tables and
                // waiting on the pull pipeline for the next block.
                .set("alias_build_secs", stats.alias_build_secs)
                .set("block_wait_secs", stats.block_wait_secs);
            // Parameter-server health, folded into the same row so long
            // and multi-process runs are observable from the CSV alone:
            // resident bytes and dedup evictions from every shard's
            // introspection op, cumulative wire traffic from the
            // transport counters.
            if let Ok(infos) = self.client.shard_infos() {
                row = row
                    .set(
                        "ps_resident_bytes",
                        infos.iter().map(|i| i.bytes).sum::<u64>() as f64,
                    )
                    .set(
                        "ps_dedup_evictions",
                        infos.iter().map(|i| i.dedup_evictions).sum::<u64>() as f64,
                    )
                    .set(
                        "ps_pending_uids",
                        infos.iter().map(|i| i.pending_uids).sum::<u64>() as f64,
                    );
            }
            row = row.set("net_tx_bytes", self.bytes_pushed() as f64);
            if self.cfg.eval_every > 0 && iter % self.cfg.eval_every == 0 {
                let model = self.pull_model()?;
                let perplexity = self.training_perplexity(&model, corpus);
                row = row.set("perplexity", perplexity);
                log_info!(
                    "iter {iter}: perplexity {perplexity:.1}, {:.0} tokens/s",
                    stats.tokens as f64 / stats.seconds.max(1e-9)
                );
            } else {
                log_info!(
                    "iter {iter}: {:.0} tokens/s ({:.1}% reassigned)",
                    stats.tokens as f64 / stats.seconds.max(1e-9),
                    100.0 * stats.changed as f64 / stats.tokens.max(1) as f64
                );
            }
            self.report.push(row);
            if let Some(dir) = self.cfg.checkpoint_dir.clone() {
                self.checkpoint(&dir)?;
            }
        }
        self.pull_model()
    }

    /// Execute one full sweep (all workers, all partitions).
    pub fn run_iteration(&mut self) -> Result<IterStats> {
        let sw = Stopwatch::new();
        // Iteration-start snapshot of the topic totals, shared read-only
        // by workers; each worker maintains its own local drift copy
        // (LightLDA's bounded-staleness model). The totals are the
        // column sums of n_wk, aggregated server-side — one K-length
        // vector per shard instead of pulling any rows.
        let nk_snapshot = self.n_wk.pull_col_sums()?;
        let n_wk = &self.n_wk;
        let scfg = self.cfg.sweep_config(self.vocab_size);
        let errors: Mutex<Vec<Error>> = Mutex::new(Vec::new());
        let totals = Mutex::new(IterStats::default());

        std::thread::scope(|scope| {
            for ws in self.workers.iter_mut() {
                let nk_snapshot = nk_snapshot.clone();
                let scfg = &scfg;
                let errors = &errors;
                let totals = &totals;
                scope.spawn(move || match ws.sweep(scfg, nk_snapshot, n_wk) {
                    Ok(stats) => {
                        let mut t = totals.lock().unwrap();
                        t.tokens += stats.tokens;
                        t.changed += stats.changed;
                        t.sparse_batches += stats.sparse_batches;
                        t.alias_build_secs += stats.alias_build_secs;
                        t.block_wait_secs += stats.block_wait_secs;
                    }
                    Err(e) => errors.lock().unwrap().push(e),
                });
            }
        });
        // Iteration barrier: every fire-and-forget push must have landed
        // before the next iteration's pulls (and before checkpointing or
        // evaluation); flush also surfaces push errors whose tickets
        // were dropped by the workers.
        let flushed = self.client.flush();
        if let Some(e) = errors.into_inner().unwrap().into_iter().next() {
            return Err(e);
        }
        flushed?;
        self.completed_iterations += 1;
        let mut stats = totals.into_inner().unwrap();
        stats.seconds = sw.secs();
        Ok(stats)
    }

    /// Write a checkpoint of all assignments (gathered from workers),
    /// then prune snapshots beyond [`TrainConfig::keep_checkpoints`].
    pub fn checkpoint(&self, dir: &std::path::Path) -> Result<()> {
        let mut assignments = Vec::new();
        for ws in &self.workers {
            assignments.extend(ws.assignments().iter().cloned());
        }
        let ckpt = Checkpoint {
            iteration: self.completed_iterations,
            num_topics: self.cfg.num_topics,
            assignments,
        };
        ckpt.save(dir)?;
        Checkpoint::prune(dir, self.cfg.keep_checkpoints)?;
        Ok(())
    }

    /// Pull the full model off the parameter server (pipelined chunk
    /// pulls plus the server-side column sums; see
    /// [`crate::lda::sweep::pull_full_model`]).
    pub fn pull_model(&self) -> Result<TopicModel> {
        pull_full_model(&self.n_wk, self.vocab_size, self.cfg.sampler.pipeline_depth, self.hyper)
    }

    /// All documents' topic counts in corpus order (gathered from the
    /// workers; used by the evaluators).
    pub fn doc_counts(&self) -> Vec<DocTopicCounts> {
        let mut counts: Vec<DocTopicCounts> = Vec::new();
        for ws in &self.workers {
            counts.extend(ws.doc_counts().iter().cloned());
        }
        counts
    }

    /// Training perplexity using the workers' local doc-topic counts.
    pub fn training_perplexity(&self, model: &TopicModel, corpus: &Corpus) -> f64 {
        let counts = self.doc_counts();
        let (ll, n) = log_likelihood(model, corpus, &counts);
        perplexity_from_loglik(ll, n)
    }

    /// Aggregate network statistics from the transport (bytes, requests,
    /// per-shard load) — powers the Fig. 5 measurement.
    pub fn shard_request_counts(&self) -> Vec<u64> {
        self.transport.stats().iter().map(|s| s.requests()).collect()
    }

    /// Total bytes sent to the parameter servers so far.
    pub fn bytes_pushed(&self) -> u64 {
        self.transport.stats().iter().map(|s| s.bytes_sent()).sum()
    }

    /// Tell externally started `serve` processes to exit (no-op concern
    /// for in-process groups, which shut down when the trainer drops).
    pub fn shutdown_servers(&self) -> Result<()> {
        self.client.shutdown_servers()
    }

    /// Consistency check for tests: the parameter-server tables must
    /// equal the counts recomputed from worker assignments.
    pub fn verify_counts(&self) -> Result<()> {
        let model = self.pull_model()?;
        let k = self.cfg.num_topics as usize;
        let mut expect_wk = vec![0i64; self.vocab_size as usize * k];
        let mut expect_k = vec![0i64; k];
        for ws in &self.workers {
            ws.for_each_word_topic(|w, z| {
                expect_wk[w as usize * k + z as usize] += 1;
                expect_k[z as usize] += 1;
            });
        }
        if expect_wk != model.n_wk {
            return Err(Error::Config("n_wk on server diverged from assignments".into()));
        }
        if expect_k != model.n_k {
            return Err(Error::Config("n_k on server diverged from assignments".into()));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synth::{generate, SynthConfig};

    fn corpus() -> Corpus {
        generate(&SynthConfig {
            num_docs: 150,
            vocab_size: 400,
            num_topics: 5,
            avg_doc_len: 30.0,
            seed: 33,
            ..Default::default()
        })
    }

    fn fast_cfg() -> TrainConfig {
        TrainConfig {
            num_topics: 8,
            iterations: 3,
            workers: 3,
            shards: 3,
            sampler: SamplerParams {
                block_words: 64,
                buffer_cap: 500,
                dense_top_words: 20,
                ..Default::default()
            },
            ..Default::default()
        }
    }

    #[test]
    fn counts_stay_consistent_across_iterations() {
        let c = corpus();
        let mut t = Trainer::new(fast_cfg(), &c).unwrap();
        t.verify_counts().unwrap();
        t.run_iteration().unwrap();
        t.verify_counts().unwrap();
        t.run_iteration().unwrap();
        t.verify_counts().unwrap();
    }

    #[test]
    fn training_reduces_perplexity() {
        let c = corpus();
        let mut cfg = fast_cfg();
        cfg.iterations = 12;
        let mut t = Trainer::new(cfg, &c).unwrap();
        let m0 = t.pull_model().unwrap();
        let p0 = t.training_perplexity(&m0, &c);
        let model = t.run(&c).unwrap();
        let p1 = t.training_perplexity(&model, &c);
        assert!(p1 < p0 * 0.9, "perplexity {p0} -> {p1}");
    }

    #[test]
    fn exactly_once_under_lossy_network_full_training() {
        let c = corpus();
        let mut cfg = fast_cfg();
        cfg.fault = FaultPlan::lossy(0.05, 0.05);
        cfg.iterations = 2;
        let mut t = Trainer::new(cfg, &c).unwrap();
        t.run_iteration().unwrap();
        t.run_iteration().unwrap();
        // Under message loss + duplication, the exactly-once protocol
        // must keep server counts exactly equal to the assignments.
        t.verify_counts().unwrap();
    }

    #[test]
    fn checkpoint_restore_roundtrip() {
        let c = corpus();
        let dir = std::env::temp_dir()
            .join(format!("glint_trainer_ckpt_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut cfg = fast_cfg();
        cfg.checkpoint_dir = Some(dir.clone());
        cfg.iterations = 2;
        let mut t = Trainer::new(cfg.clone(), &c).unwrap();
        let model_before = t.run(&c).unwrap();

        // Simulate failure: rebuild everything from the checkpoint.
        let t2 = Trainer::restore(cfg, &c).unwrap();
        assert_eq!(t2.completed_iterations(), 2);
        t2.verify_counts().unwrap();
        let model_after = t2.pull_model().unwrap();
        assert_eq!(model_before.n_wk, model_after.n_wk, "rebuilt n_wk must match");
        assert_eq!(model_before.n_k, model_after.n_k);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn overlapped_pipeline_keeps_counts_exact() {
        // Deep prefetch + small buffer cap = many fire-and-forget pushes
        // overlapping sampling; the flush barrier must still leave the
        // server tables exactly equal to the assignments.
        let c = corpus();
        let mut cfg = fast_cfg();
        cfg.sampler.pipeline_depth = 4;
        cfg.sampler.buffer_cap = 100;
        let mut t = Trainer::new(cfg, &c).unwrap();
        t.run_iteration().unwrap();
        t.run_iteration().unwrap();
        t.verify_counts().unwrap();
    }

    #[test]
    fn dense_layout_ablation_also_works() {
        // The default word-topic layout is sparse; the dense ablation
        // must keep counts exactly consistent too.
        let c = corpus();
        let mut cfg = fast_cfg();
        cfg.wt_layout = Layout::Dense;
        cfg.iterations = 2;
        let mut t = Trainer::new(cfg, &c).unwrap();
        t.run_iteration().unwrap();
        t.run_iteration().unwrap();
        t.verify_counts().unwrap();
    }

    #[test]
    fn range_scheme_also_works() {
        let c = corpus();
        let mut cfg = fast_cfg();
        cfg.scheme = PartitionScheme::Range;
        cfg.iterations = 1;
        let mut t = Trainer::new(cfg, &c).unwrap();
        t.run_iteration().unwrap();
        t.verify_counts().unwrap();
    }

    #[test]
    fn empty_corpus_rejected() {
        let c = Corpus { docs: vec![], vocab_size: 10, vocab: vec![] };
        assert!(Trainer::new(fast_cfg(), &c).is_err());
    }
}
