//! Alias tables for the LightLDA word proposal `q_w(k) ∝ n̂_wk + β`.
//!
//! Two constructions share one sampling contract ([`WordProposal`]):
//!
//! - [`AliasTable`] — the classic owned Vose table (Vose, 1991): O(K)
//!   build over arbitrary weights, O(1) sampling. Used where many
//!   tables must stay alive at once (the single-machine
//!   [`crate::lda::lightlda::sweep_light`]) and by the micro-benchmarks.
//! - [`AliasBuilder`] → [`WordAlias`] — the distributed sampler's hot
//!   path. LightLDA (Yuan et al., 2015) decomposes the word proposal
//!   into a mixture of a **sparse** mass over the row's nonzero topics
//!   and a **uniform** βK smoothing component:
//!
//!   ```text
//!   q_w(k) ∝ n̂_wk + β  =  S_w · (n̂_wk / S_w)  +  βK · (1/K)
//!   ```
//!
//!   so a Vose table is needed only over the `nnz_w` nonzeros — an
//!   O(nnz_w) build — while the β branch is drawn uniformly in O(1)
//!   with mixture weight `βK / (S_w + βK)`. Zipf-tail words (the vast
//!   majority of the vocabulary) build in time proportional to their
//!   occupancy, not to K. Hot rows past a fill threshold are built
//!   dense instead (mirroring the shards' adaptive promotion in
//!   [`crate::ps::storage`]), where the plain O(K) table is both
//!   cheaper to clear and faster to draw from. The builder owns every
//!   buffer involved (prob/alias/scaled/worklists plus the stale-weight
//!   slab behind `weight()`), so steady-state construction performs no
//!   heap allocation at all.
//!
//! Either way the table retains the **stale** build-time masses:
//! LightLDA's Metropolis–Hastings acceptance ratio needs exactly the
//! proposal mass `q(k) = n̂_wk + β` the table was built from, looked up
//! in O(1) through [`WordProposal::weight`].

use crate::util::rng::Pcg64;

/// The word-proposal contract the MH kernel
/// ([`crate::lda::lightlda::resample_token`]) samples against: an O(1)
/// draw plus O(1) access to the exact (stale, unnormalized) build-time
/// mass of any outcome.
pub trait WordProposal {
    /// Draw one outcome.
    fn sample(&self, rng: &mut Pcg64) -> u32;
    /// Build-time (stale) unnormalized weight of outcome `k`.
    fn weight(&self, k: u32) -> f64;
    /// Sum of build-time weights.
    fn total_weight(&self) -> f64;
}

/// Fill `prob[..n]` / `alias[..n]` from `scaled[..n]` (weights already
/// scaled to mean 1) with Vose's two-worklist construction. `scaled` is
/// consumed as scratch; `small`/`large` are cleared worklists.
fn vose(
    n: usize,
    scaled: &mut [f64],
    prob: &mut [f64],
    alias: &mut [u32],
    small: &mut Vec<u32>,
    large: &mut Vec<u32>,
) {
    small.clear();
    large.clear();
    for (i, &s) in scaled[..n].iter().enumerate() {
        if s < 1.0 {
            small.push(i as u32);
        } else {
            large.push(i as u32);
        }
    }
    while let (Some(&s), Some(&l)) = (small.last(), large.last()) {
        small.pop();
        prob[s as usize] = scaled[s as usize];
        alias[s as usize] = l;
        scaled[l as usize] = (scaled[l as usize] + scaled[s as usize]) - 1.0;
        if scaled[l as usize] < 1.0 {
            large.pop();
            small.push(l);
        }
    }
    // Numerical leftovers: everything remaining takes prob 1.
    for &i in small.iter().chain(large.iter()) {
        prob[i as usize] = 1.0;
        alias[i as usize] = i;
    }
}

/// A frozen owned alias table over `K` outcomes.
///
/// Retains the (unnormalized) build-time weights: LightLDA's
/// Metropolis–Hastings acceptance ratio needs the *stale* proposal mass
/// `q(k)` that the table was built from.
#[derive(Debug, Clone)]
pub struct AliasTable {
    /// Acceptance probability per slot.
    prob: Vec<f64>,
    /// Alternative outcome per slot.
    alias: Vec<u32>,
    /// Build-time unnormalized weights.
    weights: Vec<f64>,
    /// Sum of build-time weights.
    total: f64,
}

impl AliasTable {
    /// Build from unnormalized non-negative weights (at least one
    /// positive). O(K).
    pub fn new(weights: &[f64]) -> AliasTable {
        let k = weights.len();
        assert!(k > 0, "alias table needs at least one outcome");
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weights must not all be zero");
        let scale = k as f64 / total;

        let mut prob = vec![0.0f64; k];
        let mut alias = vec![0u32; k];
        let mut scaled: Vec<f64> = weights.iter().map(|&w| w * scale).collect();
        let mut small: Vec<u32> = Vec::with_capacity(k);
        let mut large: Vec<u32> = Vec::with_capacity(k);
        vose(k, &mut scaled, &mut prob, &mut alias, &mut small, &mut large);
        AliasTable { prob, alias, weights: weights.to_vec(), total }
    }

    /// Build-time (stale) unnormalized weight of outcome `k`.
    #[inline]
    pub fn weight(&self, k: u32) -> f64 {
        self.weights[k as usize]
    }

    /// Sum of build-time weights.
    pub fn total_weight(&self) -> f64 {
        self.total
    }

    /// Number of outcomes.
    pub fn len(&self) -> usize {
        self.prob.len()
    }

    /// True when the table is over zero outcomes (cannot happen by
    /// construction).
    pub fn is_empty(&self) -> bool {
        self.prob.is_empty()
    }

    /// Draw one outcome. O(1): one uniform slot + one biased coin.
    #[inline]
    pub fn sample(&self, rng: &mut Pcg64) -> u32 {
        let slot = rng.below(self.prob.len());
        if rng.f64() < self.prob[slot] {
            slot as u32
        } else {
            self.alias[slot]
        }
    }
}

impl WordProposal for AliasTable {
    #[inline]
    fn sample(&self, rng: &mut Pcg64) -> u32 {
        AliasTable::sample(self, rng)
    }

    #[inline]
    fn weight(&self, k: u32) -> f64 {
        AliasTable::weight(self, k)
    }

    #[inline]
    fn total_weight(&self) -> f64 {
        AliasTable::total_weight(self)
    }
}

/// How the stale-weight slab was last written, so the next build can
/// clear it in time proportional to what was touched.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
enum StaleDirty {
    /// Already all zeros.
    #[default]
    Clean,
    /// Only the columns in `stale_touched` are nonzero.
    Touched,
    /// A full-row build wrote everywhere.
    Full,
}

/// Reusable construction workspace for per-word proposal tables.
///
/// One builder per sampling thread; every sweep reuses the same
/// buffers, so after warm-up the per-word build performs **zero heap
/// allocations**. Exactly one [`WordAlias`] view is alive at a time
/// (it borrows the builder's buffers); building the next word's table
/// recycles them.
#[derive(Debug, Default)]
pub struct AliasBuilder {
    /// Acceptance probability per slot.
    prob: Vec<f64>,
    /// Alternative slot per slot.
    alias: Vec<u32>,
    /// Scaled-weight scratch consumed by the Vose worklists.
    scaled: Vec<f64>,
    /// Vose worklists.
    small: Vec<u32>,
    large: Vec<u32>,
    /// Hybrid tables: topic id per slot (the row's nonzero topics).
    topics: Vec<u32>,
    /// K-length stale counts behind `weight()`; zero outside the last
    /// build's footprint.
    stale: Vec<f64>,
    /// Columns of `stale` written by the last sparse-footprint build.
    stale_touched: Vec<u32>,
    /// How `stale` was last written.
    dirty: StaleDirty,
}

impl AliasBuilder {
    /// A fresh builder; buffers grow on first use and are reused after.
    pub fn new() -> AliasBuilder {
        AliasBuilder::default()
    }

    /// Zero the stale slab (proportional to the previous footprint) and
    /// make sure every buffer covers `k` outcomes.
    fn reset(&mut self, k: usize) {
        match self.dirty {
            StaleDirty::Clean => {}
            StaleDirty::Touched => {
                for &c in &self.stale_touched {
                    self.stale[c as usize] = 0.0;
                }
            }
            StaleDirty::Full => self.stale.fill(0.0),
        }
        self.stale_touched.clear();
        self.dirty = StaleDirty::Clean;
        if self.stale.len() < k {
            self.stale.resize(k, 0.0);
        }
        if self.prob.len() < k {
            self.prob.resize(k, 0.0);
            self.alias.resize(k, 0);
            self.scaled.resize(k, 0.0);
            self.topics.resize(k, 0);
        }
    }

    /// Build the word proposal from a full dense `K`-length count row:
    /// weights `row[k] + beta`. O(K).
    pub fn build_dense(&mut self, row: &[i64], beta: f64) -> WordAlias<'_> {
        let k = row.len();
        assert!(k > 0, "alias table needs at least one outcome");
        assert!(beta > 0.0, "beta must be positive");
        self.reset(k);
        let mut mass = 0.0f64;
        for (c, &v) in row.iter().enumerate() {
            self.stale[c] = v as f64;
            mass += v as f64;
        }
        self.dirty = StaleDirty::Full;
        let total = mass + beta * k as f64;
        let scale = k as f64 / total;
        for (s, st) in self.scaled[..k].iter_mut().zip(&self.stale[..k]) {
            *s = (st + beta) * scale;
        }
        vose(
            k,
            &mut self.scaled,
            &mut self.prob,
            &mut self.alias,
            &mut self.small,
            &mut self.large,
        );
        WordAlias {
            prob: &self.prob[..k],
            alias: &self.alias[..k],
            topics: None,
            stale: &self.stale[..k],
            beta,
            k: k as u32,
            sparse_mass: total,
            total,
        }
    }

    /// Build the word proposal from a sparse `(topic, count)` pair list
    /// over `k` topics — the LightLDA mixture decomposition. O(nnz)
    /// when the row stays below `dense_threshold` fill; rows at or
    /// above it get the classic dense table (O(k)), which draws faster
    /// once most slots are occupied anyway.
    ///
    /// `dense_threshold` is the nnz/K fill fraction at which to promote
    /// (0.0 = always dense, > 1.0 = never).
    pub fn build_hybrid(
        &mut self,
        pairs: &[(u32, i64)],
        k: u32,
        beta: f64,
        dense_threshold: f64,
    ) -> WordAlias<'_> {
        let kk = k as usize;
        assert!(kk > 0, "alias table needs at least one outcome");
        assert!(beta > 0.0, "beta must be positive");
        self.reset(kk);
        let nnz = pairs.len();
        let mut mass = 0.0f64;
        for &(c, v) in pairs {
            assert!((c as usize) < kk, "pair column {c} out of range for K={k}");
            self.stale[c as usize] = v as f64;
            self.stale_touched.push(c);
            mass += v as f64;
        }
        self.dirty = StaleDirty::Touched;
        let total = mass + beta * kk as f64;

        if nnz as f64 >= dense_threshold * kk as f64 {
            // Hot row: the dense table over all K outcomes (stale is
            // already the scattered row; zeros contribute just β).
            let scale = kk as f64 / total;
            for (s, st) in self.scaled[..kk].iter_mut().zip(&self.stale[..kk]) {
                *s = (st + beta) * scale;
            }
            vose(
                kk,
                &mut self.scaled,
                &mut self.prob,
                &mut self.alias,
                &mut self.small,
                &mut self.large,
            );
            return WordAlias {
                prob: &self.prob[..kk],
                alias: &self.alias[..kk],
                topics: None,
                stale: &self.stale[..kk],
                beta,
                k,
                sparse_mass: total,
                total,
            };
        }

        // Tail row: Vose only over the nonzeros; the β component is the
        // uniform branch of the mixture, never tabled.
        if mass > 0.0 {
            let scale = nnz as f64 / mass;
            for (i, &(c, v)) in pairs.iter().enumerate() {
                self.topics[i] = c;
                self.scaled[i] = v as f64 * scale;
            }
            vose(
                nnz,
                &mut self.scaled,
                &mut self.prob,
                &mut self.alias,
                &mut self.small,
                &mut self.large,
            );
        }
        let tabled = if mass > 0.0 { nnz } else { 0 };
        WordAlias {
            prob: &self.prob[..tabled],
            alias: &self.alias[..tabled],
            topics: Some(&self.topics[..tabled]),
            stale: &self.stale[..kk],
            beta,
            k,
            sparse_mass: mass,
            total,
        }
    }
}

/// A per-word proposal table borrowed from an [`AliasBuilder`] — either
/// the dense Vose table over all `K` outcomes or the hybrid
/// sparse-plus-uniform mixture. Alive only while its word's occurrences
/// are being sampled; the next build reuses the buffers.
#[derive(Debug)]
pub struct WordAlias<'a> {
    prob: &'a [f64],
    alias: &'a [u32],
    /// `Some(topic ids)` for the hybrid table (slot → topic); `None`
    /// when slots are the topics `0..k` themselves.
    topics: Option<&'a [u32]>,
    /// K-length stale counts (zero-default); `weight(k)` adds β.
    stale: &'a [f64],
    beta: f64,
    k: u32,
    /// Mass of the tabled (sparse) component, `S_w`. Equal to `total`
    /// for dense tables (the mixture branch is never taken).
    sparse_mass: f64,
    /// `S_w + βK`.
    total: f64,
}

impl WordAlias<'_> {
    /// True when this table used the sparse mixture construction.
    pub fn is_hybrid(&self) -> bool {
        self.topics.is_some()
    }

    /// Number of tabled slots (nnz for hybrid, K for dense) — the
    /// build-cost proxy the benches report.
    pub fn tabled_slots(&self) -> usize {
        match self.topics {
            Some(t) => t.len(),
            None => self.prob.len(),
        }
    }
}

impl WordProposal for WordAlias<'_> {
    /// O(1): for hybrid tables one mixture coin, then either a Vose
    /// draw over the nonzeros or a uniform topic.
    #[inline]
    fn sample(&self, rng: &mut Pcg64) -> u32 {
        match self.topics {
            None => {
                let slot = rng.below(self.prob.len());
                if rng.f64() < self.prob[slot] {
                    slot as u32
                } else {
                    self.alias[slot]
                }
            }
            Some(topics) => {
                if rng.f64() * self.total < self.sparse_mass {
                    let slot = rng.below(topics.len());
                    let idx = if rng.f64() < self.prob[slot] {
                        slot
                    } else {
                        self.alias[slot] as usize
                    };
                    topics[idx]
                } else {
                    rng.below(self.k as usize) as u32
                }
            }
        }
    }

    /// Exact stale proposal mass `n̂_wk + β`, O(1) for any topic.
    #[inline]
    fn weight(&self, k: u32) -> f64 {
        self.stale[k as usize] + self.beta
    }

    #[inline]
    fn total_weight(&self) -> f64 {
        self.total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall_explain;

    fn empirical(weights: &[f64], draws: usize, seed: u64) -> Vec<f64> {
        let table = AliasTable::new(weights);
        let mut rng = Pcg64::new(seed);
        let mut counts = vec![0usize; weights.len()];
        for _ in 0..draws {
            counts[table.sample(&mut rng) as usize] += 1;
        }
        counts.iter().map(|&c| c as f64 / draws as f64).collect()
    }

    fn empirical_of(table: &impl WordProposal, k: usize, draws: usize, seed: u64) -> Vec<f64> {
        let mut rng = Pcg64::new(seed);
        let mut counts = vec![0usize; k];
        for _ in 0..draws {
            counts[table.sample(&mut rng) as usize] += 1;
        }
        counts.iter().map(|&c| c as f64 / draws as f64).collect()
    }

    #[test]
    fn uniform_weights() {
        let freq = empirical(&[1.0; 10], 100_000, 1);
        for f in freq {
            assert!((f - 0.1).abs() < 0.01, "{f}");
        }
    }

    #[test]
    fn skewed_weights() {
        let w = [8.0, 1.0, 1.0];
        let freq = empirical(&w, 200_000, 2);
        assert!((freq[0] - 0.8).abs() < 0.01);
        assert!((freq[1] - 0.1).abs() < 0.01);
        assert!((freq[2] - 0.1).abs() < 0.01);
    }

    #[test]
    fn zero_weight_never_sampled() {
        let w = [0.0, 1.0, 0.0, 3.0];
        let freq = empirical(&w, 100_000, 3);
        assert_eq!(freq[0], 0.0);
        assert_eq!(freq[2], 0.0);
        assert!((freq[3] - 0.75).abs() < 0.01);
    }

    #[test]
    fn single_outcome() {
        let freq = empirical(&[42.0], 1000, 4);
        assert_eq!(freq[0], 1.0);
    }

    #[test]
    #[should_panic(expected = "zero")]
    fn all_zero_panics() {
        AliasTable::new(&[0.0, 0.0]);
    }

    /// Chi-square goodness of fit against the target distribution for
    /// random weight vectors.
    #[test]
    fn distribution_matches_weights_property() {
        forall_explain(
            "alias matches distribution",
            25,
            |rng| {
                let k = 2 + rng.below(50);
                let w: Vec<f64> = (0..k).map(|_| rng.f64() * 10.0 + 0.01).collect();
                w
            },
            |w| {
                let total: f64 = w.iter().sum();
                let draws = 200_000;
                let freq = empirical(w, draws, 0xabc);
                let mut chi2 = 0.0;
                for (i, &wi) in w.iter().enumerate() {
                    let expect = wi / total;
                    let diff = freq[i] - expect;
                    chi2 += diff * diff / expect;
                }
                let dof = (w.len() - 1) as f64;
                // chi2/n should be near dof/draws; allow a broad margin.
                if chi2 * draws as f64 > dof * 4.0 * draws as f64 / 1000.0 + 30.0 * dof {
                    return Err(format!("chi2 statistic too large: {}", chi2 * draws as f64));
                }
                Ok(())
            },
        );
    }

    /// Random Zipf-ish sparse rows: the hybrid table's empirical draw
    /// frequencies must match the exact masses `(n̂_wk + β) / (S_w + βK)`
    /// — i.e. the identical distribution a dense table over the
    /// densified row would sample — and `weight()`/`total_weight()`
    /// must agree with the dense construction to within float rounding.
    #[test]
    fn hybrid_matches_dense_distribution_property() {
        forall_explain(
            "hybrid matches the n̂+β mixture",
            12,
            |rng| {
                let k = 8 + rng.below(56);
                let nnz = 1 + rng.below(k / 2);
                let mut cols: Vec<u32> = (0..k as u32).collect();
                rng.shuffle(&mut cols);
                let mut pairs: Vec<(u32, i64)> =
                    cols[..nnz].iter().map(|&c| (c, 1 + rng.below(40) as i64)).collect();
                pairs.sort_unstable();
                (k, pairs)
            },
            |(k, pairs)| {
                let beta = 0.05;
                let kk = *k;
                let mut builder = AliasBuilder::new();
                // Force the sparse construction regardless of fill.
                let table = builder.build_hybrid(pairs, kk as u32, beta, 2.0);
                assert!(table.is_hybrid());
                let mut row = vec![0i64; kk];
                for &(c, v) in pairs {
                    row[c as usize] = v;
                }
                let mass: i64 = row.iter().sum();
                let total = mass as f64 + beta * kk as f64;
                // weight() is the exact stale mass for every topic.
                for c in 0..kk {
                    let want = row[c] as f64 + beta;
                    let got = table.weight(c as u32);
                    if (got - want).abs() > 1e-12 * want {
                        return Err(format!("weight({c}) = {got}, want {want}"));
                    }
                }
                if (table.total_weight() - total).abs() > 1e-9 * total {
                    return Err(format!("total_weight {} vs {}", table.total_weight(), total));
                }
                let draws = 200_000;
                let freq = empirical_of(&table, kk, draws, 0xa1d);
                let mut chi2 = 0.0;
                for c in 0..kk {
                    let expect = (row[c] as f64 + beta) / total;
                    let diff = freq[c] - expect;
                    chi2 += diff * diff / expect;
                }
                let dof = (kk - 1) as f64;
                if chi2 * draws as f64 > dof * 4.0 * draws as f64 / 1000.0 + 30.0 * dof {
                    return Err(format!("chi2 statistic too large: {}", chi2 * draws as f64));
                }
                Ok(())
            },
        );
    }

    /// The fill threshold selects the construction: 0.0 forces dense,
    /// anything above 1.0 forces the sparse mixture — and both sample
    /// the same distribution.
    #[test]
    fn dense_promotion_threshold_selects_construction() {
        let pairs: Vec<(u32, i64)> = vec![(1, 5), (3, 2), (7, 9)];
        let beta = 0.1;
        let mut builder = AliasBuilder::new();
        let dense_freq = {
            let t = builder.build_hybrid(&pairs, 8, beta, 0.0);
            assert!(!t.is_hybrid());
            assert_eq!(t.tabled_slots(), 8);
            empirical_of(&t, 8, 200_000, 21)
        };
        let hybrid_freq = {
            let t = builder.build_hybrid(&pairs, 8, beta, 2.0);
            assert!(t.is_hybrid());
            assert_eq!(t.tabled_slots(), 3);
            empirical_of(&t, 8, 200_000, 22)
        };
        let total = 16.0 + beta * 8.0;
        for c in 0..8usize {
            let count = pairs.iter().find(|&&(pc, _)| pc == c as u32).map_or(0, |&(_, v)| v);
            let expect = (count as f64 + beta) / total;
            assert!((dense_freq[c] - expect).abs() < 0.01, "dense topic {c}");
            assert!((hybrid_freq[c] - expect).abs() < 0.01, "hybrid topic {c}");
        }
        // The default promotion point mirrors the shards' 1/2-fill rule:
        // 3/8 fill stays sparse, 5/8 goes dense.
        let t = builder.build_hybrid(&pairs, 8, beta, 0.5);
        assert!(t.is_hybrid());
        let hot: Vec<(u32, i64)> = (0..5).map(|c| (c, 1)).collect();
        let t = builder.build_hybrid(&hot, 8, beta, 0.5);
        assert!(!t.is_hybrid());
    }

    /// Reusing one builder across many rows must not leak state between
    /// builds: rebuilding the same row after unrelated builds (dense and
    /// sparse, wider and narrower) reproduces bit-identical draws and
    /// weights.
    #[test]
    fn builder_reuse_is_deterministic() {
        fn draw(t: &WordAlias<'_>, seed: u64) -> (Vec<u32>, Vec<f64>, f64) {
            let mut rng = Pcg64::new(seed);
            let draws = (0..512).map(|_| t.sample(&mut rng)).collect();
            let weights = (0..16).map(|c| t.weight(c)).collect();
            (draws, weights, t.total_weight())
        }
        let pairs: Vec<(u32, i64)> = vec![(0, 3), (4, 1), (9, 12)];
        let beta = 0.01;
        let mut builder = AliasBuilder::new();
        let before = draw(&builder.build_hybrid(&pairs, 16, beta, 0.5), 77);
        // Interleave unrelated builds that dirty every buffer: a wider
        // dense row, a different sparse row, an all-zero row.
        let wide: Vec<i64> = (0..64).map(|i| (i % 7) as i64).collect();
        let _ = builder.build_dense(&wide, beta);
        let _ = builder.build_hybrid(&[(2, 8), (3, 8)], 16, beta, 2.0);
        let _ = builder.build_hybrid(&[], 16, beta, 0.5);
        let after = draw(&builder.build_hybrid(&pairs, 16, beta, 0.5), 77);
        assert_eq!(before, after);
    }

    /// An all-zero row (possible under staleness only defensively) must
    /// sample uniformly from the β smoothing component.
    #[test]
    fn hybrid_zero_row_samples_uniformly() {
        let mut builder = AliasBuilder::new();
        let t = builder.build_hybrid(&[], 10, 0.5, 0.5);
        assert!(t.is_hybrid());
        assert_eq!(t.tabled_slots(), 0);
        assert_eq!(t.weight(3), 0.5);
        assert!((t.total_weight() - 5.0).abs() < 1e-12);
        let freq = empirical_of(&t, 10, 100_000, 31);
        for f in freq {
            assert!((f - 0.1).abs() < 0.01, "{f}");
        }
    }

    /// The owned table and the builder's dense construction agree on
    /// weights and distribution (they share the Vose core).
    #[test]
    fn owned_and_builder_dense_tables_agree() {
        let row: Vec<i64> = vec![4, 0, 1, 7, 0, 2];
        let beta = 0.2;
        let weights: Vec<f64> = row.iter().map(|&c| c as f64 + beta).collect();
        let owned = AliasTable::new(&weights);
        let mut builder = AliasBuilder::new();
        let built = builder.build_dense(&row, beta);
        for c in 0..row.len() as u32 {
            assert!((owned.weight(c) - WordProposal::weight(&built, c)).abs() < 1e-12);
        }
        let a = empirical_of(&owned, row.len(), 200_000, 41);
        let b = empirical_of(&built, row.len(), 200_000, 42);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 0.01, "{x} vs {y}");
        }
    }
}
