//! Vose alias tables (Vose, 1991) — O(K) construction, O(1) sampling.
//!
//! LightLDA's word proposal `q_w(k) ∝ n_wk + β` must be drawn in O(1) to
//! reach amortized O(1) per-token sampling (paper §3 / [14]). An alias
//! table is built once per word per iteration and reused for all of that
//! word's occurrences in the partition.

use crate::util::rng::Pcg64;

/// A frozen alias table over `K` outcomes.
///
/// Retains the (unnormalized) build-time weights: LightLDA's
/// Metropolis–Hastings acceptance ratio needs the *stale* proposal mass
/// `q(k)` that the table was built from.
#[derive(Debug, Clone)]
pub struct AliasTable {
    /// Acceptance probability per slot.
    prob: Vec<f64>,
    /// Alternative outcome per slot.
    alias: Vec<u32>,
    /// Build-time unnormalized weights.
    weights: Vec<f64>,
    /// Sum of build-time weights.
    total: f64,
}

impl AliasTable {
    /// Build from unnormalized non-negative weights (at least one
    /// positive). O(K).
    pub fn new(weights: &[f64]) -> AliasTable {
        let k = weights.len();
        assert!(k > 0, "alias table needs at least one outcome");
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weights must not all be zero");
        let scale = k as f64 / total;

        let mut prob = vec![0.0f64; k];
        let mut alias = vec![0u32; k];
        // Scaled probabilities; "small" (< 1) and "large" (>= 1) worklists.
        let mut scaled: Vec<f64> = weights.iter().map(|&w| w * scale).collect();
        let mut small: Vec<u32> = Vec::with_capacity(k);
        let mut large: Vec<u32> = Vec::with_capacity(k);
        for (i, &s) in scaled.iter().enumerate() {
            if s < 1.0 {
                small.push(i as u32);
            } else {
                large.push(i as u32);
            }
        }
        while let (Some(&s), Some(&l)) = (small.last(), large.last()) {
            small.pop();
            prob[s as usize] = scaled[s as usize];
            alias[s as usize] = l;
            scaled[l as usize] = (scaled[l as usize] + scaled[s as usize]) - 1.0;
            if scaled[l as usize] < 1.0 {
                large.pop();
                small.push(l);
            }
        }
        // Numerical leftovers: everything remaining takes prob 1.
        for &i in small.iter().chain(large.iter()) {
            prob[i as usize] = 1.0;
            alias[i as usize] = i;
        }
        AliasTable { prob, alias, weights: weights.to_vec(), total }
    }

    /// Build-time (stale) unnormalized weight of outcome `k`.
    #[inline]
    pub fn weight(&self, k: u32) -> f64 {
        self.weights[k as usize]
    }

    /// Sum of build-time weights.
    pub fn total_weight(&self) -> f64 {
        self.total
    }

    /// Number of outcomes.
    pub fn len(&self) -> usize {
        self.prob.len()
    }

    /// True when the table is over zero outcomes (cannot happen by
    /// construction).
    pub fn is_empty(&self) -> bool {
        self.prob.is_empty()
    }

    /// Draw one outcome. O(1): one uniform slot + one biased coin.
    #[inline]
    pub fn sample(&self, rng: &mut Pcg64) -> u32 {
        let slot = rng.below(self.prob.len());
        if rng.f64() < self.prob[slot] {
            slot as u32
        } else {
            self.alias[slot]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall_explain;

    fn empirical(weights: &[f64], draws: usize, seed: u64) -> Vec<f64> {
        let table = AliasTable::new(weights);
        let mut rng = Pcg64::new(seed);
        let mut counts = vec![0usize; weights.len()];
        for _ in 0..draws {
            counts[table.sample(&mut rng) as usize] += 1;
        }
        counts.iter().map(|&c| c as f64 / draws as f64).collect()
    }

    #[test]
    fn uniform_weights() {
        let freq = empirical(&[1.0; 10], 100_000, 1);
        for f in freq {
            assert!((f - 0.1).abs() < 0.01, "{f}");
        }
    }

    #[test]
    fn skewed_weights() {
        let w = [8.0, 1.0, 1.0];
        let freq = empirical(&w, 200_000, 2);
        assert!((freq[0] - 0.8).abs() < 0.01);
        assert!((freq[1] - 0.1).abs() < 0.01);
        assert!((freq[2] - 0.1).abs() < 0.01);
    }

    #[test]
    fn zero_weight_never_sampled() {
        let w = [0.0, 1.0, 0.0, 3.0];
        let freq = empirical(&w, 100_000, 3);
        assert_eq!(freq[0], 0.0);
        assert_eq!(freq[2], 0.0);
        assert!((freq[3] - 0.75).abs() < 0.01);
    }

    #[test]
    fn single_outcome() {
        let freq = empirical(&[42.0], 1000, 4);
        assert_eq!(freq[0], 1.0);
    }

    #[test]
    #[should_panic(expected = "zero")]
    fn all_zero_panics() {
        AliasTable::new(&[0.0, 0.0]);
    }

    /// Chi-square goodness of fit against the target distribution for
    /// random weight vectors.
    #[test]
    fn distribution_matches_weights_property() {
        forall_explain(
            "alias matches distribution",
            25,
            |rng| {
                let k = 2 + rng.below(50);
                let w: Vec<f64> = (0..k).map(|_| rng.f64() * 10.0 + 0.01).collect();
                w
            },
            |w| {
                let total: f64 = w.iter().sum();
                let draws = 200_000;
                let freq = empirical(w, draws, 0xabc);
                let mut chi2 = 0.0;
                for (i, &wi) in w.iter().enumerate() {
                    let expect = wi / total;
                    let diff = freq[i] - expect;
                    chi2 += diff * diff / expect;
                }
                let dof = (w.len() - 1) as f64;
                // chi2/n should be near dof/draws; allow a broad margin.
                if chi2 * draws as f64 > dof * 4.0 * draws as f64 / 1000.0 + 30.0 * dof {
                    return Err(format!("chi2 statistic too large: {}", chi2 * draws as f64));
                }
                Ok(())
            },
        );
    }
}
