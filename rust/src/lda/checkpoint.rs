//! Checkpoint-based fault tolerance (paper §3.5).
//!
//! The parameter servers themselves are not fault tolerant. Instead, the
//! *algorithm* checkpoints the dataset's topic assignments `z` after each
//! iteration to durable storage; on failure the most recent checkpoint is
//! loaded and the count tables are **rebuilt** on (fresh) parameter
//! servers from the assignments, after which training continues.
//!
//! Two granularities share the same binary format:
//!
//! - whole-corpus [`Checkpoint`]s, written by the single-process
//!   [`crate::lda::trainer::Trainer`];
//! - per-partition [`PartitionCheckpoint`]s, written by cluster workers
//!   ([`crate::cluster::worker`]) so a lost partition can be rebuilt on
//!   a replacement worker without touching the other partitions.
//!
//! Loading is corruption-tolerant: a truncated or garbled newest file is
//! skipped (with a warning) and the next-newest valid checkpoint is used
//! instead, so one bad write never makes a whole run unrecoverable.
//! Retention pruning ([`prune_checkpoints`]) keeps long runs from
//! accumulating unbounded snapshots.

use std::path::{Path, PathBuf};

use crate::log_warn;
use crate::util::codec::{Reader, Writer};
use crate::util::error::{Error, Result};

/// A training checkpoint: iteration counter plus per-token topic
/// assignments for every document (parallel to the corpus).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Checkpoint {
    /// Completed iterations.
    pub iteration: u32,
    /// Number of topics the run was configured with.
    pub num_topics: u32,
    /// Per-document topic assignments.
    pub assignments: Vec<Vec<u32>>,
}

const MAGIC: u32 = 0x474c_4b50; // "GLKP"

impl Checkpoint {
    /// Serialize.
    pub fn encode(&self) -> Vec<u8> {
        let total: usize = self.assignments.iter().map(|a| a.len()).sum();
        let mut w = Writer::with_capacity(16 + total * 2);
        w.u32(MAGIC);
        w.u32(self.iteration);
        w.u32(self.num_topics);
        w.usize(self.assignments.len());
        for doc in &self.assignments {
            w.usize(doc.len());
            for &z in doc {
                w.varint(z as u64);
            }
        }
        w.into_bytes()
    }

    /// Deserialize and validate topic bounds.
    pub fn decode(bytes: &[u8]) -> Result<Checkpoint> {
        let mut r = Reader::new(bytes);
        if r.u32()? != MAGIC {
            return Err(Error::Checkpoint("bad magic (not a checkpoint)".into()));
        }
        let iteration = r.u32()?;
        let num_topics = r.u32()?;
        let nd = r.usize()?;
        let mut assignments = Vec::with_capacity(nd);
        for _ in 0..nd {
            let nt = r.usize()?;
            let mut doc = Vec::with_capacity(nt);
            for _ in 0..nt {
                let z = r.varint()? as u32;
                if z >= num_topics {
                    return Err(Error::Checkpoint(format!(
                        "assignment {z} >= num_topics {num_topics}"
                    )));
                }
                doc.push(z);
            }
            assignments.push(doc);
        }
        Ok(Checkpoint { iteration, num_topics, assignments })
    }

    /// Path of the checkpoint file for `iteration` inside `dir`.
    pub fn path_for(dir: &Path, iteration: u32) -> PathBuf {
        dir.join(format!("checkpoint-{iteration:06}.bin"))
    }

    /// Write atomically (write temp + rename) so a crash mid-write never
    /// corrupts the latest checkpoint.
    pub fn save(&self, dir: &Path) -> Result<PathBuf> {
        std::fs::create_dir_all(dir)?;
        let final_path = Self::path_for(dir, self.iteration);
        let tmp = dir.join(format!(".checkpoint-{:06}.tmp", self.iteration));
        std::fs::write(&tmp, self.encode())?;
        std::fs::rename(&tmp, &final_path)?;
        Ok(final_path)
    }

    /// Load a specific checkpoint file.
    pub fn load(path: &Path) -> Result<Checkpoint> {
        let bytes = std::fs::read(path)?;
        Checkpoint::decode(&bytes)
    }

    /// Find and load the latest checkpoint in `dir`, if any.
    ///
    /// Corruption-tolerant: a newest file that fails to read or decode
    /// (truncated write, bad disk) is skipped with a warning and the
    /// next-newest valid checkpoint is returned instead. `Ok(None)` only
    /// when no candidate file decodes.
    pub fn load_latest(dir: &Path) -> Result<Option<Checkpoint>> {
        let mut found = list_checkpoints(dir, "checkpoint-")?;
        // Newest first: fall back down the list past corrupt files.
        found.sort_by(|a, b| b.0.cmp(&a.0));
        for (iter, path) in found {
            match Checkpoint::load(&path) {
                Ok(ckpt) => return Ok(Some(ckpt)),
                Err(e) => {
                    log_warn!(
                        "checkpoint {path:?} (iteration {iter}) is unreadable ({e}); \
                         falling back to the next-newest"
                    );
                }
            }
        }
        Ok(None)
    }

    /// Delete all but the newest `keep` whole-corpus checkpoints in
    /// `dir`. `keep == 0` disables pruning.
    pub fn prune(dir: &Path, keep: usize) -> Result<()> {
        prune_checkpoints(dir, "checkpoint-", keep)
    }
}

/// Enumerate `{prefix}{number}.bin` files in `dir` as `(number, path)`
/// pairs, in no particular order. Missing dir is an empty list.
fn list_checkpoints(dir: &Path, prefix: &str) -> Result<Vec<(u32, PathBuf)>> {
    if !dir.exists() {
        return Ok(Vec::new());
    }
    let mut found = Vec::new();
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if let Some(num) = name
            .strip_prefix(prefix)
            .and_then(|s| s.strip_suffix(".bin"))
            .and_then(|s| s.parse::<u32>().ok())
        {
            found.push((num, entry.path()));
        }
    }
    Ok(found)
}

/// Retention pruning shared by both granularities: keep the newest
/// `keep` files matching `{prefix}{number}.bin`, delete the rest.
/// Best-effort per file (a checkpoint that cannot be deleted is only
/// warned about); `keep == 0` disables pruning.
pub fn prune_checkpoints(dir: &Path, prefix: &str, keep: usize) -> Result<()> {
    if keep == 0 {
        return Ok(());
    }
    let mut found = list_checkpoints(dir, prefix)?;
    if found.len() <= keep {
        return Ok(());
    }
    found.sort_by(|a, b| b.0.cmp(&a.0));
    for (_, path) in found.into_iter().skip(keep) {
        if let Err(e) = std::fs::remove_file(&path) {
            log_warn!("could not prune checkpoint {path:?}: {e}");
        }
    }
    Ok(())
}

/// One corpus partition's checkpoint, written by a cluster worker: the
/// partition id and its absolute document range pin which slice of the
/// corpus the assignments belong to, so a replacement worker can verify
/// it is rebuilding the right slice (paper §3.5, per-partition form).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PartitionCheckpoint {
    /// Partition index within the cluster run.
    pub partition: u32,
    /// First document (absolute corpus index) of the partition.
    pub doc_start: u64,
    /// Assignments and iteration counter for this partition's docs.
    pub inner: Checkpoint,
}

const PART_MAGIC: u32 = 0x474c_5050; // "GLPP"

impl PartitionCheckpoint {
    /// Serialize.
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        w.u32(PART_MAGIC);
        w.u32(self.partition);
        w.u64(self.doc_start);
        w.bytes(&self.inner.encode());
        w.into_bytes()
    }

    /// Deserialize and validate.
    pub fn decode(bytes: &[u8]) -> Result<PartitionCheckpoint> {
        let mut r = Reader::new(bytes);
        if r.u32()? != PART_MAGIC {
            return Err(Error::Checkpoint("bad magic (not a partition checkpoint)".into()));
        }
        let partition = r.u32()?;
        let doc_start = r.u64()?;
        let inner = Checkpoint::decode(&r.bytes()?)?;
        Ok(PartitionCheckpoint { partition, doc_start, inner })
    }

    /// File-name prefix for partition `p` (the iteration number and
    /// `.bin` suffix follow).
    pub fn prefix(partition: u32) -> String {
        format!("part-{partition:04}-")
    }

    /// Path of partition `p`'s checkpoint file for `iteration`.
    pub fn path_for(dir: &Path, partition: u32, iteration: u32) -> PathBuf {
        dir.join(format!("{}{iteration:06}.bin", Self::prefix(partition)))
    }

    /// Write atomically (temp + rename), then prune this partition's
    /// files down to the newest `keep` (0 disables pruning).
    pub fn save(&self, dir: &Path, keep: usize) -> Result<PathBuf> {
        std::fs::create_dir_all(dir)?;
        let final_path = Self::path_for(dir, self.partition, self.inner.iteration);
        let tmp = dir.join(format!(
            ".part-{:04}-{:06}.tmp",
            self.partition, self.inner.iteration
        ));
        std::fs::write(&tmp, self.encode())?;
        std::fs::rename(&tmp, &final_path)?;
        prune_checkpoints(dir, &Self::prefix(self.partition), keep)?;
        Ok(final_path)
    }

    /// Load a specific partition checkpoint file.
    pub fn load(path: &Path) -> Result<PartitionCheckpoint> {
        let bytes = std::fs::read(path)?;
        PartitionCheckpoint::decode(&bytes)
    }

    /// Latest valid checkpoint for `partition` in `dir`, skipping
    /// corrupt files like [`Checkpoint::load_latest`].
    pub fn load_latest(dir: &Path, partition: u32) -> Result<Option<PartitionCheckpoint>> {
        let mut found = list_checkpoints(dir, &Self::prefix(partition))?;
        found.sort_by(|a, b| b.0.cmp(&a.0));
        for (iter, path) in found {
            match PartitionCheckpoint::load(&path) {
                Ok(ckpt) if ckpt.partition == partition => return Ok(Some(ckpt)),
                Ok(ckpt) => {
                    log_warn!(
                        "checkpoint {path:?} claims partition {} (expected {partition}); \
                         skipping",
                        ckpt.partition
                    );
                }
                Err(e) => {
                    log_warn!(
                        "partition checkpoint {path:?} (iteration {iter}) is unreadable \
                         ({e}); falling back to the next-newest"
                    );
                }
            }
        }
        Ok(None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            iteration: 7,
            num_topics: 10,
            assignments: vec![vec![0, 9, 3], vec![], vec![5, 5]],
        }
    }

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("glint_ckpt_{name}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn roundtrip() {
        let c = sample();
        assert_eq!(Checkpoint::decode(&c.encode()).unwrap(), c);
    }

    #[test]
    fn rejects_out_of_range_topics() {
        let mut c = sample();
        c.assignments[0][0] = 10; // == num_topics, invalid
        assert!(Checkpoint::decode(&c.encode()).is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Checkpoint::decode(&[0, 1, 2, 3]).is_err());
    }

    #[test]
    fn save_and_load_latest() {
        let dir = tmpdir("latest");
        let mut c = sample();
        c.iteration = 1;
        c.save(&dir).unwrap();
        c.iteration = 3;
        c.save(&dir).unwrap();
        c.iteration = 2;
        c.save(&dir).unwrap();
        let latest = Checkpoint::load_latest(&dir).unwrap().unwrap();
        assert_eq!(latest.iteration, 3);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn load_latest_empty_dir() {
        let dir = tmpdir("empty");
        assert!(Checkpoint::load_latest(&dir).unwrap().is_none());
        std::fs::create_dir_all(&dir).unwrap();
        assert!(Checkpoint::load_latest(&dir).unwrap().is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_newest_falls_back_to_previous() {
        let dir = tmpdir("corrupt");
        let mut c = sample();
        c.iteration = 1;
        c.save(&dir).unwrap();
        c.iteration = 2;
        c.save(&dir).unwrap();
        // Truncate the newest file mid-payload: recovery must fall back
        // to iteration 1, not fail outright.
        let newest = Checkpoint::path_for(&dir, 2);
        let bytes = std::fs::read(&newest).unwrap();
        std::fs::write(&newest, &bytes[..bytes.len() / 2]).unwrap();
        let latest = Checkpoint::load_latest(&dir).unwrap().unwrap();
        assert_eq!(latest.iteration, 1);
        // Garbage-only dir still reports "nothing usable".
        std::fs::write(Checkpoint::path_for(&dir, 1), b"junk").unwrap();
        std::fs::remove_file(&newest).unwrap();
        assert!(Checkpoint::load_latest(&dir).unwrap().is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn retention_prunes_oldest() {
        let dir = tmpdir("prune");
        let mut c = sample();
        for i in 1..=5 {
            c.iteration = i;
            c.save(&dir).unwrap();
        }
        Checkpoint::prune(&dir, 3).unwrap();
        assert!(!Checkpoint::path_for(&dir, 1).exists());
        assert!(!Checkpoint::path_for(&dir, 2).exists());
        for i in 3..=5 {
            assert!(Checkpoint::path_for(&dir, i).exists(), "iteration {i} kept");
        }
        // keep = 0 disables pruning.
        Checkpoint::prune(&dir, 0).unwrap();
        assert!(Checkpoint::path_for(&dir, 3).exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn partition_checkpoint_roundtrip_and_isolation() {
        let dir = tmpdir("part");
        let a = PartitionCheckpoint {
            partition: 0,
            doc_start: 0,
            inner: Checkpoint {
                iteration: 3,
                num_topics: 10,
                assignments: vec![vec![1, 2], vec![0]],
            },
        };
        let b = PartitionCheckpoint {
            partition: 1,
            doc_start: 2,
            inner: Checkpoint { iteration: 4, num_topics: 10, assignments: vec![vec![9]] },
        };
        assert_eq!(PartitionCheckpoint::decode(&a.encode()).unwrap(), a);
        a.save(&dir, 0).unwrap();
        b.save(&dir, 0).unwrap();
        // Each partition only sees its own files.
        let la = PartitionCheckpoint::load_latest(&dir, 0).unwrap().unwrap();
        let lb = PartitionCheckpoint::load_latest(&dir, 1).unwrap().unwrap();
        assert_eq!(la, a);
        assert_eq!(lb, b);
        assert!(PartitionCheckpoint::load_latest(&dir, 7).unwrap().is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn partition_save_applies_retention() {
        let dir = tmpdir("part_keep");
        let mut p = PartitionCheckpoint {
            partition: 2,
            doc_start: 5,
            inner: Checkpoint { iteration: 0, num_topics: 4, assignments: vec![vec![0]] },
        };
        for i in 1..=6 {
            p.inner.iteration = i;
            p.save(&dir, 3).unwrap();
        }
        for i in 1..=3 {
            assert!(!PartitionCheckpoint::path_for(&dir, 2, i).exists());
        }
        for i in 4..=6 {
            assert!(PartitionCheckpoint::path_for(&dir, 2, i).exists());
        }
        // A corrupt newest partition file falls back too.
        let newest = PartitionCheckpoint::path_for(&dir, 2, 6);
        std::fs::write(&newest, b"bad").unwrap();
        let latest = PartitionCheckpoint::load_latest(&dir, 2).unwrap().unwrap();
        assert_eq!(latest.inner.iteration, 5);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
