//! Checkpoint-based fault tolerance (paper §3.5).
//!
//! The parameter servers themselves are not fault tolerant. Instead, the
//! *algorithm* checkpoints the dataset's topic assignments `z` after each
//! iteration to durable storage; on failure the most recent checkpoint is
//! loaded and the count tables are **rebuilt** on (fresh) parameter
//! servers from the assignments, after which training continues.

use std::path::{Path, PathBuf};

use crate::util::codec::{Reader, Writer};
use crate::util::error::{Error, Result};

/// A training checkpoint: iteration counter plus per-token topic
/// assignments for every document (parallel to the corpus).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Checkpoint {
    /// Completed iterations.
    pub iteration: u32,
    /// Number of topics the run was configured with.
    pub num_topics: u32,
    /// Per-document topic assignments.
    pub assignments: Vec<Vec<u32>>,
}

const MAGIC: u32 = 0x474c_4b50; // "GLKP"

impl Checkpoint {
    /// Serialize.
    pub fn encode(&self) -> Vec<u8> {
        let total: usize = self.assignments.iter().map(|a| a.len()).sum();
        let mut w = Writer::with_capacity(16 + total * 2);
        w.u32(MAGIC);
        w.u32(self.iteration);
        w.u32(self.num_topics);
        w.usize(self.assignments.len());
        for doc in &self.assignments {
            w.usize(doc.len());
            for &z in doc {
                w.varint(z as u64);
            }
        }
        w.into_bytes()
    }

    /// Deserialize and validate topic bounds.
    pub fn decode(bytes: &[u8]) -> Result<Checkpoint> {
        let mut r = Reader::new(bytes);
        if r.u32()? != MAGIC {
            return Err(Error::Checkpoint("bad magic (not a checkpoint)".into()));
        }
        let iteration = r.u32()?;
        let num_topics = r.u32()?;
        let nd = r.usize()?;
        let mut assignments = Vec::with_capacity(nd);
        for _ in 0..nd {
            let nt = r.usize()?;
            let mut doc = Vec::with_capacity(nt);
            for _ in 0..nt {
                let z = r.varint()? as u32;
                if z >= num_topics {
                    return Err(Error::Checkpoint(format!(
                        "assignment {z} >= num_topics {num_topics}"
                    )));
                }
                doc.push(z);
            }
            assignments.push(doc);
        }
        Ok(Checkpoint { iteration, num_topics, assignments })
    }

    /// Path of the checkpoint file for `iteration` inside `dir`.
    pub fn path_for(dir: &Path, iteration: u32) -> PathBuf {
        dir.join(format!("checkpoint-{iteration:06}.bin"))
    }

    /// Write atomically (write temp + rename) so a crash mid-write never
    /// corrupts the latest checkpoint.
    pub fn save(&self, dir: &Path) -> Result<PathBuf> {
        std::fs::create_dir_all(dir)?;
        let final_path = Self::path_for(dir, self.iteration);
        let tmp = dir.join(format!(".checkpoint-{:06}.tmp", self.iteration));
        std::fs::write(&tmp, self.encode())?;
        std::fs::rename(&tmp, &final_path)?;
        Ok(final_path)
    }

    /// Load a specific checkpoint file.
    pub fn load(path: &Path) -> Result<Checkpoint> {
        let bytes = std::fs::read(path)?;
        Checkpoint::decode(&bytes)
    }

    /// Find and load the latest checkpoint in `dir`, if any.
    pub fn load_latest(dir: &Path) -> Result<Option<Checkpoint>> {
        if !dir.exists() {
            return Ok(None);
        }
        let mut best: Option<(u32, PathBuf)> = None;
        for entry in std::fs::read_dir(dir)? {
            let entry = entry?;
            let name = entry.file_name();
            let name = name.to_string_lossy();
            if let Some(num) = name
                .strip_prefix("checkpoint-")
                .and_then(|s| s.strip_suffix(".bin"))
                .and_then(|s| s.parse::<u32>().ok())
            {
                if best.as_ref().map(|(b, _)| num > *b).unwrap_or(true) {
                    best = Some((num, entry.path()));
                }
            }
        }
        match best {
            Some((_, path)) => Ok(Some(Checkpoint::load(&path)?)),
            None => Ok(None),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            iteration: 7,
            num_topics: 10,
            assignments: vec![vec![0, 9, 3], vec![], vec![5, 5]],
        }
    }

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("glint_ckpt_{name}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn roundtrip() {
        let c = sample();
        assert_eq!(Checkpoint::decode(&c.encode()).unwrap(), c);
    }

    #[test]
    fn rejects_out_of_range_topics() {
        let mut c = sample();
        c.assignments[0][0] = 10; // == num_topics, invalid
        assert!(Checkpoint::decode(&c.encode()).is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Checkpoint::decode(&[0, 1, 2, 3]).is_err());
    }

    #[test]
    fn save_and_load_latest() {
        let dir = tmpdir("latest");
        let mut c = sample();
        c.iteration = 1;
        c.save(&dir).unwrap();
        c.iteration = 3;
        c.save(&dir).unwrap();
        c.iteration = 2;
        c.save(&dir).unwrap();
        let latest = Checkpoint::load_latest(&dir).unwrap().unwrap();
        assert_eq!(latest.iteration, 3);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn load_latest_empty_dir() {
        let dir = tmpdir("empty");
        assert!(Checkpoint::load_latest(&dir).unwrap().is_none());
        std::fs::create_dir_all(&dir).unwrap();
        assert!(Checkpoint::load_latest(&dir).unwrap().is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
