//! Fold-in inference for **unseen** documents against a frozen model —
//! the kernel of the `serve-model` tier.
//!
//! A serving replica attaches read-only to the live shards' word-topic
//! table and answers topic-inference requests by *folding in* each
//! document: a few fixed-budget sweeps of the LightLDA
//! Metropolis–Hastings kernel with the model tables frozen. The training
//! kernel ([`crate::lda::lightlda::resample_token`]) excludes the token
//! under resampling from *all* counts, because the training state
//! includes it; here the frozen `n̂_wk` / `n̂_k` never contained the
//! unseen document at all, so only the document-topic factor is
//! excluded on the fly — a different acceptance ratio, hence a separate
//! kernel.
//!
//! The word proposal reuses the Vose [`AliasTable`] machinery: weights
//! `n̂_wk + β` are exactly the frozen word factor of the target density,
//! so the word-row terms cancel out of the acceptance ratio. Tables are
//! built once per word from a single batched sparse pull
//! ([`InferEngine::infer_batch`] coalesces all of a batch's unique
//! words into one pull) and cached in a bounded LRU; fold-in *results*
//! are cached in a second LRU keyed by a hash of the token stream.

use std::collections::{BTreeSet, HashMap};
use std::sync::Arc;

use crate::lda::alias::{AliasTable, WordProposal};
use crate::lda::hyper::LdaHyper;
use crate::lda::sparse_counts::DocTopicCounts;
use crate::ps::client::{BigMatrix, PsClient, SparseRow};
use crate::ps::messages::Layout;
use crate::util::error::{Error, Result};
use crate::util::lru::LruCache;
use crate::util::rng::Pcg64;

/// Fixed sampling budget of one fold-in request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FoldInBudget {
    /// Full passes over the document.
    pub sweeps: u32,
    /// Metropolis–Hastings proposal cycles per token per pass.
    pub mh_steps: u32,
}

impl Default for FoldInBudget {
    fn default() -> FoldInBudget {
        FoldInBudget { sweeps: 5, mh_steps: 2 }
    }
}

/// Serving-engine knobs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct InferConfig {
    /// Sampling budget per document.
    pub budget: FoldInBudget,
    /// Fold-in results cached, keyed by [`doc_hash`].
    pub cache_docs: usize,
    /// Word alias tables cached (each is O(K) memory).
    pub cache_words: usize,
    /// Seed of the engine's sampling stream.
    pub seed: u64,
}

impl Default for InferConfig {
    fn default() -> InferConfig {
        InferConfig {
            budget: FoldInBudget::default(),
            cache_docs: 4096,
            cache_words: 100_000,
            seed: 0x5e21,
        }
    }
}

/// Cumulative engine counters (exposed to the serving stats endpoint
/// and the coalescing/cache tests).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct EngineStats {
    /// Documents answered (cached or folded in).
    pub docs: u64,
    /// Documents answered straight from the result cache.
    pub cache_hits: u64,
    /// Word rows fetched from the shards.
    pub words_pulled: u64,
    /// Batched sparse pulls issued (one per batch with any misses).
    pub sparse_pulls: u64,
    /// Batches processed.
    pub batches: u64,
}

/// FNV-1a over the token stream: the fold-in result cache key. Order
/// sensitive on purpose — the sampler is, too.
pub fn doc_hash(tokens: &[u32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &t in tokens {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    }
    h
}

/// Collapsed posterior mass (up to a constant) of topic `k` for the
/// token under resampling, against the frozen model: the document
/// factor excludes the token itself, the model factors exclude nothing
/// (the unseen document was never in them). `alias.weight(k)` is the
/// frozen `n̂_wk + β`.
#[inline]
fn frozen_mass<P: WordProposal>(
    alias: &P,
    counts: &DocTopicCounts,
    inv_nk: &[f64],
    alpha: f64,
    k: u32,
    z_old: u32,
) -> f64 {
    let excl = f64::from(k == z_old);
    (counts.get(k) as f64 - excl + alpha) * alias.weight(k) * inv_nk[k as usize]
}

/// Resample one token of a fold-in document: `mh_steps` cycles of the
/// word proposal (frozen alias table) and the O(1) doc proposal, each
/// corrected by its exact acceptance probability.
#[allow(clippy::too_many_arguments)]
fn infer_token<P: WordProposal>(
    z_old: u32,
    alias: &P,
    counts: &DocTopicCounts,
    assignments: &[u32],
    inv_nk: &[f64],
    k_topics: u32,
    hyper: LdaHyper,
    mh_steps: u32,
    rng: &mut Pcg64,
) -> u32 {
    let mut z = z_old;
    let mut p_z = frozen_mass(alias, counts, inv_nk, hyper.alpha, z, z_old);
    for _ in 0..mh_steps {
        // Word proposal `q_w(k) = n̂_wk + β`: the proposal mass equals the
        // frozen word factor of the target, so the acceptance reduces to
        // the document and topic-total factors.
        let t = alias.sample(rng);
        if t != z {
            let p_t = frozen_mass(alias, counts, inv_nk, hyper.alpha, t, z_old);
            let accept = p_t * alias.weight(z) / (p_z * alias.weight(t));
            if accept >= 1.0 || rng.f64() < accept {
                z = t;
                p_z = p_t;
            }
        }
        // Doc proposal `q_d(k) ∝ n_dk + α` (inclusive counts — the
        // assignments array still carries z_old), drawn in O(1) from the
        // document's own assignments plus the α-uniform branch.
        let len = assignments.len() as f64;
        let alpha_mass = hyper.alpha * k_topics as f64;
        let t = if rng.f64() * (len + alpha_mass) < len {
            assignments[rng.below(assignments.len())]
        } else {
            rng.below(k_topics as usize) as u32
        };
        if t != z {
            let p_t = frozen_mass(alias, counts, inv_nk, hyper.alpha, t, z_old);
            let accept = p_t * (counts.get(z) as f64 + hyper.alpha)
                / (p_z * (counts.get(t) as f64 + hyper.alpha));
            if accept >= 1.0 || rng.f64() < accept {
                z = t;
                p_z = p_t;
            }
        }
    }
    z
}

/// Fold in one unseen document with a fixed budget of MH sweeps over
/// frozen per-word alias tables, returning its topic counts. `tables`
/// must hold a table for every distinct token; `inv_nk[k]` is
/// `1 / (n̂_k + Vβ)`.
pub fn fold_in_frozen(
    tokens: &[u32],
    tables: &HashMap<u32, Arc<AliasTable>>,
    inv_nk: &[f64],
    k_topics: u32,
    hyper: LdaHyper,
    budget: &FoldInBudget,
    rng: &mut Pcg64,
) -> DocTopicCounts {
    let mut z: Vec<u32> =
        tokens.iter().map(|_| rng.below(k_topics as usize) as u32).collect();
    let mut counts = DocTopicCounts::from_assignments(&z);
    for _ in 0..budget.sweeps {
        for (pos, &w) in tokens.iter().enumerate() {
            let alias = &tables[&w];
            let z_old = z[pos];
            let z_new = infer_token(
                z_old,
                alias.as_ref(),
                &counts,
                &z,
                inv_nk,
                k_topics,
                hyper,
                budget.mh_steps,
                rng,
            );
            if z_new != z_old {
                counts.decrement(z_old);
                counts.increment(z_new);
                z[pos] = z_new;
            }
        }
    }
    counts
}

/// The serve-model inference engine: a read-mostly view of the live
/// shards' word-topic table plus the frozen topic-total snapshot, the
/// two LRU caches, and the per-replica sampling stream.
pub struct InferEngine {
    n_wk: BigMatrix<i64>,
    /// `1 / (n̂_k + Vβ)` from the attach-time column-sum snapshot.
    inv_nk: Vec<f64>,
    k: u32,
    v: u32,
    hyper: LdaHyper,
    cfg: InferConfig,
    /// Fold-in results keyed by [`doc_hash`].
    docs: LruCache<u64, Vec<(u32, u32)>>,
    /// Frozen per-word proposal tables.
    words: LruCache<u32, Arc<AliasTable>>,
    rng: Pcg64,
    docs_answered: u64,
    words_pulled: u64,
    sparse_pulls: u64,
    batches: u64,
}

impl InferEngine {
    /// Attach to a frozen model on live shards: reach the count table by
    /// its externally agreed id (the freeze/attach handshake — see
    /// [`crate::lda::trainer::Trainer::matrix_id`]), snapshot the topic
    /// totals server-side, and refuse a table with no mass (an id typo
    /// would otherwise create a fresh empty matrix and silently serve
    /// uniform topics).
    pub fn attach(
        client: &PsClient,
        matrix_id: u32,
        vocab_size: u32,
        num_topics: u32,
        layout: Layout,
        hyper: LdaHyper,
        cfg: InferConfig,
    ) -> Result<InferEngine> {
        hyper.validate()?;
        if cfg.budget.sweeps == 0 || cfg.budget.mh_steps == 0 {
            return Err(Error::Config("fold-in budget must be positive".into()));
        }
        let n_wk: BigMatrix<i64> =
            client.attach_matrix(matrix_id, vocab_size as u64, num_topics, layout)?;
        let n_k = n_wk.pull_col_sums()?;
        if n_k.iter().sum::<i64>() <= 0 {
            return Err(Error::Config(format!(
                "matrix {matrix_id} holds no counts; serve-model needs a trained, frozen model"
            )));
        }
        let vbeta = vocab_size as f64 * hyper.beta;
        let inv_nk = n_k.iter().map(|&n| 1.0 / (n as f64 + vbeta)).collect();
        Ok(InferEngine {
            n_wk,
            inv_nk,
            k: num_topics,
            v: vocab_size,
            hyper,
            cfg,
            docs: LruCache::new(cfg.cache_docs),
            words: LruCache::new(cfg.cache_words),
            rng: Pcg64::new(cfg.seed),
            docs_answered: 0,
            words_pulled: 0,
            sparse_pulls: 0,
            batches: 0,
        })
    }

    /// Vocabulary size of the attached model.
    pub fn vocab_size(&self) -> u32 {
        self.v
    }

    /// Topic count of the attached model.
    pub fn num_topics(&self) -> u32 {
        self.k
    }

    /// Cumulative counters.
    pub fn stats(&self) -> EngineStats {
        EngineStats {
            docs: self.docs_answered,
            cache_hits: self.docs.hits(),
            words_pulled: self.words_pulled,
            sparse_pulls: self.sparse_pulls,
            batches: self.batches,
        }
    }

    /// Infer topic counts for one document.
    pub fn infer_one(&mut self, tokens: &[u32]) -> Result<Vec<(u32, u32)>> {
        Ok(self.infer_batch(&[tokens])?.pop().expect("one result per doc"))
    }

    /// Infer topic counts for a batch of documents, coalescing the model
    /// reads: across the whole batch, every distinct uncached word is
    /// fetched exactly once, in a single sparse pull. Returns one
    /// `(topic, count)` list per document, topics ascending, counts
    /// summing to the document length.
    pub fn infer_batch(&mut self, docs: &[&[u32]]) -> Result<Vec<Vec<(u32, u32)>>> {
        self.batches += 1;
        self.docs_answered += docs.len() as u64;
        let hashes: Vec<u64> = docs.iter().map(|d| doc_hash(d)).collect();
        let mut out: Vec<Option<Vec<(u32, u32)>>> =
            hashes.iter().map(|h| self.docs.get(h).cloned()).collect();

        // Collect the batch's proposal tables: resident ones are pinned
        // (Arc) immediately so later cache churn cannot drop them, and
        // the missing words form the one coalesced pull.
        let mut tables: HashMap<u32, Arc<AliasTable>> = HashMap::new();
        let mut need: BTreeSet<u32> = BTreeSet::new();
        for (i, doc) in docs.iter().enumerate() {
            if out[i].is_some() {
                continue;
            }
            for &w in doc.iter() {
                if w >= self.v {
                    return Err(Error::Config(format!(
                        "token id {w} out of vocabulary (V = {})",
                        self.v
                    )));
                }
                if tables.contains_key(&w) || need.contains(&w) {
                    continue;
                }
                match self.words.get(&w) {
                    Some(t) => {
                        tables.insert(w, Arc::clone(t));
                    }
                    None => {
                        need.insert(w);
                    }
                }
            }
        }
        if !need.is_empty() {
            let rows: Vec<u64> = need.iter().map(|&w| w as u64).collect();
            let pulled = self.n_wk.pull_sparse_rows(&rows)?;
            self.sparse_pulls += 1;
            self.words_pulled += rows.len() as u64;
            for (&w, pairs) in need.iter().zip(&pulled) {
                let table = Arc::new(self.build_table(pairs));
                tables.insert(w, Arc::clone(&table));
                self.words.insert(w, table);
            }
        }

        for (i, doc) in docs.iter().enumerate() {
            if out[i].is_some() {
                continue;
            }
            let counts = fold_in_frozen(
                doc,
                &tables,
                &self.inv_nk,
                self.k,
                self.hyper,
                &self.cfg.budget,
                &mut self.rng,
            );
            let pairs: Vec<(u32, u32)> = counts.iter().collect();
            self.docs.insert(hashes[i], pairs.clone());
            out[i] = Some(pairs);
        }
        Ok(out.into_iter().map(|o| o.expect("every doc answered")).collect())
    }

    /// Frozen word-proposal table from a pulled sparse row: weights
    /// `n̂_wk + β` (all positive for β > 0, so the Vose construction
    /// never sees an all-zero weight vector).
    fn build_table(&self, pairs: &SparseRow<i64>) -> AliasTable {
        let mut weights = vec![self.hyper.beta; self.k as usize];
        for &(c, v) in pairs {
            weights[c as usize] += v as f64;
        }
        AliasTable::new(&weights)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn doc_hash_is_deterministic_and_order_sensitive() {
        let a = doc_hash(&[1, 2, 3]);
        assert_eq!(a, doc_hash(&[1, 2, 3]));
        assert_ne!(a, doc_hash(&[3, 2, 1]));
        assert_ne!(a, doc_hash(&[1, 2]));
        assert_ne!(doc_hash(&[]), doc_hash(&[0]));
    }

    /// Build frozen tables for a sharply peaked model: word `w` belongs
    /// to topic `w % k` with mass `peak`.
    fn peaked_tables(
        v: u32,
        k: u32,
        peak: i64,
        beta: f64,
    ) -> (HashMap<u32, Arc<AliasTable>>, Vec<f64>) {
        let mut tables = HashMap::new();
        let mut n_k = vec![0i64; k as usize];
        for w in 0..v {
            let mut weights = vec![beta; k as usize];
            weights[(w % k) as usize] += peak as f64;
            n_k[(w % k) as usize] += peak;
            tables.insert(w, Arc::new(AliasTable::new(&weights)));
        }
        let vbeta = v as f64 * beta;
        let inv_nk = n_k.iter().map(|&n| 1.0 / (n as f64 + vbeta)).collect();
        (tables, inv_nk)
    }

    #[test]
    fn fold_in_concentrates_on_the_generating_topic() {
        let (k, v) = (4u32, 40u32);
        let hyper = LdaHyper { alpha: 0.1, beta: 0.01 };
        let (tables, inv_nk) = peaked_tables(v, k, 500, hyper.beta);
        let mut rng = Pcg64::new(42);
        // A document entirely of words from topic 2.
        let tokens: Vec<u32> = (0..30).map(|i| 2 + (i % 10) * k).collect();
        let budget = FoldInBudget { sweeps: 10, mh_steps: 2 };
        let counts =
            fold_in_frozen(&tokens, &tables, &inv_nk, k, hyper, &budget, &mut rng);
        assert_eq!(counts.total(), tokens.len() as u64);
        assert!(
            counts.get(2) as usize > tokens.len() * 8 / 10,
            "topic 2 should dominate: {:?}",
            counts.iter().collect::<Vec<_>>()
        );
    }

    #[test]
    fn fold_in_preserves_token_count_and_topic_range() {
        let (k, v) = (8u32, 100u32);
        let hyper = LdaHyper::default_for(k as usize);
        let (tables, inv_nk) = peaked_tables(v, k, 50, hyper.beta);
        let mut rng = Pcg64::new(7);
        for len in [1usize, 2, 17, 64] {
            let tokens: Vec<u32> = (0..len).map(|i| (i as u32 * 13) % v).collect();
            let counts = fold_in_frozen(
                &tokens,
                &tables,
                &inv_nk,
                k,
                hyper,
                &FoldInBudget::default(),
                &mut rng,
            );
            assert_eq!(counts.total(), len as u64);
            assert!(counts.iter().all(|(t, c)| t < k && c > 0));
        }
    }

    #[test]
    fn fold_in_matches_exact_gibbs_fold_in() {
        // Same frozen model, same scoring: the MH fold-in's theta must
        // land near the exact-Gibbs fold-in's
        // ([`crate::eval::perplexity::fold_in`]) on a mixed document.
        let (k, v) = (4u32, 60u32);
        let hyper = LdaHyper { alpha: 0.5, beta: 0.01 };
        let peak = 200i64;
        let (tables, inv_nk) = peaked_tables(v, k, peak, hyper.beta);
        // The equivalent dense model for the exact reference.
        let mut n_wk = vec![0i64; (v * k) as usize];
        let mut n_k = vec![0i64; k as usize];
        for w in 0..v {
            n_wk[(w * k + w % k) as usize] = peak;
            n_k[(w % k) as usize] += peak;
        }
        let model = crate::eval::perplexity::TopicModel { k, v, n_wk, n_k, hyper };
        // 2/3 topic-1 words, 1/3 topic-3 words.
        let tokens: Vec<u32> = (0..60u32)
            .map(|i| if i % 3 == 2 { 3 + (i % 5) * k } else { 1 + (i % 7) * k })
            .collect();
        let mut rng = Pcg64::new(11);
        let budget = FoldInBudget { sweeps: 20, mh_steps: 4 };
        let mh = fold_in_frozen(&tokens, &tables, &inv_nk, k, hyper, &budget, &mut rng);
        let mut rng2 = Pcg64::new(12);
        let exact = crate::eval::perplexity::fold_in(&model, &tokens, 20, &mut rng2);
        for topic in 0..k {
            let a = mh.get(topic) as f64 / tokens.len() as f64;
            let b = exact.get(topic) as f64 / tokens.len() as f64;
            assert!(
                (a - b).abs() < 0.15,
                "topic {topic}: mh theta {a:.3} vs exact {b:.3}"
            );
        }
    }
}
