//! The LightLDA Metropolis–Hastings token kernel (paper §3, Algorithm 1).
//!
//! Resampling a token's topic by computing the full conditional is O(K).
//! LightLDA instead alternates two O(1) proposals, each corrected by its
//! exact MH acceptance probability so the chain still targets the
//! collapsed Gibbs posterior:
//!
//! - **word proposal** `q_w(k) ∝ n̂_wk + β` — drawn from a Vose alias
//!   table built from a (possibly stale) snapshot `n̂_wk` of the word's
//!   topic row; the table is rebuilt once per word per iteration and
//!   amortizes to O(1) per token;
//! - **doc proposal** `q_d(k) ∝ n_dk + α` — drawn in O(1) *without any
//!   table* by exploiting that `n_dk` is exactly the histogram of the
//!   document's own topic assignments: with probability
//!   `L_d / (L_d + αK)` pick the topic of a uniformly random token of
//!   the document, otherwise pick a uniform topic.
//!
//! Both acceptance ratios use the *excluded* counts `n^{-dw}` for the
//! target density and the proposal's own (stale/inclusive) masses for
//! the `q` terms, exactly as in Yuan et al. (2015), eqs. (3)–(4).

use crate::lda::alias::{AliasTable, WordProposal};
use crate::lda::hyper::LdaHyper;
use crate::lda::sparse_counts::DocTopicCounts;
use crate::util::rng::Pcg64;

/// Everything the token kernel needs to know about the current state.
///
/// All counts are **inclusive** of the token being resampled (carrying
/// its old topic `z_old`); the kernel performs the `n^{-dw}` exclusion
/// on the fly. This keeps the common no-change path read-only — the
/// caller mutates state only when the topic actually changes, which the
/// perf profile showed is worth ~20% of end-to-end iteration time.
///
/// Generic over the word-proposal table `P` so the distributed sweep's
/// borrowed hybrid tables ([`crate::lda::alias::WordAlias`]) and the
/// single-machine sweep's owned [`AliasTable`]s share one monomorphized
/// kernel with no dynamic dispatch in the hot loop.
pub struct TokenView<'a, P> {
    /// Live (inclusive) word-topic row `n_wk[w, ·]`.
    pub word_row: &'a [i64],
    /// Live (inclusive) global topic totals `n_k`.
    pub n_k: &'a [i64],
    /// Live (inclusive) document topic counts `n_dk`.
    pub doc_counts: &'a DocTopicCounts,
    /// The document's topic assignments, with the token under resampling
    /// still carrying its old topic (used by the O(1) doc proposal).
    pub doc_assignments: &'a [u32],
    /// Stale alias table for the word proposal (weights = `n̂_wk + β`).
    pub word_alias: &'a P,
    /// Vocabulary size.
    pub v: u32,
    /// Hyper-parameters.
    pub hyper: LdaHyper,
}

/// Collapsed posterior mass (up to the doc-independent constant) of
/// assigning this token to topic `k`, excluding the token itself
/// (`n^{-dw}` = inclusive counts minus the `k == z_old` indicator).
#[inline]
fn posterior_mass<P>(view: &TokenView<'_, P>, k: u32, z_old: u32) -> f64 {
    let excl = f64::from(k == z_old);
    let vbeta = view.v as f64 * view.hyper.beta;
    (view.doc_counts.get(k) as f64 - excl + view.hyper.alpha)
        * (view.word_row[k as usize] as f64 - excl + view.hyper.beta)
        / (view.n_k[k as usize] as f64 - excl + vbeta)
}

/// Draw from the doc proposal `q_d(k) ∝ n_dk + α` in O(1).
///
/// Total mass `L_d + αK` splits into the histogram part (pick a random
/// token's topic) and the smoothing part (uniform topic).
#[inline]
fn doc_propose<P>(view: &TokenView<'_, P>, k_topics: u32, rng: &mut Pcg64) -> u32 {
    let len = view.doc_assignments.len() as f64;
    let alpha_mass = view.hyper.alpha * k_topics as f64;
    if rng.f64() * (len + alpha_mass) < len {
        view.doc_assignments[rng.below(view.doc_assignments.len())]
    } else {
        rng.below(k_topics as usize) as u32
    }
}

/// Doc-proposal mass of topic `k` (must match [`doc_propose`]):
/// `n_dk^{inclusive} + α` (the assignments array still holds `z_old`, so
/// the inclusive counts are exactly what the proposal samples from).
#[inline]
fn doc_proposal_mass<P>(view: &TokenView<'_, P>, k: u32) -> f64 {
    view.doc_counts.get(k) as f64 + view.hyper.alpha
}

/// Resample one token with `mh_steps` rounds of the two-proposal cycle.
/// Returns the new topic. O(mh_steps), independent of K.
///
/// `p(z)` is cached across proposals and refreshed only when a proposal
/// is accepted (the profile showed `posterior_mass` as the single
/// hottest function; this halves its call count).
pub fn resample_token<P: WordProposal>(
    z_old: u32,
    view: &TokenView<'_, P>,
    k_topics: u32,
    mh_steps: u32,
    rng: &mut Pcg64,
) -> u32 {
    let mut z = z_old;
    let mut p_z = posterior_mass(view, z, z_old);
    for _ in 0..mh_steps {
        // --- word proposal ------------------------------------------------
        let t = view.word_alias.sample(rng);
        if t != z {
            // pi_w = [p(t) q_w(z)] / [p(z) q_w(t)], q_w = stale alias mass.
            let p_t = posterior_mass(view, t, z_old);
            let accept =
                p_t * view.word_alias.weight(z) / (p_z * view.word_alias.weight(t));
            if accept >= 1.0 || rng.f64() < accept {
                z = t;
                p_z = p_t;
            }
        }
        // --- doc proposal -------------------------------------------------
        let t = doc_propose(view, k_topics, rng);
        if t != z {
            // pi_d = [p(t) q_d(z)] / [p(z) q_d(t)].
            let p_t = posterior_mass(view, t, z_old);
            let accept =
                p_t * doc_proposal_mass(view, z) / (p_z * doc_proposal_mass(view, t));
            if accept >= 1.0 || rng.f64() < accept {
                z = t;
                p_z = p_t;
            }
        }
    }
    z
}

/// Build an **owned** word-proposal alias table from a (stale) dense
/// word-topic row. Used where many tables stay alive at once
/// ([`sweep_light`] keeps one per word for the whole sweep); the
/// distributed sweep instead rebuilds per word through the reusable
/// [`crate::lda::alias::AliasBuilder`] workspace.
pub fn word_alias(row: &[i64], beta: f64) -> AliasTable {
    let weights: Vec<f64> = row.iter().map(|&c| c as f64 + beta).collect();
    AliasTable::new(&weights)
}

/// One full single-machine LightLDA sweep (used by tests, the quickstart
/// example, and the O(1)-vs-O(K) benchmark; the distributed version in
/// [`crate::lda::trainer`] runs the same kernel against parameter-server
/// state).
///
/// Alias tables are built lazily per word per sweep from the sweep-start
/// snapshot semantics of LightLDA (the table a word's tokens see within
/// one sweep is the row state at first use — bounded staleness).
pub fn sweep_light(
    model: &mut crate::lda::gibbs::LocalModel,
    corpus: &crate::corpus::dataset::Corpus,
    mh_steps: u32,
    rng: &mut Pcg64,
) {
    let kk = model.k as usize;
    let mut tables: Vec<Option<AliasTable>> = vec![None; model.v as usize];
    for d in 0..corpus.docs.len() {
        let doc = &corpus.docs[d];
        for pos in 0..doc.tokens.len() {
            let w = doc.tokens[pos] as usize;
            let z_old = model.assignments[d][pos];
            if tables[w].is_none() {
                tables[w] = Some(word_alias(model.word_row(w as u32), model.hyper.beta));
            }
            // Inclusive counts; the kernel excludes on the fly.
            let z_new = {
                let view = TokenView {
                    word_row: &model.n_wk[w * kk..(w + 1) * kk],
                    n_k: &model.n_k,
                    doc_counts: &model.doc_counts[d],
                    doc_assignments: &model.assignments[d],
                    word_alias: tables[w].as_ref().unwrap(),
                    v: model.v,
                    hyper: model.hyper,
                };
                resample_token(z_old, &view, model.k, mh_steps, rng)
            };
            if z_new != z_old {
                model.doc_counts[d].decrement(z_old);
                model.doc_counts[d].increment(z_new);
                model.n_wk[w * kk + z_old as usize] -= 1;
                model.n_wk[w * kk + z_new as usize] += 1;
                model.n_k[z_old as usize] -= 1;
                model.n_k[z_new as usize] += 1;
                model.assignments[d][pos] = z_new;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synth::{generate, SynthConfig};
    use crate::eval::perplexity::training_perplexity;
    use crate::lda::gibbs::{sweep, LocalModel};

    fn tiny() -> crate::corpus::dataset::Corpus {
        generate(&SynthConfig {
            num_docs: 150,
            vocab_size: 300,
            num_topics: 5,
            avg_doc_len: 40.0,
            seed: 11,
            ..Default::default()
        })
    }

    #[test]
    fn sweep_preserves_invariants() {
        let c = tiny();
        let mut m = LocalModel::init_random(&c, 8, LdaHyper::default_for(8), 1);
        let mut rng = Pcg64::new(2);
        for _ in 0..3 {
            sweep_light(&mut m, &c, 2, &mut rng);
            m.check_consistency(&c).unwrap();
        }
    }

    #[test]
    fn lightlda_reduces_perplexity() {
        let c = tiny();
        let mut m = LocalModel::init_random(&c, 8, LdaHyper::default_for(8), 3);
        let mut rng = Pcg64::new(4);
        let before = training_perplexity(&m, &c);
        for _ in 0..20 {
            sweep_light(&mut m, &c, 2, &mut rng);
        }
        let after = training_perplexity(&m, &c);
        assert!(after < before * 0.85, "{before} -> {after}");
    }

    #[test]
    fn lightlda_matches_exact_gibbs_quality() {
        // Same corpus, same budget: the MH sampler must converge to a
        // perplexity within a few percent of exact Gibbs (same stationary
        // distribution).
        let c = tiny();
        let hyper = LdaHyper::default_for(8);
        let mut exact = LocalModel::init_random(&c, 8, hyper, 5);
        let mut light = LocalModel::init_random(&c, 8, hyper, 6);
        let mut rng_a = Pcg64::new(7);
        let mut rng_b = Pcg64::new(8);
        for _ in 0..30 {
            sweep(&mut exact, &c, &mut rng_a);
            sweep_light(&mut light, &c, 4, &mut rng_b);
        }
        let pe = training_perplexity(&exact, &c);
        let pl = training_perplexity(&light, &c);
        let rel = (pl - pe).abs() / pe;
        assert!(rel < 0.10, "exact {pe} vs light {pl} (rel {rel})");
    }

    #[test]
    fn doc_proposal_distribution_matches_mass() {
        // Empirically verify doc_propose draws from (n_dk_incl + alpha).
        let hyper = LdaHyper { alpha: 0.5, beta: 0.01 };
        let assignments = vec![0u32, 0, 1, 2, 2, 2];
        let counts = DocTopicCounts::from_assignments(&assignments);
        let row = vec![1i64; 4];
        let n_k = vec![10i64; 4];
        let table = word_alias(&row, hyper.beta);
        let view = TokenView {
            word_row: &row,
            n_k: &n_k,
            doc_counts: &counts,
            doc_assignments: &assignments,
            word_alias: &table,
            v: 100,
            hyper,
        };
        let mut rng = Pcg64::new(9);
        let n = 200_000;
        let mut hist = [0usize; 4];
        for _ in 0..n {
            hist[doc_propose(&view, 4, &mut rng) as usize] += 1;
        }
        let total_mass = 6.0 + 0.5 * 4.0;
        for (k, &h) in hist.iter().enumerate() {
            let want = (counts.get(k as u32) as f64 + 0.5) / total_mass;
            let got = h as f64 / n as f64;
            assert!((got - want).abs() < 0.01, "topic {k}: {got} vs {want}");
        }
    }

    #[test]
    fn resample_returns_valid_topic() {
        let hyper = LdaHyper::default_for(4);
        let assignments = vec![1u32, 2, 3, 0];
        let counts = DocTopicCounts::from_assignments(&assignments); // inclusive
        let row = vec![5i64, 0, 3, 1];
        let n_k = vec![50i64, 10, 30, 10];
        let table = word_alias(&row, hyper.beta);
        let view = TokenView {
            word_row: &row,
            n_k: &n_k,
            doc_counts: &counts,
            doc_assignments: &assignments,
            word_alias: &table,
            v: 100,
            hyper,
        };
        let mut rng = Pcg64::new(10);
        for _ in 0..1000 {
            let z = resample_token(1, &view, 4, 2, &mut rng);
            assert!(z < 4);
        }
    }
}
