//! Exact O(K) collapsed Gibbs sampling (Griffiths & Steyvers, 2004) and
//! the shared in-memory model state.
//!
//! This is the *reference* sampler: it computes the full conditional
//! `P(z=k) ∝ (n_dk^- + α)(n_wk^- + β)/(n_k^- + Vβ)` for every topic, so
//! each token costs O(K). It serves two purposes:
//!
//! 1. correctness oracle for the LightLDA Metropolis–Hastings sampler
//!    (same stationary distribution, so perplexities must agree);
//! 2. the O(K) side of the paper's amortized-O(1) claim, measured in
//!    `benches/sampler.rs`.

use crate::corpus::dataset::Corpus;
use crate::lda::hyper::LdaHyper;
use crate::lda::sparse_counts::DocTopicCounts;
use crate::util::error::{Error, Result};
use crate::util::rng::Pcg64;

/// Complete in-memory LDA state: count tables plus per-token topic
/// assignments. Used by the single-machine samplers and as the scratch
/// representation when rebuilding parameter-server state from a
/// checkpoint.
#[derive(Debug, Clone)]
pub struct LocalModel {
    /// Number of topics.
    pub k: u32,
    /// Vocabulary size.
    pub v: u32,
    /// Word-topic counts, `v x k` row-major.
    pub n_wk: Vec<i64>,
    /// Topic totals, length `k`.
    pub n_k: Vec<i64>,
    /// Topic assignment per token, parallel to the corpus docs.
    pub assignments: Vec<Vec<u32>>,
    /// Per-document topic counts.
    pub doc_counts: Vec<DocTopicCounts>,
    /// Hyper-parameters.
    pub hyper: LdaHyper,
}

impl LocalModel {
    /// Initialize with uniformly random topic assignments (the standard
    /// Gibbs initialization; also what the distributed trainer does
    /// before pushing initial counts to the parameter server).
    pub fn init_random(corpus: &Corpus, k: u32, hyper: LdaHyper, seed: u64) -> LocalModel {
        let mut rng = Pcg64::new(seed);
        let v = corpus.vocab_size;
        let mut n_wk = vec![0i64; v as usize * k as usize];
        let mut n_k = vec![0i64; k as usize];
        let mut assignments = Vec::with_capacity(corpus.docs.len());
        let mut doc_counts = Vec::with_capacity(corpus.docs.len());
        for doc in &corpus.docs {
            let z: Vec<u32> = doc.tokens.iter().map(|_| rng.below(k as usize) as u32).collect();
            for (&w, &zi) in doc.tokens.iter().zip(&z) {
                n_wk[w as usize * k as usize + zi as usize] += 1;
                n_k[zi as usize] += 1;
            }
            doc_counts.push(DocTopicCounts::from_assignments(&z));
            assignments.push(z);
        }
        LocalModel { k, v, n_wk, n_k, assignments, doc_counts, hyper }
    }

    /// Word-topic count.
    #[inline]
    pub fn nwk(&self, w: u32, k: u32) -> i64 {
        self.n_wk[w as usize * self.k as usize + k as usize]
    }

    /// Row of word-topic counts for `w`.
    #[inline]
    pub fn word_row(&self, w: u32) -> &[i64] {
        let k = self.k as usize;
        &self.n_wk[w as usize * k..(w as usize + 1) * k]
    }

    /// Point estimate of φ_kw = P(w | k).
    pub fn phi(&self, w: u32, k: u32) -> f64 {
        (self.nwk(w, k) as f64 + self.hyper.beta)
            / (self.n_k[k as usize] as f64 + self.v as f64 * self.hyper.beta)
    }

    /// Point estimate of θ_dk = P(k | d).
    pub fn theta(&self, d: usize, k: u32) -> f64 {
        let len = self.assignments[d].len() as f64;
        (self.doc_counts[d].get(k) as f64 + self.hyper.alpha)
            / (len + self.k as f64 * self.hyper.alpha)
    }

    /// Verify all count-table invariants (tests and checkpoint recovery):
    /// `n_wk`/`n_k`/`n_dk` must all be consistent with `assignments`.
    pub fn check_consistency(&self, corpus: &Corpus) -> Result<()> {
        let kk = self.k as usize;
        let mut n_wk = vec![0i64; self.v as usize * kk];
        let mut n_k = vec![0i64; kk];
        if corpus.docs.len() != self.assignments.len() {
            return Err(Error::Config("doc count mismatch".into()));
        }
        for (d, doc) in corpus.docs.iter().enumerate() {
            if doc.tokens.len() != self.assignments[d].len() {
                return Err(Error::Config(format!("doc {d} token/assignment length mismatch")));
            }
            for (&w, &z) in doc.tokens.iter().zip(&self.assignments[d]) {
                n_wk[w as usize * kk + z as usize] += 1;
                n_k[z as usize] += 1;
            }
            let expect = DocTopicCounts::from_assignments(&self.assignments[d]);
            if expect != self.doc_counts[d] {
                return Err(Error::Config(format!("doc {d} topic counts inconsistent")));
            }
        }
        if n_wk != self.n_wk {
            return Err(Error::Config("n_wk inconsistent with assignments".into()));
        }
        if n_k != self.n_k {
            return Err(Error::Config("n_k inconsistent with assignments".into()));
        }
        Ok(())
    }
}

/// One full exact-Gibbs sweep over the corpus. O(K) per token.
pub fn sweep(model: &mut LocalModel, corpus: &Corpus, rng: &mut Pcg64) {
    let kk = model.k as usize;
    let vbeta = model.v as f64 * model.hyper.beta;
    let mut weights = vec![0.0f64; kk];
    for (d, doc) in corpus.docs.iter().enumerate() {
        for (pos, &w) in doc.tokens.iter().enumerate() {
            let z_old = model.assignments[d][pos];
            // Exclude the token.
            model.doc_counts[d].decrement(z_old);
            model.n_wk[w as usize * kk + z_old as usize] -= 1;
            model.n_k[z_old as usize] -= 1;
            // Full conditional.
            let row = &model.n_wk[w as usize * kk..(w as usize + 1) * kk];
            for (k, wt) in weights.iter_mut().enumerate() {
                let ndk = model.doc_counts[d].get(k as u32) as f64;
                *wt = (ndk + model.hyper.alpha) * (row[k] as f64 + model.hyper.beta)
                    / (model.n_k[k] as f64 + vbeta);
            }
            let z_new = rng.categorical(&weights) as u32;
            // Re-include.
            model.doc_counts[d].increment(z_new);
            model.n_wk[w as usize * kk + z_new as usize] += 1;
            model.n_k[z_new as usize] += 1;
            model.assignments[d][pos] = z_new;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synth::{generate, SynthConfig};
    use crate::eval::perplexity::training_perplexity;

    fn tiny_corpus() -> Corpus {
        generate(&SynthConfig {
            num_docs: 120,
            vocab_size: 300,
            num_topics: 5,
            avg_doc_len: 40.0,
            seed: 7,
            ..Default::default()
        })
    }

    #[test]
    fn init_is_consistent() {
        let c = tiny_corpus();
        let m = LocalModel::init_random(&c, 5, LdaHyper::default_for(5), 1);
        m.check_consistency(&c).unwrap();
        assert_eq!(m.n_k.iter().sum::<i64>() as u64, c.num_tokens());
    }

    #[test]
    fn sweep_preserves_invariants() {
        let c = tiny_corpus();
        let mut m = LocalModel::init_random(&c, 5, LdaHyper::default_for(5), 2);
        let mut rng = Pcg64::new(3);
        for _ in 0..3 {
            sweep(&mut m, &c, &mut rng);
            m.check_consistency(&c).unwrap();
        }
    }

    #[test]
    fn gibbs_reduces_perplexity() {
        let c = tiny_corpus();
        let mut m = LocalModel::init_random(&c, 5, LdaHyper::default_for(5), 4);
        let mut rng = Pcg64::new(5);
        let before = training_perplexity(&m, &c);
        for _ in 0..15 {
            sweep(&mut m, &c, &mut rng);
        }
        let after = training_perplexity(&m, &c);
        // The Zipfian synthetic corpus has a strong unigram baseline, so
        // relative drops are modest; require a clear, consistent drop.
        assert!(
            after < before * 0.93,
            "perplexity should drop markedly: {before} -> {after}"
        );
    }

    #[test]
    fn phi_theta_are_distributions() {
        let c = tiny_corpus();
        let m = LocalModel::init_random(&c, 5, LdaHyper::default_for(5), 6);
        for k in 0..5 {
            let total: f64 = (0..m.v).map(|w| m.phi(w, k)).sum();
            assert!((total - 1.0).abs() < 1e-9, "phi_{k} sums to {total}");
        }
        for d in [0usize, 10, 50] {
            let total: f64 = (0..5).map(|k| m.theta(d, k)).sum();
            assert!((total - 1.0).abs() < 1e-9, "theta_{d} sums to {total}");
        }
    }
}
