//! glint-lda launcher.
//!
//! Subcommands:
//!
//! - `train`      — distributed LightLDA over the parameter server
//!   (in-process by default; `--transport tcp` for loopback TCP;
//!   `--connect host:port,...` to use external `serve` processes)
//! - `serve`      — host parameter-server shards over TCP for
//!   multi-process deployments
//! - `coordinate` — run the cluster coordinator: partition the corpus
//!   and drive remote `work` processes against `serve` shards
//! - `work`       — join a coordinator as a remote sampler process
//! - `shutdown`   — stop external `serve` processes
//! - `em`         — Spark-MLlib-style variational EM baseline
//! - `online`     — Spark-MLlib-style Online VB baseline
//! - `gen-corpus` — generate + save a synthetic ClueWeb12 analogue
//! - `eval`       — perplexity via both the rust and XLA evaluators
//! - `table1` / `fig4` / `fig5` / `fig6` — reproduce the paper's
//!   evaluation artifacts (also available as `cargo bench` targets)

use std::path::PathBuf;

use glint_lda::baselines::{em, online};
use glint_lda::cluster::{run_worker, Coordinator, CorpusSpec, WorkerOptions};
use glint_lda::corpus::dataset::Corpus;
use glint_lda::corpus::synth::{generate, SynthConfig};
use glint_lda::eval::topics::summarize;
use glint_lda::experiments::{fig4, fig5, fig6, table1};
use glint_lda::lda::trainer::{TrainConfig, Trainer};
use glint_lda::log_info;
use glint_lda::net::tcp::{resolve_addrs, TcpTransport};
use glint_lda::ps::client::PsClient;
use glint_lda::ps::config::{PsConfig, TransportMode};
use glint_lda::ps::messages::Layout;
use glint_lda::ps::partition::PartitionScheme;
use glint_lda::ps::server::TcpShardServer;
use glint_lda::util::cli::Args;
use glint_lda::util::error::{Error, Result};
use glint_lda::util::logger;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    logger::set_level_str(&args.str_or("log", "info"));
    let code = match dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn dispatch(args: &Args) -> Result<()> {
    match args.command.as_deref() {
        Some("train") => cmd_train(args),
        Some("serve") => cmd_serve(args),
        Some("coordinate") => cmd_coordinate(args),
        Some("work") => cmd_work(args),
        Some("shutdown") => cmd_shutdown(args),
        Some("em") => cmd_em(args),
        Some("online") => cmd_online(args),
        Some("gen-corpus") => cmd_gen_corpus(args),
        Some("eval") => cmd_eval(args),
        Some("table1") => cmd_table1(args),
        Some("fig4") => cmd_fig4(args),
        Some("fig5") => cmd_fig5(args),
        Some("fig6") => cmd_fig6(args),
        Some(other) => Err(Error::Config(format!("unknown subcommand {other:?}"))),
        None => {
            println!(
                "glint-lda — web-scale topic models with an asynchronous parameter server\n\
                 \n\
                 usage: glint-lda <train|serve|coordinate|work|shutdown|em|online|gen-corpus|eval|table1|fig4|fig5|fig6> [--opt value]...\n\
                 \n\
                 common options:\n\
                 --topics N      number of topics (default 20/100 depending on command)\n\
                 --iters N       iterations (default 20)\n\
                 --workers N     sampler threads (default 4)\n\
                 --shards N      parameter-server shards (default 4)\n\
                 --corpus PATH   corpus file (default: generate synthetic)\n\
                 --docs N        synthetic corpus size (default 8000)\n\
                 --vocab N       synthetic vocabulary size (default 8000)\n\
                 --out PATH      write the report CSV here\n\
                 --log LEVEL     error|warn|info|debug|trace\n\
                 \n\
                 sampler options (train/coordinate):\n\
                 --alias-dense-threshold F  row fill (nnz/K) at which word-proposal tables\n\
                 switch from the sparse hybrid mixture to a dense build\n\
                 (default 0.5; 0 = always dense, >1 = always hybrid)\n\
                 \n\
                 transports (train):\n\
                 --transport T   sim (in-process, default) | tcp (loopback TCP)\n\
                 --connect LIST  host:port,... of running `serve` shards\n\
                 --shutdown      stop the connected `serve` shards after training\n\
                 \n\
                 serve options:\n\
                 --bind LIST     host:port,... to listen on, one per hosted shard\n\
                 --first-shard N global id of the first hosted shard (default 0)\n\
                 --shards N      total shards in the deployment (default: hosted count)\n\
                 \n\
                 coordinate options (plus the train options above):\n\
                 --bind ADDR          control-plane listen address (default 127.0.0.1:7600)\n\
                 --connect LIST       host:port,... of running `serve` shards (required)\n\
                 --workers N          corpus partitions / expected `work` processes\n\
                 --checkpoint-dir D   per-partition checkpoints (enables failure recovery)\n\
                 --keep-checkpoints N snapshots retained per partition (default 3)\n\
                 --heartbeat-ms N     worker heartbeat period (default 1000)\n\
                 --straggler-ms N     silence before a worker is declared dead (default 10000)\n\
                 --max-staleness N    iterations a fast worker may run ahead (default 1)\n\
                 \n\
                 work options:\n\
                 --join ADDR     coordinator host:port (required)\n\
                 --corpus PATH   corpus override (else the coordinator's spec is used)\n\
                 \n\
                 shutdown options:\n\
                 --connect LIST  host:port,... of the shards to stop"
            );
            Ok(())
        }
    }
}

fn load_or_generate(args: &Args) -> Result<Corpus> {
    if let Some(path) = args.get("corpus") {
        log_info!("loading corpus from {path}");
        return Corpus::load(&PathBuf::from(path));
    }
    let cfg = SynthConfig {
        num_docs: args.get_as("docs", 8000usize)?,
        vocab_size: args.get_as("vocab", 8000u32)?,
        num_topics: args.get_as("gen-topics", 50usize)?,
        avg_doc_len: args.get_as("avg-len", 80.0f64)?,
        zipf_exponent: args.get_as("zipf", 1.07f64)?,
        seed: args.get_as("seed", 0xc1e0u64)?,
        ..SynthConfig::default()
    };
    log_info!(
        "generating synthetic corpus: {} docs, V={}",
        cfg.num_docs,
        cfg.vocab_size
    );
    Ok(generate(&cfg))
}

/// Split a `host:port,host:port` list into its entries.
fn split_addr_list(raw: &str) -> Vec<String> {
    raw.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect()
}

/// Transport selection for `train`: `--connect` wins over `--transport`.
fn transport_mode(args: &Args) -> Result<TransportMode> {
    if let Some(list) = args.get("connect") {
        let addrs = split_addr_list(list);
        if addrs.is_empty() {
            return Err(Error::Config("--connect needs at least one host:port".into()));
        }
        return Ok(TransportMode::Connect(addrs));
    }
    TransportMode::parse(&args.str_or("transport", "sim"))
        .ok_or_else(|| Error::Config("bad --transport (sim|tcp)".into()))
}

fn train_config(args: &Args) -> Result<TrainConfig> {
    Ok(TrainConfig {
        num_topics: args.get_as("topics", 20u32)?,
        iterations: args.get_as("iters", 20u32)?,
        alpha: args.get_as("alpha", 0.0f64)?,
        beta: args.get_as("beta", 0.01f64)?,
        mh_steps: args.get_as("mh-steps", 2u32)?,
        workers: args.get_as("workers", 4usize)?,
        shards: args.get_as("shards", 4usize)?,
        block_words: args.get_as("block-words", 2048usize)?,
        buffer_cap: args.get_as("buffer-cap", 100_000usize)?,
        dense_top_words: args.get_as("dense-top", 2000u64)?,
        pipeline_depth: args.get_as("pipeline-depth", 1usize)?,
        alias_dense_threshold: args.get_as("alias-dense-threshold", 0.5f64)?,
        scheme: PartitionScheme::parse(&args.str_or("scheme", "cyclic"))
            .ok_or_else(|| Error::Config("bad --scheme (cyclic|range)".into()))?,
        wt_layout: Layout::parse(&args.str_or("wt-layout", "sparse"))
            .ok_or_else(|| Error::Config("bad --wt-layout (dense|sparse)".into()))?,
        transport: transport_mode(args)?,
        seed: args.get_as("seed", 0x1dau64)?,
        eval_every: args.get_as("eval-every", 5u32)?,
        checkpoint_dir: args.get("checkpoint-dir").map(PathBuf::from),
        keep_checkpoints: args.get_as("keep-checkpoints", 3usize)?,
        heartbeat_ms: args.get_as("heartbeat-ms", 1000u64)?,
        straggler_timeout_ms: args.get_as("straggler-ms", 10_000u64)?,
        max_staleness: args.get_as("max-staleness", 1u32)?,
        ..TrainConfig::default()
    })
}

fn maybe_save(args: &Args, csv: String) -> Result<()> {
    if let Some(out) = args.get("out") {
        std::fs::write(out, csv)?;
        log_info!("report written to {out}");
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let corpus = load_or_generate(args)?;
    let cfg = train_config(args)?;
    let mut trainer = if args.flag("resume") {
        Trainer::restore(cfg, &corpus)?
    } else {
        Trainer::new(cfg, &corpus)?
    };
    let model = trainer.run(&corpus)?;
    let perplexity = trainer.training_perplexity(&model, &corpus);
    log_info!("final training perplexity: {perplexity:.1}");
    for line in summarize(&model, &corpus.vocab, args.get_as("top-words", 8usize)?)
        .into_iter()
        .take(args.get_as("show-topics", 10usize)?)
    {
        println!("{line}");
    }
    maybe_save(args, trainer.report.to_csv())?;
    if args.flag("shutdown") {
        // Best-effort: a lost shutdown ack must not fail a training run
        // that already succeeded.
        match trainer.shutdown_servers() {
            Ok(()) => log_info!("shard servers stopped"),
            Err(e) => glint_lda::log_warn!("shard shutdown incomplete: {e}"),
        }
    }
    Ok(())
}

/// Host parameter-server shards over TCP (the server half of a
/// multi-process deployment). Blocks until every hosted shard receives a
/// `shutdown` request.
fn cmd_serve(args: &Args) -> Result<()> {
    let binds = split_addr_list(&args.str_or("bind", "127.0.0.1:0"));
    let addrs = resolve_addrs(&binds)?;
    let first_shard = args.get_as("first-shard", 0usize)?;
    let total = match args.get_as("shards", 0usize)? {
        0 => first_shard + addrs.len(),
        n => n,
    };
    let cfg = PsConfig {
        shards: total,
        scheme: PartitionScheme::parse(&args.str_or("scheme", "cyclic"))
            .ok_or_else(|| Error::Config("bad --scheme (cyclic|range)".into()))?,
        ..PsConfig::default()
    };
    let server = TcpShardServer::bind(cfg, first_shard, &addrs)?;
    for (i, addr) in server.addrs().iter().enumerate() {
        log_info!("shard {}/{} listening on {addr}", first_shard + i, total);
    }
    log_info!("serving; stop with `glint-lda shutdown --connect <addrs>`");
    server.join();
    log_info!("all hosted shards shut down");
    Ok(())
}

/// Run the cluster coordinator: partition the corpus, serve the control
/// plane for `work` processes, aggregate per-iteration stats, recover
/// from worker failures. Requires running `serve` shards (`--connect`).
fn cmd_coordinate(args: &Args) -> Result<()> {
    let corpus = load_or_generate(args)?;
    let cfg = train_config(args)?;
    // What we tell workers about the corpus: an explicit file wins; a
    // synthetic corpus is described by its generator parameters so each
    // worker regenerates it deterministically.
    let corpus_spec = match args.get("corpus") {
        Some(path) => CorpusSpec::File(path.to_string()),
        None => CorpusSpec::Synth {
            num_docs: args.get_as("docs", 8000usize)? as u64,
            vocab_size: args.get_as("vocab", 8000u32)?,
            num_topics: args.get_as("gen-topics", 50usize)? as u32,
            avg_doc_len: args.get_as("avg-len", 80.0f64)?,
            zipf_exponent: args.get_as("zipf", 1.07f64)?,
            seed: args.get_as("seed", 0xc1e0u64)?,
        },
    };
    let bind = args.str_or("bind", "127.0.0.1:7600");
    let coordinator = Coordinator::bind(&bind, cfg, &corpus, corpus_spec)?;
    log_info!(
        "coordinator listening on {}; join workers with: glint-lda work --join {}",
        coordinator.addr(),
        coordinator.addr()
    );
    let outcome = coordinator.run()?;
    if let Some(p) = outcome.final_perplexity {
        log_info!("final training perplexity: {p:.1}");
    }
    log_info!(
        "run complete: {} epoch roll(s), {} reassignment(s)",
        outcome.epochs,
        outcome.reassignments
    );
    for line in summarize(&outcome.model, &corpus.vocab, args.get_as("top-words", 8usize)?)
        .into_iter()
        .take(args.get_as("show-topics", 10usize)?)
    {
        println!("{line}");
    }
    maybe_save(args, outcome.report.to_csv())
}

/// Join a coordinator as a remote sampler process.
fn cmd_work(args: &Args) -> Result<()> {
    let join = args
        .get("join")
        .ok_or_else(|| Error::Config("missing required option --join host:port".into()))?
        .to_string();
    let corpus = match args.get("corpus") {
        Some(path) => Some(Corpus::load(&PathBuf::from(path))?),
        None => None,
    };
    // Fault-injection hook for demos and tests: crash (exit without
    // reporting) right after sweeping this iteration.
    let crash_at = args.get_as("crash-at", 0u32)?;
    let summary = run_worker(WorkerOptions {
        join,
        corpus,
        crash_at_iteration: (crash_at > 0).then_some(crash_at),
    })?;
    log_info!(
        "worker {} exiting after {} sweep(s){}",
        summary.worker_id,
        summary.sweeps,
        if summary.crashed { " (simulated crash)" } else { "" }
    );
    Ok(())
}

/// Stop externally running `serve` shards.
fn cmd_shutdown(args: &Args) -> Result<()> {
    let list = args
        .get("connect")
        .ok_or_else(|| Error::Config("missing required option --connect".into()))?;
    let addrs = split_addr_list(list);
    let resolved = resolve_addrs(&addrs)?;
    let cfg = PsConfig {
        shards: resolved.len(),
        transport: TransportMode::Connect(addrs),
        ..PsConfig::default()
    };
    let transport = TcpTransport::connect(&resolved);
    let client = PsClient::connect(&transport, cfg);
    client.shutdown_servers()?;
    log_info!("{} shard(s) stopped", resolved.len());
    Ok(())
}

fn cmd_em(args: &Args) -> Result<()> {
    let corpus = load_or_generate(args)?;
    let cfg = em::EmConfig {
        num_topics: args.get_as("topics", 20u32)?,
        iterations: args.get_as("iters", 20u32)?,
        workers: args.get_as("workers", 4usize)?,
        ..em::EmConfig::default()
    };
    let model = em::train(&cfg, &corpus)?;
    log_info!(
        "EM perplexity {:.1}, simulated shuffle write {:.3} GB",
        model.perplexity(&corpus),
        model.shuffle_bytes as f64 / 1e9
    );
    maybe_save(args, model.report.to_csv())
}

fn cmd_online(args: &Args) -> Result<()> {
    let corpus = load_or_generate(args)?;
    let workers = args.get_as("workers", 4usize)?;
    let cfg = online::OnlineConfig {
        num_topics: args.get_as("topics", 20u32)?,
        epochs: args.get_as("epochs", 2u32)?,
        batch_size: args.get_as("batch", 256usize)?,
        workers,
        ..online::OnlineConfig::default()
    };
    let model = online::train(&cfg, &corpus)?;
    log_info!("Online VB perplexity {:.1}", model.perplexity(&corpus, workers));
    maybe_save(args, model.report.to_csv())
}

fn cmd_gen_corpus(args: &Args) -> Result<()> {
    let corpus = load_or_generate(args)?;
    let out = args.str_or("out", "corpus.bin");
    corpus.save(&PathBuf::from(&out))?;
    log_info!(
        "saved {} docs / {} tokens to {out}",
        corpus.num_docs(),
        corpus.num_tokens()
    );
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    // Train briefly, then evaluate via both the rust and XLA paths —
    // demonstrates the AOT artifacts working from the CLI.
    let corpus = load_or_generate(args)?;
    let mut cfg = train_config(args)?;
    cfg.eval_every = 0;
    let mut trainer = Trainer::new(cfg, &corpus)?;
    let model = trainer.run(&corpus)?;
    let rust_p = trainer.training_perplexity(&model, &corpus);
    println!("rust evaluator: perplexity {rust_p:.2}");
    let artifact_dir = PathBuf::from(args.str_or("artifacts", "artifacts"));
    match glint_lda::runtime::engine::Engine::new(&artifact_dir) {
        Ok(engine) => {
            let counts = trainer.doc_counts();
            let xla_p =
                glint_lda::eval::xla::xla_perplexity(&engine, &model, &corpus, &counts)?;
            println!("xla evaluator ({}): perplexity {xla_p:.2}", engine.platform());
        }
        Err(e) => println!("xla evaluator unavailable: {e}"),
    }
    Ok(())
}

fn cmd_table1(args: &Args) -> Result<()> {
    let cfg = table1::Table1Config {
        scale: args.get_as("scale", 1.0f64)?,
        iterations: args.get_as("iters", 20u32)?,
        workers: args.get_as("workers", 4usize)?,
        shards: args.get_as("shards", 4usize)?,
        ..table1::Table1Config::default()
    };
    let report = table1::run(&cfg)?;
    println!("{}", table1::render_paper_style(&report));
    maybe_save(args, report.to_csv())
}

fn cmd_fig4(args: &Args) -> Result<()> {
    let cfg = fig4::Fig4Config {
        scale: args.get_as("scale", 1.0f64)?,
        top: args.get_as("top", 5000usize)?,
        stride: args.get_as("stride", 10usize)?,
    };
    let r = fig4::run(&cfg)?;
    println!(
        "zipf fit: log f = {:.2} + {:.3} log r  (exponent {:.3})",
        r.intercept, r.slope, -r.slope
    );
    println!("{}", r.report.to_table());
    maybe_save(args, r.report.to_csv())
}

fn cmd_fig5(args: &Args) -> Result<()> {
    let cfg = fig5::Fig5Config {
        scale: args.get_as("scale", 1.0f64)?,
        machines: args.get_as("machines", 30usize)?,
        measure: !args.flag("no-measure"),
    };
    let r = fig5::run(&cfg)?;
    println!("{}", r.report.to_table());
    println!("imbalance factors (max/mean; 1.0 = perfect):");
    for (name, f) in &r.imbalance {
        println!("  {name:>18}: {f:.3}");
    }
    maybe_save(args, r.report.to_csv())
}

fn cmd_fig6(args: &Args) -> Result<()> {
    let cfg = fig6::Fig6Config {
        scale: args.get_as("scale", 2.0f64)?,
        num_topics: args.get_as("topics", 100u32)?,
        iterations: args.get_as("iters", 30u32)?,
        workers: args.get_as("workers", 4usize)?,
        shards: args.get_as("shards", 8usize)?,
        eval_every: args.get_as("eval-every", 1u32)?,
    };
    let r = fig6::run(&cfg)?;
    println!("{}", r.report.to_table());
    println!(
        "final perplexity {:.1}; mean throughput {:.0} tokens/s",
        r.final_perplexity, r.tokens_per_sec
    );
    maybe_save(args, r.report.to_csv())
}
