//! glint-lda launcher.
//!
//! Every mode is one entry in the [`CommandSet`] dispatch table below —
//! name, one-line summary, usage text and handler live together, and
//! `glint-lda help <command>` / `<command> --help` render from the same
//! data. Modes:
//!
//! - `train`       — distributed LightLDA over the parameter server
//! - `serve`       — host parameter-server shards over TCP
//! - `serve-model` — serve topic inference for unseen documents off
//!   live shards (fixed-budget fold-in, request batching, LRU caches)
//! - `infer`       — query a `serve-model` replica
//! - `coordinate` / `work` — the multi-process cluster control plane
//! - `shutdown`    — stop external `serve` processes
//! - `em` / `online` — Spark-MLlib-style baselines
//! - `gen-corpus` / `eval` — corpus generation and model evaluation
//! - `table1` / `fig4` / `fig5` / `fig6` — the paper's evaluation
//!   artifacts (also available as `cargo bench` targets)

use std::path::PathBuf;
use std::time::Duration;

use glint_lda::baselines::{em, online};
use glint_lda::cluster::{run_worker, Coordinator, CorpusSpec, WorkerOptions};
use glint_lda::corpus::dataset::Corpus;
use glint_lda::corpus::synth::{generate, SynthConfig};
use glint_lda::eval::topics::summarize;
use glint_lda::experiments::{fig4, fig5, fig6, table1};
use glint_lda::lda::hyper::LdaHyper;
use glint_lda::lda::infer::{FoldInBudget, InferConfig, InferEngine};
use glint_lda::lda::sweep::SamplerParams;
use glint_lda::lda::trainer::{TrainConfig, Trainer};
use glint_lda::log_info;
use glint_lda::net::chaos;
use glint_lda::net::tcp::{resolve_addrs, TcpTransport};
use glint_lda::ps::client::PsClient;
use glint_lda::ps::config::{PsConfig, TransportMode};
use glint_lda::ps::messages::Layout;
use glint_lda::ps::partition::PartitionScheme;
use glint_lda::ps::server::TcpShardServer;
use glint_lda::serving::{InferClient, InferServer, DEFAULT_BATCH_WINDOW};
use glint_lda::util::cli::{Args, Command, CommandSet};
use glint_lda::util::error::{Error, Result};
use glint_lda::util::logger;

const COMMON_USAGE: &str = "common options:
  --log LEVEL     error|warn|info|debug|trace (default info)
  --out PATH      write the mode's report CSV here (where applicable)

chaos options (deterministic TCP fault injection, any networked mode):
  --chaos-plan SPEC  inject faults on every client round-trip; SPEC is
                     comma-separated key=value pairs: drop=F (request
                     and reply), drop_req=F, drop_reply=F, dup=F,
                     delay=DUR (e.g. 2ms), partition=LEN/EVERY
                     (LEN consecutive sends black-holed out of EVERY)
  --chaos-seed N     RNG seed for the plan; the same plan + seed
                     replays a failure bit-exactly (default 1)
                     (GLINT_CHAOS_PLAN / GLINT_CHAOS_SEED work too)

corpus options (modes that read a corpus):
  --corpus PATH   corpus file (default: generate synthetic)
  --docs N        synthetic corpus size (default 8000)
  --vocab N       synthetic vocabulary size (default 8000)
  --gen-topics N  synthetic generator topics (default 50)
  --avg-len F     synthetic mean document length (default 80)
  --zipf F        synthetic Zipf exponent (default 1.07)
  --seed N        RNG seed
";

const TRAIN_USAGE: &str = "model options:
  --topics N        number of topics K (default 20)
  --iters N         Gibbs iterations (default 20)
  --alpha F         doc-topic concentration (default 50/K)
  --beta F          topic-word concentration (default 0.01)

sampler options:
  --mh-steps N               Metropolis-Hastings cycles per token (default 2)
  --block-words N            words pulled per model block (default 2048)
  --buffer-cap N             buffered push deltas per worker (default 100000)
  --dense-top N              frequent words pulled dense (default 2000)
  --pipeline-depth N         prefetched blocks / per-shard window (default 1)
  --alias-dense-threshold F  row fill (nnz/K) at which word-proposal tables
                             switch from the sparse hybrid mixture to a dense
                             build (default 0.5; 0 = always dense,
                             >1 = always hybrid)

deployment options:
  --workers N       sampler threads (default 4)
  --shards N        parameter-server shards (default 4)
  --scheme S        cyclic|range row partitioning (default cyclic)
  --wt-layout L     dense|sparse word-topic storage (default sparse)
  --transport T     sim (in-process, default) | tcp (loopback TCP)
  --connect LIST    host:port,... of running `serve` shards
                    (wins over --transport)
  --backups LIST    host:port,... of `serve --backup-of` replicas in
                    tier-major order (whole tiers of one address per
                    shard; list two tiers for a chain of depth 2);
                    enables client failover along the chain when the
                    serving head dies
  --shutdown        stop the connected `serve` shards after training

run options:
  --eval-every N        training perplexity every N iterations (default 5)
  --checkpoint-dir D    checkpoint directory (enables --resume)
  --keep-checkpoints N  snapshots retained (default 3)
  --resume              restore from the latest checkpoint
  --top-words N         words shown per topic (default 8)
  --show-topics N       topics printed after training (default 10)
";

const SERVE_USAGE: &str = "options:
  --bind LIST      host:port,... to listen on, one per hosted shard
                   (default 127.0.0.1:0)
  --first-shard N  global id of the first hosted shard (default 0)
  --shards N       total shards in the deployment (default: hosted count)
  --scheme S       cyclic|range row partitioning (default cyclic)

durability options:
  --wal-dir PATH         write-ahead log directory; each hosted shard
                         logs under <PATH>/shard-NNNN/ and replays it
                         on restart (default: no durability)
  --wal-segment-bytes N  rotate log segments past this size
                         (default 1048576)

replication options:
  --backup-of LIST  run every hosted shard as a *standby*: poll the
                    upstream at the corresponding address (indexed by
                    shard id) for committed WAL records and refuse
                    data ops until promoted. The list names ALL
                    upstreams in the deployment, shard order. Chains
                    stack: every standby tier points at the serving
                    head, and a coordinator re-points survivors
                    (`ReplSeed`) when the head changes.

admin options (one-shot against a running deployment, then exit):
  --drain N         planned hand-off: freeze shard N's serving head,
                    wait for a standby to replicate through its
                    committed tip, promote it — zero epoch rolls,
                    nothing acked is lost. Needs --connect with the
                    serving heads and --backups with the standby list
                    (tier-major, as given to `coordinate`)
  --connect LIST    serving heads, shard order (with --drain)
  --backups LIST    standby replicas, tier-major (with --drain)
";

const SERVE_MODEL_USAGE: &str = "options:
  --connect LIST       host:port,... of the live `serve` shards (required)
  --vocab N            vocabulary size V of the frozen model (required)
  --topics N           topic count K of the frozen model (required)
  --matrix-id N        server-side id of the frozen word-topic table
                       (default 1: the id the trainer's model gets)
  --alpha F            doc-topic concentration (default 50/K)
  --beta F             topic-word concentration (default 0.01)
  --wt-layout L        dense|sparse table layout (default sparse)
  --scheme S           cyclic|range row partitioning (default cyclic)
  --bind ADDR          listen address for inference clients
                       (default 127.0.0.1:0)
  --sweeps N           fold-in sweeps per document (default 5)
  --mh-steps N         MH cycles per token per sweep (default 2)
  --cache-docs N       fold-in results cached (default 4096)
  --cache-words N      word alias tables cached (default 100000)
  --batch-window-ms F  inbox-drain window for request coalescing (default 2)
";

const INFER_USAGE: &str = "options:
  --connect ADDR  host:port of the serve-model replica (required)
  --doc LIST      one document as comma-separated token ids; further
                  documents may follow as positional arguments
  --stats         print the replica's serving counters instead
  --shutdown      stop the replica instead

examples:
  glint-lda infer --connect 127.0.0.1:7700 --doc 12,7,7,3 40,41,42
  glint-lda infer --connect 127.0.0.1:7700 --stats
";

const COORDINATE_USAGE: &str = "train options apply (see `glint-lda help train`), plus:
  --bind ADDR           control-plane listen address (default 127.0.0.1:7600)
  --connect LIST        host:port,... of running `serve` shards (required)
  --backups LIST        host:port,... of `serve --backup-of` replicas in
                        tier-major order (whole tiers of one address per
                        shard); when a serving head dies the coordinator
                        promotes along the chain, rolls the epoch to heal
                        lost pushes, and re-seeds surviving standbys
                        behind the new head
  --workers N           corpus partitions / expected `work` processes
  --checkpoint-dir D    per-partition checkpoints (enables failure recovery)
  --keep-checkpoints N  snapshots retained per partition (default 3)
  --heartbeat-ms N      worker heartbeat period (default 1000)
  --straggler-ms N      silence before a worker is declared dead
                        (default 10000)
  --max-staleness N     iterations a fast worker may run ahead (default 1)
  --elastic             consistent-hash ring membership: `work` processes
                        may join and drain mid-run; partitions move warm
                        via checkpoints (requires --checkpoint-dir)
  --partition-factor N  over-partition into workers*N fixed partitions so
                        the ring has something to rebalance (default 1)
  --shed-factor F       narrow a straggling owner's ring weight when its
                        report cadence lags the staleness window by this
                        factor (0 = off, default)
  --shed-stall-ms N     minimum stall before shedding (default 3000)
  --snapshot            BSP sweeps behind a fetch barrier: bit-exact final
                        counts under any membership history
  --drain-shard-at I:S  planned maintenance hand-off: once every
                        partition has completed iteration I, drain
                        shard S onto its most caught-up standby
                        (zero epoch rolls; needs --backups)
";

const WORK_USAGE: &str = "options:
  --join ADDR       coordinator host:port (required)
  --corpus PATH     corpus override (else the coordinator's spec is used)
  --crash-at N      fault injection: exit right after sweeping iteration N
  --drain-after N   planned drain: after N sweeps, hand partitions back
                    warm and leave (no epoch roll, no reaper)
  --sweep-delay-ms N  straggler simulation: sleep before every sweep
";

const SHUTDOWN_USAGE: &str = "options:
  --connect LIST  host:port,... of the shards to stop (required)
";

const EM_USAGE: &str = "options:
  --topics N      number of topics (default 20)
  --iters N       EM iterations (default 20)
  --workers N     simulated executors (default 4)
";

const ONLINE_USAGE: &str = "options:
  --topics N      number of topics (default 20)
  --epochs N      corpus passes (default 2)
  --batch N       minibatch size (default 256)
  --workers N     simulated executors (default 4)
";

const GEN_CORPUS_USAGE: &str = "options:
  --out PATH      destination file (default corpus.bin)

The corpus options above control the generator.
";

const EVAL_USAGE: &str = "train options apply (a brief run produces the model), plus:
  --artifacts DIR  AOT-compiled XLA artifacts (default artifacts)
";

const TABLE1_USAGE: &str = "options:
  --scale F       corpus scale factor (default 1.0)
  --iters N       iterations (default 20)
  --workers N     sampler threads (default 4)
  --shards N      parameter-server shards (default 4)
";

const FIG4_USAGE: &str = "options:
  --scale F       corpus scale factor (default 1.0)
  --top N         ranks plotted (default 5000)
  --stride N      rank sampling stride (default 10)
";

const FIG5_USAGE: &str = "options:
  --scale F       corpus scale factor (default 1.0)
  --machines N    simulated shard machines (default 30)
  --no-measure    skip the timing measurements
";

const FIG6_USAGE: &str = "options:
  --scale F       corpus scale factor (default 2.0)
  --topics N      number of topics (default 100)
  --iters N       iterations (default 30)
  --workers N     sampler threads (default 4)
  --shards N      parameter-server shards (default 8)
  --eval-every N  perplexity cadence (default 1)
";

const LAUNCHER: CommandSet = CommandSet {
    program: "glint-lda",
    about: "web-scale topic models with an asynchronous parameter server",
    common: COMMON_USAGE,
    commands: &[
        Command {
            name: "train",
            summary: "distributed LightLDA over the parameter server",
            usage: TRAIN_USAGE,
            run: cmd_train,
        },
        Command {
            name: "serve",
            summary: "host parameter-server shards over TCP",
            usage: SERVE_USAGE,
            run: cmd_serve,
        },
        Command {
            name: "serve-model",
            summary: "serve topic inference for unseen documents off live shards",
            usage: SERVE_MODEL_USAGE,
            run: cmd_serve_model,
        },
        Command {
            name: "infer",
            summary: "query a serve-model replica",
            usage: INFER_USAGE,
            run: cmd_infer,
        },
        Command {
            name: "coordinate",
            summary: "run the cluster coordinator for remote `work` processes",
            usage: COORDINATE_USAGE,
            run: cmd_coordinate,
        },
        Command {
            name: "work",
            summary: "join a coordinator as a remote sampler process",
            usage: WORK_USAGE,
            run: cmd_work,
        },
        Command {
            name: "shutdown",
            summary: "stop external `serve` processes",
            usage: SHUTDOWN_USAGE,
            run: cmd_shutdown,
        },
        Command {
            name: "em",
            summary: "Spark-MLlib-style variational EM baseline",
            usage: EM_USAGE,
            run: cmd_em,
        },
        Command {
            name: "online",
            summary: "Spark-MLlib-style Online VB baseline",
            usage: ONLINE_USAGE,
            run: cmd_online,
        },
        Command {
            name: "gen-corpus",
            summary: "generate + save a synthetic ClueWeb12 analogue",
            usage: GEN_CORPUS_USAGE,
            run: cmd_gen_corpus,
        },
        Command {
            name: "eval",
            summary: "perplexity via both the rust and XLA evaluators",
            usage: EVAL_USAGE,
            run: cmd_eval,
        },
        Command {
            name: "table1",
            summary: "reproduce the paper's Table 1",
            usage: TABLE1_USAGE,
            run: cmd_table1,
        },
        Command {
            name: "fig4",
            summary: "reproduce the paper's Figure 4 (Zipf fit)",
            usage: FIG4_USAGE,
            run: cmd_fig4,
        },
        Command {
            name: "fig5",
            summary: "reproduce the paper's Figure 5 (load balance)",
            usage: FIG5_USAGE,
            run: cmd_fig5,
        },
        Command {
            name: "fig6",
            summary: "reproduce the paper's Figure 6 (convergence)",
            usage: FIG6_USAGE,
            run: cmd_fig6,
        },
    ],
};

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    logger::set_level_str(&args.str_or("log", "info"));
    if let Err(e) = install_chaos(&args) {
        eprintln!("error: {e}");
        std::process::exit(2);
    }
    let code = match LAUNCHER.dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

/// Arm the deterministic TCP fault interposer before any mode dials a
/// connection: `--chaos-plan`/`--chaos-seed` win; otherwise the
/// `GLINT_CHAOS_*` environment (how CI legs and spawned demo processes
/// inherit a plan) is consulted.
fn install_chaos(args: &Args) -> Result<()> {
    if let Some(spec) = args.get("chaos-plan") {
        let plan = chaos::parse_plan(spec)?;
        chaos::install(plan, args.get_as("chaos-seed", 1u64)?);
    } else {
        chaos::install_from_env();
    }
    Ok(())
}

fn load_or_generate(args: &Args) -> Result<Corpus> {
    if let Some(path) = args.get("corpus") {
        log_info!("loading corpus from {path}");
        return Corpus::load(&PathBuf::from(path));
    }
    let cfg = SynthConfig {
        num_docs: args.get_as("docs", 8000usize)?,
        vocab_size: args.get_as("vocab", 8000u32)?,
        num_topics: args.get_as("gen-topics", 50usize)?,
        avg_doc_len: args.get_as("avg-len", 80.0f64)?,
        zipf_exponent: args.get_as("zipf", 1.07f64)?,
        seed: args.get_as("seed", 0xc1e0u64)?,
        ..SynthConfig::default()
    };
    log_info!(
        "generating synthetic corpus: {} docs, V={}",
        cfg.num_docs,
        cfg.vocab_size
    );
    Ok(generate(&cfg))
}

/// Split a `host:port,host:port` list into its entries.
fn split_addr_list(raw: &str) -> Vec<String> {
    raw.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect()
}

/// Transport selection for `train`: `--connect` wins over `--transport`.
fn transport_mode(args: &Args) -> Result<TransportMode> {
    if let Some(list) = args.get("connect") {
        let addrs = split_addr_list(list);
        if addrs.is_empty() {
            return Err(Error::Config("--connect needs at least one host:port".into()));
        }
        return Ok(TransportMode::Connect(addrs));
    }
    TransportMode::parse(&args.str_or("transport", "sim"))
        .ok_or_else(|| Error::Config("bad --transport (sim|tcp)".into()))
}

fn parse_scheme(args: &Args) -> Result<PartitionScheme> {
    PartitionScheme::parse(&args.str_or("scheme", "cyclic"))
        .ok_or_else(|| Error::Config("bad --scheme (cyclic|range)".into()))
}

fn parse_layout(args: &Args) -> Result<Layout> {
    Layout::parse(&args.str_or("wt-layout", "sparse"))
        .ok_or_else(|| Error::Config("bad --wt-layout (dense|sparse)".into()))
}

fn train_config(args: &Args) -> Result<TrainConfig> {
    Ok(TrainConfig {
        num_topics: args.get_as("topics", 20u32)?,
        iterations: args.get_as("iters", 20u32)?,
        alpha: args.get_as("alpha", 0.0f64)?,
        beta: args.get_as("beta", 0.01f64)?,
        sampler: SamplerParams {
            mh_steps: args.get_as("mh-steps", 2u32)?,
            block_words: args.get_as("block-words", 2048usize)?,
            buffer_cap: args.get_as("buffer-cap", 100_000usize)?,
            dense_top_words: args.get_as("dense-top", 2000u64)?,
            pipeline_depth: args.get_as("pipeline-depth", 1usize)?,
            alias_dense_threshold: args.get_as("alias-dense-threshold", 0.5f64)?,
        },
        workers: args.get_as("workers", 4usize)?,
        shards: args.get_as("shards", 4usize)?,
        scheme: parse_scheme(args)?,
        wt_layout: parse_layout(args)?,
        transport: transport_mode(args)?,
        seed: args.get_as("seed", 0x1dau64)?,
        eval_every: args.get_as("eval-every", 5u32)?,
        checkpoint_dir: args.get("checkpoint-dir").map(PathBuf::from),
        keep_checkpoints: args.get_as("keep-checkpoints", 3usize)?,
        heartbeat_ms: args.get_as("heartbeat-ms", 1000u64)?,
        straggler_timeout_ms: args.get_as("straggler-ms", 10_000u64)?,
        max_staleness: args.get_as("max-staleness", 1u32)?,
        backups: args.get("backups").map(split_addr_list).unwrap_or_default(),
        elastic: args.flag("elastic"),
        partition_factor: args.get_as("partition-factor", 1usize)?,
        shed_factor: args.get_as("shed-factor", 0.0f64)?,
        shed_stall_ms: args.get_as("shed-stall-ms", 3000u64)?,
        snapshot: args.flag("snapshot"),
        drain_shard_at: parse_drain_shard_at(args)?,
        ..TrainConfig::default()
    })
}

/// `--drain-shard-at ITER:SHARD` → [`TrainConfig::drain_shard_at`].
fn parse_drain_shard_at(args: &Args) -> Result<Option<(u32, usize)>> {
    let Some(spec) = args.get("drain-shard-at") else {
        return Ok(None);
    };
    let bad = || Error::Config(format!("bad --drain-shard-at {spec:?} (want ITER:SHARD)"));
    let (iter, shard) = spec.split_once(':').ok_or_else(bad)?;
    Ok(Some((
        iter.trim().parse::<u32>().map_err(|_| bad())?,
        shard.trim().parse::<usize>().map_err(|_| bad())?,
    )))
}

fn maybe_save(args: &Args, csv: String) -> Result<()> {
    if let Some(out) = args.get("out") {
        std::fs::write(out, csv)?;
        log_info!("report written to {out}");
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let corpus = load_or_generate(args)?;
    let cfg = train_config(args)?;
    let mut trainer = if args.flag("resume") {
        Trainer::restore(cfg, &corpus)?
    } else {
        Trainer::new(cfg, &corpus)?
    };
    let model = trainer.run(&corpus)?;
    let perplexity = trainer.training_perplexity(&model, &corpus);
    log_info!("final training perplexity: {perplexity:.1}");
    log_info!(
        "frozen word-topic table: matrix id {} (serve it with `glint-lda serve-model`)",
        trainer.matrix_id()
    );
    for line in summarize(&model, &corpus.vocab, args.get_as("top-words", 8usize)?)
        .into_iter()
        .take(args.get_as("show-topics", 10usize)?)
    {
        println!("{line}");
    }
    maybe_save(args, trainer.report.to_csv())?;
    if args.flag("shutdown") {
        // Best-effort: a lost shutdown ack must not fail a training run
        // that already succeeded.
        match trainer.shutdown_servers() {
            Ok(()) => log_info!("shard servers stopped"),
            Err(e) => glint_lda::log_warn!("shard shutdown incomplete: {e}"),
        }
    }
    Ok(())
}

/// Host parameter-server shards over TCP (the server half of a
/// multi-process deployment). Blocks until every hosted shard receives a
/// `shutdown` request.
fn cmd_serve(args: &Args) -> Result<()> {
    if let Some(shard) = args.get("drain") {
        let shard = shard
            .parse::<usize>()
            .map_err(|_| Error::Config(format!("bad --drain shard id {shard:?}")))?;
        return cmd_serve_drain(args, shard);
    }
    let binds = split_addr_list(&args.str_or("bind", "127.0.0.1:0"));
    let addrs = resolve_addrs(&binds)?;
    let first_shard = args.get_as("first-shard", 0usize)?;
    let total = match args.get_as("shards", 0usize)? {
        0 => first_shard + addrs.len(),
        n => n,
    };
    let mut cfg = PsConfig { shards: total, scheme: parse_scheme(args)?, ..PsConfig::default() };
    cfg.wal_dir = args.get("wal-dir").map(PathBuf::from);
    cfg.wal_segment_bytes = args.get_as("wal-segment-bytes", cfg.wal_segment_bytes)?;
    cfg.backup_of = args.get("backup-of").map(split_addr_list);
    if let Some(primaries) = &cfg.backup_of {
        if primaries.len() < total {
            return Err(Error::Config(format!(
                "--backup-of names {} primaries for a {total}-shard deployment",
                primaries.len()
            )));
        }
    }
    let server = TcpShardServer::bind(cfg.clone(), first_shard, &addrs)?;
    let role = if cfg.backup_of.is_some() { "backup for shard" } else { "shard" };
    for (i, addr) in server.addrs().iter().enumerate() {
        log_info!("{role} {}/{} listening on {addr}", first_shard + i, total);
    }
    if let Some(dir) = &cfg.wal_dir {
        log_info!("write-ahead logging under {}", dir.display());
    }
    log_info!("serving; stop with `glint-lda shutdown --connect <addrs>`");
    server.join();
    log_info!("all hosted shards shut down");
    Ok(())
}

/// `serve --drain N`: one-shot admin client for a planned shard
/// hand-off. Freezes shard `N`'s serving head (it fsyncs and reports
/// its committed tip), waits for the most caught-up standby to
/// replicate through that tip, promotes it, and exits. No epoch roll:
/// the tip covers the whole commit window, so nothing acked is lost.
fn cmd_serve_drain(args: &Args, shard: usize) -> Result<()> {
    let list = args
        .get("connect")
        .ok_or_else(|| Error::Config("--drain needs --connect with the serving heads".into()))?;
    let heads = split_addr_list(list);
    let resolved = resolve_addrs(&heads)?;
    let mut cfg =
        PsConfig::serving(resolved.len(), parse_scheme(args)?, TransportMode::Connect(heads));
    cfg.backups = args.get("backups").map(split_addr_list).unwrap_or_default();
    if cfg.backups.is_empty() {
        return Err(Error::Config(
            "--drain needs --backups with the standby replicas (tier-major)".into(),
        ));
    }
    let transport = TcpTransport::connect(&resolved);
    let client = PsClient::connect(&transport, cfg);
    let idx = client.drain_shard(shard)?;
    log_info!("shard {shard} drained onto replica {idx} with zero epoch rolls");
    Ok(())
}

/// Serve topic inference for unseen documents off live shards: attach
/// the frozen word-topic table read-mostly by its matrix id, then answer
/// `infer` clients with fixed-budget fold-in until one sends
/// `--shutdown`.
fn cmd_serve_model(args: &Args) -> Result<()> {
    let list = args
        .get("connect")
        .ok_or_else(|| Error::Config("missing required option --connect".into()))?;
    let addrs = split_addr_list(list);
    let resolved = resolve_addrs(&addrs)?;
    let vocab = args.require::<u32>("vocab")?;
    let topics = args.require::<u32>("topics")?;
    let alpha = args.get_as("alpha", 0.0f64)?;
    let hyper = LdaHyper {
        alpha: if alpha > 0.0 { alpha } else { 50.0 / f64::from(topics) },
        beta: args.get_as("beta", 0.01f64)?,
    };
    let cfg = PsConfig::serving(
        resolved.len(),
        parse_scheme(args)?,
        TransportMode::Connect(addrs),
    );
    let transport = TcpTransport::connect(&resolved);
    let client = PsClient::connect(&transport, cfg);
    let engine = InferEngine::attach(
        &client,
        args.get_as("matrix-id", 1u32)?,
        vocab,
        topics,
        parse_layout(args)?,
        hyper,
        InferConfig {
            budget: FoldInBudget {
                sweeps: args.get_as("sweeps", 5u32)?,
                mh_steps: args.get_as("mh-steps", 2u32)?,
            },
            cache_docs: args.get_as("cache-docs", 4096usize)?,
            cache_words: args.get_as("cache-words", 100_000usize)?,
            seed: args.get_as("seed", 0x5e21u64)?,
        },
    )?;
    let window_ms =
        args.get_as("batch-window-ms", DEFAULT_BATCH_WINDOW.as_secs_f64() * 1e3)?;
    let window = Duration::from_secs_f64(window_ms.max(0.0) / 1e3);
    let server = InferServer::start(engine, &args.str_or("bind", "127.0.0.1:0"), window)?;
    log_info!(
        "serve-model replica on {} (V={vocab}, K={topics}, {} shard(s))",
        server.addr(),
        resolved.len()
    );
    log_info!("stop with `glint-lda infer --connect {} --shutdown`", server.addr());
    server.join();
    log_info!("serve-model replica stopped");
    Ok(())
}

/// One document per `--doc`/positional argument, comma-separated ids.
fn parse_doc(raw: &str) -> Result<Vec<u32>> {
    raw.split(',')
        .map(str::trim)
        .filter(|t| !t.is_empty())
        .map(|t| {
            t.parse::<u32>()
                .map_err(|_| Error::Config(format!("bad token id {t:?} in document {raw:?}")))
        })
        .collect()
}

/// Query a serve-model replica: infer documents, print its serving
/// counters, or stop it.
fn cmd_infer(args: &Args) -> Result<()> {
    let addr = args
        .get("connect")
        .ok_or_else(|| Error::Config("missing required option --connect host:port".into()))?;
    let client = InferClient::connect(addr)?;
    if args.flag("shutdown") {
        client.shutdown()?;
        log_info!("serve-model replica at {addr} stopped");
        return Ok(());
    }
    if args.flag("stats") {
        let s = client.stats()?;
        println!(
            "requests {}, docs {} ({} cache hits), batches {}, {} words over {} sparse pulls",
            s.requests, s.docs, s.cache_hits, s.batches, s.words_pulled, s.sparse_pulls
        );
        return Ok(());
    }
    let mut docs: Vec<Vec<u32>> = Vec::new();
    if let Some(d) = args.get("doc") {
        docs.push(parse_doc(d)?);
    }
    for p in &args.positional {
        docs.push(parse_doc(p)?);
    }
    if docs.is_empty() {
        return Err(Error::Config(
            "no documents; pass --doc 1,2,3 (and further comma-separated lists \
             as positional arguments)"
                .into(),
        ));
    }
    let answers = client.infer(&docs)?;
    for (doc, pairs) in docs.iter().zip(&answers) {
        let rendered: Vec<String> =
            pairs.iter().map(|&(t, c)| format!("{t}:{c}")).collect();
        println!("{} token(s) -> {}", doc.len(), rendered.join(" "));
    }
    Ok(())
}

/// Run the cluster coordinator: partition the corpus, serve the control
/// plane for `work` processes, aggregate per-iteration stats, recover
/// from worker failures. Requires running `serve` shards (`--connect`).
fn cmd_coordinate(args: &Args) -> Result<()> {
    let corpus = load_or_generate(args)?;
    let cfg = train_config(args)?;
    // What we tell workers about the corpus: an explicit file wins; a
    // synthetic corpus is described by its generator parameters so each
    // worker regenerates it deterministically.
    let corpus_spec = match args.get("corpus") {
        Some(path) => CorpusSpec::File(path.to_string()),
        None => CorpusSpec::Synth {
            num_docs: args.get_as("docs", 8000usize)? as u64,
            vocab_size: args.get_as("vocab", 8000u32)?,
            num_topics: args.get_as("gen-topics", 50usize)? as u32,
            avg_doc_len: args.get_as("avg-len", 80.0f64)?,
            zipf_exponent: args.get_as("zipf", 1.07f64)?,
            seed: args.get_as("seed", 0xc1e0u64)?,
        },
    };
    let bind = args.str_or("bind", "127.0.0.1:7600");
    let coordinator = Coordinator::bind(&bind, cfg, &corpus, corpus_spec)?;
    log_info!(
        "coordinator listening on {}; join workers with: glint-lda work --join {}",
        coordinator.addr(),
        coordinator.addr()
    );
    let outcome = coordinator.run()?;
    if let Some(p) = outcome.final_perplexity {
        log_info!("final training perplexity: {p:.1}");
    }
    log_info!(
        "run complete: {} epoch roll(s), {} reassignment(s)",
        outcome.epochs,
        outcome.reassignments
    );
    for line in summarize(&outcome.model, &corpus.vocab, args.get_as("top-words", 8usize)?)
        .into_iter()
        .take(args.get_as("show-topics", 10usize)?)
    {
        println!("{line}");
    }
    maybe_save(args, outcome.report.to_csv())
}

/// Join a coordinator as a remote sampler process.
fn cmd_work(args: &Args) -> Result<()> {
    let join = args
        .get("join")
        .ok_or_else(|| Error::Config("missing required option --join host:port".into()))?
        .to_string();
    let corpus = match args.get("corpus") {
        Some(path) => Some(Corpus::load(&PathBuf::from(path))?),
        None => None,
    };
    // Fault-injection hook for demos and tests: crash (exit without
    // reporting) right after sweeping this iteration.
    let crash_at = args.get_as("crash-at", 0u32)?;
    let drain_after = args.get_as("drain-after", 0u32)?;
    let summary = run_worker(WorkerOptions {
        join,
        corpus,
        crash_at_iteration: (crash_at > 0).then_some(crash_at),
        drain_after: (drain_after > 0).then_some(drain_after),
        sweep_delay_ms: args.get_as("sweep-delay-ms", 0u64)?,
    })?;
    let how = if summary.crashed {
        " (simulated crash)"
    } else if summary.drained {
        " (planned drain)"
    } else {
        ""
    };
    log_info!(
        "worker {} exiting after {} sweep(s){how}",
        summary.worker_id,
        summary.sweeps
    );
    Ok(())
}

/// Stop externally running `serve` shards.
fn cmd_shutdown(args: &Args) -> Result<()> {
    let list = args
        .get("connect")
        .ok_or_else(|| Error::Config("missing required option --connect".into()))?;
    let addrs = split_addr_list(list);
    let resolved = resolve_addrs(&addrs)?;
    let cfg = PsConfig {
        shards: resolved.len(),
        transport: TransportMode::Connect(addrs),
        ..PsConfig::default()
    };
    let transport = TcpTransport::connect(&resolved);
    let client = PsClient::connect(&transport, cfg);
    client.shutdown_servers()?;
    log_info!("{} shard(s) stopped", resolved.len());
    Ok(())
}

fn cmd_em(args: &Args) -> Result<()> {
    let corpus = load_or_generate(args)?;
    let cfg = em::EmConfig {
        num_topics: args.get_as("topics", 20u32)?,
        iterations: args.get_as("iters", 20u32)?,
        workers: args.get_as("workers", 4usize)?,
        ..em::EmConfig::default()
    };
    let model = em::train(&cfg, &corpus)?;
    log_info!(
        "EM perplexity {:.1}, simulated shuffle write {:.3} GB",
        model.perplexity(&corpus),
        model.shuffle_bytes as f64 / 1e9
    );
    maybe_save(args, model.report.to_csv())
}

fn cmd_online(args: &Args) -> Result<()> {
    let corpus = load_or_generate(args)?;
    let workers = args.get_as("workers", 4usize)?;
    let cfg = online::OnlineConfig {
        num_topics: args.get_as("topics", 20u32)?,
        epochs: args.get_as("epochs", 2u32)?,
        batch_size: args.get_as("batch", 256usize)?,
        workers,
        ..online::OnlineConfig::default()
    };
    let model = online::train(&cfg, &corpus)?;
    log_info!("Online VB perplexity {:.1}", model.perplexity(&corpus, workers));
    maybe_save(args, model.report.to_csv())
}

fn cmd_gen_corpus(args: &Args) -> Result<()> {
    let corpus = load_or_generate(args)?;
    let out = args.str_or("out", "corpus.bin");
    corpus.save(&PathBuf::from(&out))?;
    log_info!(
        "saved {} docs / {} tokens to {out}",
        corpus.num_docs(),
        corpus.num_tokens()
    );
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    // Train briefly, then evaluate via both the rust and XLA paths —
    // demonstrates the AOT artifacts working from the CLI.
    let corpus = load_or_generate(args)?;
    let mut cfg = train_config(args)?;
    cfg.eval_every = 0;
    let mut trainer = Trainer::new(cfg, &corpus)?;
    let model = trainer.run(&corpus)?;
    let rust_p = trainer.training_perplexity(&model, &corpus);
    println!("rust evaluator: perplexity {rust_p:.2}");
    let artifact_dir = PathBuf::from(args.str_or("artifacts", "artifacts"));
    match glint_lda::runtime::engine::Engine::new(&artifact_dir) {
        Ok(engine) => {
            let counts = trainer.doc_counts();
            let xla_p =
                glint_lda::eval::xla::xla_perplexity(&engine, &model, &corpus, &counts)?;
            println!("xla evaluator ({}): perplexity {xla_p:.2}", engine.platform());
        }
        Err(e) => println!("xla evaluator unavailable: {e}"),
    }
    Ok(())
}

fn cmd_table1(args: &Args) -> Result<()> {
    let cfg = table1::Table1Config {
        scale: args.get_as("scale", 1.0f64)?,
        iterations: args.get_as("iters", 20u32)?,
        workers: args.get_as("workers", 4usize)?,
        shards: args.get_as("shards", 4usize)?,
        ..table1::Table1Config::default()
    };
    let report = table1::run(&cfg)?;
    println!("{}", table1::render_paper_style(&report));
    maybe_save(args, report.to_csv())
}

fn cmd_fig4(args: &Args) -> Result<()> {
    let cfg = fig4::Fig4Config {
        scale: args.get_as("scale", 1.0f64)?,
        top: args.get_as("top", 5000usize)?,
        stride: args.get_as("stride", 10usize)?,
    };
    let r = fig4::run(&cfg)?;
    println!(
        "zipf fit: log f = {:.2} + {:.3} log r  (exponent {:.3})",
        r.intercept, r.slope, -r.slope
    );
    println!("{}", r.report.to_table());
    maybe_save(args, r.report.to_csv())
}

fn cmd_fig5(args: &Args) -> Result<()> {
    let cfg = fig5::Fig5Config {
        scale: args.get_as("scale", 1.0f64)?,
        machines: args.get_as("machines", 30usize)?,
        measure: !args.flag("no-measure"),
    };
    let r = fig5::run(&cfg)?;
    println!("{}", r.report.to_table());
    println!("imbalance factors (max/mean; 1.0 = perfect):");
    for (name, f) in &r.imbalance {
        println!("  {name:>18}: {f:.3}");
    }
    maybe_save(args, r.report.to_csv())
}

fn cmd_fig6(args: &Args) -> Result<()> {
    let cfg = fig6::Fig6Config {
        scale: args.get_as("scale", 2.0f64)?,
        num_topics: args.get_as("topics", 100u32)?,
        iterations: args.get_as("iters", 30u32)?,
        workers: args.get_as("workers", 4usize)?,
        shards: args.get_as("shards", 8usize)?,
        eval_every: args.get_as("eval-every", 1u32)?,
    };
    let r = fig6::run(&cfg)?;
    println!("{}", r.report.to_table());
    println!(
        "final perplexity {:.1}; mean throughput {:.0} tokens/s",
        r.final_perplexity, r.tokens_per_sec
    );
    maybe_save(args, r.report.to_csv())
}
