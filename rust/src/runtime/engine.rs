//! PJRT execution engine.
//!
//! Loads HLO-text artifacts (produced by `python/compile/aot.py`),
//! compiles them once on the PJRT CPU client, and executes them from the
//! rust hot path. HLO *text* is the interchange format: jax ≥ 0.5 emits
//! protos with 64-bit instruction ids that xla_extension 0.5.1 rejects;
//! the text parser reassigns ids (see /opt/xla-example/README.md).
//!
//! The PJRT bindings (`xla` crate) are a vendored, environment-provided
//! dependency, gated behind the off-by-default `xla` cargo feature so
//! the crate builds from a clean checkout. Without the feature an
//! API-identical stub is compiled whose [`Engine::new`] always errors —
//! every caller already degrades gracefully to the pure-rust evaluator.

#[cfg(feature = "xla")]
use std::collections::HashMap;
use std::path::Path;
#[cfg(feature = "xla")]
use std::sync::Mutex;

use crate::runtime::artifacts::{ArtifactSpec, Manifest};
use crate::util::error::{Error, Result};

/// A typed input buffer for one execution.
pub enum Input {
    /// f32 tensor with shape.
    F32(Vec<f32>, Vec<usize>),
}

/// Engine: one PJRT client plus lazily compiled executables.
#[cfg(feature = "xla")]
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    compiled: Mutex<HashMap<String, xla::PjRtLoadedExecutable>>,
}

#[cfg(feature = "xla")]
impl Engine {
    /// Create from an artifact directory (must contain `manifest.json`).
    pub fn new(artifact_dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| Error::Xla(e.to_string()))?;
        Ok(Engine { client, manifest, compiled: Mutex::new(HashMap::new()) })
    }

    /// The manifest in use.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Select the best artifact variant for `name` at `k` topics.
    pub fn select(&self, name: &str, k: usize) -> Result<ArtifactSpec> {
        self.manifest
            .select(name, k)
            .cloned()
            .ok_or_else(|| Error::MissingArtifact(format!("{name} (k >= {k})")))
    }

    fn compile(&self, spec: &ArtifactSpec) -> Result<()> {
        let mut cache = self.compiled.lock().unwrap();
        if cache.contains_key(&spec.file) {
            return Ok(());
        }
        let path = self.manifest.path_of(spec);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| Error::Xla("non-utf8 artifact path".into()))?,
        )
        .map_err(|e| Error::Xla(format!("loading {}: {e}", path.display())))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| Error::Xla(format!("compiling {}: {e}", path.display())))?;
        cache.insert(spec.file.clone(), exe);
        Ok(())
    }

    /// Execute an artifact with f32 inputs; returns the flattened f32
    /// outputs (the graphs are lowered with `return_tuple=True`; tuple
    /// elements are returned in order).
    pub fn run_f32(&self, spec: &ArtifactSpec, inputs: &[Input]) -> Result<Vec<Vec<f32>>> {
        self.compile(spec)?;
        let cache = self.compiled.lock().unwrap();
        let exe = cache.get(&spec.file).expect("compiled above");

        let mut literals = Vec::with_capacity(inputs.len());
        for input in inputs {
            match input {
                Input::F32(values, shape) => {
                    let expect: usize = shape.iter().product();
                    if values.len() != expect {
                        return Err(Error::Config(format!(
                            "input has {} values but shape {:?} needs {expect}",
                            values.len(),
                            shape
                        )));
                    }
                    let lit = if shape.is_empty() {
                        xla::Literal::scalar(values[0])
                    } else {
                        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                        xla::Literal::vec1(values)
                            .reshape(&dims)
                            .map_err(|e| Error::Xla(format!("reshape: {e}")))?
                    };
                    literals.push(lit);
                }
            }
        }
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| Error::Xla(format!("execute: {e}")))?;
        let out_literal = result[0][0]
            .to_literal_sync()
            .map_err(|e| Error::Xla(format!("to_literal: {e}")))?;
        // Graphs are lowered with return_tuple=True.
        let tuple = out_literal
            .to_tuple()
            .map_err(|e| Error::Xla(format!("decompose tuple: {e}")))?;
        let mut outs = Vec::with_capacity(tuple.len());
        for t in tuple {
            outs.push(t.to_vec::<f32>().map_err(|e| Error::Xla(format!("to_vec: {e}")))?);
        }
        Ok(outs)
    }
}

/// Stub engine compiled when the `xla` feature is off (the default).
///
/// Construction always fails with a descriptive error, so every caller's
/// fallback path (skip the XLA evaluator, use the pure-rust one)
/// engages; the remaining methods exist only to keep the API identical.
#[cfg(not(feature = "xla"))]
pub struct Engine {
    manifest: Manifest,
}

#[cfg(not(feature = "xla"))]
impl Engine {
    /// Always fails: the PJRT runtime is not compiled in.
    pub fn new(_artifact_dir: &Path) -> Result<Engine> {
        Err(Error::Xla(
            "glint-lda was built without the `xla` feature; the PJRT evaluator is unavailable"
                .into(),
        ))
    }

    /// The manifest in use.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        "unavailable".into()
    }

    /// Select the best artifact variant for `name` at `k` topics.
    pub fn select(&self, name: &str, k: usize) -> Result<ArtifactSpec> {
        self.manifest
            .select(name, k)
            .cloned()
            .ok_or_else(|| Error::MissingArtifact(format!("{name} (k >= {k})")))
    }

    /// Unreachable in practice: [`Engine::new`] never returns an engine.
    pub fn run_f32(&self, _spec: &ArtifactSpec, _inputs: &[Input]) -> Result<Vec<Vec<f32>>> {
        Err(Error::Xla("glint-lda was built without the `xla` feature".into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifact_dir() -> std::path::PathBuf {
        // Tests run from the workspace root.
        std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn engine_or_skip() -> Option<Engine> {
        let dir = artifact_dir();
        match Engine::new(&dir) {
            Ok(e) => Some(e),
            Err(_) => {
                eprintln!("skipping engine test: run `make artifacts` first");
                None
            }
        }
    }

    #[test]
    fn engine_loads_and_runs_perplexity() {
        let Some(engine) = engine_or_skip() else { return };
        let Ok(spec) = engine.select("perplexity", 8) else {
            eprintln!("no perplexity artifact; skipping");
            return;
        };
        let d = spec.batch;
        let k = spec.k;
        let vb = spec.vblock;
        // Uniform model: theta = 1/k, phi = 1/vb; one token of word 0 in
        // every doc => per-doc loglik = ln(1/vb).
        let n_dk = vec![0f32; d * k];
        let n_wk = vec![0f32; k * vb];
        let n_k = vec![0f32; k];
        let mut counts = vec![0f32; d * vb];
        for doc in 0..d {
            counts[doc * vb] = 1.0;
        }
        let scalars = |v: f32| Input::F32(vec![v], vec![]);
        let out = engine
            .run_f32(
                &spec,
                &[
                    Input::F32(n_dk, vec![d, k]),
                    Input::F32(n_wk, vec![k, vb]),
                    Input::F32(n_k, vec![k]),
                    Input::F32(counts, vec![d, vb]),
                    scalars(0.5),       // alpha
                    scalars(1.0),       // beta
                    scalars(vb as f32), // vocab size (for the phi denominator)
                    scalars(k as f32),  // k_real (no padding here)
                ],
            )
            .unwrap();
        let loglik = &out[0];
        let want = (1.0 / vb as f32).ln();
        for (i, &ll) in loglik.iter().enumerate() {
            assert!((ll - want).abs() < 1e-3, "doc {i}: {ll} vs {want}");
        }
    }
}
