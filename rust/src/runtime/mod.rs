//! XLA/PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts and
//! executes them from rust. Python never runs after `make artifacts`.

pub mod artifacts;
pub mod engine;

pub use artifacts::{ArtifactSpec, Manifest};
pub use engine::Engine;
