//! Artifact manifest: what `make artifacts` produced and with which
//! static shapes.
//!
//! `python/compile/aot.py` lowers each L2 graph for a set of static shape
//! configurations (XLA AOT requires fixed shapes) and records them in
//! `artifacts/manifest.json`. The rust side picks the smallest compiled
//! variant that fits the model at hand and pads inputs up to it.

use std::path::{Path, PathBuf};

use crate::util::error::{Error, Result};
use crate::util::json::Json;

/// One compiled artifact variant.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    /// Graph name, e.g. `"perplexity"`.
    pub name: String,
    /// HLO text file, relative to the artifact dir.
    pub file: String,
    /// Document batch size D.
    pub batch: usize,
    /// Padded topic count K.
    pub k: usize,
    /// Vocabulary block width V_B.
    pub vblock: usize,
    /// Whether the graph embeds the Pallas kernel (vs pure-jnp reference
    /// lowering).
    pub pallas: bool,
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Directory the manifest was loaded from.
    pub dir: PathBuf,
    /// All artifact variants.
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|_| {
            Error::MissingArtifact(format!("{} (manifest)", path.display()))
        })?;
        Self::parse(dir, &text)
    }

    /// Parse manifest JSON.
    pub fn parse(dir: &Path, text: &str) -> Result<Manifest> {
        let j = Json::parse(text)?;
        let version = j
            .get("version")
            .and_then(Json::as_usize)
            .ok_or_else(|| Error::Decode("manifest missing version".into()))?;
        if version != 1 {
            return Err(Error::Decode(format!("unsupported manifest version {version}")));
        }
        let arts = j
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::Decode("manifest missing artifacts".into()))?;
        let mut artifacts = Vec::with_capacity(arts.len());
        for a in arts {
            let get_usize = |key: &str| {
                a.get(key)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| Error::Decode(format!("artifact missing {key}")))
            };
            artifacts.push(ArtifactSpec {
                name: a
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| Error::Decode("artifact missing name".into()))?
                    .to_string(),
                file: a
                    .get("file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| Error::Decode("artifact missing file".into()))?
                    .to_string(),
                batch: get_usize("batch")?,
                k: get_usize("k")?,
                vblock: get_usize("vblock")?,
                pallas: matches!(a.get("pallas"), Some(Json::Bool(true))),
            });
        }
        Ok(Manifest { dir: dir.to_path_buf(), artifacts })
    }

    /// Absolute path of an artifact's HLO file.
    pub fn path_of(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }

    /// Pick the variant of `name` with the smallest padded K that still
    /// fits `k` topics (preferring the Pallas build when both exist).
    pub fn select(&self, name: &str, k: usize) -> Option<&ArtifactSpec> {
        self.artifacts
            .iter()
            .filter(|a| a.name == name && a.k >= k)
            .min_by_key(|a| (a.k, !a.pallas as usize))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "version": 1,
        "artifacts": [
            {"name": "perplexity", "file": "p_k128.hlo.txt", "batch": 64,
             "k": 128, "vblock": 2048, "pallas": true},
            {"name": "perplexity", "file": "p_k1024.hlo.txt", "batch": 64,
             "k": 1024, "vblock": 2048, "pallas": true},
            {"name": "perplexity_ref", "file": "pref_k128.hlo.txt", "batch": 64,
             "k": 128, "vblock": 2048, "pallas": false}
        ]
    }"#;

    #[test]
    fn parse_and_select() {
        let m = Manifest::parse(Path::new("/tmp/arts"), SAMPLE).unwrap();
        assert_eq!(m.artifacts.len(), 3);
        let s = m.select("perplexity", 100).unwrap();
        assert_eq!(s.k, 128);
        let s = m.select("perplexity", 129).unwrap();
        assert_eq!(s.k, 1024);
        assert!(m.select("perplexity", 2000).is_none());
        assert!(m.select("unknown", 1).is_none());
    }

    #[test]
    fn path_resolution() {
        let m = Manifest::parse(Path::new("/tmp/arts"), SAMPLE).unwrap();
        let p = m.path_of(&m.artifacts[0]);
        assert_eq!(p, PathBuf::from("/tmp/arts/p_k128.hlo.txt"));
    }

    #[test]
    fn missing_fields_rejected() {
        assert!(Manifest::parse(Path::new("."), r#"{"version": 1}"#).is_err());
        assert!(Manifest::parse(Path::new("."), r#"{"version": 9, "artifacts": []}"#).is_err());
        let ok = Manifest::parse(Path::new("."), r#"{"version": 1, "artifacts": []}"#).unwrap();
        assert!(ok.artifacts.is_empty());
    }
}
