//! Figure 4: the Zipfian word-frequency distribution of the corpus.
//!
//! The paper plots the top-5000 most common words of ClueWeb12 (after
//! stop-word removal and stemming) against their frequency on log-log
//! axes. We regenerate the plot data from the synthetic analogue and fit
//! the slope, verifying it matches the web-text exponent the generator
//! was calibrated to.

use crate::corpus::synth::generate;
use crate::corpus::zipf::fit_slope;
use crate::metrics::{Report, Row};
use crate::util::error::Result;

/// Fig. 4 harness configuration.
#[derive(Debug, Clone)]
pub struct Fig4Config {
    /// Reference corpus scale.
    pub scale: f64,
    /// Number of top ranks to emit (paper: 5000).
    pub top: usize,
    /// Emit every n-th rank to keep the series compact (1 = all).
    pub stride: usize,
}

impl Default for Fig4Config {
    fn default() -> Self {
        Fig4Config { scale: 1.0, top: 5000, stride: 1 }
    }
}

/// Result of the Fig. 4 run.
pub struct Fig4Result {
    /// (rank, frequency) series, rank starting at 1.
    pub report: Report,
    /// Fitted log-log slope (Zipf exponent is `-slope`).
    pub slope: f64,
    /// Fitted intercept.
    pub intercept: f64,
}

/// Run the experiment.
pub fn run(cfg: &Fig4Config) -> Result<Fig4Result> {
    let corpus = generate(&super::reference_corpus_config(cfg.scale));
    let counts = corpus.word_counts();
    let top = cfg.top.min(counts.len());
    // Word ids ARE frequency ranks (corpus invariant), so counts are
    // already rank-ordered.
    let head = &counts[..top];
    let (intercept, slope) = fit_slope(head);
    let report = Report::new();
    for (r, &c) in head.iter().enumerate().step_by(cfg.stride.max(1)) {
        if c == 0 {
            continue;
        }
        report.push(
            Row::new()
                .set("rank", (r + 1) as f64)
                .set("frequency", c as f64)
                .set("log_rank", ((r + 1) as f64).ln())
                .set("log_frequency", (c as f64).ln()),
        );
    }
    Ok(Fig4Result { report, slope, intercept })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slope_is_web_like() {
        let r = run(&Fig4Config { scale: 0.15, top: 1000, stride: 1 }).unwrap();
        assert!(
            (-1.6..=-0.7).contains(&r.slope),
            "slope {} outside web-text range",
            r.slope
        );
        assert!(r.report.len() > 500);
    }

    #[test]
    fn series_is_monotonically_decreasing() {
        let r = run(&Fig4Config { scale: 0.1, top: 500, stride: 1 }).unwrap();
        let freqs: Vec<f64> =
            r.report.rows().iter().map(|row| row.get("frequency").unwrap()).collect();
        assert!(freqs.windows(2).all(|w| w[0] >= w[1]));
    }
}
