//! Experiment harnesses: one module per paper table/figure.
//!
//! Each harness is a library function returning a [`crate::metrics::Report`]
//! so it can be driven from the CLI (`glint-lda table1`), from the bench
//! binaries (`cargo bench --bench table1`), and from tests. The scale
//! knob maps the paper's cluster-sized workloads onto this machine; see
//! DESIGN.md §Substitutions for the correspondence.

pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod table1;

use crate::corpus::synth::SynthConfig;

/// Shared experiment scale: the synthetic analogue of "10% of ClueWeb12
/// B13" at a size this machine sweeps in minutes. All experiments derive
/// their corpora from this so results are mutually comparable.
pub fn reference_corpus_config(scale: f64) -> SynthConfig {
    SynthConfig {
        num_docs: ((8000.0 * scale) as usize).max(50),
        vocab_size: ((8000.0 * scale) as u32).clamp(500, 60_000),
        num_topics: 50,
        avg_doc_len: 80.0,
        zipf_exponent: 1.07,
        stopwords_removed: 100,
        doc_topic_alpha: 0.12,
        topic_distinctness: 2.0,
        seed: 0xc1e0,
    }
}
