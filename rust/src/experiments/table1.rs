//! Table 1: perplexity / runtime / shuffle write for our implementation
//! vs Spark EM LDA vs Spark Online LDA, sweeping corpus size and K.
//!
//! The paper's grid: data size ∈ {2.5, 5, 7.5, 10}% of ClueWeb12 B13 at
//! K = 20, and K ∈ {20, 40, 60, 80} at 10%. Our "10%" is the scaled
//! reference corpus (DESIGN.md §Substitutions); the comparison shape —
//! who wins on runtime, perplexity parity, who shuffles — is what must
//! reproduce.

use crate::baselines::{em, online};
use crate::corpus::dataset::Corpus;
use crate::corpus::synth::generate;

use crate::lda::trainer::{TrainConfig, Trainer};
use crate::metrics::{Report, Row};
use crate::util::error::Result;
use crate::util::timer::Stopwatch;
use crate::{log_info, log_warn};

/// Table 1 harness configuration.
#[derive(Debug, Clone)]
pub struct Table1Config {
    /// Scale of the "10%" reference corpus (1.0 ≈ 8 k docs).
    pub scale: f64,
    /// Iterations for our implementation and EM; online uses epochs
    /// sized to see the corpus the same number of times Spark's default
    /// would.
    pub iterations: u32,
    /// Worker threads for every algorithm (fair comparison).
    pub workers: usize,
    /// Parameter-server shards for our implementation.
    pub shards: usize,
    /// Fractions of the reference corpus (paper: 0.25, 0.5, 0.75, 1.0
    /// of the 10% subset).
    pub size_fractions: Vec<f64>,
    /// Topic counts at full size (paper: 20, 40, 60, 80).
    pub k_sweep: Vec<u32>,
    /// Which algorithms to include ("ours", "em", "online").
    pub algos: Vec<String>,
}

impl Default for Table1Config {
    fn default() -> Self {
        Table1Config {
            scale: 1.0,
            iterations: 20,
            workers: 4,
            shards: 4,
            size_fractions: vec![0.25, 0.5, 0.75, 1.0],
            k_sweep: vec![20, 40, 60, 80],
            algos: vec!["ours".into(), "em".into(), "online".into()],
        }
    }
}

/// One cell of Table 1.
fn run_cell(
    cfg: &Table1Config,
    corpus: &Corpus,
    size_label: f64,
    k: u32,
    algo: &str,
) -> Result<Row> {
    let sw = Stopwatch::new();
    let (perplexity, shuffle_gb) = match algo {
        "ours" => {
            let tc = TrainConfig {
                num_topics: k,
                iterations: cfg.iterations,
                workers: cfg.workers,
                shards: cfg.shards,
                ..TrainConfig::default()
            };
            let mut t = Trainer::new(tc, corpus)?;
            let model = t.run(corpus)?;
            (t.training_perplexity(&model, corpus), 0.0)
        }
        "em" => {
            let ec = em::EmConfig {
                num_topics: k,
                iterations: cfg.iterations,
                workers: cfg.workers,
                ..em::EmConfig::default()
            };
            let m = em::train(&ec, corpus)?;
            (m.perplexity(corpus), m.shuffle_bytes as f64 / 1e9)
        }
        "online" => {
            let oc = online::OnlineConfig {
                num_topics: k,
                epochs: (cfg.iterations / 10).max(1),
                batch_size: (corpus.num_docs() / 20).max(16),
                workers: cfg.workers,
                ..online::OnlineConfig::default()
            };
            let m = online::train(&oc, corpus)?;
            (m.perplexity(corpus, cfg.workers), 0.0)
        }
        other => {
            return Err(crate::util::error::Error::Config(format!(
                "unknown algorithm {other}"
            )))
        }
    };
    let seconds = sw.secs();
    log_info!(
        "table1 cell: size {:.1}% K={k} {algo}: perplexity {perplexity:.0}, {seconds:.1}s, shuffle {shuffle_gb:.3} GB",
        size_label * 10.0
    );
    Ok(Row::new()
        .set("size_pct", size_label * 10.0)
        .set("k", k as f64)
        .set("algo", algo_code(algo))
        .set("perplexity", perplexity)
        .set("runtime_s", seconds)
        .set("shuffle_gb", shuffle_gb))
}

/// Numeric algorithm code for CSV rows (0=ours, 1=em, 2=online).
pub fn algo_code(algo: &str) -> f64 {
    match algo {
        "ours" => 0.0,
        "em" => 1.0,
        _ => 2.0,
    }
}

/// Run the full Table 1 grid.
pub fn run(cfg: &Table1Config) -> Result<Report> {
    let report = Report::new();
    let reference = generate(&super::reference_corpus_config(cfg.scale));
    log_info!(
        "table1: reference corpus {} docs, {} tokens, V={}",
        reference.num_docs(),
        reference.num_tokens(),
        reference.vocab_size
    );

    // Size sweep at K = first k.
    let k0 = *cfg.k_sweep.first().unwrap_or(&20);
    for &frac in &cfg.size_fractions {
        let sub = if (frac - 1.0).abs() < 1e-9 {
            reference.clone()
        } else {
            reference.subset(frac, 0x5ab)
        };
        for algo in &cfg.algos {
            match run_cell(cfg, &sub, frac, k0, algo) {
                Ok(row) => report.push(row),
                Err(e) => log_warn!("cell failed ({algo}, frac {frac}): {e}"),
            }
        }
    }
    // K sweep at full size (skip the K already measured).
    for &k in cfg.k_sweep.iter().filter(|&&k| k != k0) {
        for algo in &cfg.algos {
            match run_cell(cfg, &reference, 1.0, k, algo) {
                Ok(row) => report.push(row),
                Err(e) => log_warn!("cell failed ({algo}, K {k}): {e}"),
            }
        }
    }
    Ok(report)
}

/// Render the report the way the paper prints Table 1 (grouped metric
/// blocks, one line per grid cell, columns = algorithms).
pub fn render_paper_style(report: &Report) -> String {
    let rows = report.rows();
    let mut out = String::new();
    let algos = ["ours", "em", "online"];
    for (metric, title, unit) in [
        ("perplexity", "Perplexity", ""),
        ("runtime_s", "Runtime", " (s)"),
        ("shuffle_gb", "Shuffle write", " (GB)"),
    ] {
        out.push_str(&format!("\n== {title}{unit} ==\n"));
        out.push_str(&format!(
            "{:>9} {:>5} {:>12} {:>12} {:>12}\n",
            "size", "K", "ours", "spark-em", "spark-online"
        ));
        let mut cells: Vec<(f64, f64)> = rows
            .iter()
            .map(|r| (r.get("size_pct").unwrap_or(0.0), r.get("k").unwrap_or(0.0)))
            .collect();
        cells.dedup();
        let mut seen = std::collections::BTreeSet::new();
        for (size, k) in cells {
            if !seen.insert(((size * 10.0) as i64, k as i64)) {
                continue;
            }
            let mut line = format!("{size:>8.1}% {k:>5.0}");
            for (i, _) in algos.iter().enumerate() {
                let v = rows
                    .iter()
                    .find(|r| {
                        r.get("size_pct") == Some(size)
                            && r.get("k") == Some(k)
                            && r.get("algo") == Some(i as f64)
                    })
                    .and_then(|r| r.get(metric));
                match v {
                    Some(x) => line.push_str(&format!(" {x:>12.1}")),
                    None => line.push_str(&format!(" {:>12}", "-")),
                }
            }
            out.push_str(&line);
            out.push('\n');
        }
    }
    out
}

/// Quality cross-check used by integration tests: the three algorithms'
/// perplexities on the same corpus must be within `tolerance` of each
/// other (the paper's observation that "perplexity is roughly equal for
/// all algorithms").
pub fn perplexity_parity(report: &Report, tolerance: f64) -> bool {
    let rows = report.rows();
    let cells: std::collections::BTreeSet<(i64, i64)> = rows
        .iter()
        .map(|r| {
            (
                (r.get("size_pct").unwrap_or(0.0) * 10.0) as i64,
                r.get("k").unwrap_or(0.0) as i64,
            )
        })
        .collect();
    for (s, k) in cells {
        let ps: Vec<f64> = rows
            .iter()
            .filter(|r| {
                (r.get("size_pct").unwrap_or(0.0) * 10.0) as i64 == s
                    && r.get("k").unwrap_or(0.0) as i64 == k
            })
            .filter_map(|r| r.get("perplexity"))
            .collect();
        if ps.len() > 1 {
            let min = ps.iter().cloned().fold(f64::INFINITY, f64::min);
            let max = ps.iter().cloned().fold(0.0f64, f64::max);
            if max / min > 1.0 + tolerance {
                return false;
            }
        }
    }
    true
}

/// Scaled-down grid used by `cargo test` integration tests and the bench
/// smoke path.
pub fn smoke_config() -> Table1Config {
    Table1Config {
        scale: 0.08,
        iterations: 8,
        workers: 3,
        shards: 3,
        size_fractions: vec![0.5, 1.0],
        k_sweep: vec![10, 20],
        algos: vec!["ours".into(), "em".into(), "online".into()],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::perplexity::TopicModel;
    use crate::lda::sparse_counts::DocTopicCounts;

    #[test]
    fn algo_codes_distinct() {
        assert_eq!(algo_code("ours"), 0.0);
        assert_eq!(algo_code("em"), 1.0);
        assert_eq!(algo_code("online"), 2.0);
    }

    #[test]
    fn smoke_grid_runs_and_has_expected_shape() {
        let report = run(&smoke_config()).unwrap();
        // 2 sizes * 3 algos + 1 extra K * 3 algos = 9 rows.
        assert_eq!(report.len(), 9);
        // Ours never shuffles; EM always does.
        for row in report.rows() {
            let algo = row.get("algo").unwrap();
            let shuffle = row.get("shuffle_gb").unwrap();
            if algo == 0.0 || algo == 2.0 {
                assert_eq!(shuffle, 0.0, "ours/online must not shuffle");
            } else {
                assert!(shuffle > 0.0, "EM must shuffle");
            }
            assert!(row.get("perplexity").unwrap().is_finite());
        }
        // Paper: perplexity roughly equal across algorithms (we allow a
        // generous 40% band at this tiny scale).
        assert!(perplexity_parity(&report, 0.4), "{}", report.to_csv());
    }

    #[test]
    fn render_contains_all_blocks() {
        let report = Report::new();
        report.push(
            Row::new()
                .set("size_pct", 10.0)
                .set("k", 20.0)
                .set("algo", 0.0)
                .set("perplexity", 6108.0)
                .set("runtime_s", 6.3)
                .set("shuffle_gb", 0.0),
        );
        let s = render_paper_style(&report);
        assert!(s.contains("Perplexity"));
        assert!(s.contains("Runtime"));
        assert!(s.contains("Shuffle write"));
        assert!(s.contains("6108"));
    }

    // Silence unused-import warnings for items used only transitively.
    #[allow(dead_code)]
    fn _types(_: TopicModel, _: DocTopicCounts) {}

    #[test]
    fn parity_helper_detects_divergence() {
        let report = Report::new();
        for (algo, p) in [(0.0, 1000.0), (1.0, 5000.0)] {
            report.push(
                Row::new()
                    .set("size_pct", 10.0)
                    .set("k", 20.0)
                    .set("algo", algo)
                    .set("perplexity", p),
            );
        }
        assert!(!perplexity_parity(&report, 0.4));
    }

}
