//! Figure 6: perplexity over time for the web-scale run.
//!
//! The paper trains K=1000 on the full 27 TB ClueWeb12 for ~80 hours and
//! plots model perplexity against wall-clock time, converging to ~4250.
//! The scaled analogue trains the reference corpus at a large K with
//! per-iteration perplexity logging; the shape to reproduce is the
//! monotone convergence curve (fast early drop, long tail).

use crate::lda::trainer::{TrainConfig, Trainer};
use crate::metrics::{Report, Row};
use crate::util::error::Result;
use crate::util::timer::Stopwatch;

/// Fig. 6 harness configuration.
#[derive(Debug, Clone)]
pub struct Fig6Config {
    /// Reference corpus scale (the "web-scale" run uses > 1.0).
    pub scale: f64,
    /// Topics (paper: 1000; scaled default: 100).
    pub num_topics: u32,
    /// Iterations.
    pub iterations: u32,
    /// Worker threads.
    pub workers: usize,
    /// Parameter-server shards.
    pub shards: usize,
    /// Evaluate every n iterations.
    pub eval_every: u32,
}

impl Default for Fig6Config {
    fn default() -> Self {
        Fig6Config {
            scale: 2.0,
            num_topics: 100,
            iterations: 30,
            workers: 4,
            shards: 8,
            eval_every: 1,
        }
    }
}

/// Fig. 6 output.
pub struct Fig6Result {
    /// Rows: iter, wall_clock_s, perplexity.
    pub report: Report,
    /// Final perplexity.
    pub final_perplexity: f64,
    /// Total tokens sampled per second (mean over iterations).
    pub tokens_per_sec: f64,
}

/// Run the experiment.
pub fn run(cfg: &Fig6Config) -> Result<Fig6Result> {
    let corpus = crate::corpus::synth::generate(&super::reference_corpus_config(cfg.scale));
    let tc = TrainConfig {
        num_topics: cfg.num_topics,
        iterations: cfg.iterations,
        workers: cfg.workers,
        shards: cfg.shards,
        ..TrainConfig::default()
    };
    let mut trainer = Trainer::new(tc, &corpus)?;
    let report = Report::new();
    let clock = Stopwatch::new();
    let mut final_p = f64::NAN;
    let mut tokens_total = 0u64;
    for iter in 1..=cfg.iterations {
        let stats = trainer.run_iteration()?;
        tokens_total += stats.tokens;
        if iter % cfg.eval_every == 0 || iter == cfg.iterations {
            let model = trainer.pull_model()?;
            let p = trainer.training_perplexity(&model, &corpus);
            final_p = p;
            crate::log_info!(
                "fig6: iter {iter} t={:.1}s perplexity {p:.1}",
                clock.secs()
            );
            report.push(
                Row::new()
                    .set("iter", iter as f64)
                    .set("wall_clock_s", clock.secs())
                    .set("perplexity", p),
            );
        }
    }
    let tokens_per_sec = tokens_total as f64 / clock.secs().max(1e-9);
    Ok(Fig6Result { report, final_perplexity: final_p, tokens_per_sec })
}

/// Convergence-shape check used by tests: perplexity must decrease
/// overall, with the per-iteration improvement rate not *accelerating*
/// at the end (paper's Figure 6: steep early drop, flattening tail).
/// Short runs that are still in the near-linear regime pass as long as
/// the early rate is at least half the late rate.
pub fn is_convergence_shaped(report: &Report) -> bool {
    let ps: Vec<f64> =
        report.rows().iter().filter_map(|r| r.get("perplexity")).collect();
    if ps.len() < 4 {
        return false;
    }
    let first = ps[0];
    let third = ps[ps.len() / 3];
    let last = *ps.last().unwrap();
    if last >= first * 0.999 {
        return false; // no overall improvement
    }
    let early_rate = (first - third) / (ps.len() / 3).max(1) as f64;
    let late_rate = (third - last) / (ps.len() - ps.len() / 3) as f64;
    early_rate > 0.5 * late_rate.max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn converges_with_fig6_shape() {
        let r = run(&Fig6Config {
            scale: 0.08,
            num_topics: 16,
            iterations: 12,
            workers: 3,
            shards: 3,
            eval_every: 1,
        })
        .unwrap();
        assert!(r.final_perplexity.is_finite());
        assert!(
            is_convergence_shaped(&r.report),
            "curve not convergence-shaped:\n{}",
            r.report.to_csv()
        );
        assert!(r.tokens_per_sec > 0.0);
    }

    #[test]
    fn shape_helper_rejects_flat_curves() {
        let report = Report::new();
        for i in 0..6 {
            report.push(Row::new().set("iter", i as f64).set("perplexity", 100.0));
        }
        assert!(!is_convergence_shaped(&report));
    }
}
