//! Figure 5: the implicit load-balancing property of cyclic partitioning
//! over frequency-ordered features.
//!
//! The paper computes, for 30 machines, the expected proportion of
//! requests each machine receives given the corpus token counts, under
//! (a) cyclic partitioning of frequency-ordered features and (b) the
//! same after randomly shuffling feature order. We add (c) range
//! partitioning of ordered features — the naive layout whose head-word
//! hotspot motivates the whole trick — and validate the analytic model
//! against *measured* per-shard request counts from an actual training
//! run over the parameter server.

use crate::corpus::synth::generate;
use crate::lda::sweep::SamplerParams;
use crate::lda::trainer::{TrainConfig, Trainer};
use crate::metrics::{Report, Row};
use crate::ps::partition::{PartitionScheme, Partitioner};
use crate::util::error::Result;
use crate::util::rng::Pcg64;

/// Fig. 5 harness configuration.
#[derive(Debug, Clone)]
pub struct Fig5Config {
    /// Reference corpus scale.
    pub scale: f64,
    /// Number of machines (paper: 30).
    pub machines: usize,
    /// Also run a real (small) training job and measure per-shard
    /// request counts from the transport.
    pub measure: bool,
}

impl Default for Fig5Config {
    fn default() -> Self {
        Fig5Config { scale: 1.0, machines: 30, measure: true }
    }
}

/// Expected request share per machine for a layout.
fn expected_share(
    counts: &[u64],
    machines: usize,
    scheme: PartitionScheme,
    order: &[u32],
) -> Vec<f64> {
    let part = Partitioner::new(counts.len() as u64, machines, scheme);
    let mut load = vec![0f64; machines];
    for (row, &word) in order.iter().enumerate() {
        load[part.shard_of(row as u64)] += counts[word as usize] as f64;
    }
    let total: f64 = load.iter().sum();
    load.iter().map(|&l| l / total.max(1.0)).collect()
}

/// Max/mean imbalance factor (1.0 = perfectly balanced).
pub fn imbalance(shares: &[f64]) -> f64 {
    let mean = 1.0 / shares.len() as f64;
    shares.iter().cloned().fold(0.0f64, f64::max) / mean
}

/// Fig. 5 output: per-machine shares per layout plus summary factors.
pub struct Fig5Result {
    /// Rows: machine, share_cyclic_ordered, share_cyclic_shuffled,
    /// share_range_ordered (+ measured_share when measured).
    pub report: Report,
    /// Imbalance factors by layout name.
    pub imbalance: Vec<(String, f64)>,
}

/// Run the experiment.
pub fn run(cfg: &Fig5Config) -> Result<Fig5Result> {
    let corpus = generate(&super::reference_corpus_config(cfg.scale));
    let counts = corpus.word_counts();
    let v = counts.len();
    let identity: Vec<u32> = (0..v as u32).collect();
    let mut shuffled = identity.clone();
    Pcg64::new(0xf15).shuffle(&mut shuffled);

    let cyc_ord = expected_share(&counts, cfg.machines, PartitionScheme::Cyclic, &identity);
    let cyc_shuf = expected_share(&counts, cfg.machines, PartitionScheme::Cyclic, &shuffled);
    let rng_ord = expected_share(&counts, cfg.machines, PartitionScheme::Range, &identity);

    // Measured: run two iterations of actual training on `machines`
    // shards and read the transport's per-endpoint request counters.
    let measured = if cfg.measure {
        let tc = TrainConfig {
            num_topics: 16,
            iterations: 2,
            workers: 4,
            shards: cfg.machines,
            sampler: SamplerParams { block_words: 512, ..Default::default() },
            ..TrainConfig::default()
        };
        let sub = corpus.subset(0.25, 0x515);
        let mut t = Trainer::new(tc, &sub)?;
        t.run_iteration()?;
        t.run_iteration()?;
        let reqs = t.shard_request_counts();
        let total: u64 = reqs.iter().sum();
        Some(reqs.iter().map(|&r| r as f64 / total.max(1) as f64).collect::<Vec<_>>())
    } else {
        None
    };

    let report = Report::new();
    for m in 0..cfg.machines {
        let mut row = Row::new()
            .set("machine", m as f64)
            .set("cyclic_ordered", cyc_ord[m])
            .set("cyclic_shuffled", cyc_shuf[m])
            .set("range_ordered", rng_ord[m]);
        if let Some(ms) = &measured {
            row = row.set("measured", ms[m]);
        }
        report.push(row);
    }
    let mut imb = vec![
        ("cyclic_ordered".to_string(), imbalance(&cyc_ord)),
        ("cyclic_shuffled".to_string(), imbalance(&cyc_shuf)),
        ("range_ordered".to_string(), imbalance(&rng_ord)),
    ];
    if let Some(ms) = &measured {
        imb.push(("measured".to_string(), imbalance(ms)));
    }
    Ok(Fig5Result { report, imbalance: imb })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cyclic_ordered_beats_alternatives() {
        let r = run(&Fig5Config { scale: 0.15, machines: 10, measure: false }).unwrap();
        let get = |name: &str| {
            r.imbalance.iter().find(|(n, _)| n == name).map(|(_, v)| *v).unwrap()
        };
        let cyc = get("cyclic_ordered");
        let shuf = get("cyclic_shuffled");
        let range = get("range_ordered");
        // The paper's claim: cyclic partitioning on ordered features is
        // the most balanced; range on ordered features concentrates the
        // Zipf head catastrophically.
        assert!(cyc < shuf, "cyclic ordered {cyc} vs shuffled {shuf}");
        assert!(cyc < range, "cyclic ordered {cyc} vs range {range}");
        assert!(range > 2.0, "range layout must be badly imbalanced: {range}");
        assert!(cyc < 1.2, "cyclic ordered should be near-uniform: {cyc}");
    }

    #[test]
    fn shares_sum_to_one() {
        let r = run(&Fig5Config { scale: 0.1, machines: 7, measure: false }).unwrap();
        for col in ["cyclic_ordered", "cyclic_shuffled", "range_ordered"] {
            let total: f64 =
                r.report.rows().iter().map(|row| row.get(col).unwrap()).sum();
            assert!((total - 1.0).abs() < 1e-9, "{col} sums to {total}");
        }
    }

    #[test]
    fn measured_traffic_roughly_balanced_for_cyclic() {
        let r = run(&Fig5Config { scale: 0.08, machines: 5, measure: true }).unwrap();
        let measured =
            r.imbalance.iter().find(|(n, _)| n == "measured").map(|(_, v)| *v).unwrap();
        // Measured includes control traffic (GenUid/Forget spread evenly)
        // so it should be quite balanced.
        assert!(measured < 1.5, "measured imbalance {measured}");
    }
}
