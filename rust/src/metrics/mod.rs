//! Training/runtime metrics: counters, gauges, per-iteration reports and
//! CSV emission for the experiment harnesses.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// A monotonically increasing counter (tokens sampled, messages sent...).
#[derive(Debug, Default)]
pub struct Counter {
    value: AtomicU64,
}

impl Counter {
    /// Increment by `n`.
    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    /// Increment by one.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// One row of an iteration report: named numeric fields in insertion
/// order, e.g. `iter, seconds, perplexity, tokens_per_sec`.
#[derive(Debug, Clone, Default)]
pub struct Row {
    fields: Vec<(String, f64)>,
}

impl Row {
    /// Empty row.
    pub fn new() -> Row {
        Row::default()
    }

    /// Add (or overwrite) a field; returns self for chaining.
    pub fn set(mut self, key: &str, value: f64) -> Row {
        if let Some(slot) = self.fields.iter_mut().find(|(k, _)| k == key) {
            slot.1 = value;
        } else {
            self.fields.push((key.to_string(), value));
        }
        self
    }

    /// Read a field.
    pub fn get(&self, key: &str) -> Option<f64> {
        self.fields.iter().find(|(k, _)| k == key).map(|(_, v)| *v)
    }

    /// Field names in order.
    pub fn keys(&self) -> Vec<&str> {
        self.fields.iter().map(|(k, _)| k.as_str()).collect()
    }
}

/// Collects rows (one per iteration / experiment cell) and renders them
/// as an aligned table or CSV.
#[derive(Debug, Default)]
pub struct Report {
    rows: Mutex<Vec<Row>>,
}

impl Clone for Report {
    fn clone(&self) -> Self {
        Report { rows: Mutex::new(self.rows()) }
    }
}

impl Report {
    /// Empty report.
    pub fn new() -> Report {
        Report::default()
    }

    /// Append a row.
    pub fn push(&self, row: Row) {
        self.rows.lock().unwrap().push(row);
    }

    /// Number of rows.
    pub fn len(&self) -> usize {
        self.rows.lock().unwrap().len()
    }

    /// True when no rows collected.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Snapshot of rows.
    pub fn rows(&self) -> Vec<Row> {
        self.rows.lock().unwrap().clone()
    }

    /// Union of all field names, in first-seen order.
    fn columns(rows: &[Row]) -> Vec<String> {
        let mut cols: Vec<String> = Vec::new();
        for row in rows {
            for (k, _) in &row.fields {
                if !cols.contains(k) {
                    cols.push(k.clone());
                }
            }
        }
        cols
    }

    /// Render as CSV.
    pub fn to_csv(&self) -> String {
        let rows = self.rows();
        let cols = Self::columns(&rows);
        let mut out = String::new();
        out.push_str(&cols.join(","));
        out.push('\n');
        for row in &rows {
            let line: Vec<String> = cols
                .iter()
                .map(|c| row.get(c).map(fmt_num).unwrap_or_default())
                .collect();
            out.push_str(&line.join(","));
            out.push('\n');
        }
        out
    }

    /// Render as an aligned ASCII table (for paper-style output).
    pub fn to_table(&self) -> String {
        let rows = self.rows();
        let cols = Self::columns(&rows);
        let mut widths: Vec<usize> = cols.iter().map(|c| c.len()).collect();
        let cells: Vec<Vec<String>> = rows
            .iter()
            .map(|row| {
                cols.iter()
                    .enumerate()
                    .map(|(i, c)| {
                        let s = row.get(c).map(fmt_num).unwrap_or_default();
                        widths[i] = widths[i].max(s.len());
                        s
                    })
                    .collect()
            })
            .collect();
        let mut out = String::new();
        for (i, c) in cols.iter().enumerate() {
            let _ = write!(out, "{:>w$}  ", c, w = widths[i]);
        }
        out.push('\n');
        for row in &cells {
            for (i, s) in row.iter().enumerate() {
                let _ = write!(out, "{:>w$}  ", s, w = widths[i]);
            }
            out.push('\n');
        }
        out
    }

    /// Write the CSV to a file.
    pub fn save_csv(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_csv())
    }
}

fn fmt_num(v: f64) -> String {
    if v == v.trunc() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else if v.abs() >= 1000.0 {
        format!("{v:.1}")
    } else {
        format!("{v:.4}")
    }
}

/// Named counters registry for a training run.
#[derive(Debug, Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, std::sync::Arc<Counter>>>,
}

impl Registry {
    /// Empty registry.
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Get or create a counter by name.
    pub fn counter(&self, name: &str) -> std::sync::Arc<Counter> {
        let mut map = self.counters.lock().unwrap();
        std::sync::Arc::clone(
            map.entry(name.to_string()).or_insert_with(|| std::sync::Arc::new(Counter::default())),
        )
    }

    /// Snapshot all counters.
    pub fn snapshot(&self) -> BTreeMap<String, u64> {
        self.counters
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.get()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_concurrent() {
        let c = std::sync::Arc::new(Counter::default());
        std::thread::scope(|scope| {
            for _ in 0..4 {
                let c = std::sync::Arc::clone(&c);
                scope.spawn(move || {
                    for _ in 0..1000 {
                        c.inc();
                    }
                });
            }
        });
        assert_eq!(c.get(), 4000);
    }

    #[test]
    fn row_set_get_overwrite() {
        let r = Row::new().set("a", 1.0).set("b", 2.0).set("a", 3.0);
        assert_eq!(r.get("a"), Some(3.0));
        assert_eq!(r.keys(), vec!["a", "b"]);
    }

    #[test]
    fn report_csv_and_table() {
        let report = Report::new();
        report.push(Row::new().set("iter", 1.0).set("perplexity", 6108.2));
        report.push(Row::new().set("iter", 2.0).set("perplexity", 5731.0).set("extra", 1.0));
        let csv = report.to_csv();
        assert!(csv.starts_with("iter,perplexity,extra\n"));
        assert!(csv.contains("1,6108.2"));
        let table = report.to_table();
        assert!(table.contains("perplexity"));
    }

    #[test]
    fn registry_shares_counters() {
        let reg = Registry::new();
        reg.counter("tokens").add(5);
        reg.counter("tokens").add(7);
        assert_eq!(reg.snapshot()["tokens"], 12);
    }
}
