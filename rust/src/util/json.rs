//! Minimal JSON parser — just enough to read the AOT artifact manifest
//! written by `python/compile/aot.py` (no external crates offline).
//!
//! Supports the full JSON grammar except exotic number forms; numbers are
//! parsed as f64 (the manifest only contains small integers and strings).

use std::collections::BTreeMap;

use crate::util::error::{Error, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// null
    Null,
    /// true/false
    Bool(bool),
    /// number (f64)
    Num(f64),
    /// string
    Str(String),
    /// array
    Arr(Vec<Json>),
    /// object
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(Error::Decode(format!("trailing JSON at byte {}", p.pos)));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// As string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As f64.
    pub fn as_num(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// As usize (must be a non-negative integer).
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    /// As array slice.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Result<u8> {
        let b = self.peek().ok_or_else(|| Error::Decode("unexpected end of JSON".into()))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        let got = self.bump()?;
        if got != b {
            return Err(Error::Decode(format!(
                "expected {:?} at byte {}, got {:?}",
                b as char,
                self.pos - 1,
                got as char
            )));
        }
        Ok(())
    }

    fn literal(&mut self, text: &str, value: Json) -> Result<Json> {
        for &b in text.as_bytes() {
            self.expect(b)?;
        }
        Ok(value)
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(Error::Decode(format!("unexpected JSON byte {other:?}"))),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let b = self.bump()?;
            match b {
                b'"' => return Ok(s),
                b'\\' => {
                    let esc = self.bump()?;
                    match esc {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let mut code = 0u32;
                            for _ in 0..4 {
                                let h = self.bump()?;
                                code = code * 16
                                    + (h as char).to_digit(16).ok_or_else(|| {
                                        Error::Decode("bad \\u escape".into())
                                    })?;
                            }
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(Error::Decode("bad escape".into())),
                    }
                }
                _ => {
                    // Pass through UTF-8 bytes.
                    let start = self.pos - 1;
                    let mut end = self.pos;
                    while end < self.bytes.len() && self.bytes[end] & 0xc0 == 0x80 {
                        end += 1;
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| Error::Decode("bad utf8 in JSON".into()))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| Error::Decode(format!("bad number {text:?}")))
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => return Ok(Json::Arr(items)),
                c => return Err(Error::Decode(format!("expected , or ] got {:?}", c as char))),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let value = self.value()?;
            map.insert(key, value);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => return Ok(Json::Obj(map)),
                c => return Err(Error::Decode(format!("expected , or }} got {:?}", c as char))),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{
            "version": 1,
            "artifacts": [
                {"name": "perplexity", "file": "perplexity_d64_k128_v2048.hlo.txt",
                 "inputs": [[64,128],[128,2048]], "batch": 64, "k": 128, "vblock": 2048,
                 "pallas": true}
            ]
        }"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.get("version").unwrap().as_usize(), Some(1));
        let arts = j.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("name").unwrap().as_str(), Some("perplexity"));
        assert_eq!(arts[0].get("k").unwrap().as_usize(), Some(128));
        assert_eq!(arts[0].get("pallas").unwrap(), &Json::Bool(true));
        let shape = arts[0].get("inputs").unwrap().as_arr().unwrap()[0].as_arr().unwrap();
        assert_eq!(shape[0].as_usize(), Some(64));
    }

    #[test]
    fn scalars_and_escapes() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-2.5e2").unwrap(), Json::Num(-250.0));
        assert_eq!(
            Json::parse(r#""a\nbA""#).unwrap(),
            Json::Str("a\nbA".into())
        );
        assert_eq!(Json::parse(r#""héllo""#).unwrap(), Json::Str("héllo".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }
}
