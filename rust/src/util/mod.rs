//! Shared substrate utilities.
//!
//! Everything in here is dependency-free (std only): a PCG-family random
//! number generator with distribution samplers, a byte-level codec used by
//! the message layer and checkpoints, a scoped thread pool, special math
//! functions needed by the variational baselines, a tiny CLI argument
//! parser, a top-k heap, a property-testing harness, and logging.

pub mod cli;
pub mod codec;
pub mod error;
pub mod json;
pub mod logger;
pub mod lru;
pub mod math;
pub mod proptest;
pub mod rng;
pub mod sync_shim;
pub mod threadpool;
pub mod timer;
pub mod topk;
