//! Minimal property-based testing harness.
//!
//! `proptest`/`quickcheck` are unavailable offline, so this provides the
//! 10% we need: run a property against many randomly generated cases with
//! a fixed seed (reproducible), and on failure report the case index and
//! seed so the case can be replayed.
//!
//! ```
//! use glint_lda::util::proptest::forall;
//! forall("addition commutes", 1000, |rng| {
//!     let a = rng.below(1000) as i64;
//!     let b = rng.below(1000) as i64;
//!     (a, b)
//! }, |&(a, b)| a + b == b + a);
//! ```

use crate::util::rng::Pcg64;

/// Run `prop` against `cases` values drawn by `gen`. Panics with a
/// replayable seed on the first failing case.
pub fn forall<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut Pcg64) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    let base_seed = 0x5eed_0000u64;
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64);
        let mut rng = Pcg64::new(seed);
        let value = gen(&mut rng);
        if !prop(&value) {
            panic!(
                "property {name:?} failed at case {case} (seed {seed:#x}):\n  input: {value:?}"
            );
        }
    }
}

/// Like [`forall`] but the property returns `Result<(), String>` so the
/// failure message can carry detail.
pub fn forall_explain<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut Pcg64) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let base_seed = 0x5eed_1000u64;
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64);
        let mut rng = Pcg64::new(seed);
        let value = gen(&mut rng);
        if let Err(msg) = prop(&value) {
            panic!(
                "property {name:?} failed at case {case} (seed {seed:#x}): {msg}\n  input: {value:?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall("count", 100, |rng| rng.below(10), |_| {
            true
        });
        forall("sum symmetric", 100, |rng| (rng.below(50), rng.below(50)), |&(a, b)| {
            count += 1;
            a + b == b + a
        });
        assert_eq!(count, 100);
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_panics_with_case() {
        forall("always fails", 10, |rng| rng.below(10), |_| false);
    }

    #[test]
    #[should_panic(expected = "detail here")]
    fn explain_carries_message() {
        forall_explain("explained", 5, |rng| rng.below(10), |_| {
            Err("detail here".to_string())
        });
    }
}
