//! Special functions for the variational baselines.
//!
//! The Spark MLlib baselines (variational EM and Online VB) need `digamma`
//! and `lgamma`; perplexity needs a stable `logsumexp`. Implementations
//! follow the standard asymptotic expansions (same approach as Apache
//! Commons Math, which MLlib itself uses).

/// Digamma ψ(x) via upward recurrence + asymptotic series.
///
/// Accurate to ~1e-12 for x > 0; returns NaN for x <= 0 (our callers never
/// pass non-positive values — concentrations are strictly positive).
pub fn digamma(mut x: f64) -> f64 {
    if x <= 0.0 {
        return f64::NAN;
    }
    let mut result = 0.0;
    // Recurrence: psi(x) = psi(x+1) - 1/x until x is large enough for the
    // asymptotic expansion.
    while x < 10.0 {
        result -= 1.0 / x;
        x += 1.0;
    }
    let inv = 1.0 / x;
    let inv2 = inv * inv;
    // psi(x) ~ ln x - 1/(2x) - 1/(12x^2) + 1/(120x^4) - 1/(252x^6)
    result += x.ln() - 0.5 * inv
        - inv2 * (1.0 / 12.0 - inv2 * (1.0 / 120.0 - inv2 / 252.0));
    result
}

/// Log-gamma via the Lanczos approximation (g=7, n=9), |err| < 1e-13.
pub fn lgamma(x: f64) -> f64 {
    const COEF: [f64; 9] = [
        0.99999999999980993,
        676.5203681218851,
        -1259.1392167224028,
        771.32342877765313,
        -176.61502916214059,
        12.507343278686905,
        -0.13857109526572012,
        9.9843695780195716e-6,
        1.5056327351493116e-7,
    ];
    if x < 0.5 {
        // Reflection formula.
        let pi = std::f64::consts::PI;
        return (pi / (pi * x).sin()).ln() - lgamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = COEF[0];
    let t = x + 7.5;
    for (i, &c) in COEF.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// Numerically stable log(sum(exp(xs))).
pub fn logsumexp(xs: &[f64]) -> f64 {
    let m = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if !m.is_finite() {
        return m;
    }
    let s: f64 = xs.iter().map(|&x| (x - m).exp()).sum();
    m + s.ln()
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|&x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Ordinary least squares fit of `y = a + b*x`; returns `(a, b)`.
///
/// Used to fit the Zipf slope in log-log space (paper Fig. 4).
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    for (&x, &y) in xs.iter().zip(ys) {
        sxy += (x - mx) * (y - my);
        sxx += (x - mx) * (x - mx);
    }
    let b = if sxx > 0.0 { sxy / sxx } else { 0.0 };
    let _ = n;
    (my - b * mx, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn digamma_known_values() {
        // psi(1) = -gamma (Euler–Mascheroni)
        assert!((digamma(1.0) + 0.5772156649015329).abs() < 1e-10);
        // psi(0.5) = -gamma - 2 ln 2
        assert!((digamma(0.5) + 0.5772156649015329 + 2.0 * (2f64).ln()).abs() < 1e-10);
        // psi(10) from tables
        assert!((digamma(10.0) - 2.251752589066721).abs() < 1e-10);
    }

    #[test]
    fn digamma_recurrence_property() {
        // psi(x+1) = psi(x) + 1/x
        for &x in &[0.1, 0.7, 1.3, 5.5, 42.0] {
            assert!((digamma(x + 1.0) - digamma(x) - 1.0 / x).abs() < 1e-10, "x={x}");
        }
    }

    #[test]
    fn lgamma_known_values() {
        assert!((lgamma(1.0)).abs() < 1e-10);
        assert!((lgamma(2.0)).abs() < 1e-10);
        // Gamma(5) = 24
        assert!((lgamma(5.0) - 24f64.ln()).abs() < 1e-10);
        // Gamma(0.5) = sqrt(pi)
        assert!((lgamma(0.5) - std::f64::consts::PI.sqrt().ln()).abs() < 1e-10);
    }

    #[test]
    fn lgamma_recurrence_property() {
        // lgamma(x+1) = lgamma(x) + ln x
        for &x in &[0.3, 1.7, 9.2, 101.5] {
            assert!((lgamma(x + 1.0) - lgamma(x) - x.ln()).abs() < 1e-9, "x={x}");
        }
    }

    #[test]
    fn logsumexp_stability() {
        // Would overflow naive exp.
        let xs = [1000.0, 1000.0];
        assert!((logsumexp(&xs) - (1000.0 + 2f64.ln())).abs() < 1e-9);
        let xs = [-1000.0, -1000.0];
        assert!((logsumexp(&xs) - (-1000.0 + 2f64.ln())).abs() < 1e-9);
        assert_eq!(logsumexp(&[]), f64::NEG_INFINITY);
    }

    #[test]
    fn linear_fit_exact_line() {
        let xs: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 - 1.5 * x).collect();
        let (a, b) = linear_fit(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b + 1.5).abs() < 1e-9);
    }
}
