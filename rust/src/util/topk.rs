//! Top-k selection with a bounded min-heap.
//!
//! Used by `eval::topics` (top words per topic) and the load-balance
//! figure harness.

use std::collections::BinaryHeap;

/// (score, payload) entry ordered by score (min-heap via Reverse below).
#[derive(Debug, Clone, PartialEq)]
struct Entry<T> {
    score: f64,
    item: T,
}

impl<T: PartialEq> Eq for Entry<T> {}

impl<T: PartialEq> PartialOrd for Entry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl<T: PartialEq> Ord for Entry<T> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Reverse: BinaryHeap is a max-heap; we want the smallest on top
        // so it can be evicted. total_cmp gives NaN a fixed place in the
        // order (above +inf) instead of silently comparing Equal, which
        // would let a NaN score corrupt the heap invariant.
        other.score.total_cmp(&self.score)
    }
}

/// Maintains the `k` highest-scoring items seen.
#[derive(Debug)]
pub struct TopK<T> {
    k: usize,
    heap: BinaryHeap<Entry<T>>,
}

impl<T: PartialEq> TopK<T> {
    /// Create a selector for the top `k` items.
    pub fn new(k: usize) -> Self {
        TopK { k, heap: BinaryHeap::with_capacity(k + 1) }
    }

    /// Offer an item.
    pub fn push(&mut self, score: f64, item: T) {
        if self.k == 0 {
            return;
        }
        if self.heap.len() < self.k {
            self.heap.push(Entry { score, item });
        } else if let Some(min) = self.heap.peek() {
            if score > min.score {
                self.heap.pop();
                self.heap.push(Entry { score, item });
            }
        }
    }

    /// Number of retained items.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// True if no items retained.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Extract items sorted by descending score.
    pub fn into_sorted(self) -> Vec<(f64, T)> {
        let mut v: Vec<_> = self.heap.into_iter().map(|e| (e.score, e.item)).collect();
        v.sort_by(|a, b| b.0.total_cmp(&a.0));
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn keeps_k_largest() {
        let mut tk = TopK::new(3);
        for i in 0..100 {
            tk.push(i as f64, i);
        }
        let out = tk.into_sorted();
        assert_eq!(out.iter().map(|e| e.1).collect::<Vec<_>>(), vec![99, 98, 97]);
    }

    #[test]
    fn fewer_than_k() {
        let mut tk = TopK::new(10);
        tk.push(1.0, "a");
        tk.push(2.0, "b");
        let out = tk.into_sorted();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].1, "b");
    }

    #[test]
    fn zero_k() {
        let mut tk = TopK::new(0);
        tk.push(1.0, 1);
        assert!(tk.is_empty());
    }

    #[test]
    fn nan_scores_are_deterministic() {
        // A NaN offered to a full heap never displaces a real entry
        // (`score > min.score` is false for NaN)...
        let mut tk = TopK::new(2);
        tk.push(1.0, "a");
        tk.push(2.0, "b");
        tk.push(f64::NAN, "nan");
        let out = tk.into_sorted();
        assert_eq!(
            out.iter().map(|e| e.1).collect::<Vec<_>>(),
            vec!["b", "a"]
        );

        // ...and a NaN that entered a non-full heap sorts to a fixed
        // position (total_cmp places +NaN above +inf) instead of
        // shuffling nondeterministically as with partial_cmp-as-Equal.
        let mut tk = TopK::new(3);
        tk.push(f64::NAN, "nan");
        tk.push(f64::INFINITY, "inf");
        tk.push(1.0, "one");
        let out = tk.into_sorted();
        let order: Vec<&str> = out.iter().map(|e| e.1).collect();
        assert_eq!(order, vec!["nan", "inf", "one"]);
        assert!(out[0].0.is_nan());
    }

    #[test]
    fn matches_full_sort_randomized() {
        let mut rng = Pcg64::new(99);
        for _ in 0..20 {
            let n = 500;
            let scores: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
            let mut tk = TopK::new(25);
            for (i, &s) in scores.iter().enumerate() {
                tk.push(s, i);
            }
            let got: Vec<usize> = tk.into_sorted().into_iter().map(|e| e.1).collect();
            let mut expect: Vec<usize> = (0..n).collect();
            expect.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
            expect.truncate(25);
            assert_eq!(got, expect);
        }
    }
}
