//! Wall-clock timing helpers for the benchmark harnesses.

use std::time::{Duration, Instant};

/// Simple stopwatch.
#[derive(Debug, Clone)]
pub struct Stopwatch {
    start: Instant,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    /// Start timing now.
    pub fn new() -> Self {
        Stopwatch { start: Instant::now() }
    }

    /// Elapsed time.
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Elapsed seconds as f64.
    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    /// Elapsed milliseconds as f64.
    pub fn millis(&self) -> f64 {
        self.elapsed().as_secs_f64() * 1e3
    }

    /// Restart and return the elapsed time since the previous start.
    pub fn lap(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Measure a closure, returning `(result, seconds)`.
pub fn timed<R>(f: impl FnOnce() -> R) -> (R, f64) {
    let sw = Stopwatch::new();
    let r = f();
    (r, sw.secs())
}

/// Micro-benchmark runner: warms up, then runs `iters` timed iterations
/// and reports per-iteration statistics. This replaces criterion (not
/// available offline) for the `benches/` harnesses.
#[derive(Debug, Clone)]
pub struct BenchStats {
    /// Mean seconds per iteration.
    pub mean: f64,
    /// Minimum seconds per iteration.
    pub min: f64,
    /// Maximum seconds per iteration.
    pub max: f64,
    /// Population standard deviation.
    pub std_dev: f64,
    /// Number of timed iterations.
    pub iters: usize,
}

impl BenchStats {
    /// Human-readable one-liner, scaled to convenient units.
    pub fn summary(&self) -> String {
        format!(
            "mean {} (min {}, max {}, sd {}, n={})",
            fmt_secs(self.mean),
            fmt_secs(self.min),
            fmt_secs(self.max),
            fmt_secs(self.std_dev),
            self.iters
        )
    }
}

/// Format seconds with an auto-selected unit.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3}µs", s * 1e6)
    } else {
        format!("{:.1}ns", s * 1e9)
    }
}

/// Run `f` `warmup + iters` times; time the last `iters`.
pub fn bench<R>(warmup: usize, iters: usize, mut f: impl FnMut() -> R) -> BenchStats {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let sw = Stopwatch::new();
        std::hint::black_box(f());
        samples.push(sw.secs());
    }
    let mean = crate::util::math::mean(&samples);
    let sd = crate::util::math::std_dev(&samples);
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = samples.iter().cloned().fold(0.0f64, f64::max);
    BenchStats { mean, min, max, std_dev: sd, iters }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_advances() {
        let sw = Stopwatch::new();
        std::thread::sleep(Duration::from_millis(5));
        assert!(sw.millis() >= 4.0);
    }

    #[test]
    fn bench_collects_stats() {
        let stats = bench(2, 10, || {
            let mut s = 0u64;
            for i in 0..1000 {
                s = s.wrapping_add(i);
            }
            s
        });
        assert_eq!(stats.iters, 10);
        assert!(stats.min <= stats.mean && stats.mean <= stats.max);
    }

    #[test]
    fn fmt_secs_units() {
        assert!(fmt_secs(2.5).ends_with('s'));
        assert!(fmt_secs(2.5e-3).ends_with("ms"));
        assert!(fmt_secs(2.5e-6).ends_with("µs"));
        assert!(fmt_secs(2.5e-9).ends_with("ns"));
    }
}
