//! Leveled stderr logging with elapsed-time stamps.
//!
//! Intentionally tiny: a global level, `log_info!` / `log_debug!` macros,
//! and monotonic timestamps relative to process start so training logs
//! read like the paper's convergence plots (time on the x-axis).

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Log verbosity.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// Errors only.
    Error = 0,
    /// Warnings.
    Warn = 1,
    /// Progress messages (default).
    Info = 2,
    /// Per-iteration detail.
    Debug = 3,
    /// Message-level detail (very chatty).
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);
static START: OnceLock<Instant> = OnceLock::new();

/// Set the global log level.
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Set level from a string (error|warn|info|debug|trace).
pub fn set_level_str(s: &str) {
    let lvl = match s {
        "error" => Level::Error,
        "warn" => Level::Warn,
        "debug" => Level::Debug,
        "trace" => Level::Trace,
        _ => Level::Info,
    };
    set_level(lvl);
}

/// Current global level.
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// Whether a message at `lvl` would be emitted.
pub fn enabled(lvl: Level) -> bool {
    lvl <= level()
}

/// Seconds since the first log call.
pub fn uptime() -> f64 {
    START.get_or_init(Instant::now).elapsed().as_secs_f64()
}

/// Emit a message (used by the macros; prefer those).
pub fn emit(lvl: Level, args: std::fmt::Arguments<'_>) {
    if enabled(lvl) {
        let tag = match lvl {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{:>9.3}s {tag}] {args}", uptime());
    }
}

/// Log at info level.
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logger::emit($crate::util::logger::Level::Info, format_args!($($arg)*))
    };
}

/// Log at warn level.
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logger::emit($crate::util::logger::Level::Warn, format_args!($($arg)*))
    };
}

/// Log at error level.
#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logger::emit($crate::util::logger::Level::Error, format_args!($($arg)*))
    };
}

/// Log at debug level.
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logger::emit($crate::util::logger::Level::Debug, format_args!($($arg)*))
    };
}

/// Log at trace level.
#[macro_export]
macro_rules! log_trace {
    ($($arg:tt)*) => {
        $crate::util::logger::emit($crate::util::logger::Level::Trace, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Trace);
        assert!(Level::Info < Level::Debug);
    }

    #[test]
    fn set_level_str_parses() {
        set_level_str("debug");
        assert_eq!(level(), Level::Debug);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Trace));
        set_level_str("info");
        assert_eq!(level(), Level::Info);
    }

    #[test]
    fn uptime_monotonic() {
        let a = uptime();
        let b = uptime();
        assert!(b >= a);
    }
}
