//! Fixed-capacity LRU cache on a slab-allocated doubly-linked list.
//!
//! The serving tier keys fold-in results by document hash and alias
//! tables by word id; both caches must be bounded (a serving replica
//! runs indefinitely) and O(1) per operation (they sit on the request
//! path). Entries live in a slab (`Vec`) and the recency order is a
//! doubly-linked list of slab indices, so there is no per-entry
//! allocation after the cache fills and eviction reuses slots in place.

use std::collections::HashMap;
use std::hash::Hash;

/// Sentinel slab index for "no neighbor".
const NIL: usize = usize::MAX;

struct Slot<K, V> {
    key: K,
    value: V,
    prev: usize,
    next: usize,
}

/// Bounded map with least-recently-used eviction and hit/miss counters.
pub struct LruCache<K, V> {
    map: HashMap<K, usize>,
    slots: Vec<Slot<K, V>>,
    /// Most recently used slot (NIL when empty).
    head: usize,
    /// Least recently used slot (NIL when empty).
    tail: usize,
    cap: usize,
    hits: u64,
    misses: u64,
    evictions: u64,
}

impl<K: Eq + Hash + Clone, V> LruCache<K, V> {
    /// Cache holding at most `cap` entries (clamped to at least 1).
    pub fn new(cap: usize) -> LruCache<K, V> {
        let cap = cap.max(1);
        LruCache {
            map: HashMap::with_capacity(cap),
            slots: Vec::with_capacity(cap),
            head: NIL,
            tail: NIL,
            cap,
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    /// Entries currently resident.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    /// True when nothing is cached.
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Maximum resident entries.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Look up `key`, marking it most recently used and counting the
    /// outcome toward the hit/miss statistics.
    pub fn get(&mut self, key: &K) -> Option<&V> {
        match self.map.get(key).copied() {
            Some(i) => {
                self.hits += 1;
                self.unlink(i);
                self.push_front(i);
                Some(&self.slots[i].value)
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Look up `key` without touching recency or the statistics.
    pub fn peek(&self, key: &K) -> Option<&V> {
        self.map.get(key).map(|&i| &self.slots[i].value)
    }

    /// True when `key` is resident (no recency or statistics effect).
    pub fn contains(&self, key: &K) -> bool {
        self.map.contains_key(key)
    }

    /// Insert (or refresh) `key`, marking it most recently used. When a
    /// full cache takes a new key, the least-recently-used entry is
    /// evicted and returned.
    pub fn insert(&mut self, key: K, value: V) -> Option<(K, V)> {
        if let Some(&i) = self.map.get(&key) {
            self.slots[i].value = value;
            self.unlink(i);
            self.push_front(i);
            return None;
        }
        if self.slots.len() < self.cap {
            let i = self.slots.len();
            self.slots.push(Slot { key: key.clone(), value, prev: NIL, next: NIL });
            self.map.insert(key, i);
            self.push_front(i);
            return None;
        }
        // Full: evict the tail and reuse its slot in place.
        let i = self.tail;
        self.unlink(i);
        let old_key = std::mem::replace(&mut self.slots[i].key, key.clone());
        let old_value = std::mem::replace(&mut self.slots[i].value, value);
        self.map.remove(&old_key);
        self.map.insert(key, i);
        self.push_front(i);
        self.evictions += 1;
        Some((old_key, old_value))
    }

    /// Lookups that found their key.
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Lookups that missed.
    pub fn misses(&self) -> u64 {
        self.misses
    }

    /// Entries displaced by inserts into a full cache.
    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// Detach slot `i` from the recency list.
    fn unlink(&mut self, i: usize) {
        let (prev, next) = (self.slots[i].prev, self.slots[i].next);
        if prev != NIL {
            self.slots[prev].next = next;
        } else {
            self.head = next;
        }
        if next != NIL {
            self.slots[next].prev = prev;
        } else {
            self.tail = prev;
        }
    }

    /// Attach slot `i` as the most recently used entry.
    fn push_front(&mut self, i: usize) {
        self.slots[i].prev = NIL;
        self.slots[i].next = self.head;
        if self.head != NIL {
            self.slots[self.head].prev = i;
        } else {
            self.tail = i;
        }
        self.head = i;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn evicts_least_recently_used_in_order() {
        let mut c: LruCache<u32, &str> = LruCache::new(3);
        assert!(c.insert(1, "a").is_none());
        assert!(c.insert(2, "b").is_none());
        assert!(c.insert(3, "c").is_none());
        assert_eq!(c.insert(4, "d"), Some((1, "a")));
        assert_eq!(c.insert(5, "e"), Some((2, "b")));
        assert_eq!(c.len(), 3);
        assert!(!c.contains(&1));
        assert!(!c.contains(&2));
        assert!(c.contains(&3) && c.contains(&4) && c.contains(&5));
        assert_eq!(c.evictions(), 2);
    }

    #[test]
    fn get_refreshes_recency() {
        let mut c: LruCache<u32, u32> = LruCache::new(2);
        c.insert(1, 10);
        c.insert(2, 20);
        // Touch 1 so 2 becomes the LRU entry.
        assert_eq!(c.get(&1), Some(&10));
        assert_eq!(c.insert(3, 30), Some((2, 20)));
        assert_eq!(c.get(&1), Some(&10));
        assert_eq!(c.get(&3), Some(&30));
        assert_eq!(c.get(&2), None);
    }

    #[test]
    fn reinsert_updates_value_and_recency_without_evicting() {
        let mut c: LruCache<u32, u32> = LruCache::new(2);
        c.insert(1, 10);
        c.insert(2, 20);
        assert!(c.insert(1, 11).is_none());
        assert_eq!(c.len(), 2);
        // 2 is now least recent despite being inserted after 1.
        assert_eq!(c.insert(3, 30), Some((2, 20)));
        assert_eq!(c.peek(&1), Some(&11));
    }

    #[test]
    fn capacity_one_and_stats() {
        let mut c: LruCache<u32, u32> = LruCache::new(0); // clamps to 1
        assert_eq!(c.capacity(), 1);
        assert!(c.is_empty());
        c.insert(7, 70);
        assert_eq!(c.get(&7), Some(&70));
        assert_eq!(c.get(&8), None);
        assert_eq!(c.insert(8, 80), Some((7, 70)));
        assert_eq!(c.hits(), 1);
        assert_eq!(c.misses(), 1);
        assert_eq!(c.evictions(), 1);
        // peek leaves the statistics alone.
        assert_eq!(c.peek(&8), Some(&80));
        assert_eq!(c.hits(), 1);
    }

    #[test]
    fn random_workload_matches_reference_model() {
        // Exercise the slab list against a naive Vec-based LRU model.
        let mut c: LruCache<u32, u32> = LruCache::new(8);
        let mut model: Vec<(u32, u32)> = Vec::new(); // most recent first
        let mut rng = crate::util::rng::Pcg64::new(0x10c4);
        for _ in 0..2000 {
            let k = rng.below(24) as u32;
            if rng.bernoulli(0.5) {
                let v = rng.next_u32();
                let evicted = c.insert(k, v);
                if let Some(pos) = model.iter().position(|e| e.0 == k) {
                    model.remove(pos);
                    assert!(evicted.is_none());
                } else if model.len() == 8 {
                    let lru = model.pop().unwrap();
                    assert_eq!(evicted, Some(lru));
                } else {
                    assert!(evicted.is_none());
                }
                model.insert(0, (k, v));
            } else {
                let got = c.get(&k).copied();
                let want = model.iter().position(|e| e.0 == k).map(|pos| {
                    let e = model.remove(pos);
                    model.insert(0, e);
                    e.1
                });
                assert_eq!(got, want);
            }
            assert_eq!(c.len(), model.len());
        }
    }
}
