//! Crate-wide error type.

use std::fmt;

/// Convenience alias used throughout the crate.
pub type Result<T> = std::result::Result<T, Error>;

/// All error conditions surfaced by the library.
#[derive(Debug)]
pub enum Error {
    /// I/O failure (checkpointing, corpus loading, artifact loading).
    Io(std::io::Error),
    /// A parameter-server request exhausted its retry budget.
    PsTimeout {
        /// Operation that failed, e.g. `"pull"` or `"push-ack"`.
        op: &'static str,
        /// Shard the request was routed to.
        shard: usize,
        /// Number of attempts made before giving up.
        attempts: u32,
    },
    /// The parameter server rejected a request (bad matrix id, out of
    /// bounds indices, dtype mismatch).
    PsRejected(String),
    /// Malformed data encountered while decoding (messages, checkpoints,
    /// artifact manifests).
    Decode(String),
    /// Configuration error (invalid hyper-parameters, shape mismatch).
    Config(String),
    /// XLA/PJRT runtime failure.
    Xla(String),
    /// An artifact required by the XLA path is missing from `artifacts/`.
    MissingArtifact(String),
    /// Checkpoint is missing or inconsistent.
    Checkpoint(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::PsTimeout { op, shard, attempts } => write!(
                f,
                "parameter server {op} to shard {shard} timed out after {attempts} attempts"
            ),
            Error::PsRejected(m) => write!(f, "parameter server rejected request: {m}"),
            Error::Decode(m) => write!(f, "decode error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
            Error::MissingArtifact(m) => write!(
                f,
                "missing artifact {m}; run `make artifacts` to AOT-compile the JAX graphs"
            ),
            Error::Checkpoint(m) => write!(f, "checkpoint error: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats() {
        let e = Error::PsTimeout { op: "pull", shard: 3, attempts: 7 };
        assert!(e.to_string().contains("shard 3"));
        assert!(e.to_string().contains("7 attempts"));
        let e = Error::MissingArtifact("perplexity".into());
        assert!(e.to_string().contains("make artifacts"));
    }

    #[test]
    fn io_conversion_preserves_source() {
        let ioe = std::io::Error::new(std::io::ErrorKind::NotFound, "nope");
        let e: Error = ioe.into();
        assert!(matches!(e, Error::Io(_)));
        assert!(std::error::Error::source(&e).is_some());
    }
}
