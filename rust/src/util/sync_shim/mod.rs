//! Swappable synchronization surface for the concurrent subsystems.
//!
//! Code that wants interleaving coverage imports its primitives from here
//! instead of `std::sync` / `std::thread`:
//!
//! ```ignore
//! use crate::util::sync_shim::{mpsc, thread, Condvar, Mutex};
//! use crate::util::sync_shim::atomic::{AtomicU64, Ordering};
//! ```
//!
//! In a normal build this module is a zero-cost pile of re-exports — the
//! types *are* the `std` types and the compiled code is identical to
//! importing `std::sync` directly.
//!
//! Under `--features model` the same names resolve to model-checking
//! primitives: every lock acquire, condvar wait/notify, channel op, atomic
//! access, spawn, and join becomes a *schedule point* where a cooperative
//! virtual scheduler ([`sched`]) decides which task runs next. The
//! scheduler runs one task at a time on real OS threads, records every
//! decision, and explores many interleavings per test (seeded random walks
//! for big models, bounded-preemption DFS for small ones). A failing
//! schedule prints a `GLINT_MODEL_REPLAY` token that replays the exact
//! interleaving deterministically. See `tests/model.rs` for the models and
//! the README "Correctness tooling" section for the workflow.
//!
//! Model-build semantics intentionally differ from `std` in two documented
//! ways: lock poisoning is never reported (panicking schedules abort the
//! whole run instead, which is strictly stricter), and atomic memory
//! orderings are accepted but ignored — the scheduler serializes all
//! accesses, so every exploration runs under sequential consistency.
//! Weak-ordering bugs are covered by the nightly TSan CI leg instead.

#[cfg(not(feature = "model"))]
pub use std::sync::{
    Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard, WaitTimeoutResult,
};

/// Atomic types (std re-exports in normal builds).
#[cfg(not(feature = "model"))]
pub mod atomic {
    pub use std::sync::atomic::*;
}

/// Channels (std re-exports in normal builds).
#[cfg(not(feature = "model"))]
pub mod mpsc {
    pub use std::sync::mpsc::*;
}

/// Thread spawning (std re-exports in normal builds).
#[cfg(not(feature = "model"))]
pub mod thread {
    pub use std::thread::*;
}

#[cfg(feature = "model")]
pub mod lin;
#[cfg(feature = "model")]
mod prim;
#[cfg(feature = "model")]
pub mod sched;

#[cfg(feature = "model")]
pub use prim::{
    atomic, mpsc, thread, Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard,
    WaitTimeoutResult,
};
