//! Linearizability oracle for the exactly-once push protocol.
//!
//! The paper's push handshake (§2.4 of PAPER.md's source) promises that a
//! client increment is applied *exactly once* no matter how the transport
//! mangles delivery. At the history level that makes a shard a
//! **counter with idempotent, uid-tagged increments**: the sequential spec
//! applies each uid's delta at most once, and reads return the running
//! total.
//!
//! Model tasks record invocations/returns into a [`Recorder`]; the test
//! then runs [`linearizable_counter`] — a Wing & Gong-style backtracking
//! search with a memo on the linearized-set bitmask (valid because the
//! spec state is a function of *which* operations linearized, not their
//! order) — to decide whether some legal linearization explains what every
//! task observed. Operations that never returned (couriers killed by a
//! crash schedule) are *pending*: the checker may linearize them anywhere
//! after their invocation or drop them entirely, exactly matching the
//! "message may or may not have taken effect" ambiguity of a crash.
//!
//! The recorder uses raw `std::sync` on purpose: under the cooperative
//! scheduler exactly one task runs at a time, so these short critical
//! sections can never park a task mid-schedule or add schedule points of
//! their own — the history is an observation channel, not part of the
//! model.

use std::collections::HashSet;

/// An operation against the counter-with-exactly-once-pushes spec.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    /// Apply `delta` under idempotency key `uid`.
    Push {
        /// Exactly-once key (one per logical client push).
        uid: u64,
        /// Increment to apply.
        delta: i64,
    },
    /// Read the current total.
    Read,
}

/// What an operation returned.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RetVal {
    /// Push acknowledged (applied now or already applied earlier).
    Done,
    /// Read observed this total.
    Value(i64),
}

/// One completed-or-pending operation in a recorded history.
#[derive(Clone, Debug)]
pub struct OpRecord {
    /// Logical timestamp of the invocation.
    pub inv: usize,
    /// Logical timestamp of the return (`None` = pending at history end).
    pub ret: Option<usize>,
    /// The operation.
    pub op: Op,
    /// The observed result (`None` = pending).
    pub out: Option<RetVal>,
}

struct RecInner {
    time: usize,
    ops: Vec<OpRecord>,
}

/// Concurrent history recorder (see module docs for why it uses raw std).
pub struct Recorder {
    inner: std::sync::Mutex<RecInner>,
}

impl Default for Recorder {
    fn default() -> Self {
        Recorder::new()
    }
}

impl Recorder {
    /// Create an empty history.
    pub fn new() -> Recorder {
        Recorder {
            inner: std::sync::Mutex::new(RecInner {
                time: 0,
                ops: Vec::new(),
            }),
        }
    }

    /// Record an invocation; returns the op's index for [`Recorder::ret`].
    pub fn invoke(&self, op: Op) -> usize {
        let mut g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        g.time += 1;
        let t = g.time;
        g.ops.push(OpRecord {
            inv: t,
            ret: None,
            op,
            out: None,
        });
        g.ops.len() - 1
    }

    /// Record the return of op `idx`.
    pub fn ret(&self, idx: usize, out: RetVal) {
        let mut g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        g.time += 1;
        let t = g.time;
        let rec = &mut g.ops[idx];
        rec.ret = Some(t);
        rec.out = Some(out);
    }

    /// Consume the recorder and return the history.
    pub fn finish(self) -> Vec<OpRecord> {
        self.inner
            .into_inner()
            .unwrap_or_else(|e| e.into_inner())
            .ops
    }
}

/// Spec state reached after linearizing the ops in `mask`: the set of
/// applied uids is order-independent, so the state is a pure function of
/// the mask — which is what makes the bitmask memo below sound.
fn total_of(ops: &[OpRecord], mask: u64) -> i64 {
    let mut seen = HashSet::new();
    let mut total = 0i64;
    for (i, rec) in ops.iter().enumerate() {
        if mask & (1 << i) == 0 {
            continue;
        }
        if let Op::Push { uid, delta } = rec.op {
            if seen.insert(uid) {
                total += delta;
            }
        }
    }
    total
}

/// Wing & Gong linearizability check against the exactly-once counter
/// spec. Returns `true` iff some linearization of the history is legal.
///
/// Histories are small (model schedules run tens of ops), so the u64
/// bitmask cap of 64 ops is plenty; the memo makes the search polynomial
/// in practice.
pub fn linearizable_counter(ops: &[OpRecord]) -> bool {
    assert!(
        ops.len() <= 64,
        "history too long for the bitmask checker ({} ops)",
        ops.len()
    );
    let full_completed: u64 = ops
        .iter()
        .enumerate()
        .filter(|(_, r)| r.ret.is_some())
        .map(|(i, _)| 1u64 << i)
        .sum();
    let mut memo: HashSet<u64> = HashSet::new();
    search(ops, 0, full_completed, &mut memo)
}

fn search(ops: &[OpRecord], mask: u64, full_completed: u64, memo: &mut HashSet<u64>) -> bool {
    // Done when every *completed* op is linearized; leftover pending ops
    // are legal to drop (their effect never became visible).
    if mask & full_completed == full_completed {
        return true;
    }
    for (i, rec) in ops.iter().enumerate() {
        let bit = 1u64 << i;
        if mask & bit != 0 {
            continue;
        }
        // Minimality: `i` may linearize next only if no other remaining
        // op returned entirely before `i` was invoked.
        let minimal = ops.iter().enumerate().all(|(j, other)| {
            j == i
                || mask & (1 << j) != 0
                || other.ret.map_or(usize::MAX, |r| r) >= rec.inv
        });
        if !minimal {
            continue;
        }
        // Spec conformance of the observed result.
        let ok = match (rec.op, rec.out) {
            (Op::Push { .. }, _) => true,
            (Op::Read, Some(RetVal::Value(v))) => v == total_of(ops, mask),
            (Op::Read, Some(RetVal::Done)) => false,
            (Op::Read, None) => true,
        };
        if !ok {
            continue;
        }
        let next = mask | bit;
        if memo.insert(next) && search(ops, next, full_completed, memo) {
            return true;
        }
    }
    false
}

/// The sequential spec's final total: every distinct uid applied once.
pub fn sequential_total(ops: &[OpRecord]) -> i64 {
    total_of(ops, u64::MAX)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(inv: usize, ret: usize, op: Op, out: RetVal) -> OpRecord {
        OpRecord {
            inv,
            ret: Some(ret),
            op,
            out: Some(out),
        }
    }

    #[test]
    fn sequential_history_is_linearizable() {
        let h = vec![
            rec(1, 2, Op::Push { uid: 1, delta: 5 }, RetVal::Done),
            rec(3, 4, Op::Read, RetVal::Value(5)),
        ];
        assert!(linearizable_counter(&h));
        assert_eq!(sequential_total(&h), 5);
    }

    #[test]
    fn duplicate_uid_counts_once() {
        let h = vec![
            rec(1, 2, Op::Push { uid: 7, delta: 3 }, RetVal::Done),
            rec(3, 4, Op::Push { uid: 7, delta: 3 }, RetVal::Done),
            rec(5, 6, Op::Read, RetVal::Value(3)),
        ];
        assert!(linearizable_counter(&h));
    }

    #[test]
    fn stale_read_after_completed_push_is_rejected() {
        // Push finished (ret=2) strictly before the read began (inv=3),
        // so the read must see its effect; Value(0) is a real-time
        // ordering violation.
        let h = vec![
            rec(1, 2, Op::Push { uid: 1, delta: 5 }, RetVal::Done),
            rec(3, 4, Op::Read, RetVal::Value(0)),
        ];
        assert!(!linearizable_counter(&h));
    }

    #[test]
    fn concurrent_push_read_either_value_ok() {
        // Read overlaps the push: both 0 and 5 are linearizable.
        for v in [0, 5] {
            let h = vec![
                rec(1, 4, Op::Push { uid: 1, delta: 5 }, RetVal::Done),
                rec(2, 3, Op::Read, RetVal::Value(v)),
            ];
            assert!(linearizable_counter(&h), "value {v} should linearize");
        }
        let h = vec![
            rec(1, 4, Op::Push { uid: 1, delta: 5 }, RetVal::Done),
            rec(2, 3, Op::Read, RetVal::Value(2)),
        ];
        assert!(!linearizable_counter(&h));
    }

    #[test]
    fn pending_push_may_or_may_not_apply() {
        // A push with no return (crash) can explain either read outcome.
        for v in [0, 5] {
            let h = vec![
                OpRecord {
                    inv: 1,
                    ret: None,
                    op: Op::Push { uid: 1, delta: 5 },
                    out: None,
                },
                rec(2, 3, Op::Read, RetVal::Value(v)),
            ];
            assert!(linearizable_counter(&h), "value {v} should linearize");
        }
    }
}
