//! The cooperative virtual scheduler behind the `model` feature.
//!
//! A *model* is one closure executed many times, each time under a
//! different interleaving of its virtual tasks. Tasks are real OS threads,
//! but exactly one is ever running: every synchronization operation in
//! [`super::prim`] calls back into the task's [`Model`], which parks the
//! caller and hands control to the task chosen for the next step. Because
//! the primitives are the *only* interaction points between tasks, picking
//! the running task at each such point is enough to enumerate every
//! observable interleaving (a classic partial-order reduction: pure
//! compute between schedule points commutes).
//!
//! Each multi-way decision — which task runs, which `notify_one` waiter
//! wakes, which branch a [`choice`] takes — is appended to a trace. The
//! trace is the schedule's identity: replaying the same trace reproduces
//! the same execution bit-for-bit, which is how `GLINT_MODEL_REPLAY`
//! tokens work and why `Date`-free determinism matters in model code.
//!
//! Exploration policies:
//!
//! - **Random walk** ([`ExploreOpts::dfs`] = false): each schedule draws
//!   decisions from a per-schedule seeded PCG64. Good for large models
//!   where systematic enumeration cannot finish; distinct-trace counting
//!   makes the coverage measurable.
//! - **Bounded DFS** ([`ExploreOpts::dfs`] = true): stateless iterative
//!   deepening over *preemption bounds* (Musuvathi/Qadeer-style). A
//!   prefix stack replays a recorded prefix, takes one alternative branch,
//!   and continues with default choices; alternatives that would exceed
//!   the current preemption budget are deferred to the next bound. Most
//!   concurrency bugs need very few preemptions, so low bounds find them
//!   fast while still being systematic.
//!
//! Failure handling: a deadlock (no runnable or timed-waiting task while
//! unfinished tasks remain), a task panic, or an explicit assertion inside
//! the model marks the whole schedule failed, prints the replay token,
//! appends it to the `GLINT_MODEL_ARTIFACT` file if set, and unwinds every
//! parked task with a sentinel panic.

use std::collections::HashSet;
use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::util::rng::Pcg64;

/// Panic payload used to tear down parked tasks once a schedule fails.
/// Wrappers recognize it and do not report it as a task failure.
pub(crate) const ABORT: &str = "__glint_model_schedule_abort__";

/// Index of a virtual task within one schedule (0 is the root body).
pub type TaskId = usize;

/// Allocate a process-unique resource id for a primitive (lock, condvar,
/// channel). Blocked tasks record the rid they are waiting on; ids only
/// need to be unique within one model run, so a global counter is fine.
pub(crate) fn fresh_rid() -> usize {
    static NEXT: AtomicUsize = AtomicUsize::new(1);
    NEXT.fetch_add(1, Ordering::SeqCst)
}

/// The rid joiners of task `t` block on (top of the rid space, far above
/// anything `fresh_rid` hands out).
pub(crate) fn join_rid(t: TaskId) -> usize {
    usize::MAX - t
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Status {
    /// Eligible to run.
    Runnable,
    /// Parked until `wake_*` on this rid.
    Blocked(usize),
    /// Parked on this rid, but the scheduler may "fire the timeout" and
    /// run the task anyway — which is how the model expresses that a
    /// `wait_timeout`/`recv_timeout` deadline can race any other event.
    TimedWait(usize),
    Finished,
}

struct TaskState {
    status: Status,
    /// Set when the scheduler woke the task by firing its timeout rather
    /// than via a notify; consumed by `timed_block_on`.
    timed_out: bool,
    /// FIFO stamp taken when the task parked (tie-break for `wake_one`).
    wait_seq: u64,
}

/// One recorded nondeterministic decision. Single-option steps are not
/// recorded, so the trace is exactly the schedule's branching structure.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct Choice {
    /// How many options were available.
    pub options: usize,
    /// The option taken.
    pub chosen: usize,
    /// The option that would have kept the previously running task
    /// running, when it was still runnable (`None` for data choices and
    /// for points where the task blocked). Taking any *other* option is a
    /// preemption; the DFS bound counts those.
    pub stay: Option<usize>,
}

struct SchedState {
    tasks: Vec<TaskState>,
    active: Option<TaskId>,
    /// Tasks not yet `Finished`.
    live: usize,
    failed: Option<String>,
    trace: Vec<Choice>,
    replay: Vec<usize>,
    rng: Pcg64,
    /// Past the replay prefix: draw from `rng` (true) or take option 0.
    random: bool,
    seq: u64,
    steps: usize,
    max_steps: usize,
}

/// Scheduler for one schedule (one execution of the model body).
pub struct Model {
    name: String,
    state: Mutex<SchedState>,
    cv: Condvar,
    /// Real handles of the OS threads backing virtual tasks; the runner
    /// joins them all after the root returns so no thread leaks across
    /// schedules.
    os_handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

thread_local! {
    static CTX: std::cell::RefCell<Option<(Arc<Model>, TaskId)>> =
        const { std::cell::RefCell::new(None) };
}

/// The (model, task) identity of the calling thread, if it is a virtual
/// task. Primitives consult this; `None` means "behave like std".
pub(crate) fn current() -> Option<(Arc<Model>, TaskId)> {
    CTX.with(|c| c.borrow().clone())
}

pub(crate) fn set_ctx(v: Option<(Arc<Model>, TaskId)>) {
    CTX.with(|c| *c.borrow_mut() = v);
}

/// True when this process is replaying a `GLINT_MODEL_REPLAY` token.
/// Models other than the token's target skip themselves in that mode, so
/// tests must not assert exploration stats when this returns true.
pub fn replay_active() -> bool {
    std::env::var("GLINT_MODEL_REPLAY").is_ok()
}

impl Model {
    fn new(name: &str, replay: Vec<usize>, random: bool, seed: u64, max_steps: usize) -> Arc<Model> {
        Arc::new(Model {
            name: name.to_string(),
            state: Mutex::new(SchedState {
                tasks: Vec::new(),
                active: None,
                live: 0,
                failed: None,
                trace: Vec::new(),
                replay,
                rng: Pcg64::new(seed),
                random,
                seq: 0,
                steps: 0,
                max_steps,
            }),
            cv: Condvar::new(),
            os_handles: Mutex::new(Vec::new()),
        })
    }

    fn locked(&self) -> std::sync::MutexGuard<'_, SchedState> {
        // The scheduler lock is never held across a park except via
        // `cv.wait`, so poisoning can only come from a panic inside the
        // scheduler itself; recover the guard and keep tearing down.
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }

    pub(crate) fn register_task(&self) -> TaskId {
        let mut st = self.locked();
        let id = st.tasks.len();
        st.tasks.push(TaskState {
            status: Status::Runnable,
            timed_out: false,
            wait_seq: 0,
        });
        st.live += 1;
        id
    }

    pub(crate) fn note_os_handle(&self, h: std::thread::JoinHandle<()>) {
        self.os_handles
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .push(h);
    }

    /// Park until the scheduler makes this task active (used by a freshly
    /// spawned task before its first step).
    pub(crate) fn wait_until_active(&self, me: TaskId) {
        let mut st = self.locked();
        while st.active != Some(me) {
            if st.failed.is_some() {
                drop(st);
                panic!("{ABORT}");
            }
            st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// A plain schedule point: the running task stays runnable but the
    /// scheduler may hand control to any other candidate here.
    pub(crate) fn point(&self, me: TaskId) {
        self.reschedule(me, Status::Runnable);
    }

    /// Park the running task on `rid` until some task calls `wake_*`.
    pub(crate) fn block_on(&self, me: TaskId, rid: usize) {
        self.reschedule(me, Status::Blocked(rid));
    }

    /// Park on `rid` but let the scheduler fire the timeout instead of a
    /// wakeup. Returns true when the wait ended by timing out.
    pub(crate) fn timed_block_on(&self, me: TaskId, rid: usize) -> bool {
        self.reschedule(me, Status::TimedWait(rid));
        let mut st = self.locked();
        let fired = st.tasks[me].timed_out;
        st.tasks[me].timed_out = false;
        fired
    }

    /// Record a data decision in `0..n` for the running task.
    pub(crate) fn data_choice(&self, _me: TaskId, n: usize) -> usize {
        let mut st = self.locked();
        if st.failed.is_some() {
            drop(st);
            panic!("{ABORT}");
        }
        decide(&mut st, n.max(1), None)
    }

    /// Wake every task parked on `rid`.
    pub(crate) fn wake_all(&self, rid: usize) {
        let mut st = self.locked();
        for t in st.tasks.iter_mut() {
            if t.status == Status::Blocked(rid) || t.status == Status::TimedWait(rid) {
                t.status = Status::Runnable;
                t.timed_out = false;
            }
        }
    }

    /// Wake one task parked on `rid`. Which waiter wakes is itself a
    /// recorded scheduling decision (std's `notify_one` picks arbitrarily,
    /// so the model explores every pick).
    pub(crate) fn wake_one(&self, rid: usize) {
        let mut st = self.locked();
        let mut waiters: Vec<TaskId> = Vec::new();
        for (i, t) in st.tasks.iter().enumerate() {
            if t.status == Status::Blocked(rid) || t.status == Status::TimedWait(rid) {
                waiters.push(i);
            }
        }
        if waiters.is_empty() {
            return;
        }
        waiters.sort_by_key(|&i| st.tasks[i].wait_seq);
        let idx = decide(&mut st, waiters.len(), None);
        let w = waiters[idx];
        st.tasks[w].status = Status::Runnable;
        st.tasks[w].timed_out = false;
    }

    /// Mark the running task finished and hand control onward.
    pub(crate) fn task_finished(&self, me: TaskId) {
        let mut st = self.locked();
        if st.tasks[me].status != Status::Finished {
            st.tasks[me].status = Status::Finished;
            st.live -= 1;
        }
        let jr = join_rid(me);
        for t in st.tasks.iter_mut() {
            if t.status == Status::Blocked(jr) {
                t.status = Status::Runnable;
            }
        }
        if st.failed.is_some() {
            self.cv.notify_all();
            return;
        }
        if st.active == Some(me) {
            self.pick_next(&mut st, None);
        }
    }

    /// Called by the spawn/run wrappers when a task's closure panicked.
    /// The ABORT sentinel (scheduled teardown) is not a failure; anything
    /// else fails the schedule with the panic message.
    pub(crate) fn task_panicked(&self, me: TaskId, msg: String) {
        let aborting = msg.contains(ABORT);
        let mut st = self.locked();
        if st.tasks[me].status != Status::Finished {
            st.tasks[me].status = Status::Finished;
            st.live -= 1;
        }
        let jr = join_rid(me);
        for t in st.tasks.iter_mut() {
            if t.status == Status::Blocked(jr) {
                t.status = Status::Runnable;
            }
        }
        if !aborting && st.failed.is_none() {
            self.fail_locked(&mut st, format!("task {me} panicked: {msg}"));
        } else {
            if st.active == Some(me) {
                st.active = None;
            }
            self.cv.notify_all();
        }
    }

    /// Fail the current schedule from model code (e.g. an oracle).
    pub fn fail(&self, msg: &str) -> ! {
        let mut st = self.locked();
        if st.failed.is_none() {
            self.fail_locked(&mut st, msg.to_string());
        }
        drop(st);
        panic!("{ABORT}");
    }

    fn fail_locked(&self, st: &mut SchedState, msg: String) {
        let token = trace_token(&st.trace);
        let full = format!(
            "model '{}' failed: {msg}\n  replay with: GLINT_MODEL_REPLAY='{}:{token}'",
            self.name, self.name
        );
        eprintln!("{full}");
        if let Ok(path) = std::env::var("GLINT_MODEL_ARTIFACT") {
            use std::io::Write as _;
            if let Ok(mut f) = std::fs::OpenOptions::new()
                .create(true)
                .append(true)
                .open(&path)
            {
                let _ = writeln!(f, "{full}");
            }
        }
        st.failed = Some(full);
        st.active = None;
        self.cv.notify_all();
    }

    /// The running task hands control back with its new status and parks
    /// until it is active again.
    fn reschedule(&self, me: TaskId, status: Status) {
        if std::thread::panicking() {
            // Teardown path: drops of guards/channels during an unwind
            // must never park, or the unwinding thread would hang.
            return;
        }
        let mut st = self.locked();
        if st.failed.is_some() {
            drop(st);
            panic!("{ABORT}");
        }
        st.tasks[me].status = status;
        if matches!(status, Status::Blocked(_) | Status::TimedWait(_)) {
            st.seq += 1;
            st.tasks[me].wait_seq = st.seq;
        }
        self.pick_next(&mut st, Some(me));
        while st.active != Some(me) {
            if st.failed.is_some() {
                drop(st);
                panic!("{ABORT}");
            }
            st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
    }

    fn pick_next(&self, st: &mut SchedState, from: Option<TaskId>) {
        st.steps += 1;
        if st.steps > st.max_steps {
            self.fail_locked(
                st,
                format!("exceeded max_steps={} (livelock?)", st.max_steps),
            );
            return;
        }
        let candidates: Vec<TaskId> = st
            .tasks
            .iter()
            .enumerate()
            .filter(|(_, t)| matches!(t.status, Status::Runnable | Status::TimedWait(_)))
            .map(|(i, _)| i)
            .collect();
        if candidates.is_empty() {
            if st.live == 0 {
                st.active = None;
                self.cv.notify_all();
                return;
            }
            let blocked: Vec<String> = st
                .tasks
                .iter()
                .enumerate()
                .filter(|(_, t)| matches!(t.status, Status::Blocked(_)))
                .map(|(i, t)| format!("task {i} on {:?}", t.status))
                .collect();
            self.fail_locked(
                st,
                format!(
                    "deadlock: {} unfinished task(s), none runnable [{}]",
                    st.live,
                    blocked.join(", ")
                ),
            );
            return;
        }
        let stay = from.and_then(|f| {
            if st.tasks[f].status == Status::Runnable {
                candidates.iter().position(|&c| c == f)
            } else {
                None
            }
        });
        let idx = decide(st, candidates.len(), stay);
        let next = candidates[idx];
        if matches!(st.tasks[next].status, Status::TimedWait(_)) {
            st.tasks[next].status = Status::Runnable;
            st.tasks[next].timed_out = true;
        }
        st.active = Some(next);
        self.cv.notify_all();
    }
}

/// Take one decision with `options` alternatives: replay prefix first,
/// then the schedule policy (seeded random or option 0 for DFS default
/// continuation). Single-option decisions are not recorded.
fn decide(st: &mut SchedState, options: usize, stay: Option<usize>) -> usize {
    if options <= 1 {
        return 0;
    }
    let step = st.trace.len();
    let chosen = if step < st.replay.len() {
        st.replay[step].min(options - 1)
    } else if st.random {
        (st.rng.next_u64() % options as u64) as usize
    } else {
        0
    };
    st.trace.push(Choice {
        options,
        chosen,
        stay,
    });
    chosen
}

/// Nondeterministic data choice in `0..n` (fault injection, value picks).
/// Recorded in the trace like a scheduling decision, so replays cover it;
/// outside a model task it returns 0.
pub fn choice(n: usize) -> usize {
    match current() {
        Some((m, me)) => m.data_choice(me, n),
        None => 0,
    }
}

/// Fail the current schedule if `cond` is false. Inside a model task this
/// routes through the scheduler (printing a replay token); outside it is a
/// plain assert.
pub fn model_assert(cond: bool, msg: &str) {
    if cond {
        return;
    }
    match current() {
        Some((m, _)) => m.fail(msg),
        None => panic!("model assertion failed: {msg}"),
    }
}

fn trace_token(trace: &[Choice]) -> String {
    if trace.is_empty() {
        return "-".to_string();
    }
    trace
        .iter()
        .map(|c| c.chosen.to_string())
        .collect::<Vec<_>>()
        .join(".")
}

fn parse_token(tok: &str) -> Vec<usize> {
    if tok == "-" {
        return Vec::new();
    }
    tok.split('.')
        .filter_map(|s| s.trim().parse::<usize>().ok())
        .collect()
}

/// Exploration parameters for [`explore`].
#[derive(Clone, Copy, Debug)]
pub struct ExploreOpts {
    /// Schedule budget (random: exactly this many runs; DFS: upper bound).
    pub schedules: usize,
    /// Per-schedule decision cap; exceeding it fails the schedule. Guards
    /// against livelocks (e.g. a timeout loop the policy keeps feeding).
    pub max_steps: usize,
    /// Systematic bounded-preemption DFS instead of random walks.
    pub dfs: bool,
    /// Max preemptions per schedule for DFS (iteratively deepened 0..=N).
    pub max_preemptions: usize,
    /// Base seed for the random policy.
    pub seed: u64,
}

impl Default for ExploreOpts {
    fn default() -> Self {
        ExploreOpts {
            schedules: 1200,
            max_steps: 20_000,
            dfs: false,
            max_preemptions: 2,
            seed: 0x5eed_0915,
        }
    }
}

/// What an exploration covered.
#[derive(Clone, Copy, Debug)]
pub struct ExploreStats {
    /// Schedules executed.
    pub runs: usize,
    /// Distinct decision traces among them.
    pub distinct: usize,
}

struct RunOutcome {
    failed: Option<String>,
    trace: Vec<Choice>,
}

fn run_one(
    name: &str,
    replay: Vec<usize>,
    random: bool,
    seed: u64,
    max_steps: usize,
    body: &Arc<dyn Fn() + Send + Sync>,
) -> RunOutcome {
    let model = Model::new(name, replay, random, seed, max_steps);
    let root = model.register_task();
    model.locked().active = Some(root);
    let m2 = Arc::clone(&model);
    let b = Arc::clone(body);
    let h = std::thread::Builder::new()
        .name(format!("model-{name}-root"))
        .spawn(move || {
            set_ctx(Some((Arc::clone(&m2), root)));
            let out = panic::catch_unwind(AssertUnwindSafe(|| b()));
            match out {
                Ok(()) => m2.task_finished(root),
                Err(p) => m2.task_panicked(root, panic_msg(p.as_ref())),
            }
            set_ctx(None);
        })
        .expect("spawn model root thread");
    let _ = h.join();
    // Tasks spawned by the body may still be running (the root can return
    // while workers drain); join OS threads until none remain, including
    // any spawned by threads we are joining.
    loop {
        let hs: Vec<_> = model
            .os_handles
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .drain(..)
            .collect();
        if hs.is_empty() {
            break;
        }
        for h in hs {
            let _ = h.join();
        }
    }
    let st = model.locked();
    RunOutcome {
        failed: st.failed.clone(),
        trace: st.trace.clone(),
    }
}

pub(crate) fn panic_msg(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Silence the default panic hook for ABORT-sentinel unwinds (they are
/// scheduled teardown, not failures) while keeping it for real panics.
fn install_quiet_hook() {
    static ONCE: std::sync::Once = std::sync::Once::new();
    ONCE.call_once(|| {
        let prev = panic::take_hook();
        panic::set_hook(Box::new(move |info| {
            let quiet = info
                .payload()
                .downcast_ref::<&str>()
                .is_some_and(|s| s.contains(ABORT))
                || info
                    .payload()
                    .downcast_ref::<String>()
                    .is_some_and(|s| s.contains(ABORT));
            if !quiet {
                prev(info);
            }
        }));
    });
}

/// Run `body` under many interleavings. Panics (with the failing trace's
/// replay token already printed) on the first failing schedule; otherwise
/// returns coverage stats. When `GLINT_MODEL_REPLAY=name:token` is set,
/// runs exactly that schedule for the matching model and skips all others
/// (see [`replay_active`]).
pub fn explore<F>(name: &str, opts: ExploreOpts, body: F) -> ExploreStats
where
    F: Fn() + Send + Sync + 'static,
{
    install_quiet_hook();
    let body: Arc<dyn Fn() + Send + Sync> = Arc::new(body);

    if let Ok(spec) = std::env::var("GLINT_MODEL_REPLAY") {
        let (target, tok) = spec.split_once(':').unwrap_or((spec.as_str(), "-"));
        if target != name {
            return ExploreStats {
                runs: 0,
                distinct: 0,
            };
        }
        let out = run_one(name, parse_token(tok), false, opts.seed, opts.max_steps, &body);
        if let Some(f) = out.failed {
            panic!("{f}");
        }
        eprintln!("model '{name}': replay passed");
        return ExploreStats {
            runs: 1,
            distinct: 1,
        };
    }

    let mut seen: HashSet<Vec<Choice>> = HashSet::new();
    let mut runs = 0usize;

    if opts.dfs {
        let mut queued: HashSet<Vec<usize>> = HashSet::new();
        'bounds: for bound in 0..=opts.max_preemptions {
            let mut stack: Vec<Vec<usize>> = vec![Vec::new()];
            while let Some(prefix) = stack.pop() {
                if runs >= opts.schedules {
                    break 'bounds;
                }
                let out = run_one(name, prefix.clone(), false, opts.seed, opts.max_steps, &body);
                runs += 1;
                if let Some(f) = out.failed {
                    panic!("{f}");
                }
                // Expand alternatives past the forced prefix, respecting
                // the preemption budget along the executed trace.
                let mut preemptions = 0usize;
                for (i, c) in out.trace.iter().enumerate() {
                    if i >= prefix.len() {
                        for alt in 0..c.options {
                            if alt == c.chosen {
                                continue;
                            }
                            let is_preempt =
                                matches!(c.stay, Some(s) if s != alt) as usize;
                            if preemptions + is_preempt > bound {
                                continue;
                            }
                            let mut p: Vec<usize> =
                                out.trace[..i].iter().map(|c| c.chosen).collect();
                            p.push(alt);
                            if queued.insert(p.clone()) {
                                stack.push(p);
                            }
                        }
                    }
                    if matches!(c.stay, Some(s) if s != c.chosen) {
                        preemptions += 1;
                    }
                }
                seen.insert(out.trace);
            }
        }
    } else {
        while runs < opts.schedules {
            let seed = opts
                .seed
                .wrapping_add((runs as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15));
            let out = run_one(name, Vec::new(), true, seed, opts.max_steps, &body);
            runs += 1;
            if let Some(f) = out.failed {
                panic!("{f}");
            }
            seen.insert(out.trace);
        }
    }

    ExploreStats {
        runs,
        distinct: seen.len(),
    }
}
