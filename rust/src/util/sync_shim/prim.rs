//! Model-build implementations of the `sync_shim` primitives.
//!
//! Every type here keeps a *real* `std` primitive as its storage and
//! overlays virtual ownership on top: a virtual task first wins the
//! resource under the scheduler (parking at a schedule point if it must),
//! and only then touches the real primitive — which is therefore always
//! uncontended or held in a way the scheduler already sanctioned. Threads
//! *outside* a model (e.g. the test harness itself) fall through to plain
//! `std` behavior, so the same types work in both worlds.
//!
//! Two deliberate semantic simplifications, both documented on the shim
//! module: poisoning is never reported (a panicking schedule aborts the
//! run), and atomic orderings are ignored (the scheduler serializes every
//! access, i.e. models run under sequential consistency).

use std::time::Duration;

use super::sched::{current, fresh_rid, Model};

// ---------------------------------------------------------------------------
// Mutex
// ---------------------------------------------------------------------------

/// Model-checked mutex; see the module docs for the ownership scheme.
pub struct Mutex<T> {
    rid: usize,
    /// Virtual ownership flag. Only the running task mutates it, so a
    /// plain load/swap is race-free by construction.
    held: std::sync::atomic::AtomicBool,
    inner: std::sync::Mutex<T>,
}

/// Guard for [`Mutex`]. Dropping it releases virtual ownership and wakes
/// every task parked on the lock (re-acquisition order is then a fresh
/// scheduling decision, like real lock handoff).
pub struct MutexGuard<'a, T> {
    lock: &'a Mutex<T>,
    inner: Option<std::sync::MutexGuard<'a, T>>,
    virt: bool,
    /// Set by `Condvar::wait*`, which tears the guard down manually.
    released: bool,
}

impl<T> Mutex<T> {
    /// Create a mutex.
    pub fn new(t: T) -> Mutex<T> {
        Mutex {
            rid: fresh_rid(),
            held: std::sync::atomic::AtomicBool::new(false),
            inner: std::sync::Mutex::new(t),
        }
    }

    /// Consume the mutex, returning its value. Always `Ok`: model
    /// builds swallow poisoning (matching [`Mutex::lock`]).
    pub fn into_inner(self) -> std::sync::LockResult<T> {
        Ok(self.inner.into_inner().unwrap_or_else(|e| e.into_inner()))
    }

    /// Acquire. Always returns `Ok`: model builds swallow poisoning.
    pub fn lock(&self) -> std::sync::LockResult<MutexGuard<'_, T>> {
        if let Some((m, me)) = current() {
            loop {
                m.point(me);
                if !self
                    .held
                    .swap(true, std::sync::atomic::Ordering::SeqCst)
                {
                    break;
                }
                m.block_on(me, self.rid);
            }
            let g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
            Ok(MutexGuard {
                lock: self,
                inner: Some(g),
                virt: true,
                released: false,
            })
        } else {
            let g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
            Ok(MutexGuard {
                lock: self,
                inner: Some(g),
                virt: false,
                released: false,
            })
        }
    }

    fn virtual_unlock(&self) {
        self.held
            .store(false, std::sync::atomic::Ordering::SeqCst);
        if let Some((m, _)) = current() {
            m.wake_all(self.rid);
        }
    }
}

impl<T> std::ops::Deref for MutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.inner.as_ref().expect("guard accessed after release")
    }
}

impl<T> std::ops::DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner.as_mut().expect("guard accessed after release")
    }
}

impl<T> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        if self.released {
            return;
        }
        drop(self.inner.take());
        if self.virt {
            self.lock.virtual_unlock();
        }
    }
}

// ---------------------------------------------------------------------------
// Condvar
// ---------------------------------------------------------------------------

/// Model-checked condition variable. The model variant has *no spurious
/// wakeups*, which makes lost-wakeup bugs deterministic: a waiter that
/// nobody notifies stays parked and the schedule fails as a deadlock.
pub struct Condvar {
    rid: usize,
    real: std::sync::Condvar,
}

/// Result of [`Condvar::wait_timeout`] (std's type cannot be constructed
/// outside std, so model builds ship their own; call sites only call
/// [`WaitTimeoutResult::timed_out`]).
#[derive(Clone, Copy, Debug)]
pub struct WaitTimeoutResult(bool);

impl WaitTimeoutResult {
    /// True when the wait ended because the timeout fired.
    pub fn timed_out(&self) -> bool {
        self.0
    }
}

impl Default for Condvar {
    fn default() -> Self {
        Condvar::new()
    }
}

impl Condvar {
    /// Create a condition variable.
    pub fn new() -> Condvar {
        Condvar {
            rid: fresh_rid(),
            real: std::sync::Condvar::new(),
        }
    }

    /// Atomically (w.r.t. the virtual scheduler: the caller stays the
    /// running task throughout) release the lock, park on the condvar,
    /// and re-acquire once notified.
    pub fn wait<'a, T>(
        &self,
        guard: MutexGuard<'a, T>,
    ) -> std::sync::LockResult<MutexGuard<'a, T>> {
        if let Some((m, me)) = current() {
            let lock = self.release_for_wait(guard, &m);
            m.block_on(me, self.rid);
            lock.lock()
        } else {
            let lock = guard.lock;
            let mut guard = guard;
            let inner = guard.inner.take().expect("guard accessed after release");
            guard.released = true;
            drop(guard);
            let g = self.real.wait(inner).unwrap_or_else(|e| e.into_inner());
            Ok(MutexGuard {
                lock,
                inner: Some(g),
                virt: false,
                released: false,
            })
        }
    }

    /// Like [`Condvar::wait`] but the scheduler may fire the timeout at
    /// any step instead of delivering a notify — so every "deadline races
    /// the signal" interleaving is explored regardless of `_dur`.
    pub fn wait_timeout<'a, T>(
        &self,
        guard: MutexGuard<'a, T>,
        dur: Duration,
    ) -> std::sync::LockResult<(MutexGuard<'a, T>, WaitTimeoutResult)> {
        if let Some((m, me)) = current() {
            let lock = self.release_for_wait(guard, &m);
            let fired = m.timed_block_on(me, self.rid);
            let g = lock.lock().unwrap_or_else(|e| e.into_inner());
            Ok((g, WaitTimeoutResult(fired)))
        } else {
            let lock = guard.lock;
            let mut guard = guard;
            let inner = guard.inner.take().expect("guard accessed after release");
            guard.released = true;
            drop(guard);
            let (g, r) = self
                .real
                .wait_timeout(inner, dur)
                .unwrap_or_else(|e| e.into_inner());
            Ok((
                MutexGuard {
                    lock,
                    inner: Some(g),
                    virt: false,
                    released: false,
                },
                WaitTimeoutResult(r.timed_out()),
            ))
        }
    }

    /// Wake one waiter; which one is a recorded scheduling decision.
    pub fn notify_one(&self) {
        if let Some((m, me)) = current() {
            m.wake_one(self.rid);
            m.point(me);
        } else {
            self.real.notify_one();
        }
    }

    /// Wake all waiters.
    pub fn notify_all(&self) {
        if let Some((m, me)) = current() {
            m.wake_all(self.rid);
            m.point(me);
        } else {
            self.real.notify_all();
        }
    }

    fn release_for_wait<'a, T>(&self, mut guard: MutexGuard<'a, T>, m: &Model) -> &'a Mutex<T> {
        let lock = guard.lock;
        drop(guard.inner.take());
        guard.released = true;
        drop(guard);
        lock.held
            .store(false, std::sync::atomic::Ordering::SeqCst);
        m.wake_all(lock.rid);
        lock
    }
}

// ---------------------------------------------------------------------------
// RwLock
// ---------------------------------------------------------------------------

/// Model-checked reader/writer lock (writer-exclusive, no fairness —
/// wakeup order after a release is a scheduling decision).
pub struct RwLock<T> {
    rid: usize,
    readers: std::sync::atomic::AtomicUsize,
    writer: std::sync::atomic::AtomicBool,
    inner: std::sync::RwLock<T>,
}

/// Shared guard for [`RwLock`].
pub struct RwLockReadGuard<'a, T> {
    lock: &'a RwLock<T>,
    inner: Option<std::sync::RwLockReadGuard<'a, T>>,
    virt: bool,
}

/// Exclusive guard for [`RwLock`].
pub struct RwLockWriteGuard<'a, T> {
    lock: &'a RwLock<T>,
    inner: Option<std::sync::RwLockWriteGuard<'a, T>>,
    virt: bool,
}

impl<T> RwLock<T> {
    /// Create a reader/writer lock.
    pub fn new(t: T) -> RwLock<T> {
        RwLock {
            rid: fresh_rid(),
            readers: std::sync::atomic::AtomicUsize::new(0),
            writer: std::sync::atomic::AtomicBool::new(false),
            inner: std::sync::RwLock::new(t),
        }
    }

    /// Acquire shared. Always `Ok` (poisoning swallowed in model builds).
    pub fn read(&self) -> std::sync::LockResult<RwLockReadGuard<'_, T>> {
        if let Some((m, me)) = current() {
            loop {
                m.point(me);
                if !self.writer.load(std::sync::atomic::Ordering::SeqCst) {
                    self.readers
                        .fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                    break;
                }
                m.block_on(me, self.rid);
            }
            let g = self.inner.read().unwrap_or_else(|e| e.into_inner());
            Ok(RwLockReadGuard {
                lock: self,
                inner: Some(g),
                virt: true,
            })
        } else {
            let g = self.inner.read().unwrap_or_else(|e| e.into_inner());
            Ok(RwLockReadGuard {
                lock: self,
                inner: Some(g),
                virt: false,
            })
        }
    }

    /// Acquire exclusive. Always `Ok`.
    pub fn write(&self) -> std::sync::LockResult<RwLockWriteGuard<'_, T>> {
        if let Some((m, me)) = current() {
            loop {
                m.point(me);
                if !self.writer.load(std::sync::atomic::Ordering::SeqCst)
                    && self.readers.load(std::sync::atomic::Ordering::SeqCst) == 0
                {
                    self.writer
                        .store(true, std::sync::atomic::Ordering::SeqCst);
                    break;
                }
                m.block_on(me, self.rid);
            }
            let g = self.inner.write().unwrap_or_else(|e| e.into_inner());
            Ok(RwLockWriteGuard {
                lock: self,
                inner: Some(g),
                virt: true,
            })
        } else {
            let g = self.inner.write().unwrap_or_else(|e| e.into_inner());
            Ok(RwLockWriteGuard {
                lock: self,
                inner: Some(g),
                virt: false,
            })
        }
    }

    fn wake(&self) {
        if let Some((m, _)) = current() {
            m.wake_all(self.rid);
        }
    }
}

impl<T> std::ops::Deref for RwLockReadGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.inner.as_ref().expect("guard accessed after release")
    }
}

impl<T> Drop for RwLockReadGuard<'_, T> {
    fn drop(&mut self) {
        drop(self.inner.take());
        if self.virt {
            let prev = self
                .lock
                .readers
                .fetch_sub(1, std::sync::atomic::Ordering::SeqCst);
            if prev == 1 {
                self.lock.wake();
            }
        }
    }
}

impl<T> std::ops::Deref for RwLockWriteGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.inner.as_ref().expect("guard accessed after release")
    }
}

impl<T> std::ops::DerefMut for RwLockWriteGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner.as_mut().expect("guard accessed after release")
    }
}

impl<T> Drop for RwLockWriteGuard<'_, T> {
    fn drop(&mut self) {
        drop(self.inner.take());
        if self.virt {
            self.lock
                .writer
                .store(false, std::sync::atomic::Ordering::SeqCst);
            self.lock.wake();
        }
    }
}

// ---------------------------------------------------------------------------
// Atomics
// ---------------------------------------------------------------------------

/// Model-checked atomics: thin wrappers over the real types that insert a
/// schedule point before every access from a model task. Orderings are
/// accepted for API compatibility but ignored — the scheduler serializes
/// all accesses, so models run under sequential consistency (weak-memory
/// effects are the TSan leg's job, not the model's).
pub mod atomic {
    pub use std::sync::atomic::Ordering;

    fn point() {
        if let Some((m, me)) = super::current() {
            m.point(me);
        }
    }

    macro_rules! model_atomic_common {
        ($name:ident, $std:ty, $prim:ty) => {
            /// Model-checked atomic (schedule point before every access).
            pub struct $name($std);

            impl $name {
                /// Create the atomic.
                pub fn new(v: $prim) -> Self {
                    Self(<$std>::new(v))
                }

                /// Load (ordering ignored; see module docs).
                pub fn load(&self, _o: Ordering) -> $prim {
                    point();
                    self.0.load(Ordering::SeqCst)
                }

                /// Store (ordering ignored).
                pub fn store(&self, v: $prim, _o: Ordering) {
                    point();
                    self.0.store(v, Ordering::SeqCst)
                }

                /// Swap (ordering ignored).
                pub fn swap(&self, v: $prim, _o: Ordering) -> $prim {
                    point();
                    self.0.swap(v, Ordering::SeqCst)
                }
            }
        };
    }

    macro_rules! model_atomic_int {
        ($name:ident, $std:ty, $prim:ty) => {
            model_atomic_common!($name, $std, $prim);

            impl $name {
                /// Add, returning the previous value (ordering ignored).
                pub fn fetch_add(&self, v: $prim, _o: Ordering) -> $prim {
                    point();
                    self.0.fetch_add(v, Ordering::SeqCst)
                }

                /// Subtract, returning the previous value (ordering ignored).
                pub fn fetch_sub(&self, v: $prim, _o: Ordering) -> $prim {
                    point();
                    self.0.fetch_sub(v, Ordering::SeqCst)
                }

                /// Max, returning the previous value (ordering ignored).
                pub fn fetch_max(&self, v: $prim, _o: Ordering) -> $prim {
                    point();
                    self.0.fetch_max(v, Ordering::SeqCst)
                }
            }
        };
    }

    model_atomic_common!(AtomicBool, std::sync::atomic::AtomicBool, bool);
    model_atomic_int!(AtomicU8, std::sync::atomic::AtomicU8, u8);
    model_atomic_int!(AtomicU32, std::sync::atomic::AtomicU32, u32);
    model_atomic_int!(AtomicU64, std::sync::atomic::AtomicU64, u64);
    model_atomic_int!(AtomicUsize, std::sync::atomic::AtomicUsize, usize);
}

// ---------------------------------------------------------------------------
// Channels
// ---------------------------------------------------------------------------

/// Model-checked mpsc channels. Whether a channel is virtual is decided at
/// construction: channels created by a model task are scheduler-driven;
/// channels created outside (harness plumbing) are the real std ones, so
/// either kind can flow through the same code.
pub mod mpsc {
    pub use std::sync::mpsc::{
        RecvError, RecvTimeoutError, SendError, TryRecvError, TrySendError,
    };

    use std::collections::VecDeque;
    use std::sync::Arc;
    use std::time::Duration;

    use super::super::sched::{current, fresh_rid};

    struct Chan<T> {
        rid: usize,
        q: std::sync::Mutex<VecDeque<T>>,
        /// `None` = unbounded (`channel`), `Some(n)` = rendezvous-ish
        /// bound (`sync_channel`).
        cap: Option<usize>,
        senders: std::sync::atomic::AtomicUsize,
        rx_alive: std::sync::atomic::AtomicBool,
    }

    impl<T> Chan<T> {
        fn new(cap: Option<usize>) -> Arc<Chan<T>> {
            Arc::new(Chan {
                rid: fresh_rid(),
                q: std::sync::Mutex::new(VecDeque::new()),
                cap,
                senders: std::sync::atomic::AtomicUsize::new(1),
                rx_alive: std::sync::atomic::AtomicBool::new(true),
            })
        }

        fn push(&self, t: T) {
            self.q.lock().unwrap_or_else(|e| e.into_inner()).push_back(t);
        }

        fn pop(&self) -> Option<T> {
            self.q.lock().unwrap_or_else(|e| e.into_inner()).pop_front()
        }

        fn len(&self) -> usize {
            self.q.lock().unwrap_or_else(|e| e.into_inner()).len()
        }

        fn senders_gone(&self) -> bool {
            self.senders.load(std::sync::atomic::Ordering::SeqCst) == 0
        }

        fn rx_gone(&self) -> bool {
            !self.rx_alive.load(std::sync::atomic::Ordering::SeqCst)
        }

        fn wake(&self) {
            if let Some((m, _)) = current() {
                m.wake_all(self.rid);
            }
        }
    }

    enum SenderImpl<T> {
        Real(std::sync::mpsc::Sender<T>),
        Virt(Arc<Chan<T>>),
    }

    enum SyncSenderImpl<T> {
        Real(std::sync::mpsc::SyncSender<T>),
        Virt(Arc<Chan<T>>),
    }

    enum ReceiverImpl<T> {
        Real(std::sync::mpsc::Receiver<T>),
        Virt(Arc<Chan<T>>),
    }

    /// Asynchronous (unbounded) sender.
    pub struct Sender<T>(SenderImpl<T>);

    /// Bounded sender.
    pub struct SyncSender<T>(SyncSenderImpl<T>);

    /// Receiver for either channel flavor.
    pub struct Receiver<T>(ReceiverImpl<T>);

    /// Unbounded channel (virtual iff created by a model task).
    pub fn channel<T>() -> (Sender<T>, Receiver<T>) {
        if current().is_some() {
            let c = Chan::new(None);
            (
                Sender(SenderImpl::Virt(Arc::clone(&c))),
                Receiver(ReceiverImpl::Virt(c)),
            )
        } else {
            let (t, r) = std::sync::mpsc::channel();
            (Sender(SenderImpl::Real(t)), Receiver(ReceiverImpl::Real(r)))
        }
    }

    /// Bounded channel (virtual iff created by a model task).
    pub fn sync_channel<T>(bound: usize) -> (SyncSender<T>, Receiver<T>) {
        if current().is_some() {
            let c = Chan::new(Some(bound));
            (
                SyncSender(SyncSenderImpl::Virt(Arc::clone(&c))),
                Receiver(ReceiverImpl::Virt(c)),
            )
        } else {
            let (t, r) = std::sync::mpsc::sync_channel(bound);
            (
                SyncSender(SyncSenderImpl::Real(t)),
                Receiver(ReceiverImpl::Real(r)),
            )
        }
    }

    fn ctx() -> (Arc<super::super::sched::Model>, super::super::sched::TaskId) {
        current().expect("virtual channel endpoint used outside a model task")
    }

    impl<T> Sender<T> {
        /// Send, failing if the receiver is gone.
        pub fn send(&self, t: T) -> Result<(), SendError<T>> {
            match &self.0 {
                SenderImpl::Real(s) => s.send(t),
                SenderImpl::Virt(c) => {
                    let (m, me) = ctx();
                    m.point(me);
                    if c.rx_gone() {
                        return Err(SendError(t));
                    }
                    c.push(t);
                    c.wake();
                    Ok(())
                }
            }
        }
    }

    impl<T> Clone for Sender<T> {
        fn clone(&self) -> Self {
            match &self.0 {
                SenderImpl::Real(s) => Sender(SenderImpl::Real(s.clone())),
                SenderImpl::Virt(c) => {
                    c.senders
                        .fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                    Sender(SenderImpl::Virt(Arc::clone(c)))
                }
            }
        }
    }

    impl<T> Drop for Sender<T> {
        fn drop(&mut self) {
            if let SenderImpl::Virt(c) = &self.0 {
                let prev = c
                    .senders
                    .fetch_sub(1, std::sync::atomic::Ordering::SeqCst);
                if prev == 1 {
                    c.wake();
                }
            }
        }
    }

    impl<T> SyncSender<T> {
        /// Blocking bounded send.
        pub fn send(&self, t: T) -> Result<(), SendError<T>> {
            match &self.0 {
                SyncSenderImpl::Real(s) => s.send(t),
                SyncSenderImpl::Virt(c) => {
                    let (m, me) = ctx();
                    let mut t = Some(t);
                    loop {
                        m.point(me);
                        if c.rx_gone() {
                            return Err(SendError(t.take().expect("send value consumed twice")));
                        }
                        let cap = c.cap.unwrap_or(usize::MAX).max(1);
                        if c.len() < cap {
                            c.push(t.take().expect("send value consumed twice"));
                            c.wake();
                            return Ok(());
                        }
                        m.block_on(me, c.rid);
                    }
                }
            }
        }

        /// Non-blocking bounded send.
        pub fn try_send(&self, t: T) -> Result<(), TrySendError<T>> {
            match &self.0 {
                SyncSenderImpl::Real(s) => s.try_send(t),
                SyncSenderImpl::Virt(c) => {
                    let (m, me) = ctx();
                    m.point(me);
                    if c.rx_gone() {
                        return Err(TrySendError::Disconnected(t));
                    }
                    let cap = c.cap.unwrap_or(usize::MAX).max(1);
                    if c.len() >= cap {
                        return Err(TrySendError::Full(t));
                    }
                    c.push(t);
                    c.wake();
                    Ok(())
                }
            }
        }
    }

    impl<T> Clone for SyncSender<T> {
        fn clone(&self) -> Self {
            match &self.0 {
                SyncSenderImpl::Real(s) => SyncSender(SyncSenderImpl::Real(s.clone())),
                SyncSenderImpl::Virt(c) => {
                    c.senders
                        .fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                    SyncSender(SyncSenderImpl::Virt(Arc::clone(c)))
                }
            }
        }
    }

    impl<T> Drop for SyncSender<T> {
        fn drop(&mut self) {
            if let SyncSenderImpl::Virt(c) = &self.0 {
                let prev = c
                    .senders
                    .fetch_sub(1, std::sync::atomic::Ordering::SeqCst);
                if prev == 1 {
                    c.wake();
                }
            }
        }
    }

    impl<T> Receiver<T> {
        /// Blocking receive.
        pub fn recv(&self) -> Result<T, RecvError> {
            match &self.0 {
                ReceiverImpl::Real(r) => r.recv(),
                ReceiverImpl::Virt(c) => {
                    let (m, me) = ctx();
                    loop {
                        m.point(me);
                        if let Some(t) = c.pop() {
                            c.wake();
                            return Ok(t);
                        }
                        if c.senders_gone() {
                            return Err(RecvError);
                        }
                        m.block_on(me, c.rid);
                    }
                }
            }
        }

        /// Receive with a deadline; in model builds the scheduler may fire
        /// the timeout at any step regardless of `dur`.
        pub fn recv_timeout(&self, dur: Duration) -> Result<T, RecvTimeoutError> {
            match &self.0 {
                ReceiverImpl::Real(r) => r.recv_timeout(dur),
                ReceiverImpl::Virt(c) => {
                    let (m, me) = ctx();
                    loop {
                        m.point(me);
                        if let Some(t) = c.pop() {
                            c.wake();
                            return Ok(t);
                        }
                        if c.senders_gone() {
                            return Err(RecvTimeoutError::Disconnected);
                        }
                        if m.timed_block_on(me, c.rid) {
                            return Err(RecvTimeoutError::Timeout);
                        }
                    }
                }
            }
        }

        /// Non-blocking receive.
        pub fn try_recv(&self) -> Result<T, TryRecvError> {
            match &self.0 {
                ReceiverImpl::Real(r) => r.try_recv(),
                ReceiverImpl::Virt(c) => {
                    let (m, me) = ctx();
                    m.point(me);
                    if let Some(t) = c.pop() {
                        c.wake();
                        return Ok(t);
                    }
                    if c.senders_gone() {
                        return Err(TryRecvError::Disconnected);
                    }
                    Err(TryRecvError::Empty)
                }
            }
        }

        /// Blocking iterator over received values (ends when senders drop).
        pub fn iter(&self) -> Iter<'_, T> {
            Iter { rx: self }
        }
    }

    impl<T> Drop for Receiver<T> {
        fn drop(&mut self) {
            if let ReceiverImpl::Virt(c) = &self.0 {
                c.rx_alive
                    .store(false, std::sync::atomic::Ordering::SeqCst);
                c.wake();
            }
        }
    }

    /// Iterator returned by [`Receiver::iter`].
    pub struct Iter<'a, T> {
        rx: &'a Receiver<T>,
    }

    impl<T> Iterator for Iter<'_, T> {
        type Item = T;
        fn next(&mut self) -> Option<T> {
            self.rx.recv().ok()
        }
    }
}

// ---------------------------------------------------------------------------
// Threads
// ---------------------------------------------------------------------------

/// Model-checked thread spawning. A spawn from a model task registers a
/// new virtual task (backed by a real OS thread that parks until the
/// scheduler picks it); a spawn from outside is a plain `std` spawn.
pub mod thread {
    use std::panic::{self, AssertUnwindSafe};
    use std::sync::Arc;
    use std::time::Duration;

    use super::super::sched::{self, current, join_rid, Model, TaskId};

    /// Thread factory mirroring `std::thread::Builder`.
    #[derive(Default)]
    pub struct Builder {
        name: Option<String>,
    }

    enum HandleImpl<T> {
        Real(std::thread::JoinHandle<T>),
        Virt {
            model: Arc<Model>,
            task: TaskId,
            result: Arc<std::sync::Mutex<Option<std::thread::Result<T>>>>,
        },
    }

    /// Handle to a spawned thread/task.
    pub struct JoinHandle<T>(HandleImpl<T>);

    impl Builder {
        /// Create a builder.
        pub fn new() -> Builder {
            Builder { name: None }
        }

        /// Name the thread.
        pub fn name(mut self, name: String) -> Builder {
            self.name = Some(name);
            self
        }

        /// Spawn. From a model task this registers a virtual task; the
        /// child's first step happens when the scheduler picks it.
        pub fn spawn<F, T>(self, f: F) -> std::io::Result<JoinHandle<T>>
        where
            F: FnOnce() -> T + Send + 'static,
            T: Send + 'static,
        {
            let mut b = std::thread::Builder::new();
            if let Some(n) = &self.name {
                b = b.name(n.clone());
            }
            if let Some((m, me)) = current() {
                let model = Arc::clone(&m);
                let task = model.register_task();
                let result: Arc<std::sync::Mutex<Option<std::thread::Result<T>>>> =
                    Arc::new(std::sync::Mutex::new(None));
                let r2 = Arc::clone(&result);
                let m2 = Arc::clone(&model);
                let real = b.spawn(move || {
                    sched::set_ctx(Some((Arc::clone(&m2), task)));
                    let out = panic::catch_unwind(AssertUnwindSafe(|| {
                        m2.wait_until_active(task);
                        f()
                    }));
                    match out {
                        Ok(v) => {
                            *r2.lock().unwrap_or_else(|e| e.into_inner()) = Some(Ok(v));
                            m2.task_finished(task);
                        }
                        Err(p) => {
                            let msg = sched::panic_msg(p.as_ref());
                            *r2.lock().unwrap_or_else(|e| e.into_inner()) = Some(Err(p));
                            m2.task_panicked(task, msg);
                        }
                    }
                    sched::set_ctx(None);
                })?;
                model.note_os_handle(real);
                // Schedule point: the child is now a candidate.
                m.point(me);
                Ok(JoinHandle(HandleImpl::Virt {
                    model,
                    task,
                    result,
                }))
            } else {
                Ok(JoinHandle(HandleImpl::Real(b.spawn(f)?)))
            }
        }
    }

    /// Spawn an unnamed thread (panics on spawn failure, like std).
    pub fn spawn<F, T>(f: F) -> JoinHandle<T>
    where
        F: FnOnce() -> T + Send + 'static,
        T: Send + 'static,
    {
        Builder::new().spawn(f).expect("failed to spawn thread")
    }

    /// In a model task this is a plain schedule point (virtual time has
    /// no duration); outside it really sleeps.
    pub fn sleep(dur: Duration) {
        if let Some((m, me)) = current() {
            let _ = dur;
            m.point(me);
        } else {
            std::thread::sleep(dur);
        }
    }

    impl<T> JoinHandle<T> {
        /// Wait for the thread/task and collect its result.
        pub fn join(self) -> std::thread::Result<T> {
            match self.0 {
                HandleImpl::Real(h) => h.join(),
                HandleImpl::Virt {
                    model,
                    task,
                    result,
                } => {
                    let (m, me) =
                        current().expect("virtual JoinHandle joined outside a model task");
                    debug_assert!(Arc::ptr_eq(&m, &model));
                    loop {
                        if let Some(r) = result.lock().unwrap_or_else(|e| e.into_inner()).take()
                        {
                            return r;
                        }
                        m.block_on(me, join_rid(task));
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Smoke check that the virtual pieces agree with each other (runs only
// under `--features model`, alongside the real models in tests/model.rs).
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::super::sched::{explore, ExploreOpts};
    use super::*;

    #[test]
    fn model_mutex_counter_is_exact() {
        if super::super::sched::replay_active() {
            return;
        }
        let stats = explore(
            "prim-mutex-counter",
            ExploreOpts {
                schedules: 64,
                ..ExploreOpts::default()
            },
            || {
                let n = Arc::new(Mutex::new(0u32));
                let mut hs = Vec::new();
                for _ in 0..3 {
                    let n = Arc::clone(&n);
                    hs.push(thread::spawn(move || {
                        for _ in 0..2 {
                            *n.lock().unwrap_or_else(|e| e.into_inner()) += 1;
                        }
                    }));
                }
                for h in hs {
                    h.join().expect("worker panicked");
                }
                assert_eq!(*n.lock().unwrap_or_else(|e| e.into_inner()), 6);
            },
        );
        assert!(stats.runs >= 64);
    }

    #[test]
    fn model_channel_delivers_everything() {
        if super::super::sched::replay_active() {
            return;
        }
        explore(
            "prim-channel",
            ExploreOpts {
                schedules: 64,
                ..ExploreOpts::default()
            },
            || {
                let (tx, rx) = mpsc::sync_channel::<u32>(1);
                let tx2 = tx.clone();
                let p = thread::spawn(move || {
                    for i in 0..3 {
                        tx.send(i).expect("receiver alive");
                    }
                });
                let q = thread::spawn(move || {
                    for i in 10..13 {
                        tx2.send(i).expect("receiver alive");
                    }
                });
                let mut got = Vec::new();
                for _ in 0..6 {
                    got.push(rx.recv().expect("senders alive"));
                }
                p.join().expect("producer");
                q.join().expect("producer");
                got.sort_unstable();
                assert_eq!(got, vec![0, 1, 2, 10, 11, 12]);
            },
        );
    }
}
