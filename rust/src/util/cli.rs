//! Minimal command-line argument parser and subcommand dispatch table.
//!
//! [`Args`] supports `--flag value`, `--flag=value`, boolean `--flag`,
//! and a positional subcommand, which is all the launcher needs. No
//! external crates are available offline, so this replaces `clap`.
//!
//! [`CommandSet`] is the launcher's dispatch table: each mode is one
//! [`Command`] entry (name, one-line summary, usage text, handler), and
//! the table renders the top-level help, per-command help (`help <cmd>`
//! or `<cmd> --help`) and the unknown-command error from the same data.

use std::collections::BTreeMap;

use crate::util::error::{Error, Result};

/// Parsed arguments: one optional subcommand plus `--key value` options.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// First non-flag argument, if any.
    pub command: Option<String>,
    /// Remaining positional arguments after the subcommand.
    pub positional: Vec<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args> {
        let mut args = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.opts.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    args.opts.insert(stripped.to_string(), v);
                } else {
                    args.flags.push(stripped.to_string());
                }
            } else if args.command.is_none() {
                args.command = Some(a);
            } else {
                args.positional.push(a);
            }
        }
        Ok(args)
    }

    /// Parse the process arguments.
    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    /// Raw string option.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    /// String option with default.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Boolean flag (present without value) or `--key true/false`.
    pub fn flag(&self, key: &str) -> bool {
        if self.flags.iter().any(|f| f == key) {
            return true;
        }
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Typed option with default; errors on unparsable values.
    pub fn get_as<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.get(key) {
            None => Ok(default),
            Some(raw) => raw.parse::<T>().map_err(|_| {
                Error::Config(format!("invalid value for --{key}: {raw:?}"))
            }),
        }
    }

    /// Typed required option.
    pub fn require<T: std::str::FromStr>(&self, key: &str) -> Result<T> {
        let raw = self
            .get(key)
            .ok_or_else(|| Error::Config(format!("missing required option --{key}")))?;
        raw.parse::<T>()
            .map_err(|_| Error::Config(format!("invalid value for --{key}: {raw:?}")))
    }
}

/// One launcher subcommand: dispatch-table entry plus its help text.
pub struct Command {
    /// Name as typed on the command line (`glint-lda <name> ...`).
    pub name: &'static str,
    /// One-line summary shown in the top-level command list.
    pub summary: &'static str,
    /// Option/usage text shown by `help <name>` and `<name> --help`.
    pub usage: &'static str,
    /// The mode implementation.
    pub run: fn(&Args) -> Result<()>,
}

/// The launcher's subcommand table. All help output — the top-level
/// listing, per-command usage, and the unknown-command error — is
/// rendered from the same entries, so a mode cannot exist without help
/// text or be documented without existing.
pub struct CommandSet {
    /// Binary name used in usage lines.
    pub program: &'static str,
    /// One-line description of the whole binary.
    pub about: &'static str,
    /// Options every command accepts, appended to the top-level help.
    pub common: &'static str,
    /// The modes, in help-listing order.
    pub commands: &'static [Command],
}

impl CommandSet {
    /// Look up a command by name.
    pub fn find(&self, name: &str) -> Option<&Command> {
        self.commands.iter().find(|c| c.name == name)
    }

    /// The top-level help: usage, command list, common options.
    pub fn render_help(&self) -> String {
        let width = self.commands.iter().map(|c| c.name.len()).max().unwrap_or(0);
        let mut out = format!("{} — {}\n\n", self.program, self.about);
        out.push_str(&format!(
            "usage: {} <command> [--opt value]...\n       {} help <command>\n\ncommands:\n",
            self.program, self.program
        ));
        for c in self.commands {
            out.push_str(&format!("  {:width$}  {}\n", c.name, c.summary));
        }
        out.push('\n');
        out.push_str(self.common);
        out
    }

    /// Per-command help: usage line, summary, option text.
    pub fn render_command_help(&self, cmd: &Command) -> String {
        format!(
            "usage: {} {} [--opt value]...\n\n{}\n\n{}",
            self.program, cmd.name, cmd.summary, cmd.usage
        )
    }

    /// The unknown-command error, listing what exists.
    fn unknown(&self, name: &str) -> Error {
        let names: Vec<&str> = self.commands.iter().map(|c| c.name).collect();
        Error::Config(format!(
            "unknown subcommand {name:?} (expected one of: {}; see `{} help`)",
            names.join(", "),
            self.program
        ))
    }

    /// Dispatch parsed arguments: no command or `help` prints help,
    /// `<cmd> --help` prints that command's usage, anything else runs
    /// the matching handler.
    pub fn dispatch(&self, args: &Args) -> Result<()> {
        match args.command.as_deref() {
            None => {
                println!("{}", self.render_help());
                Ok(())
            }
            Some("help") => match args.positional.first() {
                None => {
                    println!("{}", self.render_help());
                    Ok(())
                }
                Some(name) => match self.find(name) {
                    Some(cmd) => {
                        println!("{}", self.render_command_help(cmd));
                        Ok(())
                    }
                    None => Err(self.unknown(name)),
                },
            },
            Some(name) => match self.find(name) {
                Some(cmd) => {
                    if args.flag("help") {
                        println!("{}", self.render_command_help(cmd));
                        return Ok(());
                    }
                    (cmd.run)(args)
                }
                None => Err(self.unknown(name)),
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["train", "--topics", "100", "--iters=50", "--verbose"]);
        assert_eq!(a.command.as_deref(), Some("train"));
        assert_eq!(a.get_as::<usize>("topics", 0).unwrap(), 100);
        assert_eq!(a.get_as::<usize>("iters", 0).unwrap(), 50);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["train"]);
        assert_eq!(a.get_as::<f64>("alpha", 0.1).unwrap(), 0.1);
        assert_eq!(a.str_or("corpus", "synthetic"), "synthetic");
    }

    #[test]
    fn invalid_value_errors() {
        let a = parse(&["train", "--topics", "banana"]);
        assert!(a.get_as::<usize>("topics", 0).is_err());
    }

    #[test]
    fn require_missing_errors() {
        let a = parse(&["train"]);
        assert!(a.require::<usize>("topics").is_err());
    }

    #[test]
    fn positional_after_command() {
        let a = parse(&["eval", "model.bin", "corpus.bin"]);
        assert_eq!(a.positional, vec!["model.bin", "corpus.bin"]);
    }

    #[test]
    fn boolean_with_explicit_value() {
        let a = parse(&["x", "--pipeline", "false", "--buffered", "true"]);
        assert!(!a.flag("pipeline"));
        assert!(a.flag("buffered"));
    }

    const DEMO: CommandSet = CommandSet {
        program: "demo",
        about: "a demo binary",
        common: "common options:\n  --log LEVEL\n",
        commands: &[
            Command {
                name: "ok",
                summary: "always succeeds",
                usage: "no options",
                run: |_| Ok(()),
            },
            Command {
                name: "fail",
                summary: "always fails",
                usage: "no options",
                run: |_| Err(Error::Config("handler ran".into())),
            },
        ],
    };

    #[test]
    fn dispatch_runs_the_matching_handler() {
        assert!(DEMO.dispatch(&parse(&["ok"])).is_ok());
        let err = DEMO.dispatch(&parse(&["fail"])).unwrap_err();
        assert!(err.to_string().contains("handler ran"));
    }

    #[test]
    fn unknown_command_lists_what_exists() {
        let err = DEMO.dispatch(&parse(&["frobnicate"])).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("frobnicate") && msg.contains("ok") && msg.contains("fail"));
    }

    #[test]
    fn no_command_and_help_are_ok() {
        assert!(DEMO.dispatch(&parse(&[])).is_ok());
        assert!(DEMO.dispatch(&parse(&["help"])).is_ok());
        assert!(DEMO.dispatch(&parse(&["help", "ok"])).is_ok());
        assert!(DEMO.dispatch(&parse(&["help", "nope"])).is_err());
    }

    #[test]
    fn help_flag_short_circuits_the_handler() {
        // `fail --help` must print usage instead of running the handler.
        assert!(DEMO.dispatch(&parse(&["fail", "--help"])).is_ok());
    }

    #[test]
    fn help_renders_every_command() {
        let help = DEMO.render_help();
        assert!(help.contains("ok") && help.contains("always succeeds"));
        assert!(help.contains("fail") && help.contains("always fails"));
        assert!(help.contains("common options"));
        let cmd_help = DEMO.render_command_help(DEMO.find("ok").unwrap());
        assert!(cmd_help.contains("demo ok") && cmd_help.contains("no options"));
    }
}
