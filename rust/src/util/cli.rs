//! Minimal command-line argument parser.
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and a
//! positional subcommand, which is all the launcher needs. No external
//! crates are available offline, so this replaces `clap`.

use std::collections::BTreeMap;

use crate::util::error::{Error, Result};

/// Parsed arguments: one optional subcommand plus `--key value` options.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// First non-flag argument, if any.
    pub command: Option<String>,
    /// Remaining positional arguments after the subcommand.
    pub positional: Vec<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args> {
        let mut args = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.opts.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    args.opts.insert(stripped.to_string(), v);
                } else {
                    args.flags.push(stripped.to_string());
                }
            } else if args.command.is_none() {
                args.command = Some(a);
            } else {
                args.positional.push(a);
            }
        }
        Ok(args)
    }

    /// Parse the process arguments.
    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    /// Raw string option.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    /// String option with default.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Boolean flag (present without value) or `--key true/false`.
    pub fn flag(&self, key: &str) -> bool {
        if self.flags.iter().any(|f| f == key) {
            return true;
        }
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Typed option with default; errors on unparsable values.
    pub fn get_as<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.get(key) {
            None => Ok(default),
            Some(raw) => raw.parse::<T>().map_err(|_| {
                Error::Config(format!("invalid value for --{key}: {raw:?}"))
            }),
        }
    }

    /// Typed required option.
    pub fn require<T: std::str::FromStr>(&self, key: &str) -> Result<T> {
        let raw = self
            .get(key)
            .ok_or_else(|| Error::Config(format!("missing required option --{key}")))?;
        raw.parse::<T>()
            .map_err(|_| Error::Config(format!("invalid value for --{key}: {raw:?}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["train", "--topics", "100", "--iters=50", "--verbose"]);
        assert_eq!(a.command.as_deref(), Some("train"));
        assert_eq!(a.get_as::<usize>("topics", 0).unwrap(), 100);
        assert_eq!(a.get_as::<usize>("iters", 0).unwrap(), 50);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["train"]);
        assert_eq!(a.get_as::<f64>("alpha", 0.1).unwrap(), 0.1);
        assert_eq!(a.str_or("corpus", "synthetic"), "synthetic");
    }

    #[test]
    fn invalid_value_errors() {
        let a = parse(&["train", "--topics", "banana"]);
        assert!(a.get_as::<usize>("topics", 0).is_err());
    }

    #[test]
    fn require_missing_errors() {
        let a = parse(&["train"]);
        assert!(a.require::<usize>("topics").is_err());
    }

    #[test]
    fn positional_after_command() {
        let a = parse(&["eval", "model.bin", "corpus.bin"]);
        assert_eq!(a.positional, vec!["model.bin", "corpus.bin"]);
    }

    #[test]
    fn boolean_with_explicit_value() {
        let a = parse(&["x", "--pipeline", "false", "--buffered", "true"]);
        assert!(!a.flag("pipeline"));
        assert!(a.flag("buffered"));
    }
}
