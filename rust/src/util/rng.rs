//! Pseudo-random number generation and distribution samplers.
//!
//! The crate cannot depend on external crates (offline build), so this
//! module provides a PCG64 (DXSM) generator plus the samplers the corpus
//! generator and the Gibbs samplers need: uniform ints/floats, normal
//! (Ziggurat-free Box–Muller), gamma (Marsaglia–Tsang), Dirichlet,
//! categorical, and shuffling.
//!
//! PCG64-DXSM is the same generator family NumPy uses by default; it is
//! fast (one 128-bit multiply per draw), has 2^128 period and passes
//! PractRand.

/// PCG64 DXSM generator.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_DEFAULT_MULTIPLIER: u128 = 0x2360ed051fc65da44385df649fccf645;
const PCG_DXSM_MULTIPLIER: u64 = 0xda942042e4dd58b5;

impl Pcg64 {
    /// Create a generator from a 64-bit seed. Two generators with
    /// different seeds produce independent-looking streams.
    pub fn new(seed: u64) -> Self {
        // SplitMix64 to expand the seed into 256 bits of state/stream.
        let mut sm = SplitMix64::new(seed);
        let state = ((sm.next() as u128) << 64) | sm.next() as u128;
        let stream = ((sm.next() as u128) << 64) | sm.next() as u128;
        let mut rng = Pcg64 { state: 0, inc: (stream << 1) | 1 };
        rng.state = rng.state.wrapping_add(state);
        rng.step();
        rng
    }

    /// Derive an independent child generator (for per-worker streams).
    pub fn fork(&mut self, salt: u64) -> Pcg64 {
        let s = self.next_u64() ^ salt.wrapping_mul(0x9e3779b97f4a7c15);
        Pcg64::new(s)
    }

    #[inline]
    fn step(&mut self) {
        self.state = self
            .state
            .wrapping_mul(PCG_DEFAULT_MULTIPLIER)
            .wrapping_add(self.inc);
    }

    /// Next raw 64-bit output (DXSM output function).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let state = self.state;
        self.step();
        let mut hi = (state >> 64) as u64;
        let lo = (state as u64) | 1;
        hi ^= hi >> 32;
        hi = hi.wrapping_mul(PCG_DXSM_MULTIPLIER);
        hi ^= hi >> 48;
        hi.wrapping_mul(lo)
    }

    /// Next u32.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` using Lemire's multiply-shift rejection.
    #[inline]
    pub fn below(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        let bound = bound as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (pair cached is omitted for
    /// simplicity; gamma sampling dominates our use).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.f64();
            return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        }
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang, with Johnk-style boost for
    /// shape < 1.
    pub fn gamma(&mut self, shape: f64) -> f64 {
        debug_assert!(shape > 0.0);
        if shape < 1.0 {
            // G(a) = G(a+1) * U^{1/a}
            let g = self.gamma(shape + 1.0);
            let u = loop {
                let u = self.f64();
                if u > 0.0 {
                    break u;
                }
            };
            return g * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v = v * v * v;
            let u = self.f64();
            if u < 1.0 - 0.0331 * x * x * x * x {
                return d * v;
            }
            if u > 0.0 && u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v;
            }
        }
    }

    /// Symmetric Dirichlet(alpha) sample of dimension `k`, written into
    /// `out` (overwritten, resized as needed).
    pub fn dirichlet_sym(&mut self, alpha: f64, k: usize, out: &mut Vec<f64>) {
        out.clear();
        out.reserve(k);
        let mut sum = 0.0;
        for _ in 0..k {
            let g = self.gamma(alpha);
            sum += g;
            out.push(g);
        }
        if sum <= 0.0 {
            // Degenerate draw (all gammas underflowed): fall back to uniform.
            let u = 1.0 / k as f64;
            for v in out.iter_mut() {
                *v = u;
            }
            return;
        }
        let inv = 1.0 / sum;
        for v in out.iter_mut() {
            *v *= inv;
        }
    }

    /// General Dirichlet with per-component concentrations.
    pub fn dirichlet(&mut self, alphas: &[f64], out: &mut Vec<f64>) {
        out.clear();
        out.reserve(alphas.len());
        let mut sum = 0.0;
        for &a in alphas {
            let g = self.gamma(a);
            sum += g;
            out.push(g);
        }
        let inv = if sum > 0.0 { 1.0 / sum } else { 0.0 };
        for v in out.iter_mut() {
            *v *= inv;
        }
    }

    /// Draw an index from an unnormalized weight vector in O(n).
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut u = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i + 1);
            items.swap(i, j);
        }
    }
}

/// SplitMix64 — used only for seed expansion.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create from a seed.
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    /// Next 64-bit output.
    #[inline]
    pub fn next(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e3779b97f4a7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        z ^ (z >> 31)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut rng = Pcg64::new(7);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[rng.below(10)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "count {c} out of range");
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Pcg64::new(3);
        for _ in 0..10_000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::new(11);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn gamma_moments() {
        let mut rng = Pcg64::new(13);
        for &shape in &[0.1, 0.5, 1.0, 2.5, 10.0] {
            let n = 100_000;
            let mut sum = 0.0;
            for _ in 0..n {
                sum += rng.gamma(shape);
            }
            let mean = sum / n as f64;
            assert!(
                (mean - shape).abs() < 0.05 * shape.max(1.0),
                "shape {shape} mean {mean}"
            );
        }
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut rng = Pcg64::new(17);
        let mut out = Vec::new();
        rng.dirichlet_sym(0.1, 50, &mut out);
        let s: f64 = out.iter().sum();
        assert!((s - 1.0).abs() < 1e-9);
        assert!(out.iter().all(|&p| p >= 0.0));
    }

    #[test]
    fn categorical_matches_weights() {
        let mut rng = Pcg64::new(19);
        let w = [1.0, 2.0, 7.0];
        let mut counts = [0usize; 3];
        for _ in 0..100_000 {
            counts[rng.categorical(&w)] += 1;
        }
        assert!((counts[2] as f64 / 100_000.0 - 0.7).abs() < 0.02);
        assert!((counts[1] as f64 / 100_000.0 - 0.2).abs() < 0.02);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(23);
        let mut v: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Pcg64::new(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
