//! Byte-level serialization used by the message layer and checkpoints.
//!
//! Little-endian fixed-width primitives plus LEB128 varints for lengths.
//! No external crates: this is the wire format for the simulated network
//! (so that message *sizes* are realistic — the paper reasons about ~2 MB
//! push messages) and the on-disk checkpoint format.

use crate::util::error::{Error, Result};

/// Append-only byte writer.
#[derive(Default, Debug)]
pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    /// New empty writer.
    pub fn new() -> Self {
        Writer { buf: Vec::new() }
    }

    /// New writer with a capacity hint.
    pub fn with_capacity(cap: usize) -> Self {
        Writer { buf: Vec::with_capacity(cap) }
    }

    /// Finish and take the bytes.
    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    /// Current encoded size in bytes.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// True if nothing has been written.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Write a single byte.
    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    /// Write fixed-width little-endian u32.
    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Write fixed-width little-endian u64.
    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Write fixed-width little-endian i64.
    pub fn i64(&mut self, v: i64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Write f32 bits.
    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Write f64 bits.
    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// LEB128 unsigned varint.
    pub fn varint(&mut self, mut v: u64) {
        loop {
            let byte = (v & 0x7f) as u8;
            v >>= 7;
            if v == 0 {
                self.buf.push(byte);
                return;
            }
            self.buf.push(byte | 0x80);
        }
    }

    /// usize as varint.
    pub fn usize(&mut self, v: usize) {
        self.varint(v as u64);
    }

    /// Zigzag-mapped LEB128 varint for signed values: small magnitudes
    /// (positive *or* negative) encode in one byte. The sparse data
    /// plane ships count deltas and count values, which are almost
    /// always tiny — fixed 8-byte i64s would waste ~7 bytes per value.
    pub fn zigzag(&mut self, v: i64) {
        self.varint(((v << 1) ^ (v >> 63)) as u64);
    }

    /// Length-prefixed UTF-8 string.
    pub fn str(&mut self, s: &str) {
        self.usize(s.len());
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// Length-prefixed raw bytes.
    pub fn bytes(&mut self, b: &[u8]) {
        self.usize(b.len());
        self.buf.extend_from_slice(b);
    }

    /// Length-prefixed slice of u32 (bulk, little-endian).
    pub fn slice_u32(&mut self, v: &[u32]) {
        self.usize(v.len());
        for &x in v {
            self.u32(x);
        }
    }

    /// Length-prefixed slice of u64 varints (good for row indices).
    pub fn slice_varint(&mut self, v: &[u64]) {
        self.usize(v.len());
        for &x in v {
            self.varint(x);
        }
    }

    /// Length-prefixed slice of u32 varints (good for column ids and
    /// per-row pair counts, which are bounded by K and thus usually fit
    /// in one byte).
    pub fn slice_varint_u32(&mut self, v: &[u32]) {
        self.usize(v.len());
        for &x in v {
            self.varint(x as u64);
        }
    }

    /// Length-prefixed slice of zigzag varints (sparse count values).
    pub fn slice_zigzag(&mut self, v: &[i64]) {
        self.usize(v.len());
        for &x in v {
            self.zigzag(x);
        }
    }

    /// Length-prefixed slice of i64 (bulk).
    ///
    /// On little-endian targets this is a single memcpy — the pull path
    /// moves tens of MB of count rows per iteration, so the per-element
    /// loop was a measured hot-spot (see EXPERIMENTS.md §Perf).
    pub fn slice_i64(&mut self, v: &[i64]) {
        self.usize(v.len());
        #[cfg(target_endian = "little")]
        {
            // SAFETY: `v` is a live `&[i64]`, so `v.as_ptr()` is valid
            // for reads of `v.len() * 8` bytes for the borrow's lifetime
            // (the byte view ends at `extend_from_slice` below, inside
            // it). i64 has no padding and every bit pattern is a valid
            // u8, so reinterpreting as bytes is defined; `*const u8` has
            // alignment 1, which any pointer satisfies. On LE the in-
            // memory byte order is exactly the wire order.
            let bytes = unsafe {
                std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 8)
            };
            self.buf.extend_from_slice(bytes);
        }
        #[cfg(not(target_endian = "little"))]
        for &x in v {
            self.i64(x);
        }
    }

    /// Length-prefixed slice of f32 (bulk memcpy on little-endian).
    pub fn slice_f32(&mut self, v: &[f32]) {
        self.usize(v.len());
        #[cfg(target_endian = "little")]
        {
            // SAFETY: same argument as `slice_i64` above with a 4-byte
            // element: `v.as_ptr()` is valid for `v.len() * 4` bytes of
            // reads while borrowed, f32 has no padding, and a `*const u8`
            // view imposes no alignment requirement.
            let bytes = unsafe {
                std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4)
            };
            self.buf.extend_from_slice(bytes);
        }
        #[cfg(not(target_endian = "little"))]
        for &x in v {
            self.f32(x);
        }
    }
}

/// Cursor-based reader over an encoded buffer.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    /// Wrap a byte slice.
    pub fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    /// Bytes remaining.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// True when fully consumed.
    pub fn is_done(&self) -> bool {
        self.remaining() == 0
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            return Err(Error::Decode(format!(
                "unexpected end of buffer: need {n}, have {}",
                self.remaining()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Read one byte.
    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    /// Read little-endian u32.
    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    /// Read little-endian u64.
    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Read little-endian i64.
    pub fn i64(&mut self) -> Result<i64> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Read f32.
    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    /// Read f64.
    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Read LEB128 varint.
    pub fn varint(&mut self) -> Result<u64> {
        let mut v = 0u64;
        let mut shift = 0u32;
        loop {
            let byte = self.u8()?;
            if shift >= 64 {
                return Err(Error::Decode("varint overflow".into()));
            }
            v |= ((byte & 0x7f) as u64) << shift;
            if byte & 0x80 == 0 {
                return Ok(v);
            }
            shift += 7;
        }
    }

    /// usize from varint.
    pub fn usize(&mut self) -> Result<usize> {
        Ok(self.varint()? as usize)
    }

    /// Zigzag-mapped varint back to i64.
    pub fn zigzag(&mut self) -> Result<i64> {
        let z = self.varint()?;
        Ok(((z >> 1) as i64) ^ -((z & 1) as i64))
    }

    /// Length-prefixed string.
    pub fn str(&mut self) -> Result<String> {
        let n = self.usize()?;
        let b = self.take(n)?;
        String::from_utf8(b.to_vec()).map_err(|e| Error::Decode(format!("bad utf8: {e}")))
    }

    /// Length-prefixed raw bytes.
    pub fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.usize()?;
        Ok(self.take(n)?.to_vec())
    }

    /// Length-prefixed u32 slice.
    pub fn slice_u32(&mut self) -> Result<Vec<u32>> {
        let n = self.usize()?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.u32()?);
        }
        Ok(out)
    }

    /// Length-prefixed varint slice.
    pub fn slice_varint(&mut self) -> Result<Vec<u64>> {
        let n = self.usize()?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.varint()?);
        }
        Ok(out)
    }

    /// Length-prefixed u32 varint slice.
    pub fn slice_varint_u32(&mut self) -> Result<Vec<u32>> {
        let n = self.usize()?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let v = self.varint()?;
            if v > u32::MAX as u64 {
                return Err(Error::Decode(format!("u32 varint out of range: {v}")));
            }
            out.push(v as u32);
        }
        Ok(out)
    }

    /// Length-prefixed zigzag varint slice.
    pub fn slice_zigzag(&mut self) -> Result<Vec<i64>> {
        let n = self.usize()?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.zigzag()?);
        }
        Ok(out)
    }

    /// Length-prefixed i64 slice (bulk memcpy on little-endian).
    pub fn slice_i64(&mut self) -> Result<Vec<i64>> {
        let n = self.usize()?;
        #[cfg(target_endian = "little")]
        {
            let raw = self.take(n * 8)?;
            let mut out: Vec<i64> = Vec::with_capacity(n);
            // SAFETY: `take` bounds-checked the read, so `raw` is exactly
            // `n * 8` readable bytes; `with_capacity(n)` makes
            // `out.as_mut_ptr()` valid for `n * 8` bytes of writes, and
            // the two allocations are distinct so the copy cannot
            // overlap. Writing through `*mut u8` needs no alignment, and
            // any byte pattern is a valid i64 (no padding, no invalid
            // values). `set_len(n)` runs only after all `n` elements are
            // fully initialized by the copy, within the reserved
            // capacity.
            unsafe {
                std::ptr::copy_nonoverlapping(
                    raw.as_ptr(),
                    out.as_mut_ptr() as *mut u8,
                    n * 8,
                );
                out.set_len(n);
            }
            Ok(out)
        }
        #[cfg(not(target_endian = "little"))]
        {
            let mut out = Vec::with_capacity(n);
            for _ in 0..n {
                out.push(self.i64()?);
            }
            Ok(out)
        }
    }

    /// Length-prefixed f32 slice (bulk memcpy on little-endian).
    pub fn slice_f32(&mut self) -> Result<Vec<f32>> {
        let n = self.usize()?;
        #[cfg(target_endian = "little")]
        {
            let raw = self.take(n * 4)?;
            let mut out: Vec<f32> = Vec::with_capacity(n);
            // SAFETY: same argument as `slice_i64` above with a 4-byte
            // element: `raw` is a bounds-checked `n * 4`-byte source, the
            // freshly reserved Vec is a disjoint `n * 4`-byte
            // destination, every bit pattern is a valid f32, and
            // `set_len(n)` follows full initialization.
            unsafe {
                std::ptr::copy_nonoverlapping(
                    raw.as_ptr(),
                    out.as_mut_ptr() as *mut u8,
                    n * 4,
                );
                out.set_len(n);
            }
            Ok(out)
        }
        #[cfg(not(target_endian = "little"))]
        {
            let mut out = Vec::with_capacity(n);
            for _ in 0..n {
                out.push(self.f32()?);
            }
            Ok(out)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn roundtrip_primitives() {
        let mut w = Writer::new();
        w.u8(7);
        w.u32(0xdeadbeef);
        w.u64(u64::MAX);
        w.i64(-42);
        w.f32(3.25);
        w.f64(-0.125);
        w.str("héllo");
        w.bytes(&[1, 2, 3]);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 0xdeadbeef);
        assert_eq!(r.u64().unwrap(), u64::MAX);
        assert_eq!(r.i64().unwrap(), -42);
        assert_eq!(r.f32().unwrap(), 3.25);
        assert_eq!(r.f64().unwrap(), -0.125);
        assert_eq!(r.str().unwrap(), "héllo");
        assert_eq!(r.bytes().unwrap(), vec![1, 2, 3]);
        assert!(r.is_done());
    }

    #[test]
    fn varint_boundaries() {
        for v in [0u64, 1, 127, 128, 16_383, 16_384, u32::MAX as u64, u64::MAX] {
            let mut w = Writer::new();
            w.varint(v);
            let bytes = w.into_bytes();
            let mut r = Reader::new(&bytes);
            assert_eq!(r.varint().unwrap(), v);
        }
    }

    #[test]
    fn roundtrip_slices_random() {
        let mut rng = Pcg64::new(1);
        for _ in 0..50 {
            let n = rng.below(200);
            let i64s: Vec<i64> = (0..n).map(|_| rng.next_u64() as i64).collect();
            let f32s: Vec<f32> = (0..n).map(|_| rng.f32() * 100.0 - 50.0).collect();
            let idx: Vec<u64> = (0..n).map(|_| rng.next_u64() >> rng.below(64) as u32).collect();
            let mut w = Writer::new();
            w.slice_i64(&i64s);
            w.slice_f32(&f32s);
            w.slice_varint(&idx);
            let bytes = w.into_bytes();
            let mut r = Reader::new(&bytes);
            assert_eq!(r.slice_i64().unwrap(), i64s);
            assert_eq!(r.slice_f32().unwrap(), f32s);
            assert_eq!(r.slice_varint().unwrap(), idx);
        }
    }

    #[test]
    fn zigzag_boundaries() {
        for v in [0i64, 1, -1, 63, -64, 64, -65, i32::MAX as i64, i64::MIN, i64::MAX] {
            let mut w = Writer::new();
            w.zigzag(v);
            let bytes = w.into_bytes();
            let mut r = Reader::new(&bytes);
            assert_eq!(r.zigzag().unwrap(), v, "value {v}");
        }
        // Small magnitudes must be single-byte regardless of sign.
        for v in [0i64, 1, -1, 63, -64] {
            let mut w = Writer::new();
            w.zigzag(v);
            assert_eq!(w.len(), 1, "zigzag({v}) should be 1 byte");
        }
    }

    #[test]
    fn roundtrip_sparse_slices_random() {
        let mut rng = Pcg64::new(7);
        for _ in 0..50 {
            let n = rng.below(300);
            let cols: Vec<u32> = (0..n).map(|_| rng.next_u32() >> rng.below(32) as u32).collect();
            let vals: Vec<i64> =
                (0..n).map(|_| rng.below(9) as i64 - 4).collect();
            let mut w = Writer::new();
            w.slice_varint_u32(&cols);
            w.slice_zigzag(&vals);
            let bytes = w.into_bytes();
            let mut r = Reader::new(&bytes);
            assert_eq!(r.slice_varint_u32().unwrap(), cols);
            assert_eq!(r.slice_zigzag().unwrap(), vals);
            assert!(r.is_done());
        }
    }

    #[test]
    fn oversized_u32_varint_rejected() {
        let mut w = Writer::new();
        w.usize(1);
        w.varint(u32::MAX as u64 + 1);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        assert!(r.slice_varint_u32().is_err());
    }

    #[test]
    fn truncated_buffer_errors() {
        let mut w = Writer::new();
        w.u64(123);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes[..4]);
        assert!(r.u64().is_err());
    }

    #[test]
    fn bad_utf8_errors() {
        let mut w = Writer::new();
        w.usize(2);
        w.u8(0xff);
        w.u8(0xfe);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        assert!(r.str().is_err());
    }
}
