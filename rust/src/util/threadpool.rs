//! A small work-stealing-free thread pool plus scoped parallel helpers.
//!
//! The baselines ("Spark executors") and the benchmark harnesses need
//! data-parallel loops; external crates are unavailable, so we provide:
//!
//! - [`ThreadPool`] — fixed pool with a shared injector queue, for
//!   long-lived background work. (The LDA trainer's pipelined pulls and
//!   asynchronous push flushes now ride the parameter-server client's
//!   own per-shard dispatch windows — see `ps/client.rs`.)
//! - [`parallel_chunks`] — scoped fork-join over chunks of a slice, built
//!   on `std::thread::scope`, used for the per-partition sampling loops.

use std::collections::VecDeque;
use std::sync::Arc;

use crate::util::sync_shim::{thread, Condvar, Mutex};

type Job = Box<dyn FnOnce() + Send + 'static>;

/// All mutable pool state lives behind one mutex, and both condvars
/// signal only while the predicate they guard was just changed under that
/// mutex. The previous design kept `shutdown`/`in_flight` as atomics
/// beside the queue lock; the model checker (`tests/model.rs`,
/// `threadpool-*` models) showed the shutdown flag being set between a
/// worker's check and its park — a lost wakeup that left `Drop` joining a
/// parked worker forever. Folding the flags under the lock closes every
/// such window by construction, and leaves no atomics (hence no ordering
/// choices) in the pool at all.
struct PoolState {
    queue: VecDeque<Job>,
    /// Jobs submitted and not yet finished (queued + running).
    in_flight: usize,
    shutdown: bool,
}

struct Shared {
    state: Mutex<PoolState>,
    /// Signaled when a job is queued or shutdown begins.
    available: Condvar,
    /// Signaled when `in_flight` drops to zero.
    done: Condvar,
}

/// Fixed-size thread pool with FIFO job dispatch.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn a pool with `n` worker threads (`n >= 1`).
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(PoolState {
                queue: VecDeque::new(),
                in_flight: 0,
                shutdown: false,
            }),
            available: Condvar::new(),
            done: Condvar::new(),
        });
        let workers = (0..n)
            .map(|i| {
                let shared = Arc::clone(&shared);
                thread::Builder::new()
                    .name(format!("glint-pool-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn pool worker")
            })
            .collect();
        ThreadPool { shared, workers }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Submit a job for asynchronous execution.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, job: F) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.in_flight += 1;
            st.queue.push_back(Box::new(job));
        }
        self.shared.available.notify_one();
    }

    /// Block until every submitted job has finished.
    pub fn wait_idle(&self) {
        let mut st = self.shared.state.lock().unwrap();
        while st.in_flight > 0 {
            st = self.shared.done.wait(st).unwrap();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        // `shutdown` was set under the lock, so a worker that read it as
        // false is either running a job or already parked on `available`
        // — this notify reaches it either way.
        self.shared.available.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(shared: &Shared) {
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if let Some(job) = st.queue.pop_front() {
                    break job;
                }
                if st.shutdown {
                    return;
                }
                st = shared.available.wait(st).unwrap();
            }
        };
        job();
        let mut st = shared.state.lock().unwrap();
        st.in_flight -= 1;
        if st.in_flight == 0 {
            // Signaled under the lock that guards the predicate, so a
            // `wait_idle` caller cannot recheck-and-park in between.
            shared.done.notify_all();
        }
    }
}

/// Run `f(chunk_index, chunk)` over `items` split into `num_chunks`
/// roughly equal contiguous chunks, one scoped thread per chunk.
///
/// Results are returned in chunk order. Panics in workers propagate.
pub fn parallel_chunks<T: Sync, R: Send>(
    items: &[T],
    num_chunks: usize,
    f: impl Fn(usize, &[T]) -> R + Sync,
) -> Vec<R> {
    let num_chunks = num_chunks.max(1).min(items.len().max(1));
    let chunk_size = items.len().div_ceil(num_chunks);
    let f = &f;
    std::thread::scope(|scope| {
        let handles: Vec<_> = items
            .chunks(chunk_size.max(1))
            .enumerate()
            .map(|(i, chunk)| scope.spawn(move || f(i, chunk)))
            .collect();
        handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
    })
}

/// Run `f(worker_index)` on `n` scoped threads and collect results.
pub fn parallel_workers<R: Send>(n: usize, f: impl Fn(usize) -> R + Sync) -> Vec<R> {
    let f = &f;
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..n).map(|i| scope.spawn(move || f(i))).collect();
        handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..1000 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 1000);
    }

    #[test]
    fn wait_idle_on_empty_pool_returns() {
        let pool = ThreadPool::new(2);
        pool.wait_idle();
    }

    #[test]
    fn pool_drop_joins_workers() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        drop(pool);
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn parallel_chunks_covers_everything() {
        let items: Vec<u64> = (0..10_000).collect();
        let sums = parallel_chunks(&items, 7, |_, chunk| chunk.iter().sum::<u64>());
        let total: u64 = sums.iter().sum();
        assert_eq!(total, items.iter().sum::<u64>());
    }

    #[test]
    fn parallel_chunks_single_item() {
        let items = [5u32];
        let r = parallel_chunks(&items, 16, |_, c| c.len());
        assert_eq!(r.iter().sum::<usize>(), 1);
    }

    #[test]
    fn parallel_chunks_empty() {
        let items: [u32; 0] = [];
        let r = parallel_chunks(&items, 4, |_, c| c.len());
        assert_eq!(r.iter().sum::<usize>(), 0);
    }

    #[test]
    fn parallel_workers_indexes() {
        let mut idx = parallel_workers(8, |i| i);
        idx.sort_unstable();
        assert_eq!(idx, (0..8).collect::<Vec<_>>());
    }
}
