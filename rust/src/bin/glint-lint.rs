//! In-tree concurrency/safety lint, run as a CI gate (`cargo run
//! --release --bin glint-lint`).
//!
//! Four rules, all plain-text scans over `src/` (no syntax trees — the
//! point is a zero-dependency gate that fails loudly, not a compiler):
//!
//! - **R1 `unsafe` needs `// SAFETY:`** — every `unsafe` block or fn in
//!   the crate must have a `// SAFETY:` comment within the five lines
//!   above it, stating the invariant that makes it sound.
//! - **R2 `Ordering::Relaxed` allowlist** — `Relaxed` atomics are only
//!   permitted in files audited for it (statistics counters and flags
//!   whose readers tolerate staleness). Everything else must use an
//!   ordering that says what it synchronizes, or take a lock.
//! - **R3 no stray panics in `ps/`, `net/`, `wal/`** — the server tiers
//!   must not `.unwrap()`/`.expect(` outside test code, except the
//!   poison-propagation forms (`.lock()`/`.read()`/`.write()`/`.wait*`
//!   — a poisoned lock means a sibling already panicked), infallible
//!   `try_into()` slice conversions, and sites annotated with a
//!   `// PANIC-OK:` comment explaining why panicking is correct.
//! - **R4 single-writer markers** — `ps/server.rs` and `wal/mod.rs`
//!   encode invariants that hold only on the shard's one writer thread;
//!   each must carry at least one `// SINGLE-WRITER:` comment so the
//!   invariant stays documented next to the code that relies on it.
//!
//! Exit status 0 when clean; 1 with one `file:line: rule: message` per
//! violation otherwise.

use std::path::{Path, PathBuf};

/// Files allowed to use `Ordering::Relaxed` (R2). Each is a statistics
/// counter or a flag whose readers tolerate arbitrary staleness.
const RELAXED_ALLOWLIST: &[&str] = &[
    "metrics/mod.rs",
    "net/mod.rs",
    "net/stats.rs",
    "net/tcp.rs",
    "ps/client.rs",
    "ps/server.rs",
    "util/logger.rs",
    "wal/mod.rs",
];

/// Directories whose non-test code must not panic (R3).
const NO_PANIC_DIRS: &[&str] = &["ps/", "net/", "wal/"];

/// Files that must carry at least one `// SINGLE-WRITER:` marker (R4).
const SINGLE_WRITER_FILES: &[&str] = &["ps/server.rs", "wal/mod.rs"];

/// How many lines above an `unsafe` site a `// SAFETY:` comment may
/// start (the comment block may be long; the marker is its first line).
const SAFETY_WINDOW: usize = 10;

/// How many lines above a panic site a `// PANIC-OK:` marker may sit.
const PANIC_OK_WINDOW: usize = 3;

fn main() {
    let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let mut files = Vec::new();
    collect_rs_files(&src, &mut files);
    files.sort();

    let mut violations = Vec::new();
    for path in &files {
        let rel = path
            .strip_prefix(&src)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        if rel.starts_with("bin/") {
            continue; // binaries (this linter included) are entry-point glue
        }
        let Ok(text) = std::fs::read_to_string(path) else {
            violations.push(format!("{rel}:0: io: cannot read file"));
            continue;
        };
        lint_file(&rel, &text, &mut violations);
    }

    if violations.is_empty() {
        println!("glint-lint: {} files clean", files.len());
        return;
    }
    for v in &violations {
        eprintln!("{v}");
    }
    eprintln!("glint-lint: {} violation(s)", violations.len());
    std::process::exit(1);
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = std::fs::read_dir(dir) else {
        return;
    };
    for entry in entries.flatten() {
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}

fn lint_file(rel: &str, text: &str, violations: &mut Vec<String>) {
    let lines: Vec<&str> = text.lines().collect();
    let no_panic = NO_PANIC_DIRS.iter().any(|d| rel.starts_with(d));
    let relaxed_ok = RELAXED_ALLOWLIST.contains(&rel);
    let mut in_tests = false;
    let mut single_writer_seen = false;

    for (i, &line) in lines.iter().enumerate() {
        let lineno = i + 1;
        if line.contains("#[cfg(test)]") {
            // Repo convention: the test module is the last item of a
            // file, so everything below is test code.
            in_tests = true;
        }
        if line.contains("// SINGLE-WRITER:") || line.contains("/// SINGLE-WRITER:") {
            single_writer_seen = true;
        }
        let code = strip_comment(line);

        // R1: unsafe needs a SAFETY comment just above it.
        if mentions_unsafe(code) && !has_marker_above(&lines, i, SAFETY_WINDOW, "SAFETY:") {
            violations.push(format!(
                "{rel}:{lineno}: unsafe-needs-safety: `unsafe` without a \
                 `// SAFETY:` comment starting within {SAFETY_WINDOW} lines above"
            ));
        }

        // R2: Relaxed ordering only in allowlisted files.
        if !relaxed_ok && code.contains("Ordering::Relaxed") {
            violations.push(format!(
                "{rel}:{lineno}: relaxed-ordering: `Ordering::Relaxed` outside the \
                 audited allowlist (use a stronger ordering, or audit and allowlist \
                 the file in glint-lint)"
            ));
        }

        // R3: no stray panics in the server tiers.
        if no_panic && !in_tests && has_panic_call(code) {
            let joined = if i > 0 {
                format!("{}{}", strip_comment(lines[i - 1]), code)
            } else {
                code.to_string()
            };
            let poison = ["lock()", ".read()", ".write()", ".wait(", "wait_timeout("]
                .iter()
                .any(|p| joined.contains(p));
            let infallible = joined.contains("try_into()");
            let annotated = has_marker_above(&lines, i, PANIC_OK_WINDOW, "PANIC-OK");
            if !poison && !infallible && !annotated {
                violations.push(format!(
                    "{rel}:{lineno}: no-stray-panic: `.unwrap()`/`.expect(` in server-tier \
                     code (propagate the error, or annotate with `// PANIC-OK: <why>`)"
                ));
            }
        }
    }

    // R4: single-writer invariants must stay documented.
    if SINGLE_WRITER_FILES.contains(&rel) && !single_writer_seen {
        violations.push(format!(
            "{rel}:0: single-writer-marker: file encodes single-writer invariants but \
             has no `// SINGLE-WRITER:` comment documenting them"
        ));
    }
}

/// The code part of a line (everything before a `//` comment). Not
/// string-literal aware; good enough for the patterns this lint greps.
fn strip_comment(line: &str) -> &str {
    match line.find("//") {
        Some(pos) => &line[..pos],
        None => line,
    }
}

/// True when `code` uses the `unsafe` keyword (block or fn), matched as
/// a whole word so identifiers like `unsafe_len` don't trip it.
fn mentions_unsafe(code: &str) -> bool {
    let mut rest = code;
    while let Some(pos) = rest.find("unsafe") {
        let before_ok =
            pos == 0 || !rest[..pos].ends_with(|c: char| c.is_alphanumeric() || c == '_');
        let tail = &rest[pos + "unsafe".len()..];
        let after_ok = !tail.starts_with(|c: char| c.is_alphanumeric() || c == '_');
        if before_ok && after_ok {
            return true;
        }
        rest = tail;
    }
    false
}

/// True when `code` calls `.unwrap()` or `.expect(`.
fn has_panic_call(code: &str) -> bool {
    code.contains(".unwrap()") || code.contains(".expect(")
}

/// True when any of the `window` lines above `i` (or line `i` itself)
/// carries `marker` inside a comment.
fn has_marker_above(lines: &[&str], i: usize, window: usize, marker: &str) -> bool {
    let start = i.saturating_sub(window);
    lines[start..=i].iter().any(|l| l.contains(marker))
}
