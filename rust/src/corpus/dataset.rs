//! Bag-of-words corpus representation, partitioning, and binary I/O.
//!
//! Documents store token word-ids (with repetition, in occurrence order),
//! mirroring how the Spark implementation carries RDD partitions of
//! sampled documents. The corpus can be split into worker partitions
//! (the RDD analogue) and serialized for checkpointing (§3.5).

use std::path::Path;

use crate::util::codec::{Reader, Writer};
use crate::util::error::{Error, Result};
use crate::util::rng::Pcg64;

/// One document: a sequence of word ids.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Document {
    /// Word ids in occurrence order (ids are frequency ranks: 0 = most
    /// common word in the corpus).
    pub tokens: Vec<u32>,
}

impl Document {
    /// Number of tokens.
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    /// True if no tokens.
    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }
}

/// A bag-of-words corpus with a frequency-ordered vocabulary.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Corpus {
    /// Documents.
    pub docs: Vec<Document>,
    /// Vocabulary size (word ids are `0..vocab_size`).
    pub vocab_size: u32,
    /// Optional vocabulary strings, index = word id. Empty for synthetic
    /// corpora (ids only).
    pub vocab: Vec<String>,
}

impl Corpus {
    /// Total token count.
    pub fn num_tokens(&self) -> u64 {
        self.docs.iter().map(|d| d.len() as u64).sum()
    }

    /// Number of documents.
    pub fn num_docs(&self) -> usize {
        self.docs.len()
    }

    /// Per-word-id occurrence counts (length `vocab_size`).
    pub fn word_counts(&self) -> Vec<u64> {
        let mut counts = vec![0u64; self.vocab_size as usize];
        for d in &self.docs {
            for &w in &d.tokens {
                counts[w as usize] += 1;
            }
        }
        counts
    }

    /// Check the frequency-ordering invariant: word id 0 is the most
    /// frequent, ids ascend with decreasing frequency (ties allowed).
    pub fn is_frequency_ordered(&self) -> bool {
        let counts = self.word_counts();
        counts.windows(2).all(|w| w[0] >= w[1])
    }

    /// Split into `n` contiguous partitions of roughly equal *token*
    /// counts (the Spark RDD analogue; balancing tokens rather than doc
    /// counts keeps worker sampling time even). Returns index ranges.
    pub fn partitions(&self, n: usize) -> Vec<std::ops::Range<usize>> {
        let n = n.max(1);
        let total = self.num_tokens();
        let target = total / n as u64;
        let mut ranges = Vec::with_capacity(n);
        let mut start = 0usize;
        let mut acc = 0u64;
        let mut produced = 0usize;
        for (i, d) in self.docs.iter().enumerate() {
            acc += d.len() as u64;
            // Leave enough docs for remaining partitions.
            if acc >= target && produced + 1 < n && self.docs.len() - (i + 1) >= n - produced - 1 {
                ranges.push(start..i + 1);
                start = i + 1;
                acc = 0;
                produced += 1;
            }
        }
        ranges.push(start..self.docs.len());
        while ranges.len() < n {
            ranges.push(self.docs.len()..self.docs.len());
        }
        ranges
    }

    /// Deterministic train/test split: every `holdout_every`-th document
    /// goes to the test set.
    pub fn split_holdout(&self, holdout_every: usize) -> (Corpus, Corpus) {
        let mut train = Corpus { vocab_size: self.vocab_size, vocab: self.vocab.clone(), ..Default::default() };
        let mut test = Corpus { vocab_size: self.vocab_size, vocab: self.vocab.clone(), ..Default::default() };
        for (i, d) in self.docs.iter().enumerate() {
            if holdout_every > 0 && (i + 1) % holdout_every == 0 {
                test.docs.push(d.clone());
            } else {
                train.docs.push(d.clone());
            }
        }
        (train, test)
    }

    /// Take a prefix subset containing roughly `fraction` of documents
    /// (used for the paper's 2.5%–10% scaling experiments). Documents are
    /// shuffled with `seed` first so the subset is representative.
    pub fn subset(&self, fraction: f64, seed: u64) -> Corpus {
        let mut order: Vec<usize> = (0..self.docs.len()).collect();
        let mut rng = Pcg64::new(seed);
        rng.shuffle(&mut order);
        let keep = ((self.docs.len() as f64 * fraction).round() as usize).max(1);
        let docs = order[..keep.min(order.len())]
            .iter()
            .map(|&i| self.docs[i].clone())
            .collect();
        Corpus { docs, vocab_size: self.vocab_size, vocab: self.vocab.clone() }
    }

    // --- binary I/O (checkpoints, corpus caching) -----------------------

    const MAGIC: u32 = 0x474c_4331; // "GLC1"

    /// Serialize to bytes.
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::with_capacity(16 + self.num_tokens() as usize * 2);
        w.u32(Self::MAGIC);
        w.u32(self.vocab_size);
        w.usize(self.vocab.len());
        for s in &self.vocab {
            w.str(s);
        }
        w.usize(self.docs.len());
        for d in &self.docs {
            w.usize(d.tokens.len());
            for &t in &d.tokens {
                w.varint(t as u64);
            }
        }
        w.into_bytes()
    }

    /// Deserialize from bytes.
    pub fn decode(bytes: &[u8]) -> Result<Corpus> {
        let mut r = Reader::new(bytes);
        if r.u32()? != Self::MAGIC {
            return Err(Error::Decode("not a corpus file (bad magic)".into()));
        }
        let vocab_size = r.u32()?;
        let nv = r.usize()?;
        let mut vocab = Vec::with_capacity(nv);
        for _ in 0..nv {
            vocab.push(r.str()?);
        }
        let nd = r.usize()?;
        let mut docs = Vec::with_capacity(nd);
        for _ in 0..nd {
            let nt = r.usize()?;
            let mut tokens = Vec::with_capacity(nt);
            for _ in 0..nt {
                let t = r.varint()? as u32;
                if t >= vocab_size {
                    return Err(Error::Decode(format!(
                        "token id {t} >= vocab size {vocab_size}"
                    )));
                }
                tokens.push(t);
            }
            docs.push(Document { tokens });
        }
        Ok(Corpus { docs, vocab_size, vocab })
    }

    /// Write to a file.
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.encode())?;
        Ok(())
    }

    /// Read from a file.
    pub fn load(path: &Path) -> Result<Corpus> {
        let bytes = std::fs::read(path)?;
        Corpus::decode(&bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_corpus() -> Corpus {
        Corpus {
            docs: vec![
                Document { tokens: vec![0, 1, 0, 2] },
                Document { tokens: vec![1, 0] },
                Document { tokens: vec![3, 0, 1] },
                Document { tokens: vec![0] },
            ],
            vocab_size: 4,
            vocab: vec!["the".into(), "cat".into(), "sat".into(), "mat".into()],
        }
    }

    #[test]
    fn counts_and_ordering() {
        let c = sample_corpus();
        assert_eq!(c.num_tokens(), 10);
        assert_eq!(c.word_counts(), vec![5, 3, 1, 1]);
        assert!(c.is_frequency_ordered());
    }

    #[test]
    fn unordered_detected() {
        let mut c = sample_corpus();
        c.docs.push(Document { tokens: vec![3, 3, 3, 3, 3] });
        assert!(!c.is_frequency_ordered());
    }

    #[test]
    fn roundtrip_binary() {
        let c = sample_corpus();
        let decoded = Corpus::decode(&c.encode()).unwrap();
        assert_eq!(c, decoded);
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(Corpus::decode(&[1, 2, 3]).is_err());
        // Token id out of range.
        let mut w = Writer::new();
        w.u32(0x474c_4331);
        w.u32(2); // vocab_size = 2
        w.usize(0);
        w.usize(1);
        w.usize(1);
        w.varint(5); // token 5 >= 2
        assert!(Corpus::decode(&w.into_bytes()).is_err());
    }

    #[test]
    fn partitions_cover_disjointly() {
        let c = sample_corpus();
        for n in 1..=6 {
            let parts = c.partitions(n);
            assert_eq!(parts.len(), n);
            let covered: usize = parts.iter().map(|r| r.len()).sum();
            assert_eq!(covered, c.num_docs());
            for w in parts.windows(2) {
                assert_eq!(w[0].end, w[1].start);
            }
        }
    }

    #[test]
    fn partitions_balance_tokens() {
        let docs: Vec<Document> =
            (0..100).map(|i| Document { tokens: vec![0; 1 + i % 7] }).collect();
        let c = Corpus { docs, vocab_size: 1, vocab: vec![] };
        let parts = c.partitions(4);
        let tokens: Vec<u64> = parts
            .iter()
            .map(|r| c.docs[r.clone()].iter().map(|d| d.len() as u64).sum())
            .collect();
        let max = *tokens.iter().max().unwrap() as f64;
        let min = *tokens.iter().min().unwrap() as f64;
        assert!(max / min < 1.5, "token imbalance: {tokens:?}");
    }

    #[test]
    fn holdout_split() {
        let c = sample_corpus();
        let (train, test) = c.split_holdout(2);
        assert_eq!(train.num_docs(), 2);
        assert_eq!(test.num_docs(), 2);
        assert_eq!(train.vocab_size, 4);
    }

    #[test]
    fn subset_size() {
        let docs: Vec<Document> = (0..1000).map(|_| Document { tokens: vec![0] }).collect();
        let c = Corpus { docs, vocab_size: 1, vocab: vec![] };
        let s = c.subset(0.1, 1);
        assert_eq!(s.num_docs(), 100);
        let s2 = c.subset(0.1, 1);
        assert_eq!(s, s2, "subset is deterministic for a seed");
    }

    #[test]
    fn save_load_file() {
        let c = sample_corpus();
        let path = std::env::temp_dir().join("glint_test_corpus.bin");
        c.save(&path).unwrap();
        let loaded = Corpus::load(&path).unwrap();
        assert_eq!(c, loaded);
        let _ = std::fs::remove_file(path);
    }
}
