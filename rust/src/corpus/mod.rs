//! Corpus substrate: the ClueWeb12 analogue.
//!
//! The paper trains on ClueWeb12, a 27 TB web crawl we cannot ship.
//! Everything the evaluation measures depends on corpus *statistics* —
//! the Zipfian word-frequency law (Fig. 4), document length distribution,
//! and vocabulary size — so [`synth`] generates corpora from an LDA
//! generative process whose word marginals follow a fitted Zipf law
//! (see DESIGN.md §Substitutions).
//!
//! A real-text path is also provided and exercised in tests/examples:
//! [`tokenizer`] → [`stopwords`] → [`stemmer`] (Porter) → [`vocab`]
//! (frequency-ordered, which is what makes the cyclic partitioning
//! load-balanced, §3.2).

pub mod dataset;
pub mod stemmer;
pub mod stopwords;
pub mod synth;
pub mod tokenizer;
pub mod vocab;
pub mod zipf;

pub use dataset::{Corpus, Document};
pub use synth::{generate, SynthConfig};
