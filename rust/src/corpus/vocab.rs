//! Frequency-ordered vocabulary construction (paper §3.2).
//!
//! "The features for our bag-of-words vectors are ordered by their
//! respective frequency. This means that the most commonly occurring
//! word is represented by the feature at index 1, the second most common
//! word would be at index 2, etc." — combined with cyclic partitioning
//! this is what load-balances the parameter servers.

use std::collections::HashMap;

use crate::corpus::dataset::{Corpus, Document};
use crate::corpus::stemmer::stem;
use crate::corpus::stopwords::is_stopword;
use crate::corpus::tokenizer::{tokenize, TokenizerConfig};

/// Vocabulary builder: counts words across documents, then freezes into a
/// frequency-ordered id mapping.
#[derive(Debug, Default)]
pub struct VocabBuilder {
    counts: HashMap<String, u64>,
}

impl VocabBuilder {
    /// Empty builder.
    pub fn new() -> VocabBuilder {
        VocabBuilder::default()
    }

    /// Count one token.
    pub fn add(&mut self, token: &str) {
        *self.counts.entry(token.to_string()).or_insert(0) += 1;
    }

    /// Count every token in a document.
    pub fn add_doc(&mut self, tokens: &[String]) {
        for t in tokens {
            self.add(t);
        }
    }

    /// Distinct words seen.
    pub fn distinct(&self) -> usize {
        self.counts.len()
    }

    /// Freeze into a frequency-ordered vocabulary, dropping words seen
    /// fewer than `min_count` times and keeping at most `max_size` words.
    pub fn freeze(self, min_count: u64, max_size: usize) -> Vocabulary {
        let mut entries: Vec<(String, u64)> =
            self.counts.into_iter().filter(|(_, c)| *c >= min_count).collect();
        // Descending frequency; ties broken lexicographically so the
        // ordering (and therefore shard placement) is deterministic.
        entries.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        entries.truncate(max_size);
        let words: Vec<String> = entries.iter().map(|(w, _)| w.clone()).collect();
        let index = words.iter().enumerate().map(|(i, w)| (w.clone(), i as u32)).collect();
        Vocabulary { words, index }
    }
}

/// Frozen frequency-ordered vocabulary: id 0 = most frequent word.
#[derive(Debug, Clone, Default)]
pub struct Vocabulary {
    words: Vec<String>,
    index: HashMap<String, u32>,
}

impl Vocabulary {
    /// Vocabulary size.
    pub fn len(&self) -> usize {
        self.words.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.words.is_empty()
    }

    /// Word id for a string, if in vocabulary.
    pub fn id(&self, word: &str) -> Option<u32> {
        self.index.get(word).copied()
    }

    /// Word string for an id.
    pub fn word(&self, id: u32) -> Option<&str> {
        self.words.get(id as usize).map(|s| s.as_str())
    }

    /// All words in id order.
    pub fn words(&self) -> &[String] {
        &self.words
    }
}

/// Full ingestion pipeline: raw texts → tokenize → stop-word removal →
/// Porter stemming → frequency-ordered vocabulary → bag-of-words corpus.
pub fn corpus_from_texts(
    texts: &[&str],
    tok_cfg: &TokenizerConfig,
    min_count: u64,
    max_vocab: usize,
) -> Corpus {
    // Pass 1: preprocess and count.
    let mut processed: Vec<Vec<String>> = Vec::with_capacity(texts.len());
    let mut builder = VocabBuilder::new();
    for text in texts {
        let mut toks = tokenize(text, tok_cfg);
        toks.retain(|t| !is_stopword(t));
        let toks: Vec<String> = toks.iter().map(|t| stem(t)).collect();
        builder.add_doc(&toks);
        processed.push(toks);
    }
    let vocab = builder.freeze(min_count, max_vocab);
    // Pass 2: map to ids (dropping OOV tokens).
    let docs = processed
        .into_iter()
        .map(|toks| Document {
            tokens: toks.iter().filter_map(|t| vocab.id(t)).collect(),
        })
        .collect();
    Corpus {
        docs,
        vocab_size: vocab.len() as u32,
        vocab: vocab.words().to_vec(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn freeze_orders_by_frequency() {
        let mut b = VocabBuilder::new();
        for _ in 0..5 {
            b.add("common");
        }
        for _ in 0..3 {
            b.add("middle");
        }
        b.add("rare");
        let v = b.freeze(1, 100);
        assert_eq!(v.id("common"), Some(0));
        assert_eq!(v.id("middle"), Some(1));
        assert_eq!(v.id("rare"), Some(2));
        assert_eq!(v.word(0), Some("common"));
    }

    #[test]
    fn min_count_filters() {
        let mut b = VocabBuilder::new();
        b.add("once");
        for _ in 0..2 {
            b.add("twice");
        }
        let v = b.freeze(2, 100);
        assert_eq!(v.len(), 1);
        assert_eq!(v.id("once"), None);
    }

    #[test]
    fn max_size_truncates() {
        let mut b = VocabBuilder::new();
        for i in 0..10 {
            for _ in 0..(10 - i) {
                b.add(&format!("w{i}"));
            }
        }
        let v = b.freeze(1, 3);
        assert_eq!(v.len(), 3);
        assert_eq!(v.id("w0"), Some(0));
        assert_eq!(v.id("w9"), None);
    }

    #[test]
    fn ties_are_deterministic() {
        let build = || {
            let mut b = VocabBuilder::new();
            b.add("zeta");
            b.add("alpha");
            b.freeze(1, 10)
        };
        let v1 = build();
        let v2 = build();
        assert_eq!(v1.words(), v2.words());
        assert_eq!(v1.id("alpha"), Some(0), "lexicographic tiebreak");
    }

    #[test]
    fn pipeline_end_to_end() {
        let texts = [
            "The jewelry store sells gold rings and diamond rings.",
            "Gold and diamonds: the jewelry of kings!",
            "A recipe with meat and spices. Spices make recipes great.",
        ];
        let c = corpus_from_texts(&texts, &TokenizerConfig::default(), 1, 1000);
        assert_eq!(c.num_docs(), 3);
        assert!(c.vocab_size > 0);
        assert!(c.is_frequency_ordered());
        // Stopwords are gone: "the"/"and" must not be in vocab.
        assert!(!c.vocab.iter().any(|w| w == "the" || w == "and"));
        // Stemming merged "rings"/"ring" and "recipes"/"recipe".
        assert!(c.vocab.iter().any(|w| w == "ring"));
        assert!(c.vocab.iter().any(|w| w == "recip"));
        // Tokens are valid ids.
        for d in &c.docs {
            for &t in &d.tokens {
                assert!(t < c.vocab_size);
            }
        }
    }
}
