//! English stop-word filtering (paper §3.2: "after stopword removal and
//! stemming").
//!
//! Uses a compact embedded list (the classic SMART-derived set used by
//! most IR toolkits, trimmed to high-frequency function words).

use std::collections::HashSet;
use std::sync::OnceLock;

/// The embedded stop-word list.
pub const STOPWORDS: &[&str] = &[
    "a", "about", "above", "after", "again", "against", "all", "am", "an", "and", "any",
    "are", "aren", "as", "at", "be", "because", "been", "before", "being", "below",
    "between", "both", "but", "by", "can", "cannot", "could", "couldn", "did", "didn",
    "do", "does", "doesn", "doing", "don", "down", "during", "each", "few", "for",
    "from", "further", "had", "hadn", "has", "hasn", "have", "haven", "having", "he",
    "her", "here", "hers", "herself", "him", "himself", "his", "how", "i", "if", "in",
    "into", "is", "isn", "it", "its", "itself", "just", "me", "more", "most", "mustn",
    "my", "myself", "no", "nor", "not", "now", "of", "off", "on", "once", "only", "or",
    "other", "ought", "our", "ours", "ourselves", "out", "over", "own", "same", "shan",
    "she", "should", "shouldn", "so", "some", "such", "than", "that", "the", "their",
    "theirs", "them", "themselves", "then", "there", "these", "they", "this", "those",
    "through", "to", "too", "under", "until", "up", "very", "was", "wasn", "we", "were",
    "weren", "what", "when", "where", "which", "while", "who", "whom", "why", "will",
    "with", "won", "would", "wouldn", "you", "your", "yours", "yourself", "yourselves",
];

fn set() -> &'static HashSet<&'static str> {
    static SET: OnceLock<HashSet<&'static str>> = OnceLock::new();
    SET.get_or_init(|| STOPWORDS.iter().copied().collect())
}

/// Is `word` (already lower-cased) a stop word?
pub fn is_stopword(word: &str) -> bool {
    set().contains(word)
}

/// Remove stop words in place.
pub fn remove_stopwords(tokens: &mut Vec<String>) {
    tokens.retain(|t| !is_stopword(t));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn common_words_are_stopwords() {
        for w in ["the", "and", "of", "is", "with"] {
            assert!(is_stopword(w), "{w}");
        }
    }

    #[test]
    fn content_words_are_not() {
        for w in ["recipe", "gold", "diamond", "jewelry", "spices"] {
            assert!(!is_stopword(w), "{w}");
        }
    }

    #[test]
    fn removal_filters_in_place() {
        let mut toks: Vec<String> =
            ["the", "gold", "and", "diamond", "ring"].iter().map(|s| s.to_string()).collect();
        remove_stopwords(&mut toks);
        assert_eq!(toks, vec!["gold", "diamond", "ring"]);
    }

    #[test]
    fn list_is_deduplicated() {
        let uniq: HashSet<_> = STOPWORDS.iter().collect();
        assert_eq!(uniq.len(), STOPWORDS.len());
    }
}
