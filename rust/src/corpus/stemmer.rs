//! Porter stemmer (Porter, 1980) — the classic five-step suffix-stripping
//! algorithm the paper's preprocessing applies before frequency ordering.
//!
//! Operates on lower-case ASCII; non-ASCII words are returned unchanged
//! (web corpora contain them, stemming them is out of scope for the
//! original algorithm too).

/// Stem a lower-case word.
pub fn stem(word: &str) -> String {
    if word.len() <= 2 || !word.bytes().all(|b| b.is_ascii_lowercase()) {
        return word.to_string();
    }
    let mut w: Vec<u8> = word.bytes().collect();
    step1a(&mut w);
    step1b(&mut w);
    step1c(&mut w);
    step2(&mut w);
    step3(&mut w);
    step4(&mut w);
    step5a(&mut w);
    step5b(&mut w);
    String::from_utf8(w).expect("ascii")
}

fn is_consonant(w: &[u8], i: usize) -> bool {
    match w[i] {
        b'a' | b'e' | b'i' | b'o' | b'u' => false,
        b'y' => i == 0 || !is_consonant(w, i - 1),
        _ => true,
    }
}

/// Measure m of the stem w[0..len]: number of VC sequences.
fn measure(w: &[u8], len: usize) -> usize {
    let mut m = 0;
    let mut i = 0;
    // Skip initial consonants.
    while i < len && is_consonant(w, i) {
        i += 1;
    }
    loop {
        // Vowel run.
        while i < len && !is_consonant(w, i) {
            i += 1;
        }
        if i >= len {
            return m;
        }
        // Consonant run => one VC.
        while i < len && is_consonant(w, i) {
            i += 1;
        }
        m += 1;
        if i >= len {
            return m;
        }
    }
}

fn has_vowel(w: &[u8], len: usize) -> bool {
    (0..len).any(|i| !is_consonant(w, i))
}

fn ends_double_consonant(w: &[u8]) -> bool {
    let n = w.len();
    n >= 2 && w[n - 1] == w[n - 2] && is_consonant(w, n - 1)
}

/// *o — stem ends cvc where the final c is not w, x, or y.
fn ends_cvc(w: &[u8], len: usize) -> bool {
    if len < 3 {
        return false;
    }
    let (a, b, c) = (len - 3, len - 2, len - 1);
    is_consonant(w, a)
        && !is_consonant(w, b)
        && is_consonant(w, c)
        && !matches!(w[c], b'w' | b'x' | b'y')
}

fn ends_with(w: &[u8], suffix: &[u8]) -> bool {
    w.len() >= suffix.len() && &w[w.len() - suffix.len()..] == suffix
}

/// If the word ends with `suffix` and the stem before it has measure > `m`,
/// replace the suffix with `rep` and return true.
fn replace_if_m(w: &mut Vec<u8>, suffix: &[u8], rep: &[u8], m_min: usize) -> bool {
    if ends_with(w, suffix) {
        let stem_len = w.len() - suffix.len();
        if measure(w, stem_len) > m_min {
            w.truncate(stem_len);
            w.extend_from_slice(rep);
            return true;
        }
    }
    false
}

fn step1a(w: &mut Vec<u8>) {
    if ends_with(w, b"sses") {
        w.truncate(w.len() - 2);
    } else if ends_with(w, b"ies") {
        w.truncate(w.len() - 2);
    } else if ends_with(w, b"ss") {
        // keep
    } else if ends_with(w, b"s") {
        w.truncate(w.len() - 1);
    }
}

fn step1b(w: &mut Vec<u8>) {
    if ends_with(w, b"eed") {
        if measure(w, w.len() - 3) > 0 {
            w.truncate(w.len() - 1);
        }
        return;
    }
    let hit = if ends_with(w, b"ed") && has_vowel(w, w.len() - 2) {
        w.truncate(w.len() - 2);
        true
    } else if ends_with(w, b"ing") && has_vowel(w, w.len() - 3) {
        w.truncate(w.len() - 3);
        true
    } else {
        false
    };
    if hit {
        if ends_with(w, b"at") || ends_with(w, b"bl") || ends_with(w, b"iz") {
            w.push(b'e');
        } else if ends_double_consonant(w) && !matches!(w[w.len() - 1], b'l' | b's' | b'z') {
            w.truncate(w.len() - 1);
        } else if measure(w, w.len()) == 1 && ends_cvc(w, w.len()) {
            w.push(b'e');
        }
    }
}

fn step1c(w: &mut Vec<u8>) {
    if ends_with(w, b"y") && has_vowel(w, w.len() - 1) {
        let n = w.len();
        w[n - 1] = b'i';
    }
}

fn step2(w: &mut Vec<u8>) {
    const RULES: &[(&[u8], &[u8])] = &[
        (b"ational", b"ate"),
        (b"tional", b"tion"),
        (b"enci", b"ence"),
        (b"anci", b"ance"),
        (b"izer", b"ize"),
        (b"abli", b"able"),
        (b"alli", b"al"),
        (b"entli", b"ent"),
        (b"eli", b"e"),
        (b"ousli", b"ous"),
        (b"ization", b"ize"),
        (b"ation", b"ate"),
        (b"ator", b"ate"),
        (b"alism", b"al"),
        (b"iveness", b"ive"),
        (b"fulness", b"ful"),
        (b"ousness", b"ous"),
        (b"aliti", b"al"),
        (b"iviti", b"ive"),
        (b"biliti", b"ble"),
    ];
    for (suffix, rep) in RULES {
        if ends_with(w, suffix) {
            replace_if_m(w, suffix, rep, 0);
            return;
        }
    }
}

fn step3(w: &mut Vec<u8>) {
    const RULES: &[(&[u8], &[u8])] = &[
        (b"icate", b"ic"),
        (b"ative", b""),
        (b"alize", b"al"),
        (b"iciti", b"ic"),
        (b"ical", b"ic"),
        (b"ful", b""),
        (b"ness", b""),
    ];
    for (suffix, rep) in RULES {
        if ends_with(w, suffix) {
            replace_if_m(w, suffix, rep, 0);
            return;
        }
    }
}

fn step4(w: &mut Vec<u8>) {
    const RULES: &[&[u8]] = &[
        b"al", b"ance", b"ence", b"er", b"ic", b"able", b"ible", b"ant", b"ement",
        b"ment", b"ent", b"ou", b"ism", b"ate", b"iti", b"ous", b"ive", b"ize",
    ];
    // Special case: -ion only after s or t.
    if ends_with(w, b"ion") {
        let stem_len = w.len() - 3;
        if stem_len > 0 && matches!(w[stem_len - 1], b's' | b't') && measure(w, stem_len) > 1 {
            w.truncate(stem_len);
        }
        return;
    }
    for suffix in RULES {
        if ends_with(w, suffix) {
            let stem_len = w.len() - suffix.len();
            if measure(w, stem_len) > 1 {
                w.truncate(stem_len);
            }
            return;
        }
    }
}

fn step5a(w: &mut Vec<u8>) {
    if ends_with(w, b"e") {
        let stem_len = w.len() - 1;
        let m = measure(w, stem_len);
        if m > 1 || (m == 1 && !ends_cvc(w, stem_len)) {
            w.truncate(stem_len);
        }
    }
}

fn step5b(w: &mut Vec<u8>) {
    if ends_double_consonant(w) && w[w.len() - 1] == b'l' && measure(w, w.len()) > 1 {
        w.truncate(w.len() - 1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classic_examples() {
        // Canonical cases from Porter's paper / reference vocabulary.
        let cases = [
            ("caresses", "caress"),
            ("ponies", "poni"),
            ("ties", "ti"),
            ("caress", "caress"),
            ("cats", "cat"),
            ("feed", "feed"),
            ("agreed", "agre"),
            ("plastered", "plaster"),
            ("bled", "bled"),
            ("motoring", "motor"),
            ("sing", "sing"),
            ("conflated", "conflat"),
            ("troubled", "troubl"),
            ("sized", "size"),
            ("hopping", "hop"),
            ("tanned", "tan"),
            ("falling", "fall"),
            ("hissing", "hiss"),
            ("fizzed", "fizz"),
            ("failing", "fail"),
            ("filing", "file"),
            ("happy", "happi"),
            ("sky", "sky"),
            ("relational", "relat"),
            ("conditional", "condit"),
            ("rational", "ration"),
            ("valenci", "valenc"),
            ("digitizer", "digit"),
            ("operator", "oper"),
            ("feudalism", "feudal"),
            ("decisiveness", "decis"),
            ("hopefulness", "hope"),
            ("formaliti", "formal"),
            ("formalize", "formal"),
            ("electriciti", "electr"),
            ("electrical", "electr"),
            ("hopeful", "hope"),
            ("goodness", "good"),
            ("revival", "reviv"),
            ("allowance", "allow"),
            ("inference", "infer"),
            ("airliner", "airlin"),
            ("adoption", "adopt"),
            ("activate", "activ"),
            ("effective", "effect"),
            ("probate", "probat"),
            ("rate", "rate"),
            ("cease", "ceas"),
            ("controll", "control"),
            ("roll", "roll"),
        ];
        for (input, want) in cases {
            assert_eq!(stem(input), want, "stem({input})");
        }
    }

    #[test]
    fn short_words_unchanged() {
        assert_eq!(stem("go"), "go");
        assert_eq!(stem("a"), "a");
    }

    #[test]
    fn non_ascii_unchanged() {
        assert_eq!(stem("zürich"), "zürich");
    }

    #[test]
    fn idempotent_on_common_words() {
        for w in ["recipe", "meat", "spice", "gold", "diamond", "jewelri"] {
            let once = stem(w);
            let twice = stem(&once);
            assert_eq!(once, twice, "stem not idempotent on {w}");
        }
    }
}
