//! Zipf-law sampling and slope estimation.
//!
//! Word frequencies in web corpora follow Zipf's law: frequency is
//! inversely proportional to frequency rank, `f(r) ∝ 1/r^s` with `s ≈ 1`
//! (paper §3.2, Figure 4). The synthetic corpus uses [`ZipfSampler`] for
//! its word marginals; [`fit_slope`] recovers the exponent from observed
//! counts so the reproduction can verify the generated corpus matches the
//! paper's distribution.

use crate::util::math::linear_fit;
use crate::util::rng::Pcg64;

/// Samples ranks `0..n` with `P(r) ∝ 1/(r+1)^s` via an inverse-CDF table
/// (O(log n) per draw, O(n) memory).
#[derive(Debug, Clone)]
pub struct ZipfSampler {
    cdf: Vec<f64>,
}

impl ZipfSampler {
    /// Build a sampler over `n` ranks with exponent `s`.
    pub fn new(n: usize, s: f64) -> ZipfSampler {
        assert!(n > 0, "need at least one rank");
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for r in 0..n {
            acc += 1.0 / ((r + 1) as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for v in cdf.iter_mut() {
            *v /= total;
        }
        ZipfSampler { cdf }
    }

    /// Number of ranks.
    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    /// Always false; samplers are non-empty by construction.
    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }

    /// Probability of rank `r`.
    pub fn prob(&self, r: usize) -> f64 {
        if r == 0 {
            self.cdf[0]
        } else {
            self.cdf[r] - self.cdf[r - 1]
        }
    }

    /// Draw a rank.
    pub fn sample(&self, rng: &mut Pcg64) -> usize {
        let u = rng.f64();
        // First index whose cdf >= u.
        match self.cdf.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

/// Fit the Zipf exponent from rank-ordered counts (descending): returns
/// `(intercept, slope)` of `log f = a + b log r`; the Zipf exponent is
/// `-b`. Zero counts are skipped.
pub fn fit_slope(counts_desc: &[u64]) -> (f64, f64) {
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for (r, &c) in counts_desc.iter().enumerate() {
        if c > 0 {
            xs.push(((r + 1) as f64).ln());
            ys.push((c as f64).ln());
        }
    }
    linear_fit(&xs, &ys)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probabilities_sum_to_one() {
        let z = ZipfSampler::new(100, 1.0);
        let total: f64 = (0..100).map(|r| z.prob(r)).sum();
        assert!((total - 1.0).abs() < 1e-9);
    }

    #[test]
    fn rank_zero_most_likely() {
        let z = ZipfSampler::new(50, 1.2);
        for r in 1..50 {
            assert!(z.prob(0) > z.prob(r));
        }
    }

    #[test]
    fn empirical_matches_theoretical() {
        let z = ZipfSampler::new(20, 1.0);
        let mut rng = Pcg64::new(31);
        let n = 200_000;
        let mut counts = vec![0usize; 20];
        for _ in 0..n {
            counts[z.sample(&mut rng)] += 1;
        }
        for r in 0..20 {
            let emp = counts[r] as f64 / n as f64;
            assert!(
                (emp - z.prob(r)).abs() < 0.01,
                "rank {r}: emp {emp} vs theory {}",
                z.prob(r)
            );
        }
    }

    #[test]
    fn fit_recovers_exponent() {
        // Exact Zipf counts with s = 1.1.
        let counts: Vec<u64> = (1..=5000u64)
            .map(|r| (1e9 / (r as f64).powf(1.1)) as u64)
            .collect();
        let (_, slope) = fit_slope(&counts);
        assert!((slope + 1.1).abs() < 0.01, "slope {slope}");
    }

    #[test]
    fn fit_skips_zeros() {
        let counts = vec![100, 50, 0, 25, 0];
        let (_, slope) = fit_slope(&counts);
        assert!(slope < 0.0);
    }
}
