//! Text tokenization for the real-text ingestion path.
//!
//! Lower-cases, splits on non-alphanumeric boundaries, and drops tokens
//! that are too short, too long, or purely numeric — the standard
//! preprocessing for web-scale topic modeling (the paper applies
//! stop-word removal and stemming on top; see [`crate::corpus::stopwords`]
//! and [`crate::corpus::stemmer`]).

/// Tokenizer options.
#[derive(Debug, Clone)]
pub struct TokenizerConfig {
    /// Minimum token length (chars).
    pub min_len: usize,
    /// Maximum token length (chars) — web crawls contain pathological
    /// "words".
    pub max_len: usize,
    /// Drop tokens that are entirely digits.
    pub drop_numeric: bool,
}

impl Default for TokenizerConfig {
    fn default() -> Self {
        TokenizerConfig { min_len: 2, max_len: 32, drop_numeric: true }
    }
}

/// Tokenize `text` into lower-case word strings.
pub fn tokenize(text: &str, cfg: &TokenizerConfig) -> Vec<String> {
    let mut out = Vec::new();
    let mut current = String::new();
    for ch in text.chars() {
        if ch.is_alphanumeric() {
            for lc in ch.to_lowercase() {
                current.push(lc);
            }
        } else if !current.is_empty() {
            flush(&mut current, cfg, &mut out);
        }
    }
    if !current.is_empty() {
        flush(&mut current, cfg, &mut out);
    }
    out
}

fn flush(current: &mut String, cfg: &TokenizerConfig, out: &mut Vec<String>) {
    let n = current.chars().count();
    let keep = n >= cfg.min_len
        && n <= cfg.max_len
        && !(cfg.drop_numeric && current.chars().all(|c| c.is_ascii_digit()));
    if keep {
        out.push(std::mem::take(current));
    } else {
        current.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tok(s: &str) -> Vec<String> {
        tokenize(s, &TokenizerConfig::default())
    }

    #[test]
    fn basic_splitting() {
        assert_eq!(tok("The cat sat, on the mat!"), vec!["the", "cat", "sat", "on", "the", "mat"]);
    }

    #[test]
    fn lowercases_unicode() {
        assert_eq!(tok("Zürich HTTP"), vec!["zürich", "http"]);
    }

    #[test]
    fn drops_short_and_numeric() {
        assert_eq!(tok("a I 42 2023 ok"), vec!["ok"]);
    }

    #[test]
    fn keeps_alphanumeric_mixes() {
        assert_eq!(tok("web2 x86 b2b"), vec!["web2", "x86", "b2b"]);
    }

    #[test]
    fn drops_overlong() {
        let long = "x".repeat(40);
        assert!(tok(&long).is_empty());
    }

    #[test]
    fn empty_input() {
        assert!(tok("").is_empty());
        assert!(tok("  \n\t .,!").is_empty());
    }
}
