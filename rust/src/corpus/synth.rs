//! Synthetic ClueWeb12 analogue.
//!
//! Generates corpora from the LDA generative process with **Zipfian word
//! marginals**: topic-word distributions are built by modulating a base
//! Zipf law (exponent fitted to the paper's Figure 4, ≈1.07 for web text)
//! with per-topic multiplicative noise, so that
//!
//! 1. the aggregate word-frequency plot is Zipfian (reproducing Fig. 4),
//! 2. documents have genuine latent topic structure (so LDA training has
//!    signal and perplexity behaves like it does on real text), and
//! 3. word ids are frequency ranks (id 0 = most common word), matching
//!    the paper's feature ordering that powers the implicit load
//!    balancing (§3.2).
//!
//! Document lengths are log-normal, calibrated to ClueWeb12's ~750
//! tokens/doc mean at default settings (scaled down by `avg_doc_len`).

use crate::corpus::dataset::{Corpus, Document};
use crate::corpus::zipf::ZipfSampler;
use crate::util::rng::Pcg64;

/// Synthetic corpus parameters.
#[derive(Debug, Clone)]
pub struct SynthConfig {
    /// Number of documents.
    pub num_docs: usize,
    /// Vocabulary size.
    pub vocab_size: u32,
    /// Number of latent topics used by the generator (ground truth, not
    /// necessarily what the model is trained with).
    pub num_topics: usize,
    /// Mean document length (tokens).
    pub avg_doc_len: f64,
    /// Zipf exponent of the word marginal (ClueWeb12 ≈ 1.07).
    pub zipf_exponent: f64,
    /// Number of head ranks removed before the vocabulary starts,
    /// simulating stop-word removal (the paper's Fig. 4 plots the
    /// distribution *after* stop-word removal and stemming, which chops
    /// the extreme Zipf head). Word id 0 corresponds to underlying rank
    /// `stopwords_removed`.
    pub stopwords_removed: usize,
    /// Dirichlet concentration of per-document topic mixtures.
    pub doc_topic_alpha: f64,
    /// Log-scale strength of per-topic modulation of the base Zipf law.
    /// 0 = all topics identical; larger = more distinct topics.
    pub topic_distinctness: f64,
    /// RNG seed.
    pub seed: u64,
}

impl Default for SynthConfig {
    fn default() -> Self {
        SynthConfig {
            num_docs: 2000,
            vocab_size: 5000,
            num_topics: 20,
            avg_doc_len: 120.0,
            zipf_exponent: 1.07,
            stopwords_removed: 100,
            doc_topic_alpha: 0.15,
            topic_distinctness: 2.0,
            seed: 0x5eed,
        }
    }
}

/// Per-topic cumulative word distributions for fast sampling.
struct TopicTables {
    /// `num_topics` CDFs of length `vocab_size`.
    cdfs: Vec<Vec<f64>>,
}

impl TopicTables {
    fn build(cfg: &SynthConfig, rng: &mut Pcg64) -> TopicTables {
        let v = cfg.vocab_size as usize;
        // Ranks 0..stopwords_removed are "stop words" that the paper's
        // preprocessing strips; the vocabulary starts at that rank, so
        // the head of the remaining distribution is flat enough for the
        // load-balancing behaviour to match the paper's Fig. 5.
        let skip = cfg.stopwords_removed;
        let base = ZipfSampler::new(v + skip, cfg.zipf_exponent);
        let mut cdfs = Vec::with_capacity(cfg.num_topics);
        for _ in 0..cfg.num_topics {
            let mut cdf = Vec::with_capacity(v);
            let mut acc = 0.0;
            for w in 0..v {
                // Multiplicative log-normal modulation of the shared Zipf
                // base: keeps aggregate marginals Zipfian while giving
                // each topic its own preferred words.
                let noise = (cfg.topic_distinctness * rng.normal()).exp();
                acc += base.prob(w + skip) * noise;
                cdf.push(acc);
            }
            let total = acc;
            for c in cdf.iter_mut() {
                *c /= total;
            }
            cdfs.push(cdf);
        }
        TopicTables { cdfs }
    }

    fn sample_word(&self, topic: usize, rng: &mut Pcg64) -> u32 {
        let cdf = &self.cdfs[topic];
        let u = rng.f64();
        match cdf.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
            Ok(i) => i as u32,
            Err(i) => i.min(cdf.len() - 1) as u32,
        }
    }
}

/// Generate a corpus. Word ids in the result are frequency ranks
/// (0 = most frequent), matching the paper's feature ordering.
pub fn generate(cfg: &SynthConfig) -> Corpus {
    assert!(cfg.num_topics > 0 && cfg.vocab_size > 0 && cfg.num_docs > 0);
    let mut rng = Pcg64::new(cfg.seed);
    let tables = TopicTables::build(cfg, &mut rng);

    // Log-normal doc lengths with the requested mean: if X~LN(mu, s^2)
    // then E[X] = exp(mu + s^2/2); choose s = 0.7 (web-like spread).
    let sigma = 0.7f64;
    let mu = cfg.avg_doc_len.ln() - sigma * sigma / 2.0;

    let mut theta = Vec::new();
    let mut raw_docs: Vec<Vec<u32>> = Vec::with_capacity(cfg.num_docs);
    for _ in 0..cfg.num_docs {
        rng.dirichlet_sym(cfg.doc_topic_alpha, cfg.num_topics, &mut theta);
        let len = (mu + sigma * rng.normal()).exp().round().max(1.0) as usize;
        let mut tokens = Vec::with_capacity(len);
        for _ in 0..len {
            let k = rng.categorical(&theta);
            tokens.push(tables.sample_word(k, &mut rng));
        }
        raw_docs.push(tokens);
    }

    // Relabel word ids by realized frequency so id == frequency rank.
    let mut counts = vec![0u64; cfg.vocab_size as usize];
    for d in &raw_docs {
        for &w in d {
            counts[w as usize] += 1;
        }
    }
    let mut order: Vec<u32> = (0..cfg.vocab_size).collect();
    order.sort_by(|&a, &b| counts[b as usize].cmp(&counts[a as usize]).then(a.cmp(&b)));
    let mut relabel = vec![0u32; cfg.vocab_size as usize];
    for (rank, &old) in order.iter().enumerate() {
        relabel[old as usize] = rank as u32;
    }
    let docs = raw_docs
        .into_iter()
        .map(|tokens| Document { tokens: tokens.into_iter().map(|w| relabel[w as usize]).collect() })
        .collect();

    Corpus { docs, vocab_size: cfg.vocab_size, vocab: Vec::new() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::zipf::fit_slope;

    fn small() -> SynthConfig {
        SynthConfig {
            num_docs: 400,
            vocab_size: 800,
            num_topics: 10,
            avg_doc_len: 60.0,
            ..Default::default()
        }
    }

    #[test]
    fn deterministic_for_seed() {
        let cfg = small();
        let a = generate(&cfg);
        let b = generate(&cfg);
        assert_eq!(a, b);
    }

    #[test]
    fn shapes_and_ordering() {
        let cfg = small();
        let c = generate(&cfg);
        assert_eq!(c.num_docs(), 400);
        assert_eq!(c.vocab_size, 800);
        assert!(c.is_frequency_ordered(), "ids must be frequency ranks");
        assert!(c.docs.iter().all(|d| !d.is_empty()));
    }

    #[test]
    fn mean_length_close_to_config() {
        let cfg = SynthConfig { num_docs: 2000, ..small() };
        let c = generate(&cfg);
        let mean = c.num_tokens() as f64 / c.num_docs() as f64;
        assert!(
            (mean - cfg.avg_doc_len).abs() < cfg.avg_doc_len * 0.15,
            "mean len {mean} vs target {}",
            cfg.avg_doc_len
        );
    }

    #[test]
    fn marginals_are_zipfian() {
        let cfg = SynthConfig {
            num_docs: 3000,
            vocab_size: 3000,
            avg_doc_len: 100.0,
            ..small()
        };
        let c = generate(&cfg);
        let counts = c.word_counts();
        // Fit over the reliable head (top 500 ranks).
        let (_, slope) = fit_slope(&counts[..500]);
        assert!(
            (-1.6..=-0.6).contains(&slope),
            "zipf slope {slope} not web-like"
        );
    }

    #[test]
    fn topic_structure_exists() {
        // Co-occurrence signal: generated docs should be far from
        // unigram-shuffled ones. Cheap proxy: per-document type/token
        // ratio is lower than under independent sampling (topics
        // concentrate words).
        let cfg = SynthConfig { topic_distinctness: 3.0, ..small() };
        let with_topics = generate(&cfg);
        let cfg_flat = SynthConfig { topic_distinctness: 0.0, num_topics: 1, ..small() };
        let flat = generate(&cfg_flat);
        let tt = |c: &Corpus| {
            let mut ratio = 0.0;
            for d in &c.docs {
                let uniq: std::collections::HashSet<_> = d.tokens.iter().collect();
                ratio += uniq.len() as f64 / d.len() as f64;
            }
            ratio / c.num_docs() as f64
        };
        assert!(
            tt(&with_topics) < tt(&flat),
            "topic-structured docs should repeat words more: {} vs {}",
            tt(&with_topics),
            tt(&flat)
        );
    }
}
