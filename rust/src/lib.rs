//! # glint-lda
//!
//! A reproduction of *"Computing Web-scale Topic Models using an
//! Asynchronous Parameter Server"* (Jagerman & Eickhoff, SIGIR 2017).
//!
//! The crate provides:
//!
//! - [`ps`] — **Glint**, an asynchronous parameter server: distributed
//!   matrices/vectors with ticket-based `pull`/`push` (`_async` variants
//!   return wait()-able tickets riding bounded per-shard in-flight
//!   windows, with `flush()` as the cross-ticket barrier), pluggable
//!   `Dense`/`Sparse` storage layouts with typed server-side operations
//!   (sparse row pulls, per-row top-k, column sums) executed by an
//!   op-dispatch shard executor (concurrent reads, serialized pushes),
//!   cyclic row partitioning, retrying pulls with exponential back-off
//!   and an *exactly-once* hand-shake protocol for pushes (bounded
//!   dedup window), running over pluggable at-most-once transports
//!   ([`net`]): an in-process fault-injectable simulator and a real TCP
//!   backend (correlation-tagged frames multiplexed over one connection
//!   per shard, `serve`/`--connect` multi-process deployments).
//! - [`lda`] — a distributed **LightLDA** sampler (Metropolis–Hastings
//!   collapsed Gibbs with amortized O(1) per-token complexity) built on
//!   the parameter server, with push buffering, prefetched model pulls
//!   overlapping sampling with communication, and checkpoint-based fault
//!   tolerance.
//! - [`cluster`] — the multi-process control plane: a coordinator
//!   (`coordinate`) assigning corpus partitions to remote worker
//!   processes (`work --join`), with heartbeat liveness detection, a
//!   bounded-staleness iteration barrier, and failure recovery that
//!   rolls the run onto a fresh count table rebuilt from per-partition
//!   checkpoints.
//! - [`serving`] — the serve-model inference tier: serving replicas
//!   that attach read-mostly to the live shards' frozen count table and
//!   answer topic inference for *unseen* documents by fixed-budget
//!   fold-in, with request batching (one coalesced sparse pull per
//!   batch) and LRU result caching, plus the [`serving::InferClient`]
//!   line-protocol client.
//! - [`baselines`] — faithful re-implementations of Spark MLlib's
//!   variational EM LDA and Online LDA, with a shuffle-write accounting
//!   model, used as comparison points for the paper's Table 1.
//! - [`corpus`] — a synthetic ClueWeb12 analogue (Zipfian LDA generative
//!   corpus) plus a real-text ingestion pipeline (tokenizer, stopwords,
//!   Porter stemmer, frequency-ordered vocabulary).
//! - [`eval`] — held-out perplexity (pure-rust and XLA-accelerated paths)
//!   and topic inspection utilities.
//! - [`runtime`] — a PJRT/XLA engine that loads the AOT-compiled JAX/Pallas
//!   artifacts (`artifacts/*.hlo.txt`) and executes them from rust.
//! - [`wal`] — per-shard durability: a group-committed, segmented
//!   write-ahead log with snapshot compaction, powering crash recovery
//!   (`serve --wal-dir`) and primary→backup chain replication
//!   (`serve --backup-of`) with client-side failover.
//!
//! Python (JAX + Pallas) participates only at *build* time: `make
//! artifacts` lowers the evaluation graphs to HLO text once; the rust
//! binary is self-contained afterwards.

pub mod baselines;
pub mod cluster;
pub mod corpus;
pub mod eval;
pub mod experiments;
pub mod lda;
pub mod metrics;
pub mod net;
pub mod ps;
pub mod runtime;
pub mod serving;
pub mod util;
pub mod wal;

pub use util::error::{Error, Result};
