//! A remote cluster worker: joins a coordinator, receives a set of
//! partition assignments, and drives the shared per-partition sweep
//! ([`crate::lda::sweep::SweepRunner`]) against the parameter-server
//! shards — the same kernel the in-process trainer's worker threads run,
//! so the two deployment modes are numerically equivalent.
//!
//! Lifecycle (all worker-initiated; see [`crate::cluster::protocol`]):
//!
//! 1. `Register` → a [`JobSpec`]: partition assignments, epoch, matrix
//!    id, shard addresses, corpus spec, knobs. A standby's `Register`
//!    blocks server-side (the coordinator parks the envelope) until a
//!    partition frees or the run ends.
//! 2. Rebuild partition state. A *same-epoch* respec is diffed: runners
//!    already held stay untouched, only newly assigned partitions are
//!    built — from the checkpoint iteration the spec names (warm
//!    transfers resume exactly there), else the latest valid
//!    checkpoint, else a fresh seeded initialization. Counts are pushed
//!    only where the spec says to (`push`): a warm handoff's counts are
//!    already in the epoch's table. Then `Ready`.
//! 3. `Poll` → `Run`: pull the topic totals (server-side column sums),
//!    re-derive the sweep RNG from `(seed, epoch, iteration,
//!    partition)`, sweep, flush, optionally evaluate, **checkpoint,
//!    then report**. The checkpoint-before-report order is what makes
//!    the coordinator's recovery arithmetic sound, and the per-iteration
//!    RNG derivation is what keeps the token→randomness stream identical
//!    no matter which worker sweeps the partition.
//!    `Poll` → `Transfer`: drop the named runners (their checkpoints
//!    are already on disk); the recipient resumes from them.
//! 4. On `Job` replies (any time): a rollback or reassignment happened —
//!    rebuild per the new spec. On `Error` ("unknown worker"): we were
//!    presumed dead; *re-register with the same token* and rejoin warm
//!    instead of exiting (zombie rejoin). On `Done`: `Leave`.
//!
//! In snapshot mode (`knobs.snapshot`) each `Run` first pulls the full
//! model snapshot and holds at the coordinator's fetch barrier
//! (`Fetched`) until every participating partition has pulled it; the
//! sweep then samples against the frozen snapshot while pushing deltas.
//! That makes the final count table bit-exact under any membership
//! history.
//!
//! A heartbeat thread pings the coordinator every
//! [`crate::cluster::protocol::SweepKnobs::heartbeat_ms`] for the life
//! of the process, so a long sweep or corpus load is never mistaken for
//! a death.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::cluster::protocol::{
    CorpusSpec, CtrlRequest, CtrlResponse, JobSpec, PartitionAssignment, SweepReport,
};
use crate::corpus::dataset::Corpus;
use crate::corpus::synth::{generate, SynthConfig};
use crate::eval::perplexity::TopicModel;
use crate::lda::checkpoint::{Checkpoint, PartitionCheckpoint};
use crate::lda::hyper::LdaHyper;
use crate::lda::sweep::{partition_rng, pull_full_model, SweepConfig, SweepRunner};
use crate::net::tcp::{resolve_addrs, TcpTransport};
use crate::net::{Endpoint, Transport};
use crate::ps::client::{BigMatrix, PsClient};
use crate::ps::config::{PsConfig, TransportMode};
use crate::util::error::{Error, Result};
use crate::util::timer::Stopwatch;
use crate::{log_info, log_warn};

/// Per-attempt control round-trip timeout.
const CTRL_TIMEOUT: Duration = Duration::from_secs(2);
/// Per-attempt `Register` timeout: a standby's envelope is parked
/// coordinator-side and only answered when a seat frees, so the worker
/// must be willing to wait far longer than a normal round trip.
const REGISTER_TIMEOUT: Duration = Duration::from_secs(30);
/// Control-plane retries before giving the coordinator up for dead.
const CTRL_RETRIES: u32 = 5;
/// Ceiling on honored `Wait` back-off (the coordinator's suggestions
/// are already small; this bounds a corrupt value).
const MAX_WAIT: Duration = Duration::from_secs(2);

/// Golden-ratio mix of the iteration counter into the sweep-RNG seed:
/// iteration `t` of a partition samples from the same stream no matter
/// which worker runs it, or whether it runs fresh or after a warm
/// handoff.
fn iter_mix(iteration: u32) -> u64 {
    (iteration as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// How a worker process is launched.
#[derive(Default)]
pub struct WorkerOptions {
    /// Coordinator control address (`host:port`).
    pub join: String,
    /// Pre-loaded corpus (in-process workers, or `work --corpus`); when
    /// `None` the corpus comes from the job's [`CorpusSpec`].
    pub corpus: Option<Corpus>,
    /// Fault-injection hook for tests and demos: after *sweeping* this
    /// iteration (pushes flushed, nothing checkpointed or reported —
    /// i.e. mid-iteration from the control plane's view), the worker
    /// vanishes without a goodbye, exactly like a crashed process.
    pub crash_at_iteration: Option<u32>,
    /// Planned drain: after completing this many sweeps, ask the
    /// coordinator to `Drain` — finish hand-offs at sweep boundaries
    /// and leave without tripping the reaper or rolling the epoch.
    pub drain_after: Option<u32>,
    /// Test/demo hook: sleep this long before every sweep, simulating a
    /// straggler (drives the coordinator's load shedding).
    pub sweep_delay_ms: u64,
}

/// What a worker did before exiting.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WorkerSummary {
    /// Coordinator-assigned id (0 if the run was already over at
    /// registration time). The *latest* id when a zombie rejoin
    /// re-seated the worker.
    pub worker_id: u64,
    /// Sweeps completed (across epochs and partitions).
    pub sweeps: u32,
    /// True when the crash hook fired.
    pub crashed: bool,
    /// True when the worker left via a planned drain.
    pub drained: bool,
    /// Checkpoint bytes loaded for warm handoffs (transfers in).
    pub warm_bytes: u64,
}

/// Retrying request/reply channel to the coordinator. Cloning shares
/// the underlying multiplexed connection, so the heartbeat thread rides
/// the same socket as the main loop.
#[derive(Clone)]
struct CtrlChannel {
    ep: Endpoint,
}

impl CtrlChannel {
    fn connect(addr: &str) -> Result<CtrlChannel> {
        let resolved = resolve_addrs(&[addr.to_string()])?;
        let transport = TcpTransport::connect(&resolved);
        Ok(CtrlChannel { ep: transport.endpoint(0) })
    }

    fn call(&self, req: &CtrlRequest) -> Result<CtrlResponse> {
        self.call_timeout(req, CTRL_TIMEOUT)
    }

    fn call_timeout(&self, req: &CtrlRequest, timeout: Duration) -> Result<CtrlResponse> {
        let payload = req.encode();
        for attempt in 0..CTRL_RETRIES {
            match self.ep.request(payload.clone(), timeout) {
                Ok(bytes) => return CtrlResponse::decode(&bytes),
                Err(()) => {
                    std::thread::sleep(Duration::from_millis(50 << attempt.min(4)));
                }
            }
        }
        Err(Error::PsTimeout { op: "control", shard: 0, attempts: CTRL_RETRIES })
    }
}

/// One owned partition: its assignment, its sweep state, and where that
/// state came from.
struct PartState {
    assign: PartitionAssignment,
    runner: SweepRunner,
    /// Latest iteration this partition's in-memory state corresponds to
    /// (resume point at build, then the last swept iteration).
    done: u32,
    /// A checkpoint file actually loaded at build time.
    loaded: bool,
}

/// Everything bound to one `(epoch, matrix)` pair: the PS connection,
/// the epoch's count table, and the owned partitions.
struct ActiveJob {
    /// Keeps the shard connections alive for `client`/`n_wk`.
    _transport: Arc<dyn Transport>,
    client: PsClient,
    n_wk: BigMatrix<i64>,
    scfg: SweepConfig,
    hyper: LdaHyper,
    epoch: u32,
    matrix_id: u32,
    parts: HashMap<u32, PartState>,
}

/// Load the corpus a job names (when the caller didn't supply one).
pub fn load_corpus(spec: &CorpusSpec) -> Result<Corpus> {
    match spec {
        CorpusSpec::File(path) => {
            log_info!("loading corpus from {path}");
            Corpus::load(std::path::Path::new(path))
        }
        CorpusSpec::Synth {
            num_docs,
            vocab_size,
            num_topics,
            avg_doc_len,
            zipf_exponent,
            seed,
        } => {
            log_info!("generating synthetic corpus ({num_docs} docs, V={vocab_size})");
            Ok(generate(&SynthConfig {
                num_docs: *num_docs as usize,
                vocab_size: *vocab_size,
                num_topics: *num_topics as usize,
                avg_doc_len: *avg_doc_len,
                zipf_exponent: *zipf_exponent,
                seed: *seed,
                ..SynthConfig::default()
            }))
        }
        CorpusSpec::Provided => Err(Error::Config(
            "job says the corpus is provided out-of-band; pass --corpus to this worker".into(),
        )),
    }
}

impl ActiveJob {
    /// Connect to the shards, attach the epoch's table, and build every
    /// assigned partition.
    fn build(spec: &JobSpec, corpus: &Corpus) -> Result<(ActiveJob, u64)> {
        let knobs = &spec.knobs;
        let hyper = LdaHyper { alpha: knobs.alpha, beta: knobs.beta };
        hyper.validate()?;
        let resolved = resolve_addrs(&spec.shard_addrs)?;
        let mut ps_cfg = PsConfig::deployment(
            resolved.len(),
            knobs.scheme,
            TransportMode::Connect(spec.shard_addrs.clone()),
            knobs.sampler.pipeline_depth,
        );
        // Replica failover: pushes outlive a dying primary by routing to
        // its (promoted) backup.
        ps_cfg.backups = spec.backup_addrs.clone();
        let transport: Arc<dyn Transport> = Arc::new(TcpTransport::connect(&resolved));
        let client = PsClient::connect(&*transport, ps_cfg);
        client.validate_deployment()?;
        let n_wk: BigMatrix<i64> = client.attach_matrix(
            spec.matrix_id,
            corpus.vocab_size as u64,
            knobs.num_topics,
            knobs.wt_layout,
        )?;
        let scfg = SweepConfig {
            num_topics: knobs.num_topics,
            sampler: knobs.sampler,
            hyper,
            vocab_size: corpus.vocab_size,
        };
        let mut job = ActiveJob {
            _transport: transport,
            client,
            n_wk,
            scfg,
            hyper,
            epoch: spec.epoch,
            matrix_id: spec.matrix_id,
            parts: HashMap::new(),
        };
        let bytes = job.add_parts(spec, &spec.parts, corpus)?;
        Ok((job, bytes))
    }

    /// Same-epoch respec: drop partitions no longer assigned, build the
    /// newly assigned ones, leave held runners untouched. Returns warm
    /// checkpoint bytes loaded.
    fn diff(&mut self, spec: &JobSpec, corpus: &Corpus) -> Result<u64> {
        let keep: Vec<u32> = spec.parts.iter().map(|a| a.partition).collect();
        self.parts.retain(|p, _| keep.contains(p));
        let fresh: Vec<PartitionAssignment> = spec
            .parts
            .iter()
            .filter(|a| !self.parts.contains_key(&a.partition))
            .cloned()
            .collect();
        self.add_parts(spec, &fresh, corpus)
    }

    /// Build runners for `assigns`, pushing counts where the spec says
    /// to, and flush. Returns warm checkpoint bytes loaded.
    fn add_parts(
        &mut self,
        spec: &JobSpec,
        assigns: &[PartitionAssignment],
        corpus: &Corpus,
    ) -> Result<u64> {
        let mut pushed_any = false;
        let mut warm_bytes = 0u64;
        for assign in assigns {
            let (runner, done, loaded, bytes) = restore_partition(spec, assign, corpus)?;
            if assign.push {
                runner.push_counts(&self.scfg, &self.n_wk);
                pushed_any = true;
            } else {
                warm_bytes += bytes;
            }
            self.parts.insert(
                assign.partition,
                PartState { assign: assign.clone(), runner, done, loaded },
            );
        }
        if pushed_any {
            self.client.flush()?;
        }
        Ok(warm_bytes)
    }

    /// The `Ready` items for the current partition set, in partition
    /// order.
    fn ready_items(&self) -> Vec<(u32, u32, bool)> {
        let mut items: Vec<(u32, u32, bool)> =
            self.parts.values().map(|s| (s.assign.partition, s.done, s.loaded)).collect();
        items.sort_unstable();
        items
    }
}

/// Rebuild one partition's sweep state: the exact checkpoint iteration
/// the spec names when it exists, else the latest valid one, else a
/// fresh seeded initialization. Returns `(runner, iteration, loaded,
/// checkpoint_bytes)`.
fn restore_partition(
    spec: &JobSpec,
    assign: &PartitionAssignment,
    corpus: &Corpus,
) -> Result<(SweepRunner, u32, bool, u64)> {
    let knobs = &spec.knobs;
    let (start, end) = (assign.doc_start as usize, assign.doc_end as usize);
    if start > end || end > corpus.num_docs() {
        return Err(Error::Config(format!(
            "partition {}..{} exceeds the {}-doc corpus (wrong corpus?)",
            start,
            end,
            corpus.num_docs()
        )));
    }
    let range = start..end;
    // Fresh initialization is deterministic per (epoch, partition): the
    // same stream every member would derive, which is what lets a warm
    // handoff at iteration 0 rebuild the pushed counts without a file.
    let epoch_salt = (spec.epoch as u64) << 32;
    let init_rng =
        partition_rng(knobs.seed ^ epoch_salt, assign.partition as usize, assign.doc_start);
    if let Some((ckpt, bytes)) = load_partition_checkpoint(assign, knobs, corpus) {
        let iteration = ckpt.inner.iteration;
        let assignments = std::cell::RefCell::new(ckpt.inner.assignments);
        let next = std::cell::Cell::new(0usize);
        let runner = SweepRunner::build(corpus, range, init_rng, |_, _| {
            let i = next.get();
            next.set(i + 1);
            assignments.borrow_mut()[i].clone()
        });
        log_info!(
            "partition {} restored from checkpoint at iteration {iteration}",
            assign.partition
        );
        Ok((runner, iteration, true, bytes))
    } else {
        let k = knobs.num_topics;
        Ok((SweepRunner::build_random(corpus, range, k, init_rng), 0, false, 0))
    }
}

/// The partition checkpoint to resume from, if checkpointing is on and
/// a compatible one exists: the exact `resume` iteration the spec names
/// when that file is valid (warm transfers must match the table), else
/// the latest valid one. Shape mismatches (different corpus, topic
/// count, or partition bounds) are treated as "no checkpoint" — a fresh
/// start is always a safe recovery, because the coordinator's `Ready`
/// check rolls the epoch when a warm handoff comes back wrong.
fn load_partition_checkpoint(
    assign: &PartitionAssignment,
    knobs: &crate::cluster::protocol::SweepKnobs,
    corpus: &Corpus,
) -> Option<(PartitionCheckpoint, u64)> {
    if knobs.checkpoint_dir.is_empty() {
        return None;
    }
    let dir = std::path::Path::new(&knobs.checkpoint_dir);
    let mut found: Option<PartitionCheckpoint> = None;
    if assign.resume > 0 {
        let exact = PartitionCheckpoint::path_for(dir, assign.partition, assign.resume);
        match PartitionCheckpoint::load(&exact) {
            Ok(ckpt) => found = Some(ckpt),
            Err(e) => log_warn!(
                "partition {} checkpoint for iteration {} unreadable ({e}); \
                 falling back to the latest",
                assign.partition,
                assign.resume
            ),
        }
    }
    let ckpt = match found {
        Some(c) => c,
        None => match PartitionCheckpoint::load_latest(dir, assign.partition) {
            Ok(c) => c?,
            Err(e) => {
                log_warn!("cannot scan checkpoints in {dir:?}: {e}");
                return None;
            }
        },
    };
    let (start, end) = (assign.doc_start as usize, assign.doc_end as usize);
    if ckpt.doc_start != assign.doc_start
        || ckpt.inner.num_topics != knobs.num_topics
        || ckpt.inner.assignments.len() != end - start
    {
        log_warn!(
            "partition {} checkpoint does not match the assignment (doc_start {} vs {}, \
             K {} vs {}, {} docs vs {}); starting fresh",
            assign.partition,
            ckpt.doc_start,
            assign.doc_start,
            ckpt.inner.num_topics,
            knobs.num_topics,
            ckpt.inner.assignments.len(),
            end - start
        );
        return None;
    }
    for (i, doc) in corpus.docs[start..end].iter().enumerate() {
        if ckpt.inner.assignments[i].len() != doc.tokens.len() {
            log_warn!(
                "partition {} checkpoint doc {i} length mismatch; starting fresh",
                assign.partition
            );
            return None;
        }
    }
    let bytes: u64 = ckpt.inner.assignments.iter().map(|d| d.len() as u64 * 4).sum();
    Some((ckpt, bytes))
}

/// Register (or zombie-re-register) with `token`. `Ok(None)` means the
/// run is already complete.
fn register(ctrl: &CtrlChannel, token: u64) -> Result<Option<JobSpec>> {
    loop {
        match ctrl.call_timeout(&CtrlRequest::Register { token }, REGISTER_TIMEOUT)? {
            CtrlResponse::Job(spec) => return Ok(Some(*spec)),
            CtrlResponse::Wait { millis } => {
                std::thread::sleep(Duration::from_millis(millis).min(MAX_WAIT));
            }
            CtrlResponse::Done => return Ok(None),
            CtrlResponse::Error(e) => return Err(Error::Config(e)),
            other => {
                return Err(Error::Decode(format!("unexpected register reply {other:?}")))
            }
        }
    }
}

/// Join the coordinator at `opts.join` and work until the run completes
/// (or the worker drains, or the crash hook fires). Blocks for the life
/// of the membership.
pub fn run_worker(opts: WorkerOptions) -> Result<WorkerSummary> {
    let ctrl = CtrlChannel::connect(&opts.join)?;
    // Idempotency token for registration: entropy-seeded like the PS
    // client's matrix ids, so a retried Register (lost reply) re-reads
    // its assignment instead of being seated twice — and a reaped
    // worker re-registers with the *same* token to reclaim its old ring
    // position (zombie rejoin).
    let token = {
        let now = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap_or_default();
        (now.as_nanos() as u64) ^ ((std::process::id() as u64) << 32)
    };
    let Some(spec) = register(&ctrl, token)? else {
        log_info!("training already complete; nothing to do");
        return Ok(WorkerSummary {
            worker_id: 0,
            sweeps: 0,
            crashed: false,
            drained: false,
            warm_bytes: 0,
        });
    };
    let worker_id = Arc::new(AtomicU64::new(spec.worker));
    log_info!(
        "joined as worker {}: {} partitions, epoch {}",
        spec.worker,
        spec.parts.len(),
        spec.epoch
    );

    // Heartbeats start before the (possibly slow) corpus load so the
    // coordinator never mistakes setup time for death. The id cell
    // tracks re-registrations.
    let stop = Arc::new(AtomicBool::new(false));
    let hb = {
        let ctrl = ctrl.clone();
        let stop = Arc::clone(&stop);
        let wid = Arc::clone(&worker_id);
        let period = Duration::from_millis(spec.knobs.heartbeat_ms.max(10));
        std::thread::Builder::new()
            .name("glint-worker-heartbeat".into())
            .spawn(move || {
                while !stop.load(Ordering::SeqCst) {
                    let w = wid.load(Ordering::SeqCst);
                    let _ = ctrl.call(&CtrlRequest::Heartbeat { worker: w });
                    std::thread::sleep(period);
                }
            })
            .expect("spawn heartbeat thread")
    };

    // Every exit path below must stop the heartbeat thread — a leaked
    // heartbeat would keep a failed worker "alive" forever and wedge
    // the Ready barrier.
    let result = match &opts.corpus {
        Some(c) => drive(&ctrl, spec, c, &opts, token, &worker_id),
        None => match load_corpus(&spec.corpus) {
            Ok(c) => drive(&ctrl, spec, &c, &opts, token, &worker_id),
            Err(e) => Err(e),
        },
    };
    stop.store(true, Ordering::SeqCst);
    let _ = hb.join();
    result
}

/// The worker's main loop: rebuild (or diff) per job spec, then
/// poll/sweep/report until done, drained, crashed, or re-specced.
fn drive(
    ctrl: &CtrlChannel,
    mut spec: JobSpec,
    corpus: &Corpus,
    opts: &WorkerOptions,
    token: u64,
    worker_id: &AtomicU64,
) -> Result<WorkerSummary> {
    let mut sweeps = 0u32;
    let mut warm_bytes = 0u64;
    let mut drained = false;
    let mut drain_requested = false;
    let mut job: Option<ActiveJob> = None;
    // Snapshot mode pulls the frozen model once per (epoch,
    // iteration) and sweeps every held partition against it.
    let mut snap_cache: Option<(u32, u32, TopicModel)> = None;
    'job: loop {
        let wid = spec.worker;
        worker_id.store(wid, Ordering::SeqCst);
        // Same (epoch, matrix): an incremental respec — keep held
        // runners warm, build only what's new. Otherwise a rollback or
        // rejoin: rebuild everything against the fresh count table.
        match job.as_mut() {
            Some(j) if j.epoch == spec.epoch && j.matrix_id == spec.matrix_id => {
                warm_bytes += j.diff(&spec, corpus)?;
            }
            _ => {
                let (built, bytes) = ActiveJob::build(&spec, corpus)?;
                warm_bytes += bytes;
                job = Some(built);
            }
        }
        let j = job.as_mut().expect("job just built");
        match ctrl.call(&CtrlRequest::Ready {
            worker: wid,
            epoch: spec.epoch,
            parts: j.ready_items(),
        })? {
            CtrlResponse::Ack => {}
            CtrlResponse::Job(new) => {
                spec = *new;
                continue 'job;
            }
            CtrlResponse::Done => break 'job,
            CtrlResponse::Error(_) => match register(ctrl, token)? {
                Some(new) => {
                    spec = new;
                    drain_requested = false;
                    continue 'job;
                }
                None => break 'job,
            },
            other => return Err(Error::Decode(format!("unexpected ready reply {other:?}"))),
        }

        loop {
            let j = job.as_mut().expect("job active");
            match ctrl.call(&CtrlRequest::Poll { worker: wid })? {
                CtrlResponse::Run { partition, iteration, evaluate } => {
                    let Some(st) = j.parts.get_mut(&partition) else {
                        return Err(Error::Decode(format!(
                            "coordinator ran partition {partition} this worker does not hold"
                        )));
                    };
                    if opts.sweep_delay_ms > 0 {
                        std::thread::sleep(Duration::from_millis(opts.sweep_delay_ms));
                    }
                    let sw = Stopwatch::new();
                    // Snapshot mode: pull the frozen model (once per
                    // iteration — all held partitions sample against
                    // the same snapshot, and the coordinator credits
                    // the fetch to all of them) and hold at the fetch
                    // barrier before sampling against it.
                    let snapshot = if spec.knobs.snapshot {
                        let cached = matches!(&snap_cache,
                            Some((e, i, _)) if *e == spec.epoch && *i == iteration);
                        if !cached {
                            let model = pull_full_model(
                                &j.n_wk,
                                corpus.vocab_size,
                                j.scfg.sampler.pipeline_depth,
                                j.hyper,
                            )?;
                            snap_cache = Some((spec.epoch, iteration, model));
                        }
                        loop {
                            match ctrl.call(&CtrlRequest::Fetched {
                                worker: wid,
                                epoch: spec.epoch,
                                partition,
                                iteration,
                            })? {
                                CtrlResponse::Ack => break,
                                CtrlResponse::Wait { millis } => {
                                    std::thread::sleep(
                                        Duration::from_millis(millis).min(MAX_WAIT),
                                    );
                                }
                                CtrlResponse::Job(new) => {
                                    spec = *new;
                                    continue 'job;
                                }
                                CtrlResponse::Done => break 'job,
                                CtrlResponse::Error(_) => match register(ctrl, token)? {
                                    Some(new) => {
                                        spec = new;
                                        drain_requested = false;
                                        continue 'job;
                                    }
                                    None => break 'job,
                                },
                                other => {
                                    return Err(Error::Decode(format!(
                                        "unexpected fetch reply {other:?}"
                                    )))
                                }
                            }
                        }
                        true
                    } else {
                        false
                    };
                    // Per-iteration RNG: derived from (seed, epoch,
                    // iteration, partition), never from which worker
                    // happens to hold the partition.
                    let epoch_salt = (spec.epoch as u64) << 32;
                    st.runner.reseed(partition_rng(
                        spec.knobs.seed ^ epoch_salt ^ iter_mix(iteration),
                        partition as usize,
                        st.assign.doc_start,
                    ));
                    let stats = if snapshot {
                        let (_, _, model) = snap_cache.as_ref().expect("snapshot cached");
                        st.runner.sweep_snapshot(&j.scfg, model, &j.n_wk)?
                    } else {
                        let nk = j.n_wk.pull_col_sums()?;
                        st.runner.sweep(&j.scfg, nk, &j.n_wk)?
                    };
                    // The flush barrier: every push of this sweep has
                    // landed (exactly-once) before we evaluate,
                    // checkpoint or report.
                    j.client.flush()?;
                    sweeps += 1;
                    st.done = iteration;
                    if opts.crash_at_iteration.is_some_and(|at| iteration >= at) {
                        log_warn!("worker {wid}: simulated crash mid-iteration {iteration}");
                        return Ok(WorkerSummary {
                            worker_id: wid,
                            sweeps,
                            crashed: true,
                            drained: false,
                            warm_bytes,
                        });
                    }
                    let mut report = SweepReport {
                        tokens: stats.tokens,
                        changed: stats.changed,
                        sparse_batches: stats.sparse_batches,
                        seconds: sw.secs(),
                        alias_build_secs: stats.alias_build_secs,
                        block_wait_secs: stats.block_wait_secs,
                        ..SweepReport::default()
                    };
                    if evaluate {
                        let model = pull_full_model(
                            &j.n_wk,
                            corpus.vocab_size,
                            j.scfg.sampler.pipeline_depth,
                            j.hyper,
                        )?;
                        let (ll, n) = st.runner.log_likelihood(&model, corpus);
                        report.evaluated = true;
                        report.log_likelihood = ll;
                        report.ll_tokens = n;
                    }
                    if !spec.knobs.checkpoint_dir.is_empty() {
                        let ckpt = PartitionCheckpoint {
                            partition,
                            doc_start: st.assign.doc_start,
                            inner: Checkpoint {
                                iteration,
                                num_topics: spec.knobs.num_topics,
                                assignments: st.runner.assignments().to_vec(),
                            },
                        };
                        ckpt.save(
                            std::path::Path::new(&spec.knobs.checkpoint_dir),
                            spec.knobs.keep_checkpoints as usize,
                        )?;
                    }
                    match ctrl.call(&CtrlRequest::Report {
                        worker: wid,
                        epoch: spec.epoch,
                        partition,
                        iteration,
                        stats: report,
                    })? {
                        CtrlResponse::Ack => {}
                        CtrlResponse::Job(new) => {
                            spec = *new;
                            continue 'job;
                        }
                        CtrlResponse::Done => break 'job,
                        CtrlResponse::Error(_) => match register(ctrl, token)? {
                            Some(new) => {
                                spec = new;
                                drain_requested = false;
                                continue 'job;
                            }
                            None => break 'job,
                        },
                        other => {
                            return Err(Error::Decode(format!(
                                "unexpected report reply {other:?}"
                            )))
                        }
                    }
                    // Planned drain: ask once, after the configured
                    // number of sweeps; then keep polling so transfers
                    // drain out at boundaries.
                    if !drain_requested && opts.drain_after.is_some_and(|n| sweeps >= n) {
                        drain_requested = true;
                        match ctrl.call(&CtrlRequest::Drain { worker: wid })? {
                            CtrlResponse::Ack => {
                                log_info!("worker {wid} draining; finishing hand-offs");
                            }
                            CtrlResponse::Drained => {
                                drained = true;
                                break 'job;
                            }
                            CtrlResponse::Job(new) => {
                                spec = *new;
                                continue 'job;
                            }
                            CtrlResponse::Done => break 'job,
                            // "unknown worker": already reaped; we
                            // wanted out anyway.
                            CtrlResponse::Error(_) => break 'job,
                            other => {
                                return Err(Error::Decode(format!(
                                    "unexpected drain reply {other:?}"
                                )))
                            }
                        }
                    }
                }
                CtrlResponse::Transfer { parts } => {
                    // Warm transfer out: the checkpoints written before
                    // our last reports are the handoff payload; just
                    // drop the runners and keep polling.
                    for p in &parts {
                        j.parts.remove(p);
                    }
                    log_info!("worker {wid} released partitions {parts:?} (warm transfer)");
                }
                CtrlResponse::Wait { millis } => {
                    std::thread::sleep(Duration::from_millis(millis).min(MAX_WAIT));
                }
                CtrlResponse::Job(new) => {
                    spec = *new;
                    continue 'job;
                }
                CtrlResponse::Drained => {
                    drained = true;
                    break 'job;
                }
                CtrlResponse::Done => break 'job,
                CtrlResponse::Error(_) => {
                    // Presumed dead (e.g. a long stall): the zombie
                    // warm-rejoin path. Re-register with the same token;
                    // the ring hands back whatever is still unowned, and
                    // our checkpoints make the pickup warm.
                    log_warn!("worker {wid} evicted; re-registering warm with same token");
                    match register(ctrl, token)? {
                        Some(new) => {
                            spec = new;
                            drain_requested = false;
                            continue 'job;
                        }
                        None => break 'job,
                    }
                }
                CtrlResponse::Ack => {
                    return Err(Error::Decode("unexpected bare ack to poll".into()))
                }
            }
        }
    }
    let wid = worker_id.load(Ordering::SeqCst);
    if !drained {
        let _ = ctrl.call(&CtrlRequest::Leave { worker: wid });
    }
    log_info!(
        "worker {wid} {} after {sweeps} sweeps",
        if drained { "drained" } else { "done" }
    );
    Ok(WorkerSummary { worker_id: wid, sweeps, crashed: false, drained, warm_bytes })
}
