//! A remote cluster worker: joins a coordinator, receives a partition
//! assignment, and drives the shared per-partition sweep
//! ([`crate::lda::sweep::SweepRunner`]) against the parameter-server
//! shards — the same kernel the in-process trainer's worker threads run,
//! so the two deployment modes are numerically equivalent.
//!
//! Lifecycle (all worker-initiated; see [`crate::cluster::protocol`]):
//!
//! 1. `Register` → a [`JobSpec`]: partition range, epoch, matrix id,
//!    shard addresses, corpus spec, knobs.
//! 2. Rebuild partition state — from the partition's latest valid
//!    checkpoint when one exists, else a fresh seeded random
//!    initialization — push its counts into the epoch's table, `Ready`.
//! 3. `Poll` → `Run`: pull the topic totals (server-side column sums),
//!    sweep, flush, optionally evaluate, **checkpoint, then report**.
//!    The checkpoint-before-report order is what makes the
//!    coordinator's recovery arithmetic sound.
//! 4. On `Job` replies (any time): a rollback happened — rebuild from
//!    checkpoint under the new epoch and matrix id. On `Done`: `Leave`.
//!
//! A heartbeat thread pings the coordinator every
//! [`crate::cluster::protocol::SweepKnobs::heartbeat_ms`] for the life
//! of the process, so a long sweep or corpus load is never mistaken for
//! a death.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::cluster::protocol::{CorpusSpec, CtrlRequest, CtrlResponse, JobSpec, SweepReport};
use crate::corpus::dataset::Corpus;
use crate::corpus::synth::{generate, SynthConfig};
use crate::lda::checkpoint::{Checkpoint, PartitionCheckpoint};
use crate::lda::hyper::LdaHyper;
use crate::lda::sweep::{partition_rng, pull_full_model, SweepConfig, SweepRunner};
use crate::net::tcp::{resolve_addrs, TcpTransport};
use crate::net::{Endpoint, Transport};
use crate::ps::client::{BigMatrix, PsClient};
use crate::ps::config::{PsConfig, TransportMode};
use crate::util::error::{Error, Result};
use crate::util::timer::Stopwatch;
use crate::{log_info, log_warn};

/// Per-attempt control round-trip timeout.
const CTRL_TIMEOUT: Duration = Duration::from_secs(2);
/// Control-plane retries before giving the coordinator up for dead.
const CTRL_RETRIES: u32 = 5;
/// Ceiling on honored `Wait` back-off (the coordinator's suggestions
/// are already small; this bounds a corrupt value).
const MAX_WAIT: Duration = Duration::from_secs(2);

/// How a worker process is launched.
#[derive(Default)]
pub struct WorkerOptions {
    /// Coordinator control address (`host:port`).
    pub join: String,
    /// Pre-loaded corpus (in-process workers, or `work --corpus`); when
    /// `None` the corpus comes from the job's [`CorpusSpec`].
    pub corpus: Option<Corpus>,
    /// Fault-injection hook for tests and demos: after *sweeping* this
    /// iteration (pushes flushed, nothing checkpointed or reported —
    /// i.e. mid-iteration from the control plane's view), the worker
    /// vanishes without a goodbye, exactly like a crashed process.
    pub crash_at_iteration: Option<u32>,
}

/// What a worker did before exiting.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WorkerSummary {
    /// Coordinator-assigned id (0 if the run was already over at
    /// registration time).
    pub worker_id: u64,
    /// Sweeps completed (across epochs).
    pub sweeps: u32,
    /// True when the crash hook fired.
    pub crashed: bool,
}

/// Retrying request/reply channel to the coordinator. Cloning shares
/// the underlying multiplexed connection, so the heartbeat thread rides
/// the same socket as the main loop.
#[derive(Clone)]
struct CtrlChannel {
    ep: Endpoint,
}

impl CtrlChannel {
    fn connect(addr: &str) -> Result<CtrlChannel> {
        let resolved = resolve_addrs(&[addr.to_string()])?;
        let transport = TcpTransport::connect(&resolved);
        Ok(CtrlChannel { ep: transport.endpoint(0) })
    }

    fn call(&self, req: &CtrlRequest) -> Result<CtrlResponse> {
        let payload = req.encode();
        for attempt in 0..CTRL_RETRIES {
            match self.ep.request(payload.clone(), CTRL_TIMEOUT) {
                Ok(bytes) => return CtrlResponse::decode(&bytes),
                Err(()) => {
                    std::thread::sleep(Duration::from_millis(50 << attempt.min(4)));
                }
            }
        }
        Err(Error::PsTimeout { op: "control", shard: 0, attempts: CTRL_RETRIES })
    }
}

/// Everything bound to one `JobSpec`: the PS connection, the epoch's
/// count table, and the rebuilt partition state.
struct ActiveJob {
    /// Keeps the shard connections alive for `client`/`n_wk`.
    _transport: Arc<dyn Transport>,
    client: PsClient,
    n_wk: BigMatrix<i64>,
    runner: SweepRunner,
    scfg: SweepConfig,
    hyper: LdaHyper,
    /// Iteration the restored state corresponds to (0 = fresh).
    resumed: u32,
}

/// Load the corpus a job names (when the caller didn't supply one).
pub fn load_corpus(spec: &CorpusSpec) -> Result<Corpus> {
    match spec {
        CorpusSpec::File(path) => {
            log_info!("loading corpus from {path}");
            Corpus::load(std::path::Path::new(path))
        }
        CorpusSpec::Synth {
            num_docs,
            vocab_size,
            num_topics,
            avg_doc_len,
            zipf_exponent,
            seed,
        } => {
            log_info!("generating synthetic corpus ({num_docs} docs, V={vocab_size})");
            Ok(generate(&SynthConfig {
                num_docs: *num_docs as usize,
                vocab_size: *vocab_size,
                num_topics: *num_topics as usize,
                avg_doc_len: *avg_doc_len,
                zipf_exponent: *zipf_exponent,
                seed: *seed,
                ..SynthConfig::default()
            }))
        }
        CorpusSpec::Provided => Err(Error::Config(
            "job says the corpus is provided out-of-band; pass --corpus to this worker".into(),
        )),
    }
}

/// Rebuild all state for `spec`: connect to the shards, attach the
/// epoch's table, restore the partition (checkpoint or fresh), push its
/// counts and flush.
fn setup_job(spec: &JobSpec, corpus: &Corpus) -> Result<ActiveJob> {
    let knobs = &spec.knobs;
    let hyper = LdaHyper { alpha: knobs.alpha, beta: knobs.beta };
    hyper.validate()?;
    let (start, end) = (spec.doc_start as usize, spec.doc_end as usize);
    if start > end || end > corpus.num_docs() {
        return Err(Error::Config(format!(
            "partition {}..{} exceeds the {}-doc corpus (wrong corpus?)",
            start,
            end,
            corpus.num_docs()
        )));
    }

    let resolved = resolve_addrs(&spec.shard_addrs)?;
    let mut ps_cfg = PsConfig::deployment(
        resolved.len(),
        knobs.scheme,
        TransportMode::Connect(spec.shard_addrs.clone()),
        knobs.sampler.pipeline_depth,
    );
    // Replica failover: pushes outlive a dying primary by routing to
    // its (promoted) backup.
    ps_cfg.backups = spec.backup_addrs.clone();
    let transport: Arc<dyn Transport> = Arc::new(TcpTransport::connect(&resolved));
    let client = PsClient::connect(&*transport, ps_cfg);
    client.validate_deployment()?;
    let n_wk: BigMatrix<i64> = client.attach_matrix(
        spec.matrix_id,
        corpus.vocab_size as u64,
        knobs.num_topics,
        knobs.wt_layout,
    )?;

    let scfg = SweepConfig {
        num_topics: knobs.num_topics,
        sampler: knobs.sampler,
        hyper,
        vocab_size: corpus.vocab_size,
    };

    // Epoch 0's fresh initialization uses the bare cluster seed, so it
    // is the exact stream the in-process trainer would hand partition
    // `p`; later epochs and checkpoint resumes mix in distinguishers
    // (mirroring Trainer::restore's `^ 0xc4`) so no epoch replays
    // another's proposals.
    let epoch_salt = (spec.epoch as u64) << 32;
    let range = start..end;
    let (runner, resumed) = match load_partition_checkpoint(spec, corpus) {
        Some(ckpt) => {
            let rng = partition_rng(
                knobs.seed ^ 0xc4 ^ epoch_salt,
                spec.partition as usize,
                spec.doc_start,
            );
            let iteration = ckpt.inner.iteration;
            let assignments = std::cell::RefCell::new(ckpt.inner.assignments);
            let next = std::cell::Cell::new(0usize);
            let runner = SweepRunner::build(corpus, range, rng, |_, _| {
                let i = next.get();
                next.set(i + 1);
                assignments.borrow_mut()[i].clone()
            });
            log_info!(
                "partition {} restored from checkpoint at iteration {iteration}",
                spec.partition
            );
            (runner, iteration)
        }
        None => {
            let rng = partition_rng(
                knobs.seed ^ epoch_salt,
                spec.partition as usize,
                spec.doc_start,
            );
            let k = knobs.num_topics;
            (SweepRunner::build_random(corpus, range, k, rng), 0)
        }
    };

    runner.push_counts(&scfg, &n_wk);
    client.flush()?;
    Ok(ActiveJob { _transport: transport, client, n_wk, runner, scfg, hyper, resumed })
}

/// The partition's latest valid checkpoint, if checkpointing is on and
/// a compatible one exists. Shape mismatches (different corpus, topic
/// count, or partition bounds) are treated as "no checkpoint" — a fresh
/// start is always a safe recovery.
fn load_partition_checkpoint(spec: &JobSpec, corpus: &Corpus) -> Option<PartitionCheckpoint> {
    if spec.knobs.checkpoint_dir.is_empty() {
        return None;
    }
    let dir = std::path::Path::new(&spec.knobs.checkpoint_dir);
    let ckpt = match PartitionCheckpoint::load_latest(dir, spec.partition) {
        Ok(found) => found?,
        Err(e) => {
            log_warn!("cannot scan checkpoints in {dir:?}: {e}");
            return None;
        }
    };
    let (start, end) = (spec.doc_start as usize, spec.doc_end as usize);
    if ckpt.doc_start != spec.doc_start
        || ckpt.inner.num_topics != spec.knobs.num_topics
        || ckpt.inner.assignments.len() != end - start
    {
        log_warn!(
            "partition {} checkpoint does not match the assignment (doc_start {} vs {}, \
             K {} vs {}, {} docs vs {}); starting fresh",
            spec.partition,
            ckpt.doc_start,
            spec.doc_start,
            ckpt.inner.num_topics,
            spec.knobs.num_topics,
            ckpt.inner.assignments.len(),
            end - start
        );
        return None;
    }
    for (i, doc) in corpus.docs[start..end].iter().enumerate() {
        if ckpt.inner.assignments[i].len() != doc.tokens.len() {
            log_warn!(
                "partition {} checkpoint doc {i} length mismatch; starting fresh",
                spec.partition
            );
            return None;
        }
    }
    Some(ckpt)
}

/// Join the coordinator at `opts.join` and work until the run
/// completes (or the crash hook fires). Blocks for the life of the
/// membership.
pub fn run_worker(opts: WorkerOptions) -> Result<WorkerSummary> {
    let ctrl = CtrlChannel::connect(&opts.join)?;
    // Idempotency token for registration: entropy-seeded like the PS
    // client's matrix ids, so a retried Register (lost reply) re-reads
    // its assignment instead of being seated twice.
    let token = {
        let now = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap_or_default();
        (now.as_nanos() as u64) ^ ((std::process::id() as u64) << 32)
    };
    // Register, waiting out a fully staffed cluster (a failure may free
    // a partition for us at any time).
    let mut spec: JobSpec = loop {
        match ctrl.call(&CtrlRequest::Register { token })? {
            CtrlResponse::Job(spec) => break *spec,
            CtrlResponse::Wait { millis } => {
                std::thread::sleep(Duration::from_millis(millis).min(MAX_WAIT));
            }
            CtrlResponse::Done => {
                log_info!("training already complete; nothing to do");
                return Ok(WorkerSummary { worker_id: 0, sweeps: 0, crashed: false });
            }
            CtrlResponse::Error(e) => return Err(Error::Config(e)),
            other => {
                return Err(Error::Decode(format!("unexpected register reply {other:?}")))
            }
        }
    };
    let worker_id = spec.worker;
    log_info!(
        "joined as worker {worker_id}: partition {} (docs {}..{}), epoch {}",
        spec.partition,
        spec.doc_start,
        spec.doc_end,
        spec.epoch
    );

    // Heartbeats start before the (possibly slow) corpus load so the
    // coordinator never mistakes setup time for death.
    let stop = Arc::new(AtomicBool::new(false));
    let hb = {
        let ctrl = ctrl.clone();
        let stop = Arc::clone(&stop);
        let period = Duration::from_millis(spec.knobs.heartbeat_ms.max(10));
        std::thread::Builder::new()
            .name("glint-worker-heartbeat".into())
            .spawn(move || {
                while !stop.load(Ordering::SeqCst) {
                    let _ = ctrl.call(&CtrlRequest::Heartbeat { worker: worker_id });
                    std::thread::sleep(period);
                }
            })
            .expect("spawn heartbeat thread")
    };

    // Every exit path below must stop the heartbeat thread — a leaked
    // heartbeat would keep a failed worker "alive" forever and wedge
    // the Ready barrier.
    let result = match &opts.corpus {
        Some(c) => drive(&ctrl, spec, c, &opts, worker_id),
        None => match load_corpus(&spec.corpus) {
            Ok(c) => drive(&ctrl, spec, &c, &opts, worker_id),
            Err(e) => Err(e),
        },
    };
    stop.store(true, Ordering::SeqCst);
    let _ = hb.join();
    result
}

/// The worker's main loop: rebuild per job spec, then poll/sweep/report
/// until done (or crashed, or re-specced into a new epoch).
fn drive(
    ctrl: &CtrlChannel,
    mut spec: JobSpec,
    corpus: &Corpus,
    opts: &WorkerOptions,
    worker_id: u64,
) -> Result<WorkerSummary> {
    let mut sweeps = 0u32;
    'job: loop {
        let mut job = setup_job(&spec, corpus)?;
        match ctrl.call(&CtrlRequest::Ready {
            worker: worker_id,
            epoch: spec.epoch,
            iteration: job.resumed,
        })? {
            CtrlResponse::Ack => {}
            CtrlResponse::Job(new) => {
                spec = *new;
                continue 'job;
            }
            CtrlResponse::Done => break 'job,
            other => return Err(Error::Decode(format!("unexpected ready reply {other:?}"))),
        }

        loop {
            match ctrl.call(&CtrlRequest::Poll { worker: worker_id })? {
                CtrlResponse::Run { iteration, evaluate } => {
                    let sw = Stopwatch::new();
                    let nk = job.n_wk.pull_col_sums()?;
                    let stats = job.runner.sweep(&job.scfg, nk, &job.n_wk)?;
                    // The flush barrier: every push of this sweep has
                    // landed (exactly-once) before we evaluate,
                    // checkpoint or report.
                    job.client.flush()?;
                    sweeps += 1;
                    if opts.crash_at_iteration.is_some_and(|at| iteration >= at) {
                        log_warn!(
                            "worker {worker_id}: simulated crash mid-iteration {iteration}"
                        );
                        return Ok(WorkerSummary { worker_id, sweeps, crashed: true });
                    }
                    let mut report = SweepReport {
                        tokens: stats.tokens,
                        changed: stats.changed,
                        sparse_batches: stats.sparse_batches,
                        seconds: sw.secs(),
                        alias_build_secs: stats.alias_build_secs,
                        block_wait_secs: stats.block_wait_secs,
                        ..SweepReport::default()
                    };
                    if evaluate {
                        let model = pull_full_model(
                            &job.n_wk,
                            corpus.vocab_size,
                            job.scfg.sampler.pipeline_depth,
                            job.hyper,
                        )?;
                        let (ll, n) = job.runner.log_likelihood(&model, corpus);
                        report.evaluated = true;
                        report.log_likelihood = ll;
                        report.ll_tokens = n;
                    }
                    if !spec.knobs.checkpoint_dir.is_empty() {
                        let ckpt = PartitionCheckpoint {
                            partition: spec.partition,
                            doc_start: spec.doc_start,
                            inner: Checkpoint {
                                iteration,
                                num_topics: spec.knobs.num_topics,
                                assignments: job.runner.assignments().to_vec(),
                            },
                        };
                        ckpt.save(
                            std::path::Path::new(&spec.knobs.checkpoint_dir),
                            spec.knobs.keep_checkpoints as usize,
                        )?;
                    }
                    match ctrl.call(&CtrlRequest::Report {
                        worker: worker_id,
                        epoch: spec.epoch,
                        iteration,
                        stats: report,
                    })? {
                        CtrlResponse::Ack => {}
                        CtrlResponse::Job(new) => {
                            spec = *new;
                            continue 'job;
                        }
                        CtrlResponse::Done => break 'job,
                        other => {
                            return Err(Error::Decode(format!(
                                "unexpected report reply {other:?}"
                            )))
                        }
                    }
                }
                CtrlResponse::Wait { millis } => {
                    std::thread::sleep(Duration::from_millis(millis).min(MAX_WAIT));
                }
                CtrlResponse::Job(new) => {
                    spec = *new;
                    continue 'job;
                }
                CtrlResponse::Done => break 'job,
                CtrlResponse::Error(e) => {
                    // Typically "unknown worker": we were presumed dead
                    // (e.g. a long stall). Our partition may already be
                    // reassigned; restart the process to rejoin cleanly.
                    return Err(Error::Config(format!("evicted by coordinator: {e}")));
                }
                CtrlResponse::Ack => {
                    return Err(Error::Decode("unexpected bare ack to poll".into()))
                }
            }
        }
    }
    let _ = ctrl.call(&CtrlRequest::Leave { worker: worker_id });
    log_info!("worker {worker_id} done after {sweeps} sweeps");
    Ok(WorkerSummary { worker_id, sweeps, crashed: false })
}
