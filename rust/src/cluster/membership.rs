//! Elastic membership: the pure state machine behind the coordinator's
//! control plane.
//!
//! The coordinator used to keep a fixed `Vec<Slot>` sized at
//! `--workers`: a freed partition parked until a standby re-registered,
//! and every ownership change rolled the epoch. This module replaces
//! that table with a [`Membership`] manager that supports two
//! disciplines behind one API:
//!
//! - **static** (`elastic = false`, the default): the historical
//!   behavior. The first `workers` registrants fill partitions in index
//!   order, later registrants are *parked* (the coordinator holds their
//!   envelope and replies when a partition frees — no re-register
//!   polling), and recovery goes through epoch rolls.
//! - **elastic** (`elastic = true`, requires checkpointing): members
//!   live on a murmur3 consistent-hash [`Ring`](crate::cluster::ring)
//!   keyed by their registration token. Joins, planned drains, reaps
//!   and straggler shedding recompute the target assignment, and
//!   partitions move between live members via *warm transfers* — the
//!   donor releases at a sweep boundary, the recipient resumes from the
//!   partition checkpoint with its counts already in the table (no
//!   re-push, no epoch roll).
//!
//! Everything here is pure state: no sockets, no clocks (timestamps are
//! passed in as `u64` milliseconds), no filesystem. That is what lets
//! `tests/model.rs` drive the *real* membership logic under
//! `util/sync_shim` schedules, and the coordinator stay a thin
//! network/parameter-server shell around it.
//!
//! # Warm-transfer safety rules
//!
//! A partition may change owners mid-epoch only when **all** hold:
//!
//! 1. `issued == completed` — the donor is at a sweep boundary, not
//!    mid-flight (transfers are delivered as poll replies, so the donor
//!    observes the release before it could start another sweep).
//! 2. `warm` — the partition's counts are settled in the *current*
//!    epoch's table (its owner pushed and confirmed via `Ready`), so
//!    the recipient must not push again (`PartAssign::push == false`).
//! 3. The recipient may not be issued a sweep until it confirms the
//!    checkpoint loaded at exactly the table's iteration (`confirmed`);
//!    a failed or mismatched load falls back to an epoch roll, which
//!    heals by rebuilding the table from everyone's checkpoints.
//!
//! Epoch rolls realize all pending moves for free (everyone re-pushes
//! into a fresh table), so `rolled()` applies `target` directly.

use std::collections::HashMap;
use std::ops::Range;

use crate::cluster::ring::Ring;

/// Default virtual nodes per ring member.
pub const DEFAULT_VNODES: u32 = 64;

/// Membership configuration, derived from `TrainConfig` by the
/// coordinator.
#[derive(Debug, Clone)]
pub struct MembershipCfg {
    /// Elastic (ring) discipline instead of the static partition table.
    pub elastic: bool,
    /// Static-mode seat count (and sizing hint for partitioning).
    pub workers: usize,
    /// Virtual nodes per member at full weight.
    pub vnodes: u32,
    /// Total sweep iterations for the run.
    pub iterations: u32,
    /// Bounded-staleness window (0 = lockstep).
    pub max_staleness: u32,
    /// Partition checkpoints are enabled (required for warm transfers).
    pub checkpointing: bool,
    /// Straggler shedding: shed when a partition lags the staleness
    /// window by this factor. `<= 0` disables shedding.
    pub shed_factor: f64,
    /// How long a lagging partition must make no progress before it is
    /// considered stalled (also the shed cool-down).
    pub shed_stall_ms: u64,
}

impl MembershipCfg {
    fn shed_threshold(&self) -> u32 {
        let scaled = (self.max_staleness as f64 * self.shed_factor).ceil() as u32;
        scaled.max(1).min(self.max_staleness + 1)
    }
}

/// One corpus partition's control state.
#[derive(Debug, Clone)]
struct Part {
    range: Range<usize>,
    /// Live owner (a member id), if any.
    owner: Option<u64>,
    /// Ring-desired owner; `Some(owner)` when no move is pending.
    target: Option<u64>,
    /// Counts for the current epoch are in the table.
    ready: bool,
    /// Counts are settled in the current table — the next owner resumes
    /// warm (`push = false`). Cleared by epoch rolls.
    warm: bool,
    /// The current owner confirmed (via `Ready`) that its runner is
    /// built; sweeps are only issued for confirmed partitions.
    confirmed: bool,
    /// Lost its owner to a failure; counted as a reassignment when
    /// re-seated.
    orphaned: bool,
    completed: u32,
    checkpointed: u32,
    /// Highest iteration handed out via `Run`.
    issued: u32,
    /// Highest iteration whose model snapshot the owner has pulled
    /// (snapshot-mode fetch barrier).
    fetched: u32,
    /// Last time this partition completed an iteration (or was seated).
    last_progress_ms: u64,
}

#[derive(Debug, Clone)]
struct MemberState {
    token: u64,
    last_seen_ms: u64,
    draining: bool,
    /// The member's delivered job spec is stale (seat, transfer-in,
    /// epoch roll); next poll replies with a fresh spec.
    needs_spec: bool,
}

/// Outcome of a registration attempt.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Admission {
    /// Seated as a member; deliver a job spec.
    Seated { worker: u64 },
    /// Idempotent retry of a live registration.
    Existing { worker: u64 },
    /// No partition free (static mode): hold the envelope, reply when
    /// one frees.
    Parked,
    /// The run is already complete.
    Finished,
}

/// Reply to a worker poll.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PollVerdict {
    /// Assignment or epoch changed: deliver a fresh job spec.
    Respec,
    /// Release these partitions (warm transfer out); keep polling.
    Transfer(Vec<u32>),
    /// Sweep `part` at `iteration`.
    Run { part: u32, iteration: u32 },
    /// Nothing to do yet.
    Wait,
    /// Planned drain complete: checkpointed, ranges handed back, leave.
    Drained,
    /// Run complete.
    Done,
    /// Not a member (evicted): re-register to rejoin warm.
    Unknown,
}

/// Reply to `Ready` / `Report`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AckVerdict {
    Ok,
    /// Stale epoch: deliver a fresh job spec.
    Respec,
    Unknown,
}

/// Reply to a snapshot-mode `Fetched` notification.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FetchVerdict {
    /// Every participating partition has fetched this iteration: sweep.
    Go,
    /// Barrier not met yet; re-poll.
    Hold,
    /// Stale epoch: go back to the poll loop for a fresh spec.
    Respec,
    Unknown,
}

/// Reply to a `Drain` request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DrainVerdict {
    /// Keep working; partitions will transfer out at sweep boundaries
    /// and a later poll answers `Drained`.
    Draining,
    /// Drain complete immediately (cold drain, or nothing owned).
    Drained,
    Unknown,
}

/// A partition assignment inside a job spec.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PartAssign {
    pub part: u32,
    pub doc_start: usize,
    pub doc_end: usize,
    /// Checkpoint iteration to resume from (0 = none yet).
    pub resume: u32,
    /// Push the partition's counts into the table after building
    /// (`false` for warm handoffs — the counts are already there).
    pub push: bool,
}

/// Straggler-shedding event, for logging.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShedEvent {
    pub worker: u64,
    pub part: u32,
    pub new_weight: u32,
}

/// Observability counters surfaced in the coordinator report.
#[derive(Debug, Clone, Copy, Default)]
pub struct Counters {
    /// Target-assignment recomputations that changed ownership.
    pub rebalances: u64,
    /// Partitions moved between live members (warm transfers + roll
    /// realizations + warm pickups).
    pub moved_partitions: u64,
    /// Planned drains completed.
    pub drain_count: u64,
    /// Failure reassignments (orphaned partition re-seated).
    pub reassignments: u64,
    /// Straggler shed events.
    pub sheds: u64,
}

/// The membership manager. See the module docs for the two disciplines.
#[derive(Debug, Clone)]
pub struct Membership {
    cfg: MembershipCfg,
    parts: Vec<Part>,
    ring: Ring,
    members: HashMap<u64, MemberState>,
    /// Registration token → member id, for idempotent retries and
    /// zombie rejoin.
    tokens: HashMap<u64, u64>,
    /// Parked registration tokens, FIFO (static mode).
    parked: Vec<u64>,
    /// Parked tokens admitted by a capacity change; the coordinator
    /// drains this and replies to the held envelopes.
    admitted: Vec<(u64, u64)>,
    next_member: u64,
    epoch: u32,
    roll_wanted: bool,
    shed_cooldown_until_ms: u64,
    pub counters: Counters,
}

impl Membership {
    pub fn new(cfg: MembershipCfg, ranges: Vec<Range<usize>>) -> Membership {
        let parts = ranges
            .into_iter()
            .map(|range| Part {
                range,
                owner: None,
                target: None,
                ready: false,
                warm: false,
                confirmed: false,
                orphaned: false,
                completed: 0,
                checkpointed: 0,
                issued: 0,
                fetched: 0,
                last_progress_ms: 0,
            })
            .collect();
        Membership {
            cfg,
            parts,
            ring: Ring::new(),
            members: HashMap::new(),
            tokens: HashMap::new(),
            parked: Vec::new(),
            admitted: Vec::new(),
            next_member: 0,
            epoch: 0,
            roll_wanted: false,
            shed_cooldown_until_ms: 0,
            counters: Counters::default(),
        }
    }

    // ------------------------------------------------------------------
    // Read accessors (used by the coordinator shell and the models).

    pub fn epoch(&self) -> u32 {
        self.epoch
    }

    pub fn roll_wanted(&self) -> bool {
        self.roll_wanted
    }

    pub fn parts_len(&self) -> usize {
        self.parts.len()
    }

    pub fn members_len(&self) -> usize {
        self.members.len()
    }

    pub fn parked_len(&self) -> usize {
        self.parked.len()
    }

    pub fn owner(&self, part: u32) -> Option<u64> {
        self.parts.get(part as usize).and_then(|p| p.owner)
    }

    pub fn completed(&self, part: u32) -> u32 {
        self.parts.get(part as usize).map_or(0, |p| p.completed)
    }

    pub fn finished(&self) -> bool {
        self.parts.iter().all(|p| p.completed >= self.cfg.iterations)
    }

    pub fn min_completed(&self) -> u32 {
        self.parts.iter().map(|p| p.completed).min().unwrap_or(0)
    }

    fn all_ready(&self) -> bool {
        self.parts.iter().all(|p| p.ready)
    }

    fn owns_any(&self, worker: u64) -> bool {
        self.parts.iter().any(|p| p.owner == Some(worker))
    }

    /// Sanity invariants, asserted by the model checker after every
    /// step: an owner is always a live member, a live target is always
    /// a live member, and counters never run backwards.
    pub fn check_invariants(&self) {
        for (i, p) in self.parts.iter().enumerate() {
            if let Some(w) = p.owner {
                assert!(self.members.contains_key(&w), "part {i} owned by dead member {w}");
            }
            if let Some(w) = p.target {
                assert!(
                    self.members.contains_key(&w),
                    "part {i} targeted at dead member {w}"
                );
            }
            assert!(p.completed <= p.issued, "part {i} completed past issued");
            assert!(p.checkpointed <= p.completed, "part {i} checkpointed past completed");
        }
        for (&token, &w) in &self.tokens {
            assert!(
                self.members.contains_key(&w),
                "token {token:#x} maps to dead member {w}"
            );
        }
    }

    // ------------------------------------------------------------------
    // Registration and admission.

    /// Register a worker by token. Idempotent; a token whose member was
    /// reaped re-registers fresh (zombie rejoin — it keeps its
    /// checkpoint files because partition identity is stable).
    pub fn register(&mut self, token: u64, now_ms: u64) -> Admission {
        if let Some(&w) = self.tokens.get(&token) {
            if let Some(m) = self.members.get_mut(&w) {
                m.last_seen_ms = now_ms;
                return Admission::Existing { worker: w };
            }
            self.tokens.remove(&token);
        }
        if self.finished() {
            return Admission::Finished;
        }
        if self.cfg.elastic {
            let w = self.seat(token, now_ms);
            self.ring.insert(token, self.cfg.vnodes);
            self.recompute_targets(true, now_ms);
            Admission::Seated { worker: w }
        } else if self.static_seat_available() {
            let w = self.seat(token, now_ms);
            self.static_fill(w, now_ms);
            Admission::Seated { worker: w }
        } else {
            if !self.parked.contains(&token) {
                self.parked.push(token);
            }
            Admission::Parked
        }
    }

    fn seat(&mut self, token: u64, now_ms: u64) -> u64 {
        let w = self.next_member;
        self.next_member += 1;
        self.members.insert(
            w,
            MemberState { token, last_seen_ms: now_ms, draining: false, needs_spec: true },
        );
        self.tokens.insert(token, w);
        w
    }

    fn static_seat_available(&self) -> bool {
        self.members.len() < self.cfg.workers
            && self.parts.iter().any(|p| p.owner.is_none())
    }

    /// Static discipline: hand `worker` unowned partitions in index
    /// order, up to the per-seat quota.
    fn static_fill(&mut self, worker: u64, now_ms: u64) {
        let quota = self.parts.len().div_ceil(self.cfg.workers.max(1));
        let mut taken = 0usize;
        for p in self.parts.iter_mut() {
            if taken >= quota {
                break;
            }
            if p.owner.is_some() {
                continue;
            }
            p.owner = Some(worker);
            p.target = Some(worker);
            p.confirmed = false;
            p.issued = p.completed;
            p.fetched = p.completed;
            p.last_progress_ms = now_ms;
            if p.orphaned {
                p.orphaned = false;
                self.counters.reassignments += 1;
            } else if p.warm {
                // Warm pickup after a static planned drain.
                self.counters.moved_partitions += 1;
            }
            taken += 1;
        }
        if let Some(m) = self.members.get_mut(&worker) {
            m.needs_spec = true;
        }
    }

    /// Admit parked registrants while capacity is free (static mode).
    /// The coordinator drains [`take_admitted`](Self::take_admitted)
    /// and replies to the envelopes it held.
    fn admit_parked(&mut self, now_ms: u64) {
        while !self.parked.is_empty() && self.static_seat_available() {
            let token = self.parked.remove(0);
            let w = self.seat(token, now_ms);
            self.static_fill(w, now_ms);
            self.admitted.push((token, w));
        }
    }

    /// Parked tokens admitted since the last call (token, member id).
    pub fn take_admitted(&mut self) -> Vec<(u64, u64)> {
        std::mem::take(&mut self.admitted)
    }

    /// Parked tokens still waiting (the coordinator answers their
    /// envelopes with `Done` when the run finishes).
    pub fn parked_tokens(&self) -> &[u64] {
        &self.parked
    }

    // ------------------------------------------------------------------
    // Ring target recomputation (elastic mode).

    /// Recompute the desired owner of every partition from the ring and
    /// directly seat unowned partitions (fresh starts and post-roll
    /// orphans need no warm handoff — there is no donor).
    fn recompute_targets(&mut self, count_rebalance: bool, now_ms: u64) {
        if !self.cfg.elastic {
            return;
        }
        let assign = self.ring.assign(self.parts.len() as u32);
        let by_token: HashMap<u64, u64> =
            self.members.iter().map(|(&w, m)| (m.token, w)).collect();
        let mut changed = false;
        for (i, p) in self.parts.iter_mut().enumerate() {
            let tgt = assign.get(i).and_then(|tok| by_token.get(tok)).copied();
            if p.target != tgt {
                p.target = tgt;
                changed = true;
            }
            if p.owner.is_none() {
                if let Some(w) = tgt {
                    p.owner = Some(w);
                    p.confirmed = false;
                    p.issued = p.completed;
                    p.fetched = p.completed;
                    p.last_progress_ms = now_ms;
                    if p.orphaned {
                        p.orphaned = false;
                        self.counters.reassignments += 1;
                    } else if p.warm {
                        self.counters.moved_partitions += 1;
                    }
                    if let Some(m) = self.members.get_mut(&w) {
                        m.needs_spec = true;
                    }
                }
            }
        }
        if changed && count_rebalance {
            self.counters.rebalances += 1;
        }
    }

    // ------------------------------------------------------------------
    // Job specs.

    /// The worker's current assignment, for building a `JobSpec`.
    /// Clears the respec flag.
    pub fn spec_for(&mut self, worker: u64) -> Vec<PartAssign> {
        if let Some(m) = self.members.get_mut(&worker) {
            m.needs_spec = false;
        }
        self.parts
            .iter()
            .enumerate()
            .filter(|(_, p)| p.owner == Some(worker))
            .map(|(i, p)| PartAssign {
                part: i as u32,
                doc_start: p.range.start,
                doc_end: p.range.end,
                resume: p.checkpointed,
                push: !p.warm,
            })
            .collect()
    }

    // ------------------------------------------------------------------
    // Worker messages.

    /// `Ready`: the worker built runners for its spec'd partitions.
    /// `items` is `(part, iteration, loaded)` — the iteration each
    /// runner resumed at, and whether the checkpoint loaded. Warm
    /// handoffs must load at exactly the table's iteration; anything
    /// else forces an epoch roll (the heal-everything path).
    pub fn ready(
        &mut self,
        worker: u64,
        epoch: u32,
        items: &[(u32, u32, bool)],
        now_ms: u64,
    ) -> AckVerdict {
        let Some(m) = self.members.get_mut(&worker) else {
            return AckVerdict::Unknown;
        };
        m.last_seen_ms = now_ms;
        if epoch != self.epoch {
            m.needs_spec = true;
            return AckVerdict::Respec;
        }
        let finished = self.finished();
        for &(part, iteration, loaded) in items {
            let Some(p) = self.parts.get_mut(part as usize) else { continue };
            if p.owner != Some(worker) {
                continue; // moved away since the spec was delivered
            }
            if p.warm {
                // A warm handoff must resume at exactly the table's
                // iteration. `resume == 0` needs no file: the fresh
                // init stream is deterministic per (epoch, partition),
                // so a rebuild reproduces the pushed counts bit-exact.
                let ok = iteration == p.checkpointed && (loaded || iteration == 0);
                if !ok {
                    // The handoff checkpoint is gone or stale; the
                    // table no longer matches any disk state this
                    // worker can produce. Roll the epoch to rebuild.
                    if !finished {
                        self.roll_wanted = true;
                    }
                    continue;
                }
                p.confirmed = true;
            } else {
                // The worker pushed its (checkpoint or fresh) counts
                // before `Ready`; its disk is the authority on where
                // this partition resumes.
                p.completed = iteration;
                p.checkpointed = if loaded { iteration } else { 0 };
                p.issued = iteration;
                p.fetched = iteration;
                p.ready = true;
                p.warm = true;
                p.confirmed = true;
                p.last_progress_ms = now_ms;
            }
        }
        AckVerdict::Ok
    }

    /// `Report`: the worker finished sweeping `part` at `iteration`.
    pub fn report(
        &mut self,
        worker: u64,
        epoch: u32,
        part: u32,
        iteration: u32,
        now_ms: u64,
    ) -> AckVerdict {
        let Some(m) = self.members.get_mut(&worker) else {
            return AckVerdict::Unknown;
        };
        m.last_seen_ms = now_ms;
        if epoch != self.epoch {
            m.needs_spec = true;
            return AckVerdict::Respec;
        }
        let checkpointing = self.cfg.checkpointing;
        let Some(p) = self.parts.get_mut(part as usize) else {
            return AckVerdict::Ok;
        };
        if p.owner != Some(worker) {
            return AckVerdict::Ok; // stale report from a past owner
        }
        p.completed = iteration;
        p.issued = p.issued.max(iteration);
        p.fetched = p.fetched.max(iteration);
        if checkpointing {
            p.checkpointed = iteration;
        }
        p.last_progress_ms = now_ms;
        AckVerdict::Ok
    }

    /// Snapshot-mode fetch barrier: the worker pulled the model
    /// snapshot for `iteration`. A worker pulls once per iteration and
    /// sweeps every partition it owns against that one snapshot, so
    /// the fetch covers all of its partitions — marking only `part`
    /// would deadlock a worker that owns several (it cannot poll for
    /// the others while parked at the barrier). Sweeping may start
    /// only once every partition still participating in `iteration`
    /// has fetched it — that is what makes the per-iteration snapshot
    /// (and so the final count table) deterministic under any
    /// membership.
    pub fn fetched(
        &mut self,
        worker: u64,
        epoch: u32,
        part: u32,
        iteration: u32,
        now_ms: u64,
    ) -> FetchVerdict {
        let Some(m) = self.members.get_mut(&worker) else {
            return FetchVerdict::Unknown;
        };
        m.last_seen_ms = now_ms;
        if epoch != self.epoch {
            m.needs_spec = true;
            return FetchVerdict::Respec;
        }
        if !matches!(self.parts.get(part as usize), Some(p) if p.owner == Some(worker)) {
            return FetchVerdict::Unknown;
        }
        for p in self.parts.iter_mut() {
            if p.owner == Some(worker) {
                p.fetched = p.fetched.max(iteration);
            }
        }
        let barrier_met = self
            .parts
            .iter()
            .all(|p| p.fetched >= iteration || p.completed >= iteration);
        if barrier_met {
            FetchVerdict::Go
        } else {
            FetchVerdict::Hold
        }
    }

    /// Heartbeat. Returns false for unknown members.
    pub fn touch(&mut self, worker: u64, now_ms: u64) -> bool {
        match self.members.get_mut(&worker) {
            Some(m) => {
                m.last_seen_ms = now_ms;
                true
            }
            None => false,
        }
    }

    /// Worker poll: the scheduling heart. Order matters — transfers
    /// release before the ready barrier so a drain can finish even
    /// while an orphan blocks the barrier.
    pub fn poll(&mut self, worker: u64, now_ms: u64) -> PollVerdict {
        let Some(m) = self.members.get_mut(&worker) else {
            return PollVerdict::Unknown;
        };
        m.last_seen_ms = now_ms;
        if self.finished() {
            self.release_finished(worker);
            self.remove_member(worker);
            return PollVerdict::Done;
        }
        if self.members.get(&worker).is_some_and(|m| m.needs_spec) {
            return PollVerdict::Respec;
        }
        // Pending warm transfers out of this worker, at sweep
        // boundaries only.
        let mut released = Vec::new();
        for (i, p) in self.parts.iter_mut().enumerate() {
            if p.owner == Some(worker)
                && p.target.is_some()
                && p.target != p.owner
                && p.warm
                && p.issued == p.completed
                // In snapshot mode the owner may already have pulled
                // (and fetch-marked) the next iteration for this
                // partition; hand it over only once that sweep lands,
                // so the recipient's own pull stays pre-barrier clean.
                && p.fetched == p.completed
            {
                let to = p.target.expect("checked is_some");
                p.owner = Some(to);
                p.confirmed = false;
                p.fetched = p.completed;
                p.last_progress_ms = now_ms;
                released.push((i as u32, to));
            }
        }
        if !released.is_empty() {
            self.counters.moved_partitions += released.len() as u64;
            for &(_, to) in &released {
                if let Some(rm) = self.members.get_mut(&to) {
                    rm.needs_spec = true;
                }
            }
            return PollVerdict::Transfer(released.into_iter().map(|(p, _)| p).collect());
        }
        if self.members.get(&worker).is_some_and(|m| m.draining) && !self.owns_any(worker) {
            self.remove_member(worker);
            self.counters.drain_count += 1;
            return PollVerdict::Drained;
        }
        if !self.all_ready() {
            return PollVerdict::Wait;
        }
        // Pick a sweep: owned, confirmed, at a boundary, inside the
        // staleness window; least-completed first for fairness.
        let min_c = self.min_completed();
        let candidate = self
            .parts
            .iter()
            .enumerate()
            .filter(|(_, p)| {
                p.owner == Some(worker)
                    && p.confirmed
                    && p.issued == p.completed
                    && p.completed < self.cfg.iterations
                    && p.completed <= min_c.saturating_add(self.cfg.max_staleness)
            })
            .min_by_key(|(i, p)| (p.completed, *i))
            .map(|(i, _)| i);
        match candidate {
            Some(i) => {
                let p = &mut self.parts[i];
                p.issued = p.completed + 1;
                PollVerdict::Run { part: i as u32, iteration: p.issued }
            }
            None => PollVerdict::Wait,
        }
    }

    /// Planned drain request.
    pub fn drain(&mut self, worker: u64, now_ms: u64) -> DrainVerdict {
        if !self.members.contains_key(&worker) {
            return DrainVerdict::Unknown;
        }
        if self.finished() || !self.owns_any(worker) {
            self.release_finished(worker);
            self.remove_member(worker);
            self.counters.drain_count += 1;
            return DrainVerdict::Drained;
        }
        if !self.cfg.checkpointing {
            // Cold drain: no checkpoints to hand off, so the partitions
            // restart fresh under a new epoch.
            let token = self.members.get(&worker).map(|m| m.token);
            for p in self.parts.iter_mut() {
                if p.owner == Some(worker) {
                    p.owner = None;
                    p.target = None;
                    p.ready = false;
                    p.warm = false;
                    p.confirmed = false;
                    p.orphaned = true;
                    p.issued = p.completed;
                }
            }
            self.remove_member(worker);
            if let Some(tok) = token {
                self.ring.remove(tok);
            }
            self.roll_wanted = true;
            self.counters.drain_count += 1;
            self.recompute_targets(true, now_ms);
            self.admit_parked(now_ms);
            return DrainVerdict::Drained;
        }
        if self.cfg.elastic {
            // Warm drain: leave the ring now; partitions transfer out
            // at sweep boundaries and a later poll answers `Drained`.
            let token = self.members.get(&worker).map(|m| m.token);
            if let Some(m) = self.members.get_mut(&worker) {
                m.draining = true;
            }
            if let Some(tok) = token {
                self.ring.remove(tok);
            }
            self.recompute_targets(true, now_ms);
            DrainVerdict::Draining
        } else {
            // Static warm drain: the worker is at a boundary with all
            // partitions checkpointed; free them warm for the next
            // registrant (or a parked standby).
            for p in self.parts.iter_mut() {
                if p.owner == Some(worker) {
                    p.owner = None;
                    p.target = None;
                    p.confirmed = false;
                    p.issued = p.completed;
                }
            }
            self.remove_member(worker);
            self.counters.drain_count += 1;
            self.admit_parked(now_ms);
            DrainVerdict::Drained
        }
    }

    /// Clean leave. Mid-run with owned partitions this is a failure
    /// (orphan + roll), matching the historical coordinator.
    pub fn leave(&mut self, worker: u64, now_ms: u64) {
        if !self.members.contains_key(&worker) {
            return;
        }
        let owned = self.owns_any(worker);
        let finished = self.finished();
        if owned && !finished {
            self.orphan_owned_by(worker);
            self.remove_member(worker);
            self.roll_wanted = true;
            self.recompute_targets(true, now_ms);
            self.admit_parked(now_ms);
        } else {
            self.release_finished(worker);
            self.remove_member(worker);
        }
    }

    /// Reap members silent past `timeout_ms`. Rolls the epoch only when
    /// a reaped member actually owned partitions.
    pub fn reap(&mut self, now_ms: u64, timeout_ms: u64) -> Vec<u64> {
        let dead: Vec<u64> = self
            .members
            .iter()
            .filter(|(_, m)| now_ms.saturating_sub(m.last_seen_ms) > timeout_ms)
            .map(|(&w, _)| w)
            .collect();
        if dead.is_empty() {
            return dead;
        }
        let finished = self.finished();
        let mut any_owned = false;
        for &w in &dead {
            let owned = self.owns_any(w);
            any_owned |= owned;
            self.remove_member(w);
            if owned {
                self.orphan_owned_by(w);
            }
        }
        if any_owned && !finished {
            self.roll_wanted = true;
        }
        self.recompute_targets(true, now_ms);
        self.admit_parked(now_ms);
        dead
    }

    fn orphan_owned_by(&mut self, worker: u64) {
        for p in self.parts.iter_mut() {
            if p.owner == Some(worker) {
                p.owner = None;
                p.target = None;
                p.ready = false;
                p.warm = false;
                p.confirmed = false;
                p.orphaned = true;
                p.issued = p.completed;
            }
        }
    }

    /// Drop ownership of a departing member's partitions without
    /// orphaning them (run finished, or nothing left to do).
    fn release_finished(&mut self, worker: u64) {
        for p in self.parts.iter_mut() {
            if p.owner == Some(worker) {
                p.owner = None;
                p.confirmed = false;
            }
        }
    }

    /// Remove a member, its token registration, and its ring entry, and
    /// retract any pending moves toward it. Clearing the *owner* side
    /// is the caller's business (orphan vs. finished-release).
    fn remove_member(&mut self, worker: u64) {
        if let Some(m) = self.members.remove(&worker) {
            self.tokens.remove(&m.token);
            self.ring.remove(m.token);
        }
        for p in self.parts.iter_mut() {
            if p.target == Some(worker) {
                p.target = None;
            }
        }
    }

    // ------------------------------------------------------------------
    // Epoch rolls.

    /// The coordinator created the new epoch's matrix; reset control
    /// state. Rolls realize pending moves for free — everyone re-pushes
    /// checkpoint counts into the fresh table.
    pub fn rolled(&mut self, now_ms: u64) {
        self.epoch += 1;
        self.roll_wanted = false;
        for p in self.parts.iter_mut() {
            if let (Some(o), Some(t)) = (p.owner, p.target) {
                if o != t {
                    p.owner = Some(t);
                    self.counters.moved_partitions += 1;
                }
            }
            if p.orphaned && p.owner.is_some() {
                p.orphaned = false;
                self.counters.reassignments += 1;
            }
            p.ready = false;
            p.warm = false;
            p.confirmed = false;
            p.completed = p.checkpointed;
            p.issued = p.completed;
            p.fetched = p.completed;
            p.last_progress_ms = now_ms;
        }
        for m in self.members.values_mut() {
            m.needs_spec = true;
        }
    }

    // ------------------------------------------------------------------
    // Straggler shedding.

    /// Shed load from a straggler: when the least-completed partition
    /// lags the staleness window by `shed_factor` *and* has made no
    /// progress for `shed_stall_ms`, halve its owner's ring weight so
    /// the rebalance narrows that worker's range instead of letting it
    /// gate the barrier.
    pub fn maybe_shed(&mut self, now_ms: u64) -> Option<ShedEvent> {
        if !self.cfg.elastic
            || !self.cfg.checkpointing
            || self.cfg.shed_factor <= 0.0
            || self.members.len() < 2
            || now_ms < self.shed_cooldown_until_ms
        {
            return None;
        }
        let max_c = self.parts.iter().map(|p| p.completed).max().unwrap_or(0);
        let (pid, p) = self
            .parts
            .iter()
            .enumerate()
            .filter(|(_, p)| p.completed < self.cfg.iterations && p.owner.is_some())
            .min_by_key(|(i, p)| (p.completed, *i))?;
        if max_c.saturating_sub(p.completed) < self.cfg.shed_threshold() {
            return None;
        }
        if now_ms.saturating_sub(p.last_progress_ms) < self.cfg.shed_stall_ms {
            return None;
        }
        let worker = p.owner.expect("filtered on owner");
        let token = self.members.get(&worker)?.token;
        if self.ring.weight(token)? <= 1 {
            return None;
        }
        let new_weight = self.ring.narrow(token)?;
        self.shed_cooldown_until_ms = now_ms + self.cfg.shed_stall_ms;
        self.counters.sheds += 1;
        let part = pid as u32;
        self.recompute_targets(true, now_ms);
        Some(ShedEvent { worker, part, new_weight })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(elastic: bool, workers: usize, iterations: u32) -> MembershipCfg {
        MembershipCfg {
            elastic,
            workers,
            vnodes: 16,
            iterations,
            max_staleness: 1,
            checkpointing: true,
            shed_factor: 0.0,
            shed_stall_ms: 1000,
        }
    }

    fn ranges(n: usize) -> Vec<Range<usize>> {
        (0..n).map(|i| i * 10..(i + 1) * 10).collect()
    }

    fn seat_worker(ms: &mut Membership, token: u64, now: u64) -> u64 {
        match ms.register(token, now) {
            Admission::Seated { worker } => worker,
            other => panic!("expected seat, got {other:?}"),
        }
    }

    /// Drive `w` through respec + ready for all its parts at their
    /// checkpoint iterations.
    fn bring_up(ms: &mut Membership, w: u64, now: u64) -> Vec<PartAssign> {
        assert_eq!(ms.poll(w, now), PollVerdict::Respec);
        let spec = ms.spec_for(w);
        let items: Vec<(u32, u32, bool)> =
            spec.iter().map(|a| (a.part, a.resume, a.resume > 0)).collect();
        assert_eq!(ms.ready(w, ms.epoch(), &items, now), AckVerdict::Ok);
        spec
    }

    #[test]
    fn static_seats_in_index_order_and_parks_standby() {
        let mut ms = Membership::new(cfg(false, 2, 4), ranges(2));
        let w0 = seat_worker(&mut ms, 100, 0);
        let w1 = seat_worker(&mut ms, 200, 0);
        assert_eq!(ms.owner(0), Some(w0));
        assert_eq!(ms.owner(1), Some(w1));
        assert_eq!(ms.register(300, 0), Admission::Parked);
        assert_eq!(ms.parked_len(), 1);
        // Re-register of a live token is idempotent.
        assert_eq!(ms.register(100, 1), Admission::Existing { worker: w0 });
    }

    #[test]
    fn static_lockstep_runs_and_finishes() {
        let mut ms = Membership::new(cfg(false, 2, 2), ranges(2));
        let w0 = seat_worker(&mut ms, 100, 0);
        let w1 = seat_worker(&mut ms, 200, 0);
        bring_up(&mut ms, w0, 0);
        // Barrier: w0 alone is not enough.
        assert_eq!(ms.poll(w0, 1), PollVerdict::Wait);
        bring_up(&mut ms, w1, 1);
        for it in 1..=2u32 {
            assert_eq!(ms.poll(w0, 2), PollVerdict::Run { part: 0, iteration: it });
            assert_eq!(ms.poll(w1, 2), PollVerdict::Run { part: 1, iteration: it });
            assert_eq!(ms.report(w0, 0, 0, it, 3), AckVerdict::Ok);
            assert_eq!(ms.report(w1, 0, 1, it, 3), AckVerdict::Ok);
        }
        assert!(ms.finished());
        assert_eq!(ms.poll(w0, 4), PollVerdict::Done);
        assert_eq!(ms.poll(w1, 4), PollVerdict::Done);
        assert_eq!(ms.members_len(), 0);
    }

    #[test]
    fn elastic_join_transfers_warm_at_boundary() {
        let mut ms = Membership::new(cfg(true, 2, 10), ranges(4));
        let w0 = seat_worker(&mut ms, 100, 0);
        // Sole member owns everything.
        bring_up(&mut ms, w0, 0);
        assert!((0..4).all(|p| ms.owner(p) == Some(w0)));
        // Run part 0 so it is mid-flight when the join lands.
        let PollVerdict::Run { part: inflight, iteration } = ms.poll(w0, 1) else {
            panic!("expected a run");
        };
        let w1 = seat_worker(&mut ms, 200, 2);
        // Mid-flight partition must not move; the others may.
        let PollVerdict::Transfer(moved) = ms.poll(w0, 3) else {
            panic!("expected transfers after join");
        };
        assert!(!moved.is_empty());
        assert!(!moved.contains(&inflight));
        for &p in &moved {
            assert_eq!(ms.owner(p), Some(w1));
        }
        // Recipient respecs warm: no re-push.
        assert_eq!(ms.poll(w1, 4), PollVerdict::Respec);
        let spec = ms.spec_for(w1);
        assert!(spec.iter().all(|a| !a.push));
        // In-flight sweep still completes under the donor.
        assert_eq!(ms.report(w0, 0, inflight, iteration, 5), AckVerdict::Ok);
        assert_eq!(ms.epoch(), 0, "no epoch roll on join");
        assert!(ms.counters.moved_partitions >= moved.len() as u64);
    }

    /// Poll until a non-transfer verdict; complete any issued sweep so
    /// every partition sits at a boundary afterwards.
    fn settle(ms: &mut Membership, w: u64, now: u64) {
        loop {
            match ms.poll(w, now) {
                PollVerdict::Transfer(_) => {}
                PollVerdict::Run { part, iteration } => {
                    assert_eq!(ms.report(w, ms.epoch(), part, iteration, now), AckVerdict::Ok);
                    return;
                }
                _ => return,
            }
        }
    }

    #[test]
    fn elastic_drain_hands_back_and_completes() {
        let mut ms = Membership::new(cfg(true, 2, 10), ranges(4));
        let w0 = seat_worker(&mut ms, 100, 0);
        bring_up(&mut ms, w0, 0);
        let w1 = seat_worker(&mut ms, 200, 1);
        // Settle the join transfers (and any sweep issued meanwhile).
        settle(&mut ms, w0, 2);
        bring_up(&mut ms, w1, 3);
        assert_eq!(ms.drain(w0, 4), DrainVerdict::Draining);
        // All at boundary: everything w0 owns releases, then Drained.
        match ms.poll(w0, 5) {
            PollVerdict::Transfer(parts) => {
                for p in parts {
                    assert_eq!(ms.owner(p), Some(w1));
                }
            }
            other => panic!("expected transfer, got {other:?}"),
        }
        assert_eq!(ms.poll(w0, 6), PollVerdict::Drained);
        assert_eq!(ms.counters.drain_count, 1);
        assert_eq!(ms.epoch(), 0, "planned drain must not roll the epoch");
        assert!(!ms.roll_wanted());
        assert!((0..4).all(|p| ms.owner(p) == Some(w1)));
    }

    #[test]
    fn reap_rolls_only_when_partitions_owned() {
        let mut ms = Membership::new(cfg(true, 2, 10), ranges(2));
        let w0 = seat_worker(&mut ms, 100, 0);
        bring_up(&mut ms, w0, 0);
        // A second member that never managed to take a partition (all
        // transfers pending) dying must not roll.
        let w1 = seat_worker(&mut ms, 200, 1);
        assert!(!ms.owns_any(w1));
        let dead = ms.reap(10_000, 5_000);
        assert_eq!(dead.len(), 2); // both silent
        assert!(ms.roll_wanted(), "w0 owned partitions");
        ms.rolled(10_001);
        assert_eq!(ms.epoch(), 1);

        // Now: a member with no partitions reaped alone → no roll.
        let mut ms = Membership::new(cfg(true, 2, 10), ranges(2));
        let w0 = seat_worker(&mut ms, 100, 0);
        bring_up(&mut ms, w0, 0);
        let _w1 = seat_worker(&mut ms, 200, 9_000);
        // w1 owns nothing (transfers pending, none released yet).
        let dead = ms.reap(10_000, 5_000);
        assert_eq!(dead, vec![w0]);
        assert!(ms.roll_wanted());
        ms.rolled(10_001);
        // Orphans were re-seated on the surviving member.
        assert!((0..2).all(|p| ms.owner(p).is_some()));
        assert!(ms.counters.reassignments >= 2);
    }

    #[test]
    fn zombie_rejoins_with_old_token_after_reap() {
        let mut ms = Membership::new(cfg(true, 1, 10), ranges(2));
        let w0 = seat_worker(&mut ms, 100, 0);
        bring_up(&mut ms, w0, 0);
        let dead = ms.reap(10_000, 5_000);
        assert_eq!(dead, vec![w0]);
        ms.rolled(10_001);
        // Same token re-registers: fresh member id, same ring position,
        // so it deterministically reclaims its old partitions.
        let w0b = seat_worker(&mut ms, 100, 10_002);
        assert_ne!(w0, w0b);
        assert!((0..2).all(|p| ms.owner(p) == Some(w0b)));
    }

    #[test]
    fn shed_narrows_straggler_weight() {
        let mut c = cfg(true, 2, 100);
        c.shed_factor = 1.0;
        c.shed_stall_ms = 100;
        let mut ms = Membership::new(c, ranges(8));
        let w0 = seat_worker(&mut ms, 100, 0);
        bring_up(&mut ms, w0, 0);
        let w1 = seat_worker(&mut ms, 200, 1);
        settle(&mut ms, w0, 2);
        bring_up(&mut ms, w1, 3);
        // Advance every partition except w0's first to iteration 2.
        let lagging = (0..8).find(|&p| ms.owner(p) == Some(w0)).unwrap();
        for it in 1..=2u32 {
            for p in 0..8u32 {
                if p == lagging {
                    continue;
                }
                let w = ms.owner(p).unwrap();
                assert_eq!(ms.report(w, 0, p, it, 10), AckVerdict::Ok);
            }
        }
        // Lag 2 > staleness window 1 and stalled past shed_stall_ms.
        let ev = ms.maybe_shed(10_000).expect("shed triggers");
        assert_eq!(ev.worker, w0);
        assert_eq!(ev.part, lagging);
        assert!(ev.new_weight < 16);
        assert_eq!(ms.counters.sheds, 1);
        // Cool-down: no immediate second shed.
        assert!(ms.maybe_shed(10_001).is_none());
    }

    #[test]
    fn static_warm_drain_frees_partitions_for_parked_standby() {
        let mut ms = Membership::new(cfg(false, 2, 10), ranges(2));
        let w0 = seat_worker(&mut ms, 100, 0);
        let w1 = seat_worker(&mut ms, 200, 0);
        bring_up(&mut ms, w0, 0);
        bring_up(&mut ms, w1, 0);
        assert_eq!(ms.register(300, 1), Admission::Parked);
        assert_eq!(ms.report(w0, 0, 0, 3, 2), AckVerdict::Ok);
        assert_eq!(ms.drain(w0, 3), DrainVerdict::Drained);
        // The parked standby was admitted to the freed partition, warm.
        let admitted = ms.take_admitted();
        assert_eq!(admitted.len(), 1);
        let (token, w2) = admitted[0];
        assert_eq!(token, 300);
        assert_eq!(ms.owner(0), Some(w2));
        let spec = ms.spec_for(w2);
        assert_eq!(spec.len(), 1);
        assert!(!spec[0].push, "warm pickup must not re-push");
        assert_eq!(spec[0].resume, 3);
        assert_eq!(ms.epoch(), 0);
        assert_eq!(ms.counters.drain_count, 1);
    }

    #[test]
    fn fetch_barrier_holds_until_all_participants_fetch() {
        let mut ms = Membership::new(cfg(false, 2, 10), ranges(2));
        let w0 = seat_worker(&mut ms, 100, 0);
        let w1 = seat_worker(&mut ms, 200, 0);
        bring_up(&mut ms, w0, 0);
        bring_up(&mut ms, w1, 0);
        assert_eq!(ms.fetched(w0, 0, 0, 1, 1), FetchVerdict::Hold);
        assert_eq!(ms.fetched(w1, 0, 1, 1, 1), FetchVerdict::Go);
        // Re-asking after the barrier passed still says Go.
        assert_eq!(ms.fetched(w0, 0, 0, 1, 2), FetchVerdict::Go);
        // A stale-epoch fetch cannot poison the barrier: it respecs.
        assert_eq!(ms.fetched(w0, 9, 0, 2, 3), FetchVerdict::Respec);
    }

    #[test]
    fn invariants_hold_through_a_churny_run() {
        let mut ms = Membership::new(cfg(true, 2, 6), ranges(4));
        let w0 = seat_worker(&mut ms, 100, 0);
        ms.check_invariants();
        bring_up(&mut ms, w0, 0);
        let w1 = seat_worker(&mut ms, 200, 1);
        ms.check_invariants();
        while let PollVerdict::Transfer(_) = ms.poll(w0, 2) {
            ms.check_invariants();
        }
        bring_up(&mut ms, w1, 3);
        ms.drain(w1, 4);
        ms.check_invariants();
        while let PollVerdict::Transfer(_) = ms.poll(w1, 5) {
            ms.check_invariants();
        }
        assert_eq!(ms.poll(w1, 6), PollVerdict::Drained);
        ms.check_invariants();
        assert!((0..4).all(|p| ms.owner(p) == Some(w0)));
    }
}
