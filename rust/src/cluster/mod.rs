//! Cluster runtime: a coordinator process plus remote worker processes
//! for multi-process LightLDA (the driver/executor analog of the
//! paper's Spark integration).
//!
//! PR 1 distributed the parameter-server *shards* across processes
//! (`serve` / `--connect`); this module distributes the *samplers*. A
//! deployment is three kinds of processes wired over the same
//! tagged-frame TCP layer:
//!
//! ```text
//!                        ┌─────────────┐  control plane
//!          ┌────────────►│ coordinator │◄────────────┐
//!          │ register/   │ (coordinate)│  poll/report│
//!          │ heartbeat   └─────────────┘             │
//!          ▼                                         ▼
//!   ┌────────────┐                            ┌────────────┐
//!   │  worker 0  │                            │  worker 1  │
//!   │   (work)   │                            │   (work)   │
//!   └─────┬──────┘                            └─────┬──────┘
//!         │      pulls / pushes (data plane)        │
//!         ▼                                         ▼
//!   ┌────────────┐   ┌────────────┐   ┌────────────┐
//!   │  shard 0   │   │  shard 1   │   │  shard …   │
//!   │  (serve)   │   │  (serve)   │   │  (serve)   │
//!   └────────────┘   └────────────┘   └────────────┘
//! ```
//!
//! - [`protocol`] — the control-plane messages (register / assign /
//!   run / report / heartbeat / drain / transfer), codec-serialized
//!   like the data plane.
//! - [`ring`] — the murmur3 consistent-hash partition ring with
//!   weighted virtual nodes (who *should* own which partition).
//! - [`membership`] — the elastic membership manager: admission,
//!   parked standbys, warm partition transfers, planned drain,
//!   zombie rejoin, straggler shedding (pure state machine, no I/O).
//! - [`coordinator`] — the network shell around [`membership`]: the
//!   `Ready` barrier, the bounded-staleness iteration gate, heartbeat
//!   liveness, and epoch-rolling failure recovery over per-partition
//!   checkpoints.
//! - [`worker`] — the remote executor driving the shared
//!   [`crate::lda::sweep::SweepRunner`] kernel over its set of owned
//!   partitions.

pub mod coordinator;
pub mod membership;
pub mod protocol;
pub mod ring;
pub mod worker;

pub use coordinator::{ClusterOutcome, Coordinator};
pub use protocol::{CorpusSpec, JobSpec};
pub use worker::{run_worker, WorkerOptions, WorkerSummary};
