//! The consistent-hash partition ring: who owns which corpus partition.
//!
//! Corpus partitions live on a murmur3 ring with *weighted virtual
//! nodes* (the `OtherShard` ring idiom: hash every member name, sort by
//! hash, walk clockwise). Each member contributes `weight` virtual
//! points; a partition's key hashes to a position and is owned by the
//! first member point at or after it. Two refinements on the textbook
//! ring:
//!
//! - **Bounded load.** A raw ring with few partitions is badly
//!   unbalanced (with 2 members and 2 partitions, one member owns both
//!   about half the time). Assignment therefore walks the ring with a
//!   per-member capacity of `ceil(P / members)` live partitions: a
//!   member at capacity is skipped and the partition falls to the next
//!   point clockwise (Mirrokni's consistent hashing with bounded
//!   loads). Balance is guaranteed within one partition of even, while
//!   ownership stays a pure function of the member set — and *minimal
//!   movement* still holds: members untouched by a join/leave keep the
//!   partitions they had, except where the capacity bound itself
//!   shifts.
//!
//! - **Weights.** Straggler shedding narrows a member's ring range by
//!   lowering its weight: fewer virtual points *and* a proportionally
//!   lower capacity, so the remainder of its range reassigns to the
//!   neighbors without disturbing unrelated members.
//!
//! The ring is pure data — no clocks, no I/O — so the membership model
//! checker ([`crate::util::sync_shim`]) can drive it through arbitrary
//! schedules.

/// One member's virtual point on the ring.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct VNode {
    hash: u32,
    member: u64,
}

/// Weighted ring member.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Member {
    /// Stable member identity (the worker's registration token, so a
    /// zombie that re-registers lands back on its old ranges).
    pub id: u64,
    /// Virtual-node count; halved by straggler shedding (never below 1).
    pub weight: u32,
}

/// murmur3 32-bit (x86 variant), implemented in-repo: the crate is
/// pure-std by policy, so the `murmur3` crate the `OtherShard` idiom
/// uses is hand-rolled here. Standard reference constants; verified
/// against the published test vectors in the unit tests below.
pub fn murmur3_32(data: &[u8], seed: u32) -> u32 {
    const C1: u32 = 0xcc9e_2d51;
    const C2: u32 = 0x1b87_3593;
    let mut h = seed;
    let mut chunks = data.chunks_exact(4);
    for chunk in &mut chunks {
        let mut k = u32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
        k = k.wrapping_mul(C1).rotate_left(15).wrapping_mul(C2);
        h = (h ^ k).rotate_left(13).wrapping_mul(5).wrapping_add(0xe654_6b64);
    }
    let tail = chunks.remainder();
    if !tail.is_empty() {
        let mut k = 0u32;
        for (i, &b) in tail.iter().enumerate() {
            k |= (b as u32) << (8 * i);
        }
        k = k.wrapping_mul(C1).rotate_left(15).wrapping_mul(C2);
        h ^= k;
    }
    h ^= data.len() as u32;
    h ^= h >> 16;
    h = h.wrapping_mul(0x85eb_ca6b);
    h ^= h >> 13;
    h = h.wrapping_mul(0xc2b2_ae35);
    h ^= h >> 16;
    h
}

/// Ring position of member `id`'s `i`-th virtual node.
fn vnode_hash(id: u64, i: u32) -> u32 {
    let mut key = [0u8; 12];
    key[..8].copy_from_slice(&id.to_le_bytes());
    key[8..].copy_from_slice(&i.to_le_bytes());
    murmur3_32(&key, 0x9e37)
}

/// Ring position of partition `p`'s key (`part-{p}`, the same
/// name-hashing shape as the `OtherShard` ring).
pub fn partition_point(p: u32) -> u32 {
    murmur3_32(format!("part-{p}").as_bytes(), 0)
}

/// The consistent-hash ring: weighted members, deterministic
/// partition→member assignment.
#[derive(Debug, Clone, Default)]
pub struct Ring {
    members: Vec<Member>,
}

impl Ring {
    /// Empty ring.
    pub fn new() -> Ring {
        Ring::default()
    }

    /// Current members (insertion order; assignment does not depend on
    /// this order).
    pub fn members(&self) -> &[Member] {
        &self.members
    }

    /// Number of members.
    pub fn len(&self) -> usize {
        self.members.len()
    }

    /// True when no members are present.
    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    /// True when `id` is a member.
    pub fn contains(&self, id: u64) -> bool {
        self.members.iter().any(|m| m.id == id)
    }

    /// Add a member with `weight` virtual nodes. No-op if present.
    pub fn insert(&mut self, id: u64, weight: u32) {
        if !self.contains(id) {
            self.members.push(Member { id, weight: weight.max(1) });
        }
    }

    /// Remove a member. No-op if absent.
    pub fn remove(&mut self, id: u64) {
        self.members.retain(|m| m.id != id);
    }

    /// Member `id`'s current weight, if present.
    pub fn weight(&self, id: u64) -> Option<u32> {
        self.members.iter().find(|m| m.id == id).map(|m| m.weight)
    }

    /// Halve a member's weight (straggler shedding), never below 1.
    /// Returns the new weight, or `None` for an unknown member.
    pub fn narrow(&mut self, id: u64) -> Option<u32> {
        let m = self.members.iter_mut().find(|m| m.id == id)?;
        m.weight = (m.weight / 2).max(1);
        Some(m.weight)
    }

    /// All virtual points, sorted by ring position (ties broken by
    /// member id so assignment is deterministic even under hash
    /// collisions).
    fn points(&self) -> Vec<VNode> {
        let mut points = Vec::new();
        for m in &self.members {
            for i in 0..m.weight {
                points.push(VNode { hash: vnode_hash(m.id, i), member: m.id });
            }
        }
        points.sort_by_key(|v| (v.hash, v.member));
        points
    }

    /// Assign `partitions` partitions to members: partition `p` goes to
    /// the owner of the first virtual point clockwise from
    /// [`partition_point`]`(p)` that still has capacity. Capacity is
    /// `ceil(P * w_m / W_total)` (so shedding weight sheds load), with
    /// a floor of 1. Returns `owner[p]`; empty ring returns an empty
    /// vector.
    ///
    /// Deterministic in the member *set* (ids + weights), balanced
    /// within the capacity bound, and minimal-movement: a partition
    /// only moves when its clockwise walk changes — i.e. when a member
    /// joined/left/re-weighted in the arc it lands on, or the capacity
    /// bound shifted.
    pub fn assign(&self, partitions: u32) -> Vec<u64> {
        if self.members.is_empty() || partitions == 0 {
            return Vec::new();
        }
        let points = self.points();
        let total_w: u64 = self.members.iter().map(|m| m.weight as u64).sum();
        let cap_of = |w: u32| -> u32 {
            let c = (partitions as u64 * w as u64).div_ceil(total_w);
            (c as u32).max(1)
        };
        let mut load: std::collections::HashMap<u64, u32> =
            self.members.iter().map(|m| (m.id, 0)).collect();
        let mut owner = vec![0u64; partitions as usize];
        // Partitions are placed in ascending ring position of their
        // keys, so the clockwise walk is well-defined and order-free:
        // the same member set always fills the same way.
        let mut order: Vec<u32> = (0..partitions).collect();
        order.sort_by_key(|&p| (partition_point(p), p));
        for &p in &order {
            let key = partition_point(p);
            // First point at/after the key, wrapping.
            let start = points.partition_point(|v| v.hash < key) % points.len();
            let mut placed = false;
            for off in 0..points.len() {
                let v = &points[(start + off) % points.len()];
                let w = self.weight(v.member).unwrap_or(1);
                let l = load.get_mut(&v.member).expect("member in load map");
                if *l < cap_of(w) {
                    *l += 1;
                    owner[p as usize] = v.member;
                    placed = true;
                    break;
                }
            }
            if !placed {
                // All members at capacity (can only happen when the
                // floor-of-1 caps sum below P with extreme weights);
                // fall back to the least-loaded member.
                let m = *load.iter().min_by_key(|&(id, l)| (*l, *id)).expect("nonempty").0;
                *load.get_mut(&m).expect("member") += 1;
                owner[p as usize] = m;
            }
        }
        owner
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;
    use std::collections::{HashMap, HashSet};

    #[test]
    fn murmur3_reference_vectors() {
        // Published x86_32 test vectors.
        assert_eq!(murmur3_32(b"", 0), 0);
        assert_eq!(murmur3_32(b"", 1), 0x514e_28b7);
        assert_eq!(murmur3_32(b"test", 0), 0xba6b_d213);
        assert_eq!(murmur3_32(b"Hello, world!", 0), 0xc037_2da5);
        assert_eq!(murmur3_32(b"The quick brown fox jumps over the lazy dog", 0), 0x2e4f_f723);
    }

    fn counts(owner: &[u64]) -> HashMap<u64, u32> {
        let mut c = HashMap::new();
        for &m in owner {
            *c.entry(m).or_insert(0) += 1;
        }
        c
    }

    /// Property: load balanced within the capacity bound at various
    /// vnode counts and member counts.
    #[test]
    fn balance_within_bound_across_vnode_counts() {
        for vnodes in [1u32, 4, 16, 64, 128] {
            for members in [1usize, 2, 3, 5, 8, 13] {
                for partitions in [1u32, 2, 8, 16, 64] {
                    let mut ring = Ring::new();
                    for m in 0..members {
                        ring.insert(0x1000 + m as u64 * 7919, vnodes);
                    }
                    let owner = ring.assign(partitions);
                    assert_eq!(owner.len(), partitions as usize);
                    let cap = (partitions as usize).div_ceil(members) as u32;
                    for (&m, &load) in counts(&owner).iter() {
                        assert!(
                            load <= cap,
                            "member {m:#x} holds {load} > cap {cap} \
                             (v={vnodes}, m={members}, p={partitions})"
                        );
                    }
                    // Every member gets work when P >= members.
                    if partitions as usize >= members {
                        assert_eq!(
                            counts(&owner).len(),
                            members,
                            "some member idle (v={vnodes}, m={members}, p={partitions})"
                        );
                    }
                }
            }
        }
    }

    /// Property: ownership is a pure function of the member set —
    /// insertion order and repeated evaluation don't matter.
    #[test]
    fn deterministic_ownership_given_same_member_set() {
        let ids = [11u64, 22, 33, 44, 55];
        let mut fwd = Ring::new();
        for &id in &ids {
            fwd.insert(id, 32);
        }
        let mut rev = Ring::new();
        for &id in ids.iter().rev() {
            rev.insert(id, 32);
        }
        for p in [3u32, 16, 40] {
            assert_eq!(fwd.assign(p), rev.assign(p), "insertion order changed ownership");
            assert_eq!(fwd.assign(p), fwd.assign(p), "re-evaluation changed ownership");
        }
    }

    /// Property: joins and leaves move few partitions — only those whose
    /// clockwise walk the change intersects. The textbook bound is
    /// ~P/members expected moves per membership change; with the
    /// capacity bound a change can also shift the boundary, so assert a
    /// generous but meaningful cap (< half of all partitions move, and
    /// on leave every move originates at the removed member or a
    /// capacity shift).
    #[test]
    fn minimal_movement_on_join_and_leave() {
        let partitions = 64u32;
        let mut rng = Pcg64::new(0xA11CE);
        for trial in 0..20u64 {
            let members = 3 + (trial % 5) as usize;
            let mut ring = Ring::new();
            for m in 0..members {
                ring.insert(rng.next_u64() | 1, 64);
            }
            let before = ring.assign(partitions);

            // Join: only partitions that end up on the joiner may move.
            let joiner = rng.next_u64() | 1;
            let mut joined = ring.clone();
            joined.insert(joiner, 64);
            let after_join = joined.assign(partitions);
            let mut moved_elsewhere = 0;
            for p in 0..partitions as usize {
                if after_join[p] != before[p] && after_join[p] != joiner {
                    moved_elsewhere += 1;
                }
            }
            let moved: usize =
                (0..partitions as usize).filter(|&p| after_join[p] != before[p]).count();
            assert!(
                moved <= partitions as usize / 2,
                "join moved {moved}/{partitions} partitions"
            );
            // Moves not landing on the joiner are capacity-shift
            // ripples; they must be a small minority.
            assert!(
                moved_elsewhere <= moved / 2 + 1,
                "join caused {moved_elsewhere} unrelated moves of {moved}"
            );

            // Leave: partitions not owned by the leaver overwhelmingly
            // stay put.
            let leaver = before[0];
            let mut left = ring.clone();
            left.remove(leaver);
            let after_leave = left.assign(partitions);
            let mut unrelated_moves = 0;
            for p in 0..partitions as usize {
                if before[p] != leaver && after_leave[p] != before[p] {
                    unrelated_moves += 1;
                }
            }
            let orphaned =
                (0..partitions as usize).filter(|&p| before[p] == leaver).count();
            assert!(
                unrelated_moves <= orphaned + partitions as usize / 8,
                "leave of {leaver:#x} moved {unrelated_moves} unrelated partitions \
                 (only {orphaned} were orphaned)"
            );
        }
    }

    /// Narrowing a member's range (weight halving) sheds some of its
    /// partitions and touches nobody else's beyond the shed.
    #[test]
    fn narrow_sheds_load_monotonically() {
        let mut ring = Ring::new();
        for m in 0..4u64 {
            ring.insert(0xBEE0 + m * 101, 64);
        }
        let straggler = 0xBEE0;
        let partitions = 32u32;
        let before = counts(&ring.assign(partitions));
        let w = ring.narrow(straggler).expect("member present");
        assert_eq!(w, 32);
        let after = counts(&ring.assign(partitions));
        assert!(
            after.get(&straggler).copied().unwrap_or(0)
                <= before.get(&straggler).copied().unwrap_or(0),
            "narrowing must not grow the straggler's load"
        );
        // Repeated narrowing converges to the floor weight of 1 and a
        // minimal share, never zero members.
        for _ in 0..10 {
            ring.narrow(straggler);
        }
        assert_eq!(ring.weight(straggler), Some(1));
        let floor = counts(&ring.assign(partitions));
        assert!(floor.get(&straggler).copied().unwrap_or(0) >= 1, "capacity floor is 1");
    }

    /// The zombie-rejoin contract: removing a member and re-inserting
    /// the same id restores exactly the pre-removal assignment.
    #[test]
    fn rejoin_restores_previous_ranges() {
        let mut ring = Ring::new();
        for &id in &[7u64, 8, 9] {
            ring.insert(id, 48);
        }
        let before = ring.assign(24);
        ring.remove(8);
        let without = ring.assign(24);
        assert_ne!(before, without);
        ring.insert(8, 48);
        assert_eq!(ring.assign(24), before, "same member set must restore ownership");
    }

    /// Degenerate shapes stay well-defined.
    #[test]
    fn degenerate_rings() {
        let ring = Ring::new();
        assert!(ring.assign(8).is_empty());
        let mut one = Ring::new();
        one.insert(42, 16);
        assert_eq!(one.assign(5), vec![42; 5]);
        assert_eq!(one.assign(0), Vec::<u64>::new());
        let mut dup = Ring::new();
        dup.insert(42, 16);
        dup.insert(42, 16);
        assert_eq!(dup.members().len(), 1, "double insert is a no-op");
        let mut set = HashSet::new();
        set.insert(dup.assign(3)[0]);
        assert_eq!(set.into_iter().collect::<Vec<_>>(), vec![42]);
    }
}
