//! The cluster coordinator: the driver of a multi-process LightLDA run
//! (the analog of the paper's Spark driver dispatching APS-LDA tasks).
//!
//! The coordinator owns the run's control state — corpus partitions,
//! worker registrations, the per-iteration barrier — while the *data*
//! (count tables) lives on the parameter-server shards and the *work*
//! (sampling) happens in worker processes. It is a single-threaded
//! actor draining one tagged-frame TCP inbox, exactly like a shard
//! serve loop: workers drive the protocol by polling, so no state here
//! is ever touched concurrently.
//!
//! # Iteration loop
//!
//! A partition may start iteration `t+1` once (a) every partition has
//! pushed its counts for the current epoch (the `Ready` barrier — the
//! column-sum topic totals are meaningless before that) and (b) it is
//! at most [`TrainConfig::max_staleness`] iterations ahead of the
//! slowest partition — the asynchronous bounded-staleness barrier.
//! Workers flush their pushes and checkpoint *before* reporting, so
//! when every partition has reported iteration `t`, the tables on the
//! shards are exactly the counts of the reported assignments.
//!
//! # Failure recovery (paper §3.5, per-partition form)
//!
//! A worker silent for [`TrainConfig::straggler_timeout_ms`] is
//! declared dead. Its partial pushes have already contaminated the
//! epoch's count table, so the coordinator *rolls the epoch*: it bumps
//! the epoch counter, creates a **fresh** count table (a new matrix id
//! — which also fences off any zombie worker still pushing to the old
//! one), and reissues every partition's [`JobSpec`]. Each worker —
//! survivors included — reloads its partition's last valid checkpoint
//! (or re-initializes, if none), pushes those counts into the new
//! table, and resumes from its checkpointed iteration. The dead
//! partition itself is handed to the next worker that registers.
//!
//! # Shard failure (replicated deployments)
//!
//! With backups (`serve --backup-of` processes named by
//! [`TrainConfig::backups`]), worker and coordinator clients fail over
//! to a shard's backup automatically after repeated delivery failures.
//! The coordinator additionally *probes* every shard's
//! `ShardInfo`: an answer from an un-promoted backup means its own
//! route abandoned the primary — the shard-death signal. It then
//! promotes the backup, repoints the shard address in future
//! [`JobSpec`]s, and rolls the epoch, so every partition re-pushes its
//! checkpoint counts into a fresh table on the surviving replica set —
//! healing whatever the group-commit window or replication lag lost at
//! the moment of death.

use std::collections::{BTreeMap, HashMap};
use std::ops::Range;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::cluster::protocol::{
    CorpusSpec, CtrlRequest, CtrlResponse, JobSpec, SweepKnobs, SweepReport,
};
use crate::corpus::dataset::Corpus;
use crate::eval::perplexity::{perplexity_from_loglik, TopicModel};
use crate::lda::sweep::pull_full_model;
use crate::lda::trainer::TrainConfig;
use crate::metrics::{Report, Row};
use crate::net::tcp::{resolve_addrs, TcpServer, TcpTransport};
use crate::net::{respond, Inbox, Transport};
use crate::ps::client::{BigMatrix, PsClient};
use crate::ps::config::{PsConfig, TransportMode};
use crate::util::error::{Error, Result};
use crate::{log_info, log_warn};

/// How long the coordinator's inbox waits per tick before re-checking
/// worker liveness and completion.
const TICK: Duration = Duration::from_millis(50);
/// Back-off suggested to a worker parked at a barrier.
const BARRIER_WAIT_MS: u64 = 100;
/// Back-off suggested to a worker the cluster has no partition for.
const SPARE_WAIT_MS: u64 = 500;
/// How long the coordinator keeps answering `Done` after completion so
/// workers can exit cleanly before it tears the listener down.
const DRAIN_GRACE: Duration = Duration::from_secs(5);
/// How often the coordinator probes shard roles for primary death
/// (replicated deployments only).
const REPLICA_PROBE: Duration = Duration::from_millis(500);

/// One corpus partition's control state.
struct Slot {
    /// Absolute document range.
    range: Range<usize>,
    /// Worker currently assigned, if any.
    worker: Option<u64>,
    /// Epoch of the last `JobSpec` delivered to that worker.
    delivered_epoch: Option<u32>,
    /// Whether the worker confirmed `Ready` for the current epoch.
    ready: bool,
    /// Iterations completed (absolute, survives epochs).
    completed: u32,
    /// Newest iteration known checkpointed on disk.
    checkpointed: u32,
    /// A previous owner died or left; the next registration that picks
    /// this slot up counts as a reassignment.
    orphaned: bool,
}

/// One iteration's aggregate across partitions (only built once every
/// partition has reported it).
struct IterAgg {
    tokens: u64,
    changed: u64,
    /// Wall-clock of the slowest partition.
    secs: f64,
    /// Alias-construction seconds summed over partitions.
    alias_build_secs: f64,
    /// Pipeline-stall seconds summed over partitions.
    block_wait_secs: f64,
    partitions: usize,
    /// Summed perplexity when every partition evaluated this iteration.
    perplexity: Option<f64>,
}

/// Fold a complete report set into its aggregate; `None` while any
/// partition is missing.
fn aggregate(reports: &[Option<SweepReport>]) -> Option<IterAgg> {
    if !reports.iter().all(|r| r.is_some()) {
        return None;
    }
    let tokens = reports.iter().flatten().map(|r| r.tokens).sum();
    let changed = reports.iter().flatten().map(|r| r.changed).sum();
    let secs = reports.iter().flatten().map(|r| r.seconds).fold(0.0f64, f64::max);
    let alias_build_secs = reports.iter().flatten().map(|r| r.alias_build_secs).sum();
    let block_wait_secs = reports.iter().flatten().map(|r| r.block_wait_secs).sum();
    let perplexity = if reports.iter().flatten().all(|r| r.evaluated) {
        let ll: f64 = reports.iter().flatten().map(|r| r.log_likelihood).sum();
        let n: u64 = reports.iter().flatten().map(|r| r.ll_tokens).sum();
        Some(perplexity_from_loglik(ll, n))
    } else {
        None
    };
    Some(IterAgg {
        tokens,
        changed,
        secs,
        alias_build_secs,
        block_wait_secs,
        partitions: reports.len(),
        perplexity,
    })
}

/// A registered worker.
struct WorkerEntry {
    /// Partition index it drives.
    slot: usize,
    /// Last time any request arrived from it.
    last_seen: Instant,
}

/// Parameter-server health sampled when an iteration completes, summed
/// over shards.
#[derive(Clone, Copy)]
struct PsHealth {
    bytes: u64,
    dedup_evictions: u64,
    wal_bytes: u64,
    repl_lag: u64,
}

/// What a finished cluster run produced.
pub struct ClusterOutcome {
    /// Per-iteration aggregate rows (tokens, seconds, perplexity at
    /// evaluation points, parameter-server health).
    pub report: Report,
    /// The final model pulled off the parameter servers.
    pub model: TopicModel,
    /// Perplexity of the last evaluated iteration, if any was scheduled.
    pub final_perplexity: Option<f64>,
    /// Recovery epochs the run went through (0 = no failures).
    pub epochs: u32,
    /// Partitions handed to a replacement worker after a failure.
    pub reassignments: u32,
    /// Shard backups promoted to primary after a shard death.
    pub promotions: u32,
}

/// The coordinator half of a cluster run. Construct with
/// [`Coordinator::bind`], hand out [`Coordinator::addr`] to workers
/// (`glint-lda work --join <addr>`), then [`Coordinator::run`] to
/// completion.
pub struct Coordinator {
    cfg: TrainConfig,
    corpus_spec: CorpusSpec,
    shard_addrs: Vec<String>,
    /// Backup replica addresses parallel to `shard_addrs` (empty =
    /// unreplicated deployment).
    backup_addrs: Vec<String>,
    vocab_size: u32,
    server: TcpServer,
    inbox: Inbox,
    /// The PS-facing transport backing `client`/`n_wk` (epoch-table
    /// creation, health sampling, final model pull).
    _transport: Arc<dyn Transport>,
    client: PsClient,
    n_wk: BigMatrix<i64>,
    slots: Vec<Slot>,
    workers: HashMap<u64, WorkerEntry>,
    next_worker: u64,
    epoch: u32,
    reassignments: u32,
    promotions: u32,
    /// Count table fenced off by the last epoch roll, retired (deleted
    /// on the shards) at the *next* roll — the one-epoch grace lets
    /// mid-sweep pushes that still reference it land harmlessly.
    fenced: Option<u32>,
    /// Last shard-role probe (rate-limits `probe_replicas`).
    last_probe: Instant,
    /// Per-iteration, per-partition reports (overwritten on re-runs
    /// after a rollback).
    agg: BTreeMap<u32, Vec<Option<SweepReport>>>,
    /// Parameter-server health sampled when an iteration completes.
    ps_health: BTreeMap<u32, PsHealth>,
    /// Iterations already announced in the log.
    announced: u32,
    /// Set when recovery is impossible (e.g. no fresh count table could
    /// be created); the run loop aborts with this error.
    fatal: Option<Error>,
    /// Token → worker id of successful registrations, so a retried
    /// `Register` whose reply was lost re-receives its assignment
    /// instead of being seated twice.
    registrations: HashMap<u64, u64>,
}

impl Coordinator {
    /// Bind the control listener on `bind` (`host:port`; port 0 picks an
    /// ephemeral port), connect to the parameter-server shards named by
    /// `cfg.transport` (`TransportMode::Connect` required), create the
    /// epoch-0 count table and compute the partition table for
    /// `corpus`. `corpus_spec` is what workers are told about where to
    /// find that same corpus.
    pub fn bind(
        bind: &str,
        cfg: TrainConfig,
        corpus: &Corpus,
        corpus_spec: CorpusSpec,
    ) -> Result<Coordinator> {
        cfg.hyper().validate()?;
        if corpus.num_docs() == 0 {
            return Err(Error::Config("empty corpus".into()));
        }
        let TransportMode::Connect(addrs) = &cfg.transport else {
            return Err(Error::Config(
                "cluster mode needs --connect shard addresses (start `serve` first)".into(),
            ));
        };
        let shard_addrs = addrs.clone();
        let resolved = resolve_addrs(&shard_addrs)?;
        let backup_addrs = cfg.backups.clone();
        if !backup_addrs.is_empty() && backup_addrs.len() != shard_addrs.len() {
            return Err(Error::Config(format!(
                "--backups needs one address per shard ({}), got {}",
                shard_addrs.len(),
                backup_addrs.len()
            )));
        }
        let mut ps_cfg = PsConfig::deployment(
            resolved.len(),
            cfg.scheme,
            cfg.transport.clone(),
            cfg.sampler.pipeline_depth,
        );
        ps_cfg.backups = backup_addrs.clone();
        let transport: Arc<dyn Transport> = Arc::new(TcpTransport::connect(&resolved));
        let client = PsClient::connect(&*transport, ps_cfg);
        client.validate_deployment()?;
        let n_wk: BigMatrix<i64> = client.matrix_with_layout(
            corpus.vocab_size as u64,
            cfg.num_topics,
            cfg.wt_layout,
        )?;

        let bind_addr = resolve_addrs(&[bind.to_string()])?[0];
        let (server, mut inboxes) = TcpServer::bind(&[bind_addr])?;
        let inbox = inboxes.remove(0);

        let slots = corpus
            .partitions(cfg.workers)
            .into_iter()
            .map(|range| Slot {
                range,
                worker: None,
                delivered_epoch: None,
                ready: false,
                completed: 0,
                checkpointed: 0,
                orphaned: false,
            })
            .collect();

        Ok(Coordinator {
            vocab_size: corpus.vocab_size,
            corpus_spec,
            shard_addrs,
            backup_addrs,
            server,
            inbox,
            _transport: transport,
            client,
            n_wk,
            slots,
            workers: HashMap::new(),
            next_worker: 1,
            epoch: 0,
            reassignments: 0,
            promotions: 0,
            fenced: None,
            last_probe: Instant::now(),
            agg: BTreeMap::new(),
            ps_health: BTreeMap::new(),
            announced: 0,
            fatal: None,
            registrations: HashMap::new(),
            cfg,
        })
    }

    /// The control-plane address workers join at.
    pub fn addr(&self) -> std::net::SocketAddr {
        self.server.addrs()[0]
    }

    /// Drive the run to completion: serve the control plane, detect dead
    /// workers, roll epochs on failure, and return the aggregated
    /// report plus the final model.
    pub fn run(mut self) -> Result<ClusterOutcome> {
        let total = self.cfg.iterations;
        let straggler = Duration::from_millis(self.cfg.straggler_timeout_ms.max(1));
        log_info!(
            "coordinator up on {} ({} partitions, {} iterations, staleness {})",
            self.addr(),
            self.slots.len(),
            total,
            self.cfg.max_staleness
        );
        while !self.finished() {
            if let Some(env) = self.inbox.recv_timeout(TICK) {
                self.serve_one(env);
                // Drain everything already queued before judging
                // liveness: a brief stall in this loop (e.g. creating an
                // epoch's table) must not let queued-but-unread
                // heartbeats read as worker silence.
                while let Some(env) = self.inbox.recv_timeout(Duration::ZERO) {
                    self.serve_one(env);
                }
            }
            self.reap_dead(straggler);
            self.probe_replicas();
            if let Some(e) = self.fatal.take() {
                self.server.shutdown();
                return Err(e);
            }
        }
        log_info!("all {} iterations complete; draining workers", total);
        // Keep answering (with Done) until every registered worker said
        // goodbye AND the line has been quiet long enough for parked
        // standbys (which re-register every SPARE_WAIT_MS) to hear the
        // verdict too — bounded by a hard grace deadline.
        let drain_deadline = Instant::now() + DRAIN_GRACE;
        let quiet_needed = Duration::from_millis(SPARE_WAIT_MS + 200);
        let mut last_request = Instant::now();
        while Instant::now() < drain_deadline
            && (!self.workers.is_empty() || last_request.elapsed() < quiet_needed)
        {
            if let Some(env) = self.inbox.recv_timeout(TICK) {
                last_request = Instant::now();
                self.serve_one(env);
            }
        }
        self.server.shutdown();

        let model = pull_full_model(
            &self.n_wk,
            self.vocab_size,
            self.cfg.sampler.pipeline_depth,
            self.cfg.hyper(),
        )?;
        let (report, final_perplexity) = self.build_report();
        Ok(ClusterOutcome {
            report,
            model,
            final_perplexity,
            epochs: self.epoch,
            reassignments: self.reassignments,
            promotions: self.promotions,
        })
    }

    /// Decode, dispatch and answer one inbound control envelope.
    fn serve_one(&mut self, env: crate::net::Envelope) {
        let resp = match CtrlRequest::decode(&env.payload) {
            Ok(req) => self.handle(req),
            Err(e) => CtrlResponse::Error(e.to_string()),
        };
        respond(&env, resp.encode());
    }

    /// True once every partition has completed every iteration.
    fn finished(&self) -> bool {
        self.slots.iter().all(|s| s.completed >= self.cfg.iterations)
    }

    /// Smallest completed-iteration count across partitions.
    fn min_completed(&self) -> u32 {
        self.slots.iter().map(|s| s.completed).min().unwrap_or(0)
    }

    /// True once every partition's worker confirmed `Ready` for the
    /// current epoch.
    fn all_ready(&self) -> bool {
        self.slots.iter().all(|s| s.ready)
    }

    /// Build the `JobSpec` for `slot` under the current epoch. The
    /// knobs are the one canonical projection of the trainer config
    /// (`SweepKnobs::from`), so coordinator and wire can never drift.
    fn spec_for(&self, slot: usize, worker: u64) -> JobSpec {
        let s = &self.slots[slot];
        JobSpec {
            worker,
            partition: slot as u32,
            doc_start: s.range.start as u64,
            doc_end: s.range.end as u64,
            epoch: self.epoch,
            matrix_id: self.n_wk.id(),
            iterations: self.cfg.iterations,
            shard_addrs: self.shard_addrs.clone(),
            backup_addrs: self.backup_addrs.clone(),
            corpus: self.corpus_spec.clone(),
            knobs: SweepKnobs::from(&self.cfg),
        }
    }

    /// Handle one control request, returning the reply.
    fn handle(&mut self, req: CtrlRequest) -> CtrlResponse {
        match req {
            CtrlRequest::Register { token } => self.handle_register(token),
            CtrlRequest::Ready { worker, epoch, iteration } => {
                self.touch(worker);
                self.handle_ready(worker, epoch, iteration)
            }
            CtrlRequest::Poll { worker } => {
                self.touch(worker);
                self.handle_poll(worker)
            }
            CtrlRequest::Report { worker, epoch, iteration, stats } => {
                self.touch(worker);
                self.handle_report(worker, epoch, iteration, stats)
            }
            CtrlRequest::Heartbeat { worker } => {
                if self.touch(worker) {
                    CtrlResponse::Ack
                } else {
                    CtrlResponse::Error(format!("unknown worker {worker}"))
                }
            }
            CtrlRequest::Leave { worker } => self.handle_leave(worker),
        }
    }

    /// Refresh a worker's liveness stamp. False when unknown.
    fn touch(&mut self, worker: u64) -> bool {
        match self.workers.get_mut(&worker) {
            Some(entry) => {
                entry.last_seen = Instant::now();
                true
            }
            None => false,
        }
    }

    fn handle_register(&mut self, token: u64) -> CtrlResponse {
        if self.finished() {
            return CtrlResponse::Done;
        }
        // Idempotency: a retried Register whose reply was lost must not
        // seat the same process twice (the ghost seat would never
        // heartbeat, get reaped, and force a spurious epoch roll).
        if let Some(&worker) = self.registrations.get(&token) {
            if let Some(entry) = self.workers.get(&worker) {
                let slot = entry.slot;
                self.slots[slot].delivered_epoch = Some(self.epoch);
                return CtrlResponse::Job(Box::new(self.spec_for(slot, worker)));
            }
            // The original seat was reaped meanwhile; register afresh.
            self.registrations.remove(&token);
        }
        let Some(slot) = self.slots.iter().position(|s| s.worker.is_none()) else {
            // Fully staffed: the joiner becomes a standby. It retries
            // Register and picks a partition up the moment a failure
            // frees one.
            return CtrlResponse::Wait { millis: SPARE_WAIT_MS };
        };
        let worker = self.next_worker;
        self.next_worker += 1;
        self.registrations.insert(token, worker);
        if self.slots[slot].orphaned {
            // This partition lost its owner: a replacement pickup.
            self.reassignments += 1;
            self.slots[slot].orphaned = false;
        }
        self.slots[slot].worker = Some(worker);
        self.slots[slot].delivered_epoch = Some(self.epoch);
        self.slots[slot].ready = false;
        self.workers.insert(worker, WorkerEntry { slot, last_seen: Instant::now() });
        log_info!(
            "worker {worker} registered; assigned partition {slot} (epoch {})",
            self.epoch
        );
        CtrlResponse::Job(Box::new(self.spec_for(slot, worker)))
    }

    fn handle_ready(&mut self, worker: u64, epoch: u32, iteration: u32) -> CtrlResponse {
        let Some(slot) = self.workers.get(&worker).map(|e| e.slot) else {
            return CtrlResponse::Error(format!("unknown worker {worker}"));
        };
        if epoch != self.epoch {
            // Raced a rollback; hand out the fresh spec. Marking it
            // delivered here matters: otherwise the worker's next Poll
            // would see a stale delivered_epoch, get the job AGAIN, and
            // push its partition counts into the epoch's table twice
            // (pushes are additive deltas, not idempotent).
            self.slots[slot].delivered_epoch = Some(self.epoch);
            self.slots[slot].ready = false;
            return CtrlResponse::Job(Box::new(self.spec_for(slot, worker)));
        }
        let s = &mut self.slots[slot];
        s.ready = true;
        // The worker's disk is the authority on the resume point: its
        // restored state *is* a checkpoint at `iteration`.
        s.completed = iteration;
        s.checkpointed = iteration;
        log_info!(
            "partition {slot} ready at iteration {iteration} (epoch {epoch}, worker {worker})"
        );
        CtrlResponse::Ack
    }

    fn handle_poll(&mut self, worker: u64) -> CtrlResponse {
        if self.finished() {
            return CtrlResponse::Done;
        }
        let Some(slot) = self.workers.get(&worker).map(|e| e.slot) else {
            return CtrlResponse::Error(format!("unknown worker {worker} (re-register)"));
        };
        if self.slots[slot].delivered_epoch != Some(self.epoch) {
            // A rollback happened since this worker's last instruction:
            // reissue the assignment under the new epoch.
            self.slots[slot].delivered_epoch = Some(self.epoch);
            self.slots[slot].ready = false;
            return CtrlResponse::Job(Box::new(self.spec_for(slot, worker)));
        }
        if !self.slots[slot].ready || !self.all_ready() {
            // Either this worker polled before confirming Ready (odd but
            // harmless) or some partition is still rebuilding. The
            // column-sum totals are not meaningful yet.
            return CtrlResponse::Wait { millis: BARRIER_WAIT_MS };
        }
        let s = &self.slots[slot];
        if s.completed >= self.cfg.iterations {
            // This partition is done; idle until the rest catch up.
            return CtrlResponse::Wait { millis: BARRIER_WAIT_MS };
        }
        if s.completed > self.min_completed() + self.cfg.max_staleness {
            // Bounded-staleness barrier: too far ahead of the slowest.
            return CtrlResponse::Wait { millis: BARRIER_WAIT_MS };
        }
        let iteration = s.completed + 1;
        let evaluate = self.cfg.eval_every > 0 && iteration % self.cfg.eval_every == 0;
        CtrlResponse::Run { iteration, evaluate }
    }

    fn handle_report(
        &mut self,
        worker: u64,
        epoch: u32,
        iteration: u32,
        stats: SweepReport,
    ) -> CtrlResponse {
        let Some(slot) = self.workers.get(&worker).map(|e| e.slot) else {
            return CtrlResponse::Error(format!("unknown worker {worker} (re-register)"));
        };
        if epoch != self.epoch {
            // The sweep ran under a rolled-back epoch: its pushes went to
            // the fenced-off old table. Discard and reissue the job.
            self.slots[slot].delivered_epoch = Some(self.epoch);
            self.slots[slot].ready = false;
            return CtrlResponse::Job(Box::new(self.spec_for(slot, worker)));
        }
        let checkpointing = self.cfg.checkpoint_dir.is_some();
        {
            let s = &mut self.slots[slot];
            s.completed = iteration;
            if checkpointing {
                // Workers checkpoint before they report.
                s.checkpointed = iteration;
            }
        }
        let parts = self.slots.len();
        self.agg.entry(iteration).or_insert_with(|| vec![None; parts])[slot] = Some(stats);
        self.announce_progress();
        CtrlResponse::Ack
    }

    fn handle_leave(&mut self, worker: u64) -> CtrlResponse {
        if let Some(entry) = self.workers.remove(&worker) {
            if !self.finished() {
                // A mid-run goodbye is a failure for recovery purposes:
                // the partition's pushes stop at an arbitrary point.
                log_warn!("worker {worker} left mid-run; rolling epoch");
                self.slots[entry.slot].worker = None;
                self.slots[entry.slot].orphaned = true;
                self.roll_epoch();
            } else {
                self.slots[entry.slot].worker = None;
            }
        }
        CtrlResponse::Ack
    }

    /// Declare workers dead after the straggler timeout and roll the
    /// epoch if any held a partition.
    fn reap_dead(&mut self, straggler: Duration) {
        let now = Instant::now();
        let dead: Vec<u64> = self
            .workers
            .iter()
            .filter(|(_, e)| now.duration_since(e.last_seen) > straggler)
            .map(|(&id, _)| id)
            .collect();
        if dead.is_empty() {
            return;
        }
        for id in dead {
            if let Some(entry) = self.workers.remove(&id) {
                log_warn!(
                    "worker {id} (partition {}) missed the straggler timeout; presumed dead",
                    entry.slot
                );
                self.slots[entry.slot].worker = None;
                self.slots[entry.slot].orphaned = true;
            }
        }
        self.roll_epoch();
    }

    /// Watch replicated shards for primary death. The detector is the
    /// client's own failover: `ShardInfo` rides the shard's route, so
    /// an answer from an *un-promoted backup* (role 1) means the route
    /// already abandoned an unresponsive primary. Recovery is then
    /// promote → repoint the address future `JobSpec`s carry → roll the
    /// epoch, so every partition re-pushes its checkpoint counts into a
    /// fresh table on the survivor (healing the group-commit window and
    /// any replication lag lost with the primary).
    fn probe_replicas(&mut self) {
        if self.backup_addrs.is_empty() || self.last_probe.elapsed() < REPLICA_PROBE {
            return;
        }
        self.last_probe = Instant::now();
        for s in 0..self.client.shards() {
            let info = match self.client.shard_info(s) {
                Ok(info) => info,
                Err(e) => {
                    log_warn!("replica probe of shard {s} failed: {e}");
                    continue;
                }
            };
            if info.role != crate::ps::server::ROLE_BACKUP {
                continue;
            }
            log_warn!("shard {s}: primary presumed dead; promoting its backup");
            match self.client.promote_backup(s) {
                Ok(()) => {
                    self.shard_addrs[s] = self.backup_addrs[s].clone();
                    self.promotions += 1;
                    self.roll_epoch();
                }
                Err(e) => log_warn!("promotion of shard {s}'s backup failed: {e}"),
            }
        }
    }

    /// Start a fresh epoch after a failure: new count table (fencing off
    /// the old one), everyone rebuilds from checkpoints.
    fn roll_epoch(&mut self) {
        self.epoch += 1;
        let fenced = self.n_wk.id();
        match self.client.matrix_with_layout::<i64>(
            self.vocab_size as u64,
            self.cfg.num_topics,
            self.cfg.wt_layout,
        ) {
            Ok(m) => {
                self.n_wk = m;
                // Retire the table fenced off by the *previous* roll.
                // The just-fenced table gets one epoch of grace: live
                // workers may still be mid-sweep with pushes referencing
                // it, and those must land in the abandoned table (and be
                // ignored) rather than bounce with "unknown matrix" and
                // kill an otherwise healthy worker. One roll later no
                // sweep can reference it, so shards free its resident
                // rows and their WAL compactions stop carrying it.
                // Best-effort — a shard that misses the delete only
                // wastes memory (a zombie push to the deleted id is
                // rejected, which is also what fencing wants).
                if let Some(old) = self.fenced.replace(fenced) {
                    if let Err(e) = self.client.delete_matrix(old) {
                        log_warn!("could not retire fenced count table {old}: {e}");
                    }
                }
            }
            Err(e) => {
                // Without a fresh table there is no consistent recovery:
                // directing workers to re-push their checkpoint counts
                // into the old (contaminated) table would double every
                // surviving partition. The create already ran the full
                // retry/back-off budget, so the shards are genuinely
                // unreachable — abort the run instead of corrupting it.
                log_warn!(
                    "could not create epoch {} count table ({e}); aborting the run",
                    self.epoch
                );
                self.fatal = Some(e);
                return;
            }
        }
        for s in self.slots.iter_mut() {
            s.ready = false;
            s.delivered_epoch = None;
            // Resume point: the newest checkpoint we know of. The
            // worker's Ready confirms (or corrects) this from disk.
            s.completed = s.checkpointed;
        }
        // Drop aggregate rows beyond the common resume point: partitions
        // behind it will re-report those iterations under the new table,
        // while partitions ahead will not — a mix that would produce
        // rows (and perplexities) spanning two different count tables.
        // Dropped iterations simply re-complete (or stay absent, which
        // is honest) rather than reporting a silently wrong metric.
        let base = self.min_completed();
        self.agg.retain(|&it, _| it <= base);
        self.ps_health.retain(|&it, _| it <= base);
        self.announced = self.announced.min(base);
        log_info!(
            "epoch rolled to {} (matrix {}); partitions resume from their checkpoints",
            self.epoch,
            self.n_wk.id()
        );
    }

    /// Log iterations as they become fully reported, in order, and
    /// sample parameter-server health for the iteration's report row.
    fn announce_progress(&mut self) {
        loop {
            let next = self.announced + 1;
            let Some(agg) = self.agg.get(&next).and_then(|r| aggregate(r)) else {
                return;
            };
            if self.min_completed() < next {
                return;
            }
            let rate = agg.tokens as f64 / agg.secs.max(1e-9);
            match agg.perplexity {
                Some(p) => log_info!("iter {next}: perplexity {p:.1}, {rate:.0} tokens/s"),
                None => log_info!(
                    "iter {next}: {rate:.0} tokens/s across {} partitions",
                    agg.partitions
                ),
            }
            self.announced = next;
            if let Ok(infos) = self.client.shard_infos() {
                self.ps_health.insert(
                    next,
                    PsHealth {
                        bytes: infos.iter().map(|i| i.bytes).sum(),
                        dedup_evictions: infos.iter().map(|i| i.dedup_evictions).sum(),
                        wal_bytes: infos.iter().map(|i| i.wal_bytes).sum(),
                        repl_lag: infos.iter().map(|i| i.repl_lag).sum(),
                    },
                );
            }
        }
    }

    /// Assemble the final per-iteration report (and the last evaluated
    /// perplexity) from the aggregation map.
    fn build_report(&self) -> (Report, Option<f64>) {
        let report = Report::new();
        let mut final_perplexity = None;
        for (&iter, reports) in &self.agg {
            let Some(agg) = aggregate(reports) else {
                continue;
            };
            let mut row = Row::new()
                .set("iter", iter as f64)
                .set("seconds", agg.secs)
                .set("tokens", agg.tokens as f64)
                .set(
                    "tokens_per_sec",
                    if agg.secs > 0.0 { agg.tokens as f64 / agg.secs } else { 0.0 },
                )
                .set("changed_frac", agg.changed as f64 / agg.tokens.max(1) as f64)
                .set("alias_build_secs", agg.alias_build_secs)
                .set("block_wait_secs", agg.block_wait_secs)
                .set("partitions", agg.partitions as f64);
            if let Some(p) = agg.perplexity {
                row = row.set("perplexity", p);
                final_perplexity = Some(p);
            }
            if let Some(&h) = self.ps_health.get(&iter) {
                row = row
                    .set("ps_resident_bytes", h.bytes as f64)
                    .set("ps_dedup_evictions", h.dedup_evictions as f64)
                    .set("ps_wal_bytes", h.wal_bytes as f64)
                    .set("ps_repl_lag", h.repl_lag as f64);
            }
            report.push(row);
        }
        (report, final_perplexity)
    }
}
