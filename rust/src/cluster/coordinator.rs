//! The cluster coordinator: the driver of a multi-process LightLDA run
//! (the analog of the paper's Spark driver dispatching APS-LDA tasks).
//!
//! Since the elastic-membership refactor the coordinator is a thin
//! network / parameter-server shell around
//! [`Membership`](crate::cluster::membership::Membership), the pure
//! state machine that owns partitions, admissions, warm transfers,
//! drains and straggler shedding. This file only does I/O: it drains
//! one tagged-frame TCP inbox (single-threaded actor, exactly like a
//! shard serve loop), maps control requests onto membership verdicts,
//! creates fresh count tables when an epoch rolls, and aggregates
//! per-iteration reports.
//!
//! # Iteration loop
//!
//! A partition may start iteration `t+1` once (a) every partition has
//! pushed its counts for the current epoch (the `Ready` barrier — the
//! column-sum topic totals are meaningless before that) and (b) it is
//! at most [`TrainConfig::max_staleness`] iterations ahead of the
//! slowest partition — the asynchronous bounded-staleness barrier.
//! Workers flush their pushes and checkpoint *before* reporting, so
//! when every partition has reported iteration `t`, the tables on the
//! shards are exactly the counts of the reported assignments. In
//! snapshot mode ([`TrainConfig::snapshot`]) an additional *fetch
//! barrier* ([`CtrlRequest::Fetched`]) orders snapshot pulls against
//! sweeps, making the final table bit-exact under any membership
//! history.
//!
//! # Elasticity
//!
//! With `--elastic`, members live on a consistent-hash ring and
//! partitions move between live workers as *warm transfers*: the donor
//! releases at a sweep boundary ([`CtrlResponse::Transfer`]), the
//! recipient resumes from the partition checkpoint with its counts
//! already in the table — no re-push, no epoch roll. Joins mid-run,
//! planned drains (`Drain`) and straggler shedding all reduce to ring
//! recomputations plus warm transfers. Static mode (the default) keeps
//! the historical fixed partition table, except that surplus
//! registrants are now *parked*: the coordinator holds their `Register`
//! envelope and replies the moment a partition frees, instead of
//! making them re-poll.
//!
//! # Failure recovery (paper §3.5, per-partition form)
//!
//! A worker silent for [`TrainConfig::straggler_timeout_ms`] is
//! declared dead. Its partial pushes have already contaminated the
//! epoch's count table, so the coordinator *rolls the epoch*: it bumps
//! the epoch counter, creates a **fresh** count table (a new matrix id
//! — which also fences off any zombie worker still pushing to the old
//! one), and reissues every [`JobSpec`]. Each worker — survivors
//! included — reloads its partitions' last valid checkpoints (or
//! re-initializes, if none), pushes those counts into the new table,
//! and resumes. A reaped worker that was merely slow *rejoins warm*:
//! its next request answers `Error`, it re-registers with the same
//! token, and the ring hands it back its old ranges.
//!
//! # Shard failure (replicated deployments)
//!
//! With backups (`serve --backup-of` processes named by
//! [`TrainConfig::backups`]), worker and coordinator clients fail over
//! to a shard's backup automatically after repeated delivery failures.
//! The coordinator additionally *probes* every shard's `ShardInfo`: an
//! answer from an un-promoted backup means its own route abandoned the
//! primary — the shard-death signal. It then promotes the backup,
//! repoints the shard address in future [`JobSpec`]s, and rolls the
//! epoch, so every partition re-pushes its checkpoint counts into a
//! fresh table on the surviving replica set.

use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::cluster::membership::{
    AckVerdict, Admission, Counters, DrainVerdict, FetchVerdict, Membership, MembershipCfg,
    PollVerdict, DEFAULT_VNODES,
};
use crate::cluster::protocol::{
    CorpusSpec, CtrlRequest, CtrlResponse, JobSpec, PartitionAssignment, SweepKnobs,
    SweepReport,
};
use crate::corpus::dataset::Corpus;
use crate::eval::perplexity::{perplexity_from_loglik, TopicModel};
use crate::lda::sweep::pull_full_model;
use crate::lda::trainer::TrainConfig;
use crate::metrics::{Report, Row};
use crate::net::tcp::{resolve_addrs, TcpServer, TcpTransport};
use crate::net::{respond, Envelope, Inbox, Transport};
use crate::ps::client::{BigMatrix, PsClient};
use crate::ps::config::{PsConfig, TransportMode};
use crate::util::error::{Error, Result};
use crate::{log_info, log_warn};

/// How long the coordinator's inbox waits per tick before re-checking
/// worker liveness and completion.
const TICK: Duration = Duration::from_millis(50);
/// Back-off suggested to a worker parked at a barrier.
const BARRIER_WAIT_MS: u64 = 100;
/// How long the coordinator keeps answering `Done` after completion so
/// workers can exit cleanly before it tears the listener down.
const DRAIN_GRACE: Duration = Duration::from_secs(5);
/// How long the control line must stay quiet during the final drain
/// before the coordinator stops listening.
const QUIET_MS: u64 = 400;
/// How often the coordinator probes shard roles for primary death
/// (replicated deployments only).
const REPLICA_PROBE: Duration = Duration::from_millis(500);

/// One iteration's aggregate across partitions (only built once every
/// partition has reported it).
struct IterAgg {
    tokens: u64,
    changed: u64,
    /// Wall-clock of the slowest partition.
    secs: f64,
    /// Alias-construction seconds summed over partitions.
    alias_build_secs: f64,
    /// Pipeline-stall seconds summed over partitions.
    block_wait_secs: f64,
    partitions: usize,
    /// Summed perplexity when every partition evaluated this iteration.
    perplexity: Option<f64>,
}

/// Fold a complete report set into its aggregate; `None` while any
/// partition is missing.
fn aggregate(reports: &[Option<SweepReport>]) -> Option<IterAgg> {
    if !reports.iter().all(|r| r.is_some()) {
        return None;
    }
    let tokens = reports.iter().flatten().map(|r| r.tokens).sum();
    let changed = reports.iter().flatten().map(|r| r.changed).sum();
    let secs = reports.iter().flatten().map(|r| r.seconds).fold(0.0f64, f64::max);
    let alias_build_secs = reports.iter().flatten().map(|r| r.alias_build_secs).sum();
    let block_wait_secs = reports.iter().flatten().map(|r| r.block_wait_secs).sum();
    let perplexity = if reports.iter().flatten().all(|r| r.evaluated) {
        let ll: f64 = reports.iter().flatten().map(|r| r.log_likelihood).sum();
        let n: u64 = reports.iter().flatten().map(|r| r.ll_tokens).sum();
        Some(perplexity_from_loglik(ll, n))
    } else {
        None
    };
    Some(IterAgg {
        tokens,
        changed,
        secs,
        alias_build_secs,
        block_wait_secs,
        partitions: reports.len(),
        perplexity,
    })
}

/// Parameter-server health sampled when an iteration completes, summed
/// over shards.
#[derive(Clone, Copy)]
struct PsHealth {
    bytes: u64,
    dedup_evictions: u64,
    wal_bytes: u64,
    repl_lag: u64,
    unavailable_retries: u64,
}

/// Membership state sampled when an iteration completes.
#[derive(Clone, Copy)]
struct MemberSample {
    members: usize,
    rebalances: u64,
    moved_partitions: u64,
    drain_count: u64,
}

/// What a finished cluster run produced.
pub struct ClusterOutcome {
    /// Per-iteration aggregate rows (tokens, seconds, perplexity at
    /// evaluation points, parameter-server health, membership).
    pub report: Report,
    /// The final model pulled off the parameter servers.
    pub model: TopicModel,
    /// Perplexity of the last evaluated iteration, if any was scheduled.
    pub final_perplexity: Option<f64>,
    /// Recovery epochs the run went through (0 = no failures).
    pub epochs: u32,
    /// Partitions handed to a replacement worker after a failure.
    pub reassignments: u32,
    /// Shard backups promoted to primary after a shard death.
    pub promotions: u32,
    /// Standbys re-seeded behind a freshly promoted head (chain heals).
    pub reseeds: u32,
    /// Planned zero-roll shard hand-offs ([`Coordinator::drain_shard`]).
    pub shard_drains: u32,
    /// Total `Unavailable` retry pauses the coordinator's own PS client
    /// sat through, summed over shards — the drain demo's no-storm gate.
    pub ps_unavailable_retries: u64,
    /// Membership counters: rebalances, warm moves, drains, sheds.
    pub counters: Counters,
}

/// The coordinator half of a cluster run. Construct with
/// [`Coordinator::bind`], hand out [`Coordinator::addr`] to workers
/// (`glint-lda work --join <addr>`), then [`Coordinator::run`] to
/// completion.
pub struct Coordinator {
    cfg: TrainConfig,
    corpus_spec: CorpusSpec,
    shard_addrs: Vec<String>,
    /// Backup replica addresses, tier-major: `k * shards` entries
    /// describe a chain of depth `k`, `backup_addrs[t*shards + s]`
    /// being shard `s`'s tier-`t+1` replica (empty = unreplicated
    /// deployment).
    backup_addrs: Vec<String>,
    vocab_size: u32,
    server: TcpServer,
    inbox: Inbox,
    /// The PS-facing transport backing `client`/`n_wk` (epoch-table
    /// creation, health sampling, final model pull).
    _transport: Arc<dyn Transport>,
    client: PsClient,
    n_wk: BigMatrix<i64>,
    /// The membership state machine: partitions, admissions, transfers.
    membership: Membership,
    /// Zero point for the relative millisecond clock membership sees.
    start: Instant,
    promotions: u32,
    reseeds: u32,
    shard_drains: u32,
    /// Count table fenced off by the last epoch roll, retired (deleted
    /// on the shards) at the *next* roll — the one-epoch grace lets
    /// mid-sweep pushes that still reference it land harmlessly.
    fenced: Option<u32>,
    /// Last shard-role probe (rate-limits `probe_replicas`).
    last_probe: Instant,
    /// Per-iteration, per-partition reports (overwritten on re-runs
    /// after a rollback).
    agg: BTreeMap<u32, Vec<Option<SweepReport>>>,
    /// Parameter-server health sampled when an iteration completes.
    ps_health: BTreeMap<u32, PsHealth>,
    /// Membership sampled when an iteration completes.
    member_health: BTreeMap<u32, MemberSample>,
    /// Iterations already announced in the log.
    announced: u32,
    /// Set when recovery is impossible (e.g. no fresh count table could
    /// be created); the run loop aborts with this error.
    fatal: Option<Error>,
    /// Held `Register` envelopes of parked standbys (static mode),
    /// keyed by registration token: answered with a `Job` the moment a
    /// partition frees, or `Done` when the run finishes.
    parked: HashMap<u64, Envelope>,
}

impl Coordinator {
    /// Bind the control listener on `bind` (`host:port`; port 0 picks an
    /// ephemeral port), connect to the parameter-server shards named by
    /// `cfg.transport` (`TransportMode::Connect` required), create the
    /// epoch-0 count table and compute the partition table for
    /// `corpus`. `corpus_spec` is what workers are told about where to
    /// find that same corpus.
    pub fn bind(
        bind: &str,
        cfg: TrainConfig,
        corpus: &Corpus,
        corpus_spec: CorpusSpec,
    ) -> Result<Coordinator> {
        cfg.hyper().validate()?;
        if corpus.num_docs() == 0 {
            return Err(Error::Config("empty corpus".into()));
        }
        if cfg.elastic && cfg.checkpoint_dir.is_none() {
            return Err(Error::Config(
                "--elastic needs --checkpoint-dir: warm partition transfers resume \
                 from per-partition checkpoints"
                    .into(),
            ));
        }
        let TransportMode::Connect(addrs) = &cfg.transport else {
            return Err(Error::Config(
                "cluster mode needs --connect shard addresses (start `serve` first)".into(),
            ));
        };
        let shard_addrs = addrs.clone();
        let resolved = resolve_addrs(&shard_addrs)?;
        let backup_addrs = cfg.backups.clone();
        if !backup_addrs.is_empty() && backup_addrs.len() % shard_addrs.len() != 0 {
            return Err(Error::Config(format!(
                "--backups needs whole tiers of {} address(es) (tier-major, one per \
                 shard), got {}",
                shard_addrs.len(),
                backup_addrs.len()
            )));
        }
        let mut ps_cfg = PsConfig::deployment(
            resolved.len(),
            cfg.scheme,
            cfg.transport.clone(),
            cfg.sampler.pipeline_depth,
        );
        ps_cfg.backups = backup_addrs.clone();
        let transport: Arc<dyn Transport> = Arc::new(TcpTransport::connect(&resolved));
        let client = PsClient::connect(&*transport, ps_cfg);
        client.validate_deployment()?;
        let n_wk: BigMatrix<i64> = client.matrix_with_layout(
            corpus.vocab_size as u64,
            cfg.num_topics,
            cfg.wt_layout,
        )?;

        let bind_addr = resolve_addrs(&[bind.to_string()])?[0];
        let (server, mut inboxes) = TcpServer::bind(&[bind_addr])?;
        let inbox = inboxes.remove(0);

        // Over-partition: partition identity (index, doc range, RNG
        // stream, checkpoint prefix) is fixed for the whole run; the
        // ring moves whole partitions between members.
        let parts = cfg.workers.max(1) * cfg.partition_factor.max(1);
        let membership = Membership::new(
            MembershipCfg {
                elastic: cfg.elastic,
                workers: cfg.workers,
                vnodes: DEFAULT_VNODES,
                iterations: cfg.iterations,
                max_staleness: cfg.max_staleness,
                checkpointing: cfg.checkpoint_dir.is_some(),
                shed_factor: cfg.shed_factor,
                shed_stall_ms: cfg.shed_stall_ms,
            },
            corpus.partitions(parts),
        );

        Ok(Coordinator {
            vocab_size: corpus.vocab_size,
            corpus_spec,
            shard_addrs,
            backup_addrs,
            server,
            inbox,
            _transport: transport,
            client,
            n_wk,
            membership,
            start: Instant::now(),
            promotions: 0,
            reseeds: 0,
            shard_drains: 0,
            fenced: None,
            last_probe: Instant::now(),
            agg: BTreeMap::new(),
            ps_health: BTreeMap::new(),
            member_health: BTreeMap::new(),
            announced: 0,
            fatal: None,
            parked: HashMap::new(),
            cfg,
        })
    }

    /// The control-plane address workers join at.
    pub fn addr(&self) -> std::net::SocketAddr {
        self.server.addrs()[0]
    }

    /// Milliseconds since the coordinator came up (the monotonic clock
    /// the membership state machine runs on).
    fn now_ms(&self) -> u64 {
        self.start.elapsed().as_millis() as u64
    }

    /// Drive the run to completion: serve the control plane, detect dead
    /// workers, roll epochs on failure, and return the aggregated
    /// report plus the final model.
    pub fn run(mut self) -> Result<ClusterOutcome> {
        let total = self.cfg.iterations;
        let straggler_ms = self.cfg.straggler_timeout_ms.max(1);
        log_info!(
            "coordinator up on {} ({} partitions, {} iterations, staleness {}, {})",
            self.addr(),
            self.membership.parts_len(),
            total,
            self.cfg.max_staleness,
            if self.cfg.elastic { "elastic" } else { "static" }
        );
        while !self.membership.finished() {
            if let Some(env) = self.inbox.recv_timeout(TICK) {
                self.serve_one(env);
                // Drain everything already queued before judging
                // liveness: a brief stall in this loop (e.g. creating an
                // epoch's table) must not let queued-but-unread
                // heartbeats read as worker silence.
                while let Some(env) = self.inbox.recv_timeout(Duration::ZERO) {
                    self.serve_one(env);
                }
            }
            self.reap_dead(straggler_ms);
            self.maybe_roll();
            self.maybe_shed();
            self.flush_admitted();
            self.probe_replicas();
            self.maybe_drain_shard();
            if let Some(e) = self.fatal.take() {
                self.answer_parked_done();
                self.server.shutdown();
                return Err(e);
            }
        }
        log_info!("all {} iterations complete; draining workers", total);
        // Standbys parked on a held envelope hear the verdict directly.
        self.answer_parked_done();
        // Keep answering (with Done) until every member said goodbye AND
        // the line has been quiet for a beat — bounded by a hard grace
        // deadline.
        let drain_deadline = Instant::now() + DRAIN_GRACE;
        let quiet_needed = Duration::from_millis(QUIET_MS);
        let mut last_request = Instant::now();
        while Instant::now() < drain_deadline
            && (self.membership.members_len() > 0 || last_request.elapsed() < quiet_needed)
        {
            if let Some(env) = self.inbox.recv_timeout(TICK) {
                last_request = Instant::now();
                self.serve_one(env);
            }
        }
        self.server.shutdown();

        let model = pull_full_model(
            &self.n_wk,
            self.vocab_size,
            self.cfg.sampler.pipeline_depth,
            self.cfg.hyper(),
        )?;
        let (report, final_perplexity) = self.build_report();
        Ok(ClusterOutcome {
            report,
            model,
            final_perplexity,
            epochs: self.membership.epoch(),
            reassignments: self.membership.counters.reassignments as u32,
            promotions: self.promotions,
            reseeds: self.reseeds,
            shard_drains: self.shard_drains,
            ps_unavailable_retries: (0..self.client.shards())
                .map(|s| self.client.unavailable_retries(s))
                .sum(),
            counters: self.membership.counters,
        })
    }

    /// Decode, dispatch and answer one inbound control envelope.
    /// `Register` may *hold* the envelope instead (parked standby).
    fn serve_one(&mut self, env: Envelope) {
        let req = match CtrlRequest::decode(&env.payload) {
            Ok(req) => req,
            Err(e) => {
                respond(&env, CtrlResponse::Error(e.to_string()).encode());
                return;
            }
        };
        if let CtrlRequest::Register { token } = req {
            self.handle_register(token, env);
        } else {
            let resp = self.handle(req);
            respond(&env, resp.encode());
        }
        self.maybe_roll();
        self.flush_admitted();
    }

    /// Build the `JobSpec` reply for `worker`'s current assignment. The
    /// knobs are the one canonical projection of the trainer config
    /// (`SweepKnobs::from`), so coordinator and wire can never drift.
    fn build_spec(&mut self, worker: u64) -> CtrlResponse {
        let parts = self
            .membership
            .spec_for(worker)
            .into_iter()
            .map(|a| PartitionAssignment {
                partition: a.part,
                doc_start: a.doc_start as u64,
                doc_end: a.doc_end as u64,
                resume: a.resume,
                push: a.push,
            })
            .collect();
        CtrlResponse::Job(Box::new(JobSpec {
            worker,
            parts,
            epoch: self.membership.epoch(),
            matrix_id: self.n_wk.id(),
            iterations: self.cfg.iterations,
            shard_addrs: self.shard_addrs.clone(),
            backup_addrs: self.backup_addrs.clone(),
            corpus: self.corpus_spec.clone(),
            knobs: SweepKnobs::from(&self.cfg),
        }))
    }

    /// Handle one control request, returning the reply.
    fn handle(&mut self, req: CtrlRequest) -> CtrlResponse {
        let now = self.now_ms();
        match req {
            // Held-envelope path; never reaches here.
            CtrlRequest::Register { token } => {
                match self.membership.register(token, now) {
                    Admission::Seated { worker } | Admission::Existing { worker } => {
                        self.build_spec(worker)
                    }
                    Admission::Parked => CtrlResponse::Wait { millis: QUIET_MS },
                    Admission::Finished => CtrlResponse::Done,
                }
            }
            CtrlRequest::Ready { worker, epoch, parts } => {
                match self.membership.ready(worker, epoch, &parts, now) {
                    AckVerdict::Ok => CtrlResponse::Ack,
                    AckVerdict::Respec => self.build_spec(worker),
                    AckVerdict::Unknown => unknown(worker),
                }
            }
            CtrlRequest::Poll { worker } => match self.membership.poll(worker, now) {
                PollVerdict::Respec => self.build_spec(worker),
                PollVerdict::Transfer(parts) => CtrlResponse::Transfer { parts },
                PollVerdict::Run { part, iteration } => {
                    let evaluate =
                        self.cfg.eval_every > 0 && iteration % self.cfg.eval_every == 0;
                    CtrlResponse::Run { partition: part, iteration, evaluate }
                }
                PollVerdict::Wait => CtrlResponse::Wait { millis: BARRIER_WAIT_MS },
                PollVerdict::Drained => {
                    let remaining = self.membership.members_len();
                    log_info!("worker {worker} drained; {remaining} members remain");
                    CtrlResponse::Drained
                }
                PollVerdict::Done => CtrlResponse::Done,
                PollVerdict::Unknown => unknown(worker),
            },
            CtrlRequest::Report { worker, epoch, partition, iteration, stats } => {
                match self.membership.report(worker, epoch, partition, iteration, now) {
                    AckVerdict::Ok => {
                        if self.membership.owner(partition) == Some(worker) {
                            let parts = self.membership.parts_len();
                            self.agg.entry(iteration).or_insert_with(|| vec![None; parts])
                                [partition as usize] = Some(stats);
                            self.announce_progress();
                        }
                        CtrlResponse::Ack
                    }
                    AckVerdict::Respec => self.build_spec(worker),
                    AckVerdict::Unknown => unknown(worker),
                }
            }
            CtrlRequest::Fetched { worker, epoch, partition, iteration } => {
                match self.membership.fetched(worker, epoch, partition, iteration, now) {
                    FetchVerdict::Go => CtrlResponse::Ack,
                    FetchVerdict::Hold => CtrlResponse::Wait { millis: BARRIER_WAIT_MS },
                    FetchVerdict::Respec => self.build_spec(worker),
                    FetchVerdict::Unknown => unknown(worker),
                }
            }
            CtrlRequest::Heartbeat { worker } => {
                if self.membership.touch(worker, now) {
                    CtrlResponse::Ack
                } else {
                    unknown(worker)
                }
            }
            CtrlRequest::Drain { worker } => match self.membership.drain(worker, now) {
                DrainVerdict::Draining => {
                    log_info!("worker {worker} draining; partitions transfer at boundaries");
                    CtrlResponse::Ack
                }
                DrainVerdict::Drained => {
                    log_info!("worker {worker} drained");
                    CtrlResponse::Drained
                }
                DrainVerdict::Unknown => unknown(worker),
            },
            CtrlRequest::Leave { worker } => {
                self.membership.leave(worker, now);
                CtrlResponse::Ack
            }
        }
    }

    /// Seat, park, or re-acknowledge a registrant. A parked standby's
    /// envelope is held (no reply) until a partition frees or the run
    /// finishes; a re-register from the same token replaces the held
    /// envelope (its predecessor's reply channel timed out worker-side).
    fn handle_register(&mut self, token: u64, env: Envelope) {
        match self.membership.register(token, self.now_ms()) {
            Admission::Seated { worker } => {
                log_info!(
                    "worker {worker} registered (token {token:#018x}, epoch {})",
                    self.membership.epoch()
                );
                let resp = self.build_spec(worker);
                respond(&env, resp.encode());
            }
            Admission::Existing { worker } => {
                // Idempotency: a retried Register whose reply was lost
                // re-receives its current assignment instead of being
                // seated twice.
                let resp = self.build_spec(worker);
                respond(&env, resp.encode());
            }
            Admission::Parked => {
                log_info!("standby parked (token {token:#018x}); answered when a seat frees");
                self.parked.insert(token, env);
            }
            Admission::Finished => {
                respond(&env, CtrlResponse::Done.encode());
            }
        }
    }

    /// Reply to parked standbys admitted by a capacity change.
    fn flush_admitted(&mut self) {
        for (token, worker) in self.membership.take_admitted() {
            let resp = self.build_spec(worker);
            match self.parked.remove(&token) {
                Some(env) => {
                    log_info!("parked standby admitted as worker {worker}");
                    respond(&env, resp.encode());
                }
                // Envelope lost (connection died while parked): the
                // standby re-registers with the same token and the
                // idempotent path re-delivers the spec.
                None => log_warn!("admitted token {token:#018x} had no held envelope"),
            }
        }
    }

    /// Answer every held standby envelope with `Done`.
    fn answer_parked_done(&mut self) {
        for (_, env) in self.parked.drain() {
            respond(&env, CtrlResponse::Done.encode());
        }
    }

    /// Declare workers dead after the straggler timeout; membership
    /// decides whether that forces an epoch roll.
    fn reap_dead(&mut self, straggler_ms: u64) {
        let dead = self.membership.reap(self.now_ms(), straggler_ms);
        for w in dead {
            log_warn!("worker {w} missed the straggler timeout; presumed dead");
        }
    }

    /// Roll the epoch if membership wants one (reap with owned
    /// partitions, failed warm handoff, cold drain, mid-run leave).
    fn maybe_roll(&mut self) {
        if self.membership.roll_wanted() {
            self.roll_epoch();
        }
    }

    /// Shed load off a straggler: narrow its ring range so the next
    /// rebalance moves partitions to faster members.
    fn maybe_shed(&mut self) {
        if let Some(ev) = self.membership.maybe_shed(self.now_ms()) {
            log_warn!(
                "straggler shed: partition {} lags; worker {} narrowed to ring weight {}",
                ev.part,
                ev.worker,
                ev.new_weight
            );
        }
    }

    /// Watch replicated shards for head death. The detector is the
    /// client's own failover: `ShardInfo` rides the shard's route, so
    /// an answer from an *un-promoted backup* means the route already
    /// abandoned an unresponsive head. Recovery walks the chain:
    /// promote the first live standby (tier 1, or tier 2 if that too is
    /// gone), repoint the address future `JobSpec`s carry, roll the
    /// epoch (the head's un-replicated commit window died with it), and
    /// then *re-seed* every remaining standby behind the new head so
    /// the chain heals back toward full depth without pausing training.
    /// A shard answering as a draining head is mid-planned-hand-off
    /// ([`Coordinator::drain_shard`]) and is left alone.
    fn probe_replicas(&mut self) {
        if self.backup_addrs.is_empty() || self.last_probe.elapsed() < REPLICA_PROBE {
            return;
        }
        self.last_probe = Instant::now();
        let shards = self.client.shards();
        for s in 0..shards {
            let info = match self.client.shard_info(s) {
                Ok(info) => info,
                Err(e) => {
                    log_warn!("replica probe of shard {s} failed: {e}");
                    continue;
                }
            };
            if info.role != crate::ps::server::ROLE_BACKUP {
                continue;
            }
            log_warn!("shard {s}: head presumed dead; promoting along the chain");
            let idx = match self.client.promote_backup(s) {
                Ok(idx) => idx,
                Err(e) => {
                    log_warn!("promotion on shard {s}'s chain failed: {e}");
                    continue;
                }
            };
            // Route position idx is chain tier idx (tier-major list).
            let head = self.backup_addrs[(idx - 1) * shards + s].clone();
            self.shard_addrs[s] = head.clone();
            self.promotions += 1;
            self.roll_epoch();
            self.reseed_standbys(s, idx, &head);
        }
    }

    /// Re-attach every remaining standby on `shard`'s chain behind the
    /// replica now serving at route position `head_idx` (listening on
    /// `head`): each standby receives the head's newest snapshot slice
    /// over `ReplSeed`, re-points its poller, and tails the head's log
    /// from there — the chain heals mid-run, with no training pause.
    fn reseed_standbys(&mut self, shard: usize, head_idx: usize, head: &str) {
        for (idx, role) in self.client.replica_roles(shard).into_iter().enumerate() {
            if idx == head_idx || role != Some(crate::ps::server::ROLE_BACKUP) {
                continue;
            }
            match self.client.reseed_backup(shard, idx, head) {
                Ok(()) => {
                    self.reseeds += 1;
                    log_info!("shard {shard}: standby {idx} re-seeded behind new head {head}");
                }
                Err(e) => log_warn!("shard {shard}: re-seed of standby {idx} failed: {e}"),
            }
        }
    }

    /// Fire the configured planned hand-off ([`TrainConfig::drain_shard_at`])
    /// once the slowest partition has completed the trigger iteration.
    /// One-shot: the knob is cleared after the first attempt, success or
    /// not — `drain_shard` blocks up to the client's timeout waiting for
    /// a standby to catch up, and retrying that every tick would stall
    /// the control loop.
    fn maybe_drain_shard(&mut self) {
        let Some((after, shard)) = self.cfg.drain_shard_at else {
            return;
        };
        if self.announced < after {
            return;
        }
        self.cfg.drain_shard_at = None;
        if let Err(e) = self.drain_shard(shard) {
            log_warn!("planned drain of shard {shard} failed: {e}");
        }
    }

    /// Planned zero-loss hand-off of `shard` to a standby (rolling
    /// maintenance): drain the serving head — it freezes writes, fsyncs
    /// and reports its committed tip — wait for a standby to replicate
    /// through that tip, promote it, and repoint future `JobSpec`s.
    /// Unlike crash recovery this needs **no epoch roll**: the tip
    /// covers the entire commit window, so nothing acked is lost and
    /// in-flight couriers simply retry their `Unavailable` answers onto
    /// the new head. Returns the route position now serving the shard.
    pub fn drain_shard(&mut self, shard: usize) -> Result<usize> {
        let idx = self.client.drain_shard(shard)?;
        if idx > 0 {
            let shards = self.client.shards();
            self.shard_addrs[shard] = self.backup_addrs[(idx - 1) * shards + shard].clone();
        }
        self.shard_drains += 1;
        log_info!("shard {shard}: drained onto replica {idx} with zero epoch rolls");
        Ok(idx)
    }

    /// Start a fresh epoch after a failure: new count table (fencing off
    /// the old one), everyone rebuilds from checkpoints.
    fn roll_epoch(&mut self) {
        match self.client.matrix_with_layout::<i64>(
            self.vocab_size as u64,
            self.cfg.num_topics,
            self.cfg.wt_layout,
        ) {
            Ok(m) => {
                let fenced = self.n_wk.id();
                self.n_wk = m;
                // Retire the table fenced off by the *previous* roll.
                // The just-fenced table gets one epoch of grace: live
                // workers may still be mid-sweep with pushes referencing
                // it, and those must land in the abandoned table (and be
                // ignored) rather than bounce with "unknown matrix" and
                // kill an otherwise healthy worker. One roll later no
                // sweep can reference it, so shards free its resident
                // rows and their WAL compactions stop carrying it.
                // Best-effort — a shard that misses the delete only
                // wastes memory (a zombie push to the deleted id is
                // rejected, which is also what fencing wants).
                if let Some(old) = self.fenced.replace(fenced) {
                    if let Err(e) = self.client.delete_matrix(old) {
                        log_warn!("could not retire fenced count table {old}: {e}");
                    }
                }
            }
            Err(e) => {
                // Without a fresh table there is no consistent recovery:
                // directing workers to re-push their checkpoint counts
                // into the old (contaminated) table would double every
                // surviving partition. The create already ran the full
                // retry/back-off budget, so the shards are genuinely
                // unreachable — abort the run instead of corrupting it.
                log_warn!(
                    "could not create epoch {} count table ({e}); aborting the run",
                    self.membership.epoch() + 1
                );
                self.fatal = Some(e);
                return;
            }
        }
        self.membership.rolled(self.now_ms());
        // Drop aggregate rows beyond the common resume point: partitions
        // behind it will re-report those iterations under the new table,
        // while partitions ahead will not — a mix that would produce
        // rows (and perplexities) spanning two different count tables.
        // Dropped iterations simply re-complete (or stay absent, which
        // is honest) rather than reporting a silently wrong metric.
        let base = self.membership.min_completed();
        self.agg.retain(|&it, _| it <= base);
        self.ps_health.retain(|&it, _| it <= base);
        self.member_health.retain(|&it, _| it <= base);
        self.announced = self.announced.min(base);
        log_info!(
            "epoch rolled to {} (matrix {}); partitions resume from their checkpoints",
            self.membership.epoch(),
            self.n_wk.id()
        );
    }

    /// Log iterations as they become fully reported, in order, and
    /// sample parameter-server health and membership for the
    /// iteration's report row.
    fn announce_progress(&mut self) {
        loop {
            let next = self.announced + 1;
            let Some(agg) = self.agg.get(&next).and_then(|r| aggregate(r)) else {
                return;
            };
            if self.membership.min_completed() < next {
                return;
            }
            let rate = agg.tokens as f64 / agg.secs.max(1e-9);
            match agg.perplexity {
                Some(p) => log_info!("iter {next}: perplexity {p:.1}, {rate:.0} tokens/s"),
                None => log_info!(
                    "iter {next}: {rate:.0} tokens/s across {} partitions",
                    agg.partitions
                ),
            }
            self.announced = next;
            self.member_health.insert(
                next,
                MemberSample {
                    members: self.membership.members_len(),
                    rebalances: self.membership.counters.rebalances,
                    moved_partitions: self.membership.counters.moved_partitions,
                    drain_count: self.membership.counters.drain_count,
                },
            );
            if let Ok(infos) = self.client.shard_infos() {
                self.ps_health.insert(
                    next,
                    PsHealth {
                        bytes: infos.iter().map(|i| i.bytes).sum(),
                        dedup_evictions: infos.iter().map(|i| i.dedup_evictions).sum(),
                        wal_bytes: infos.iter().map(|i| i.wal_bytes).sum(),
                        repl_lag: infos.iter().map(|i| i.repl_lag).sum(),
                        unavailable_retries: (0..self.client.shards())
                            .map(|s| self.client.unavailable_retries(s))
                            .sum(),
                    },
                );
            }
        }
    }

    /// Assemble the final per-iteration report (and the last evaluated
    /// perplexity) from the aggregation map.
    fn build_report(&self) -> (Report, Option<f64>) {
        let report = Report::new();
        let mut final_perplexity = None;
        for (&iter, reports) in &self.agg {
            let Some(agg) = aggregate(reports) else {
                continue;
            };
            let mut row = Row::new()
                .set("iter", iter as f64)
                .set("seconds", agg.secs)
                .set("tokens", agg.tokens as f64)
                .set(
                    "tokens_per_sec",
                    if agg.secs > 0.0 { agg.tokens as f64 / agg.secs } else { 0.0 },
                )
                .set("changed_frac", agg.changed as f64 / agg.tokens.max(1) as f64)
                .set("alias_build_secs", agg.alias_build_secs)
                .set("block_wait_secs", agg.block_wait_secs)
                .set("partitions", agg.partitions as f64);
            if let Some(p) = agg.perplexity {
                row = row.set("perplexity", p);
                final_perplexity = Some(p);
            }
            if let Some(&m) = self.member_health.get(&iter) {
                row = row
                    .set("members", m.members as f64)
                    .set("rebalances", m.rebalances as f64)
                    .set("moved_partitions", m.moved_partitions as f64)
                    .set("drain_count", m.drain_count as f64);
            }
            if let Some(&h) = self.ps_health.get(&iter) {
                row = row
                    .set("ps_resident_bytes", h.bytes as f64)
                    .set("ps_dedup_evictions", h.dedup_evictions as f64)
                    .set("ps_wal_bytes", h.wal_bytes as f64)
                    .set("ps_repl_lag", h.repl_lag as f64)
                    .set("ps_unavailable_retries", h.unavailable_retries as f64);
            }
            report.push(row);
        }
        (report, final_perplexity)
    }
}

/// The `Error` reply that tells a worker to re-register (zombie warm
/// rejoin path).
fn unknown(worker: u64) -> CtrlResponse {
    CtrlResponse::Error(format!("unknown worker {worker} (re-register)"))
}
