//! Control-plane wire messages between the cluster coordinator and its
//! worker processes.
//!
//! The control plane is worker-driven, matching the request/reply shape
//! of the tagged-frame TCP layer: workers *pull* their instructions
//! ([`CtrlRequest::Poll`]) instead of the coordinator pushing them, so
//! the coordinator stays a single-threaded actor over one inbox — the
//! same serve-loop model as a parameter-server shard — and a worker
//! behind a NAT or a slow link needs no listening socket of its own.
//!
//! Everything rides [`crate::util::codec`], like the data-plane
//! messages in [`crate::ps::messages`], so message sizes are faithful
//! and the two planes are wire-compatible with the same transports.

use crate::lda::sweep::SamplerParams;
use crate::lda::trainer::TrainConfig;
use crate::ps::messages::Layout;
use crate::ps::partition::PartitionScheme;
use crate::util::codec::{Reader, Writer};
use crate::util::error::{Error, Result};

/// Where a worker should get the training corpus.
#[derive(Debug, Clone, PartialEq)]
pub enum CorpusSpec {
    /// Load from this path (shared storage or a per-machine copy).
    File(String),
    /// Regenerate the synthetic ClueWeb12 analogue deterministically
    /// from these parameters ([`crate::corpus::synth::generate`]).
    Synth {
        /// Documents.
        num_docs: u64,
        /// Vocabulary size.
        vocab_size: u32,
        /// Generative topics.
        num_topics: u32,
        /// Average document length.
        avg_doc_len: f64,
        /// Zipf exponent of the word distribution.
        zipf_exponent: f64,
        /// Generator seed.
        seed: u64,
    },
    /// The worker was handed the corpus out-of-band (in-process workers
    /// in tests and examples). A standalone `work` process receiving
    /// this must have been given `--corpus` explicitly.
    Provided,
}

/// The sampling/deployment knobs a worker needs to run its partition —
/// the cluster projection of [`crate::lda::trainer::TrainConfig`].
#[derive(Debug, Clone, PartialEq)]
pub struct SweepKnobs {
    /// Number of topics K.
    pub num_topics: u32,
    /// Document-topic concentration (resolved, not the `<= 0` sentinel).
    pub alpha: f64,
    /// Topic-word concentration.
    pub beta: f64,
    /// Sampler-performance knobs, embedded verbatim from
    /// [`TrainConfig::sampler`].
    pub sampler: SamplerParams,
    /// Row partitioning scheme on the shards.
    pub scheme: PartitionScheme,
    /// Storage layout of the word-topic matrix.
    pub wt_layout: Layout,
    /// Cluster-wide RNG seed.
    pub seed: u64,
    /// Evaluate perplexity every N iterations (0 = never).
    pub eval_every: u32,
    /// Per-partition checkpoint directory (empty = checkpointing off).
    pub checkpoint_dir: String,
    /// Checkpoints retained per partition (0 keeps everything).
    pub keep_checkpoints: u32,
    /// Worker heartbeat period, milliseconds.
    pub heartbeat_ms: u64,
    /// Snapshot (BSP) mode: each sweep samples against a per-iteration
    /// model snapshot behind the coordinator's fetch barrier, making
    /// the final count table bit-identical for any membership history
    /// (see `README` "Elastic membership").
    pub snapshot: bool,
}

impl From<&TrainConfig> for SweepKnobs {
    /// Project a trainer configuration onto the wire: hyper-parameters
    /// are resolved (the `<= 0` alpha sentinel never crosses the
    /// network) and the checkpoint path flattens to a string (empty =
    /// checkpointing off).
    fn from(cfg: &TrainConfig) -> SweepKnobs {
        let hyper = cfg.hyper();
        SweepKnobs {
            num_topics: cfg.num_topics,
            alpha: hyper.alpha,
            beta: hyper.beta,
            sampler: cfg.sampler,
            scheme: cfg.scheme,
            wt_layout: cfg.wt_layout,
            seed: cfg.seed,
            eval_every: cfg.eval_every,
            checkpoint_dir: cfg
                .checkpoint_dir
                .as_ref()
                .map(|d| d.to_string_lossy().into_owned())
                .unwrap_or_default(),
            keep_checkpoints: cfg.keep_checkpoints as u32,
            heartbeat_ms: cfg.heartbeat_ms,
            snapshot: cfg.snapshot,
        }
    }
}

/// One partition's slice of a [`JobSpec`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PartitionAssignment {
    /// Partition index within the run (stable for the whole run: it
    /// keys the RNG stream and the checkpoint file prefix).
    pub partition: u32,
    /// First document (absolute corpus index) of the partition.
    pub doc_start: u64,
    /// One past the last document of the partition.
    pub doc_end: u64,
    /// Checkpoint iteration to resume from (0 = none; build fresh).
    pub resume: u32,
    /// Whether to push the rebuilt counts into the epoch's table.
    /// `false` on warm handoffs: the donor's counts are already there.
    pub push: bool,
}

/// A worker's marching orders: which partitions of which corpus to
/// sample, against which shards, into which count table. Reissued in
/// full whenever the assignment changes (a new epoch after a failure, a
/// ring rebalance granting a partition, or a partition handed to a
/// replacement worker); a worker diffs successive specs and keeps the
/// runners it already has.
#[derive(Debug, Clone, PartialEq)]
pub struct JobSpec {
    /// The coordinator-assigned worker id (echoed in every subsequent
    /// request).
    pub worker: u64,
    /// The partitions this worker currently owns (may be empty for a
    /// freshly joined member whose transfers are still pending).
    pub parts: Vec<PartitionAssignment>,
    /// Recovery epoch: bumped on every failure rollback. Each epoch has
    /// its own count table on the parameter servers.
    pub epoch: u32,
    /// Matrix id of this epoch's word-topic table (attach with
    /// [`crate::ps::client::PsClient::attach_matrix`]).
    pub matrix_id: u32,
    /// Total sweeps the run performs.
    pub iterations: u32,
    /// Parameter-server shard addresses, in shard order.
    pub shard_addrs: Vec<String>,
    /// Backup replica addresses, parallel to `shard_addrs` (empty when
    /// the deployment runs without replication). Workers hand these to
    /// their [`crate::ps::client::PsClient`] so pushes fail over to a
    /// promoted backup instead of dying with the primary.
    pub backup_addrs: Vec<String>,
    /// Where the worker gets the corpus.
    pub corpus: CorpusSpec,
    /// Sampling and deployment knobs.
    pub knobs: SweepKnobs,
}

/// Per-sweep counters a worker reports back, plus its log-likelihood
/// contribution when the iteration was an evaluation point.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct SweepReport {
    /// Tokens resampled.
    pub tokens: u64,
    /// Topic reassignments.
    pub changed: u64,
    /// Sparse delta messages pushed.
    pub sparse_batches: u64,
    /// Wall-clock seconds of the sweep.
    pub seconds: f64,
    /// Seconds spent densifying rows and building word-proposal tables.
    pub alias_build_secs: f64,
    /// Seconds the sampler waited on the pull pipeline for its next
    /// block.
    pub block_wait_secs: f64,
    /// Whether `log_likelihood`/`ll_tokens` carry an evaluation.
    pub evaluated: bool,
    /// Partition log-likelihood (additive across partitions).
    pub log_likelihood: f64,
    /// Tokens the log-likelihood covers.
    pub ll_tokens: u64,
}

/// Worker → coordinator requests.
#[derive(Debug, Clone, PartialEq)]
pub enum CtrlRequest {
    /// Join the cluster. The reply is a [`CtrlResponse::Job`] when a
    /// partition is free, [`CtrlResponse::Wait`] when the cluster is
    /// fully staffed (retry later — a failure may free a partition), or
    /// [`CtrlResponse::Done`] when training already finished.
    Register {
        /// Client-chosen idempotency token: a retried `Register` whose
        /// original reply was lost re-receives the same assignment
        /// instead of being seated as a second (ghost) worker.
        token: u64,
    },
    /// The worker rebuilt runner state for its spec'd partitions under
    /// `epoch` (pushing counts where the spec said to).
    Ready {
        /// Worker id from the [`JobSpec`].
        worker: u64,
        /// Epoch the worker rebuilt for.
        epoch: u32,
        /// Per partition: `(partition, iteration, loaded)` — the
        /// iteration its restored state corresponds to (0 = fresh) and
        /// whether a checkpoint file actually loaded.
        parts: Vec<(u32, u32, bool)>,
    },
    /// Ask for the next instruction.
    Poll {
        /// Worker id.
        worker: u64,
    },
    /// One sweep finished (pushes flushed, checkpoint written).
    Report {
        /// Worker id.
        worker: u64,
        /// Epoch the sweep ran under.
        epoch: u32,
        /// Partition swept.
        partition: u32,
        /// Iteration completed.
        iteration: u32,
        /// Sweep counters (and evaluation, when scheduled).
        stats: SweepReport,
    },
    /// Snapshot mode: the worker pulled the model snapshot for
    /// `iteration` of `partition` and waits at the fetch barrier. The
    /// reply is [`CtrlResponse::Ack`] (go sweep) or
    /// [`CtrlResponse::Wait`] (someone hasn't fetched yet).
    Fetched {
        /// Worker id.
        worker: u64,
        /// Epoch the fetch belongs to.
        epoch: u32,
        /// Partition about to sweep.
        partition: u32,
        /// Iteration whose snapshot was pulled.
        iteration: u32,
    },
    /// Liveness signal, sent on a side thread during long sweeps.
    Heartbeat {
        /// Worker id.
        worker: u64,
    },
    /// Planned drain: finish in-flight work, hand partitions back warm,
    /// and leave without an epoch roll. The reply is
    /// [`CtrlResponse::Ack`] (keep polling; partitions transfer out at
    /// sweep boundaries and a later poll answers
    /// [`CtrlResponse::Drained`]) or [`CtrlResponse::Drained`]
    /// immediately when there is nothing to hand off.
    Drain {
        /// Worker id.
        worker: u64,
    },
    /// Graceful goodbye (after [`CtrlResponse::Done`]).
    Leave {
        /// Worker id.
        worker: u64,
    },
}

/// Coordinator → worker responses.
#[derive(Debug, Clone, PartialEq)]
pub enum CtrlResponse {
    /// A (re)assignment: rebuild partition state per this spec, then
    /// send [`CtrlRequest::Ready`].
    Job(Box<JobSpec>),
    /// Run one sweep of one owned partition.
    Run {
        /// Partition to sweep.
        partition: u32,
        /// Iteration to run (1-based).
        iteration: u32,
        /// Whether to also evaluate the partition log-likelihood.
        evaluate: bool,
    },
    /// Release these partitions (warm transfer out): drop their runners
    /// after the already-written checkpoints; the recipient resumes
    /// from disk. Keep polling.
    Transfer {
        /// Partitions to drop.
        parts: Vec<u32>,
    },
    /// Nothing to do yet (barrier, staleness bound, or full cluster);
    /// poll again after roughly this long.
    Wait {
        /// Suggested back-off, milliseconds.
        millis: u64,
    },
    /// Planned drain complete: everything handed off, leave now.
    Drained,
    /// Training is complete; send [`CtrlRequest::Leave`] and exit.
    Done,
    /// Acknowledged (reports, heartbeats, ready, drain, leave, fetch
    /// barrier passed).
    Ack,
    /// The coordinator rejected the request (e.g. an unknown worker id
    /// after the worker was presumed dead — re-register to rejoin).
    Error(String),
}

// --- encoding ----------------------------------------------------------

const C_REGISTER: u8 = 1;
const C_READY: u8 = 2;
const C_POLL: u8 = 3;
const C_REPORT: u8 = 4;
const C_HEARTBEAT: u8 = 5;
const C_LEAVE: u8 = 6;
const C_DRAIN: u8 = 7;
const C_FETCHED: u8 = 8;

const R_JOB: u8 = 1;
const R_RUN: u8 = 2;
const R_WAIT: u8 = 3;
const R_DONE: u8 = 4;
const R_ACK: u8 = 5;
const R_ERROR: u8 = 6;
const R_TRANSFER: u8 = 7;
const R_DRAINED: u8 = 8;

const CORPUS_FILE: u8 = 1;
const CORPUS_SYNTH: u8 = 2;
const CORPUS_PROVIDED: u8 = 3;

impl CorpusSpec {
    fn encode(&self, w: &mut Writer) {
        match self {
            CorpusSpec::File(path) => {
                w.u8(CORPUS_FILE);
                w.str(path);
            }
            CorpusSpec::Synth {
                num_docs,
                vocab_size,
                num_topics,
                avg_doc_len,
                zipf_exponent,
                seed,
            } => {
                w.u8(CORPUS_SYNTH);
                w.u64(*num_docs);
                w.u32(*vocab_size);
                w.u32(*num_topics);
                w.f64(*avg_doc_len);
                w.f64(*zipf_exponent);
                w.u64(*seed);
            }
            CorpusSpec::Provided => w.u8(CORPUS_PROVIDED),
        }
    }

    fn decode(r: &mut Reader) -> Result<CorpusSpec> {
        match r.u8()? {
            CORPUS_FILE => Ok(CorpusSpec::File(r.str()?)),
            CORPUS_SYNTH => Ok(CorpusSpec::Synth {
                num_docs: r.u64()?,
                vocab_size: r.u32()?,
                num_topics: r.u32()?,
                avg_doc_len: r.f64()?,
                zipf_exponent: r.f64()?,
                seed: r.u64()?,
            }),
            CORPUS_PROVIDED => Ok(CorpusSpec::Provided),
            t => Err(Error::Decode(format!("bad corpus spec tag {t}"))),
        }
    }
}

impl SweepKnobs {
    fn encode(&self, w: &mut Writer) {
        w.u32(self.num_topics);
        w.f64(self.alpha);
        w.f64(self.beta);
        w.u32(self.sampler.mh_steps);
        w.usize(self.sampler.block_words);
        w.usize(self.sampler.buffer_cap);
        w.u64(self.sampler.dense_top_words);
        w.usize(self.sampler.pipeline_depth);
        w.f64(self.sampler.alias_dense_threshold);
        w.u8(self.scheme.tag());
        w.u8(self.wt_layout.tag());
        w.u64(self.seed);
        w.u32(self.eval_every);
        w.str(&self.checkpoint_dir);
        w.u32(self.keep_checkpoints);
        w.u64(self.heartbeat_ms);
        w.u8(u8::from(self.snapshot));
    }

    fn decode(r: &mut Reader) -> Result<SweepKnobs> {
        Ok(SweepKnobs {
            num_topics: r.u32()?,
            alpha: r.f64()?,
            beta: r.f64()?,
            sampler: SamplerParams {
                mh_steps: r.u32()?,
                block_words: r.usize()?,
                buffer_cap: r.usize()?,
                dense_top_words: r.u64()?,
                pipeline_depth: r.usize()?,
                alias_dense_threshold: r.f64()?,
            },
            scheme: {
                let t = r.u8()?;
                PartitionScheme::from_tag(t)
                    .ok_or_else(|| Error::Decode(format!("bad scheme tag {t}")))?
            },
            wt_layout: Layout::from_tag(r.u8()?)?,
            seed: r.u64()?,
            eval_every: r.u32()?,
            checkpoint_dir: r.str()?,
            keep_checkpoints: r.u32()?,
            heartbeat_ms: r.u64()?,
            snapshot: r.u8()? != 0,
        })
    }
}

impl PartitionAssignment {
    fn encode(&self, w: &mut Writer) {
        w.u32(self.partition);
        w.u64(self.doc_start);
        w.u64(self.doc_end);
        w.u32(self.resume);
        w.u8(u8::from(self.push));
    }

    fn decode(r: &mut Reader) -> Result<PartitionAssignment> {
        Ok(PartitionAssignment {
            partition: r.u32()?,
            doc_start: r.u64()?,
            doc_end: r.u64()?,
            resume: r.u32()?,
            push: r.u8()? != 0,
        })
    }
}

impl JobSpec {
    fn encode(&self, w: &mut Writer) {
        w.u64(self.worker);
        w.usize(self.parts.len());
        for part in &self.parts {
            part.encode(w);
        }
        w.u32(self.epoch);
        w.u32(self.matrix_id);
        w.u32(self.iterations);
        w.usize(self.shard_addrs.len());
        for addr in &self.shard_addrs {
            w.str(addr);
        }
        w.usize(self.backup_addrs.len());
        for addr in &self.backup_addrs {
            w.str(addr);
        }
        self.corpus.encode(w);
        self.knobs.encode(w);
    }

    fn decode(r: &mut Reader) -> Result<JobSpec> {
        let worker = r.u64()?;
        let n = r.usize()?;
        let mut parts = Vec::with_capacity(n.min(1 << 16));
        for _ in 0..n {
            parts.push(PartitionAssignment::decode(r)?);
        }
        let epoch = r.u32()?;
        let matrix_id = r.u32()?;
        let iterations = r.u32()?;
        let n = r.usize()?;
        let mut shard_addrs = Vec::with_capacity(n);
        for _ in 0..n {
            shard_addrs.push(r.str()?);
        }
        let n = r.usize()?;
        let mut backup_addrs = Vec::with_capacity(n);
        for _ in 0..n {
            backup_addrs.push(r.str()?);
        }
        Ok(JobSpec {
            worker,
            parts,
            epoch,
            matrix_id,
            iterations,
            shard_addrs,
            backup_addrs,
            corpus: CorpusSpec::decode(r)?,
            knobs: SweepKnobs::decode(r)?,
        })
    }
}

impl SweepReport {
    fn encode(&self, w: &mut Writer) {
        w.u64(self.tokens);
        w.u64(self.changed);
        w.u64(self.sparse_batches);
        w.f64(self.seconds);
        w.f64(self.alias_build_secs);
        w.f64(self.block_wait_secs);
        w.u8(u8::from(self.evaluated));
        w.f64(self.log_likelihood);
        w.u64(self.ll_tokens);
    }

    fn decode(r: &mut Reader) -> Result<SweepReport> {
        Ok(SweepReport {
            tokens: r.u64()?,
            changed: r.u64()?,
            sparse_batches: r.u64()?,
            seconds: r.f64()?,
            alias_build_secs: r.f64()?,
            block_wait_secs: r.f64()?,
            evaluated: r.u8()? != 0,
            log_likelihood: r.f64()?,
            ll_tokens: r.u64()?,
        })
    }
}

impl CtrlRequest {
    /// Serialize to wire bytes.
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        match self {
            CtrlRequest::Register { token } => {
                w.u8(C_REGISTER);
                w.u64(*token);
            }
            CtrlRequest::Ready { worker, epoch, parts } => {
                w.u8(C_READY);
                w.u64(*worker);
                w.u32(*epoch);
                w.usize(parts.len());
                for &(part, iteration, loaded) in parts {
                    w.u32(part);
                    w.u32(iteration);
                    w.u8(u8::from(loaded));
                }
            }
            CtrlRequest::Poll { worker } => {
                w.u8(C_POLL);
                w.u64(*worker);
            }
            CtrlRequest::Report { worker, epoch, partition, iteration, stats } => {
                w.u8(C_REPORT);
                w.u64(*worker);
                w.u32(*epoch);
                w.u32(*partition);
                w.u32(*iteration);
                stats.encode(&mut w);
            }
            CtrlRequest::Fetched { worker, epoch, partition, iteration } => {
                w.u8(C_FETCHED);
                w.u64(*worker);
                w.u32(*epoch);
                w.u32(*partition);
                w.u32(*iteration);
            }
            CtrlRequest::Heartbeat { worker } => {
                w.u8(C_HEARTBEAT);
                w.u64(*worker);
            }
            CtrlRequest::Drain { worker } => {
                w.u8(C_DRAIN);
                w.u64(*worker);
            }
            CtrlRequest::Leave { worker } => {
                w.u8(C_LEAVE);
                w.u64(*worker);
            }
        }
        w.into_bytes()
    }

    /// Parse from wire bytes.
    pub fn decode(bytes: &[u8]) -> Result<CtrlRequest> {
        let mut r = Reader::new(bytes);
        let req = match r.u8()? {
            C_REGISTER => CtrlRequest::Register { token: r.u64()? },
            C_READY => {
                let worker = r.u64()?;
                let epoch = r.u32()?;
                let n = r.usize()?;
                let mut parts = Vec::with_capacity(n.min(1 << 16));
                for _ in 0..n {
                    parts.push((r.u32()?, r.u32()?, r.u8()? != 0));
                }
                CtrlRequest::Ready { worker, epoch, parts }
            }
            C_POLL => CtrlRequest::Poll { worker: r.u64()? },
            C_REPORT => CtrlRequest::Report {
                worker: r.u64()?,
                epoch: r.u32()?,
                partition: r.u32()?,
                iteration: r.u32()?,
                stats: SweepReport::decode(&mut r)?,
            },
            C_FETCHED => CtrlRequest::Fetched {
                worker: r.u64()?,
                epoch: r.u32()?,
                partition: r.u32()?,
                iteration: r.u32()?,
            },
            C_HEARTBEAT => CtrlRequest::Heartbeat { worker: r.u64()? },
            C_DRAIN => CtrlRequest::Drain { worker: r.u64()? },
            C_LEAVE => CtrlRequest::Leave { worker: r.u64()? },
            t => return Err(Error::Decode(format!("bad control request tag {t}"))),
        };
        Ok(req)
    }
}

impl CtrlResponse {
    /// Serialize to wire bytes.
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        match self {
            CtrlResponse::Job(spec) => {
                w.u8(R_JOB);
                spec.encode(&mut w);
            }
            CtrlResponse::Run { partition, iteration, evaluate } => {
                w.u8(R_RUN);
                w.u32(*partition);
                w.u32(*iteration);
                w.u8(u8::from(*evaluate));
            }
            CtrlResponse::Transfer { parts } => {
                w.u8(R_TRANSFER);
                w.usize(parts.len());
                for &p in parts {
                    w.u32(p);
                }
            }
            CtrlResponse::Wait { millis } => {
                w.u8(R_WAIT);
                w.u64(*millis);
            }
            CtrlResponse::Drained => w.u8(R_DRAINED),
            CtrlResponse::Done => w.u8(R_DONE),
            CtrlResponse::Ack => w.u8(R_ACK),
            CtrlResponse::Error(msg) => {
                w.u8(R_ERROR);
                w.str(msg);
            }
        }
        w.into_bytes()
    }

    /// Parse from wire bytes.
    pub fn decode(bytes: &[u8]) -> Result<CtrlResponse> {
        let mut r = Reader::new(bytes);
        let resp = match r.u8()? {
            R_JOB => CtrlResponse::Job(Box::new(JobSpec::decode(&mut r)?)),
            R_RUN => CtrlResponse::Run {
                partition: r.u32()?,
                iteration: r.u32()?,
                evaluate: r.u8()? != 0,
            },
            R_TRANSFER => {
                let n = r.usize()?;
                let mut parts = Vec::with_capacity(n.min(1 << 16));
                for _ in 0..n {
                    parts.push(r.u32()?);
                }
                CtrlResponse::Transfer { parts }
            }
            R_WAIT => CtrlResponse::Wait { millis: r.u64()? },
            R_DRAINED => CtrlResponse::Drained,
            R_DONE => CtrlResponse::Done,
            R_ACK => CtrlResponse::Ack,
            R_ERROR => CtrlResponse::Error(r.str()?),
            t => return Err(Error::Decode(format!("bad control response tag {t}"))),
        };
        Ok(resp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn knobs() -> SweepKnobs {
        SweepKnobs {
            num_topics: 20,
            alpha: 2.5,
            beta: 0.01,
            sampler: SamplerParams { pipeline_depth: 4, ..Default::default() },
            scheme: PartitionScheme::Cyclic,
            wt_layout: Layout::Sparse,
            seed: 0x1da,
            eval_every: 5,
            checkpoint_dir: "/tmp/ckpt".into(),
            keep_checkpoints: 3,
            heartbeat_ms: 500,
            snapshot: true,
        }
    }

    fn job() -> JobSpec {
        JobSpec {
            worker: 7,
            parts: vec![
                PartitionAssignment {
                    partition: 1,
                    doc_start: 1000,
                    doc_end: 2000,
                    resume: 4,
                    push: true,
                },
                PartitionAssignment {
                    partition: 5,
                    doc_start: 5000,
                    doc_end: 6000,
                    resume: 0,
                    push: false,
                },
            ],
            epoch: 2,
            matrix_id: 0xdead,
            iterations: 50,
            shard_addrs: vec!["127.0.0.1:7001".into(), "127.0.0.1:7002".into()],
            backup_addrs: vec!["127.0.0.1:8001".into(), "127.0.0.1:8002".into()],
            corpus: CorpusSpec::File("corpus.bin".into()),
            knobs: knobs(),
        }
    }

    fn roundtrip_req(req: CtrlRequest) {
        assert_eq!(CtrlRequest::decode(&req.encode()).unwrap(), req);
    }

    fn roundtrip_resp(resp: CtrlResponse) {
        assert_eq!(CtrlResponse::decode(&resp.encode()).unwrap(), resp);
    }

    #[test]
    fn roundtrip_all_request_variants() {
        roundtrip_req(CtrlRequest::Register { token: 0xfeed_beef });
        roundtrip_req(CtrlRequest::Ready {
            worker: 3,
            epoch: 1,
            parts: vec![(0, 12, true), (3, 0, false)],
        });
        roundtrip_req(CtrlRequest::Ready { worker: 4, epoch: 0, parts: vec![] });
        roundtrip_req(CtrlRequest::Poll { worker: u64::MAX });
        roundtrip_req(CtrlRequest::Report {
            worker: 3,
            epoch: 0,
            partition: 6,
            iteration: 9,
            stats: SweepReport {
                tokens: 120_000,
                changed: 40_000,
                sparse_batches: 12,
                seconds: 1.75,
                alias_build_secs: 0.125,
                block_wait_secs: 0.0625,
                evaluated: true,
                log_likelihood: -987654.25,
                ll_tokens: 120_000,
            },
        });
        roundtrip_req(CtrlRequest::Fetched { worker: 3, epoch: 2, partition: 1, iteration: 8 });
        roundtrip_req(CtrlRequest::Heartbeat { worker: 0 });
        roundtrip_req(CtrlRequest::Drain { worker: 5 });
        roundtrip_req(CtrlRequest::Leave { worker: 9 });
    }

    #[test]
    fn roundtrip_all_response_variants() {
        roundtrip_resp(CtrlResponse::Job(Box::new(job())));
        roundtrip_resp(CtrlResponse::Run { partition: 2, iteration: 17, evaluate: false });
        roundtrip_resp(CtrlResponse::Run { partition: 0, iteration: 20, evaluate: true });
        roundtrip_resp(CtrlResponse::Transfer { parts: vec![1, 4, 9] });
        roundtrip_resp(CtrlResponse::Transfer { parts: vec![] });
        roundtrip_resp(CtrlResponse::Wait { millis: 250 });
        roundtrip_resp(CtrlResponse::Drained);
        roundtrip_resp(CtrlResponse::Done);
        roundtrip_resp(CtrlResponse::Ack);
        roundtrip_resp(CtrlResponse::Error("no such worker".into()));
    }

    #[test]
    fn empty_parts_job_roundtrips() {
        let mut spec = job();
        spec.parts.clear();
        roundtrip_resp(CtrlResponse::Job(Box::new(spec)));
    }

    #[test]
    fn roundtrip_corpus_specs() {
        for corpus in [
            CorpusSpec::File("/data/clueweb.bin".into()),
            CorpusSpec::Synth {
                num_docs: 1 << 20,
                vocab_size: 100_000,
                num_topics: 1000,
                avg_doc_len: 380.5,
                zipf_exponent: 1.07,
                seed: 42,
            },
            CorpusSpec::Provided,
        ] {
            let mut spec = job();
            spec.corpus = corpus;
            roundtrip_resp(CtrlResponse::Job(Box::new(spec)));
        }
    }

    #[test]
    fn empty_checkpoint_dir_means_disabled() {
        let mut k = knobs();
        k.checkpoint_dir = String::new();
        let mut spec = job();
        spec.knobs = k;
        roundtrip_resp(CtrlResponse::Job(Box::new(spec)));
    }

    #[test]
    fn garbage_rejected() {
        assert!(CtrlRequest::decode(&[]).is_err());
        assert!(CtrlRequest::decode(&[0xfe]).is_err());
        assert!(CtrlResponse::decode(&[0xfe]).is_err());
        // A truncated JobSpec payload must error, not panic.
        let bytes = CtrlResponse::Job(Box::new(job())).encode();
        assert!(CtrlResponse::decode(&bytes[..bytes.len() - 3]).is_err());
    }
}
