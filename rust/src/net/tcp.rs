//! Real TCP transport: correlation-tagged, length-prefixed frames over
//! `std::net`, multiplexed over one connection per shard.
//!
//! The client side ([`TcpTransport`]) keeps **one** connection per shard
//! endpoint and multiplexes every concurrently outstanding request over
//! it: each request is written as a tagged frame
//! ([`super::frame::write_tagged_frame`]) carrying a correlation id, and
//! a per-connection reader thread matches replies back to their waiters
//! by that id — responses may complete in any order. A request that
//! times out simply abandons its correlation id; a late reply finds no
//! waiter and is dropped, so the connection stays usable (no framing
//! desynchronization is possible). Only dial/write/read *errors* discard
//! the connection and force a redial.
//!
//! The server side ([`TcpServer`]) runs one listener per hosted shard.
//! Each accepted connection gets a reader that forwards decoded frames
//! into the shard's [`Inbox`] — so many requests from one connection can
//! be outstanding at once — and a writer thread that sends the shard's
//! replies back under the request's correlation id. The single-threaded
//! serve loop of [`crate::ps::server`] is shared verbatim with the
//! simulated transport.
//!
//! Delivery semantics are the same **at-most-once** contract the
//! simulated transport models: any dial/write/read failure or timeout is
//! reported as a lost message (`Err(())`) and the retry/exactly-once
//! machinery in `ps/client.rs` takes over unchanged.

use std::collections::HashMap;
use std::io::{self, Read};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

// Locks, channels, and atomics come from the sync_shim: the protocol
// surface of the mux (the [`MuxPending`] waiter table) is model-checked in
// `tests/model.rs`, while the socket I/O threads themselves stay on real
// `std::thread` (a blocking `read` cannot be a virtual task).
use crate::util::error::{Error, Result};
use crate::util::sync_shim::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use crate::util::sync_shim::{mpsc, Mutex};

use super::frame::{parse_tagged_header, read_tagged_frame, write_tagged_frame, TAGGED_HEADER_LEN};
use super::stats::EndpointStats;
use super::{Endpoint, EndpointInner, Envelope, Inbox, Transport};

/// Dial timeout for new connections (further clamped to the request
/// timeout).
const CONNECT_TIMEOUT: Duration = Duration::from_secs(2);
/// How long a server-side connection writer waits for the shard's reply
/// before abandoning the connection.
const HANDLER_REPLY_TIMEOUT: Duration = Duration::from_secs(60);
/// Polling interval of the nonblocking accept loops.
const ACCEPT_POLL: Duration = Duration::from_millis(10);
/// How often an idle mux reader wakes up to check whether its connection
/// is still referenced by anyone.
const MUX_IDLE_POLL: Duration = Duration::from_secs(2);
/// Consecutive round-trip timeouts (with no frame arriving in between)
/// before a mux connection is *suspected* wedged. One timeout is always
/// tolerated — a slow shard reply is normal and matching by correlation
/// id makes late replies harmless.
const MUX_SUSPECT_TIMEOUTS: u32 = 2;
/// A suspected connection is only torn down when, additionally, nothing
/// at all has arrived on it for this long. A brief server stall under a
/// deep pipeline trips the strike counter from several waiters at once;
/// the quiet-period requirement keeps that from aborting every in-flight
/// request, while a dead-but-open socket (which delivers nothing, ever)
/// still gets redialed instead of consuming the whole retry budget.
const MUX_WEDGE_QUIET: Duration = Duration::from_secs(2);

/// The waiter table of one mux connection: reply waiters keyed by
/// correlation id, plus the `dead` flag that closes the
/// registration/death race.
///
/// This is the pure protocol core of the mux — no sockets — extracted so
/// the model checker can drive it directly (`tests/model.rs`, the
/// `mux-*` models) with hand-written requester/reader/killer tasks. Its
/// one invariant: [`MuxPending::kill`] (used by both [`MuxConn::kill`]
/// and the reader's exit path) sets `dead` *before* clearing the table,
/// so a waiter that registers on a dying connection either observes
/// `dead` on its post-insert check or has its sender dropped by the
/// clear — never a silent wait for a reply that cannot come.
pub struct MuxPending {
    /// Reply waiters keyed by correlation id.
    waiters: Mutex<HashMap<u64, mpsc::SyncSender<Vec<u8>>>>,
    /// Set once the connection is known broken; round-trips then dial a
    /// replacement.
    dead: AtomicBool,
}

impl Default for MuxPending {
    fn default() -> Self {
        MuxPending::new()
    }
}

impl MuxPending {
    /// Empty table on a live connection.
    pub fn new() -> MuxPending {
        MuxPending { waiters: Mutex::new(HashMap::new()), dead: AtomicBool::new(false) }
    }

    /// Register a reply waiter under `corr`. The caller must check
    /// [`MuxPending::is_dead`] *after* registering and withdraw on death
    /// (see the race note on the type).
    pub fn register(&self, corr: u64, tx: mpsc::SyncSender<Vec<u8>>) {
        self.waiters.lock().unwrap().insert(corr, tx);
    }

    /// True once the connection is known broken.
    pub fn is_dead(&self) -> bool {
        self.dead.load(Ordering::SeqCst)
    }

    /// Hand `payload` to the waiter registered under `corr`, if any.
    /// Returns whether a waiter was found *and* still listening; a reply
    /// whose waiter already gave up is dropped (late replies are
    /// harmless — matching by id means they can never be mistaken for
    /// another request's answer).
    pub fn deliver(&self, corr: u64, payload: Vec<u8>) -> bool {
        match self.waiters.lock().unwrap().remove(&corr) {
            Some(tx) => tx.try_send(payload).is_ok(),
            None => false,
        }
    }

    /// Withdraw the waiter registered under `corr` (timeout or write
    /// failure: the reply slot must not outlive the requester).
    pub fn remove(&self, corr: u64) {
        self.waiters.lock().unwrap().remove(&corr);
    }

    /// Mark the connection dead, then fail every parked waiter by
    /// dropping its sender (so each errors out fast instead of running
    /// its full timeout). The order is the invariant — see the type doc.
    pub fn kill(&self) {
        self.dead.store(true, Ordering::SeqCst);
        self.waiters.lock().unwrap().clear();
    }
}

/// One multiplexed client connection: a shared write half plus a reader
/// thread that routes tagged replies to waiters by correlation id.
struct MuxConn {
    /// Write half; concurrent requests serialize their frames through
    /// this lock (one `write_all` per frame keeps frames atomic).
    writer: Mutex<TcpStream>,
    /// Dedicated handle for [`MuxConn::kill`] to shut the socket down
    /// (shutdown acts on the shared underlying socket) without
    /// contending on the writer mutex — a kill must never wait behind a
    /// slow in-progress write.
    closer: TcpStream,
    /// Reply waiters + death flag (the model-checked protocol core).
    pending: MuxPending,
    /// Round-trip timeouts since the last frame arrived (any frame —
    /// progress proves the connection alive). See
    /// [`MUX_SUSPECT_TIMEOUTS`].
    strikes: AtomicU32,
    /// When the last frame arrived (dial time initially). See
    /// [`MUX_WEDGE_QUIET`].
    last_rx: Mutex<Instant>,
}

impl MuxConn {
    /// Dial `addr` and start the reader thread.
    fn dial(addr: &SocketAddr, budget: Duration) -> std::result::Result<Arc<MuxConn>, ()> {
        let stream = TcpStream::connect_timeout(addr, budget).map_err(|_| ())?;
        let _ = stream.set_nodelay(true);
        let read_half = stream.try_clone().map_err(|_| ())?;
        let closer = stream.try_clone().map_err(|_| ())?;
        let conn = Arc::new(MuxConn {
            writer: Mutex::new(stream),
            closer,
            pending: MuxPending::new(),
            strikes: AtomicU32::new(0),
            last_rx: Mutex::new(Instant::now()),
        });
        let handle = Arc::clone(&conn);
        if std::thread::Builder::new()
            .name("glint-tcp-mux".into())
            .spawn(move || mux_reader_loop(read_half, &handle))
            .is_err()
        {
            return Err(());
        }
        Ok(conn)
    }

    /// Record byte arrival: the connection is alive, however slowly.
    fn mark_progress(&self) {
        self.strikes.store(0, Ordering::Relaxed);
        *self.last_rx.lock().unwrap() = Instant::now();
    }

    /// Mark the connection broken (dead flag set, parked waiters failed
    /// — see [`MuxPending::kill`]) and close the socket, which wakes the
    /// reader and errors out any in-progress write. Never blocks on the
    /// writer mutex.
    fn kill(&self) {
        self.pending.kill();
        let _ = self.closer.shutdown(Shutdown::Both);
    }
}

/// Reader half of a [`MuxConn`]: decode tagged frames and hand each
/// payload to the waiter registered under its correlation id. Replies
/// whose waiter already gave up (timed out) are dropped — the retry
/// machinery owns recovery, and matching by id means a late reply can
/// never be mistaken for the answer to a different request.
fn mux_reader_loop(mut stream: TcpStream, conn: &Arc<MuxConn>) {
    let _ = stream.set_read_timeout(Some(MUX_IDLE_POLL));
    let mut header = [0u8; TAGGED_HEADER_LEN];
    loop {
        if !read_full(&mut stream, &mut header, conn) {
            break;
        }
        let Ok((len, corr)) = parse_tagged_header(&header) else {
            break; // corrupt prefix: the stream cannot be trusted
        };
        let mut payload = vec![0u8; len];
        if !read_full(&mut stream, &mut payload, conn) {
            break;
        }
        conn.pending.deliver(corr, payload);
    }
    // Dead-before-clear, so waiters racing with this exit either see the
    // flag or lose their sender (never a silent wait).
    conn.pending.kill();
}

/// Fill `buf` completely from the socket, tolerating read timeouts:
/// every received byte marks progress (holding off wedge detection —
/// a large frame trickling in over a slow link is alive), and a timeout
/// only finishes the connection when it was killed or nothing references
/// it any more. Active round-trips hold an `Arc`, so a strong count
/// of 1 means no result could ever be delivered and exiting is always
/// safe, even mid-frame. Returns `false` when the connection is done
/// (EOF, I/O error, killed, or unreferenced).
fn read_full(stream: &mut TcpStream, buf: &mut [u8], conn: &Arc<MuxConn>) -> bool {
    let mut filled = 0;
    while filled < buf.len() {
        match stream.read(&mut buf[filled..]) {
            Ok(0) => return false,
            Ok(n) => {
                filled += n;
                conn.mark_progress();
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) if is_timeout(&e) => {
                if conn.pending.is_dead() || Arc::strong_count(conn) <= 1 {
                    return false;
                }
            }
            Err(_) => return false,
        }
    }
    true
}

/// True for the error kinds a socket read timeout surfaces as.
fn is_timeout(e: &io::Error) -> bool {
    matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut)
}

/// Client half of one shard connection: the address plus the current
/// multiplexed connection. Cheap to clone; clones share the connection.
#[derive(Clone)]
pub(crate) struct TcpEndpoint {
    addr: SocketAddr,
    conn: Arc<Mutex<Option<Arc<MuxConn>>>>,
    /// Correlation-id allocator for this endpoint.
    next_corr: Arc<AtomicU64>,
}

impl TcpEndpoint {
    pub(crate) fn new(addr: SocketAddr) -> TcpEndpoint {
        TcpEndpoint {
            addr,
            conn: Arc::new(Mutex::new(None)),
            next_corr: Arc::new(AtomicU64::new(1)),
        }
    }

    /// The live mux connection, dialing a replacement when there is none
    /// or the current one is dead. The (possibly seconds-long) dial runs
    /// *outside* the endpoint lock so concurrent round-trips to an
    /// unreachable shard each fail on their own clock instead of
    /// serializing behind one another; racing re-dials are resolved by
    /// keeping whichever connection was installed first.
    fn connect(
        &self,
        started: Instant,
        timeout: Duration,
        deadline: Instant,
    ) -> std::result::Result<Arc<MuxConn>, ()> {
        {
            let mut guard = self.conn.lock().unwrap();
            if let Some(current) = guard.as_ref() {
                if !current.pending.is_dead() {
                    return Ok(Arc::clone(current));
                }
                current.kill();
                *guard = None;
            }
        }
        let budget = remaining(deadline).max(Duration::from_millis(1));
        match MuxConn::dial(&self.addr, CONNECT_TIMEOUT.min(budget)) {
            Ok(fresh) => {
                let mut guard = self.conn.lock().unwrap();
                if let Some(current) = guard.as_ref() {
                    if !current.pending.is_dead() {
                        // Another worker installed a live connection
                        // while we dialed; use it and close ours.
                        let winner = Arc::clone(current);
                        drop(guard);
                        fresh.kill();
                        return Ok(winner);
                    }
                }
                *guard = Some(Arc::clone(&fresh));
                Ok(fresh)
            }
            Err(()) => {
                // Pace refused dials just enough that the caller's retry
                // loop cannot hot-spin, but capped well below the attempt
                // timeout: ECONNREFUSED is a definitive answer and a dead
                // server must not cost the full back-off schedule (~60s
                // with default PsConfig) to report.
                std::thread::sleep(
                    timeout.saturating_sub(started.elapsed()).min(Duration::from_millis(50)),
                );
                Err(())
            }
        }
    }

    /// Forget `failed` (if it is still the current connection) and close
    /// it so the reader exits.
    fn discard(&self, failed: &Arc<MuxConn>) {
        let mut guard = self.conn.lock().unwrap();
        if let Some(current) = guard.as_ref() {
            if Arc::ptr_eq(current, failed) {
                *guard = None;
            }
        }
        drop(guard);
        failed.kill();
    }

    /// One request/reply round-trip bounded by `timeout` as a whole-call
    /// deadline, subject to the process-global chaos interposer when one
    /// is installed ([`super::chaos`]): the data path every retryable
    /// request takes.
    pub(crate) fn roundtrip(
        &self,
        payload: &[u8],
        timeout: Duration,
    ) -> std::result::Result<Vec<u8>, ()> {
        let Some(v) = super::chaos::verdict() else {
            return self.roundtrip_inner(payload, timeout, false);
        };
        if !v.delay.is_zero() {
            std::thread::sleep(v.delay);
        }
        if v.drop_request {
            // Lost before the wire: indistinguishable from a dead peer.
            return Err(());
        }
        let reply = self.roundtrip_inner(payload, timeout, v.duplicate);
        if v.drop_reply {
            // The server processed the request (and any duplicate); the
            // client just never hears back — the dangerous case the
            // exactly-once push hand-shake exists for.
            return Err(());
        }
        reply
    }

    /// Chaos-free round-trip, multiplexed over the shared connection: any
    /// number may be outstanding concurrently. With `duplicate`, the
    /// frame is written twice under distinct correlation ids — the
    /// second reply finds no waiter and is dropped by the mux reader.
    pub(crate) fn roundtrip_inner(
        &self,
        payload: &[u8],
        timeout: Duration,
        duplicate: bool,
    ) -> std::result::Result<Vec<u8>, ()> {
        // Duration::ZERO means "no timeout" to the socket API; never pass
        // it through.
        let timeout = timeout.max(Duration::from_millis(1));
        let started = Instant::now();
        let deadline = started + timeout;
        let conn = self.connect(started, timeout, deadline)?;
        let corr = self.next_corr.fetch_add(1, Ordering::Relaxed);
        let (reply_tx, reply_rx) = mpsc::sync_channel(1);
        conn.pending.register(corr, reply_tx);
        // Close the registration/death race: `kill` and the reader's
        // exit path both set `dead` *before* clearing the waiter table
        // (see [`MuxPending`]), so a waiter registered on a dying
        // connection either sees `dead` here or had its sender dropped
        // by the clear — never a silent wait for a reply that cannot
        // come.
        if conn.pending.is_dead() {
            conn.pending.remove(corr);
            self.discard(&conn);
            return Err(());
        }
        {
            let mut stream = conn.writer.lock().unwrap();
            let budget = remaining(deadline).max(Duration::from_millis(1));
            if stream.set_write_timeout(Some(budget)).is_err()
                || write_tagged_frame(&mut *stream, corr, payload).is_err()
            {
                drop(stream);
                conn.pending.remove(corr);
                self.discard(&conn);
                return Err(());
            }
            if duplicate {
                // Chaos retransmission: a second frame under its own
                // (unregistered) correlation id. The server processes it;
                // its reply matches no waiter and is dropped.
                let dup_corr = self.next_corr.fetch_add(1, Ordering::Relaxed);
                let _ = write_tagged_frame(&mut *stream, dup_corr, payload);
            }
        }
        match reply_rx.recv_timeout(remaining(deadline).max(Duration::from_millis(1))) {
            Ok(reply) => Ok(reply),
            Err(_) => {
                // Timed out (the reply may arrive later and will be
                // dropped by correlation-id mismatch — the connection
                // stays usable), or the reader died and dropped our
                // sender (then the connection is replaced). A connection
                // that keeps timing out while delivering *no* frame for
                // the whole quiet period is presumed wedged and replaced
                // too, so a stalled socket cannot consume the caller's
                // whole retry budget.
                conn.pending.remove(corr);
                let strikes = conn.strikes.fetch_add(1, Ordering::Relaxed) + 1;
                let quiet = conn.last_rx.lock().unwrap().elapsed();
                if conn.pending.is_dead()
                    || (strikes >= MUX_SUSPECT_TIMEOUTS && quiet >= MUX_WEDGE_QUIET)
                {
                    self.discard(&conn);
                }
                Err(())
            }
        }
    }
}

/// Time left until `deadline` (zero if passed).
fn remaining(deadline: Instant) -> Duration {
    deadline.saturating_duration_since(Instant::now())
}

/// Client-side transport connecting to `n` shard servers over TCP.
pub struct TcpTransport {
    endpoints: Vec<Endpoint>,
    addrs: Vec<SocketAddr>,
}

impl TcpTransport {
    /// One multiplexed endpoint per shard address, in shard order.
    pub fn connect(addrs: &[SocketAddr]) -> TcpTransport {
        let endpoints = addrs
            .iter()
            .map(|&addr| Endpoint {
                inner: EndpointInner::Tcp(TcpEndpoint::new(addr)),
                stats: Arc::new(EndpointStats::default()),
            })
            .collect();
        TcpTransport { endpoints, addrs: addrs.to_vec() }
    }

    /// Shard addresses in shard order.
    pub fn addrs(&self) -> &[SocketAddr] {
        &self.addrs
    }
}

impl Transport for TcpTransport {
    fn shards(&self) -> usize {
        self.endpoints.len()
    }

    fn endpoint(&self, shard: usize) -> Endpoint {
        self.endpoints[shard].clone()
    }

    fn stats(&self) -> Vec<Arc<EndpointStats>> {
        self.endpoints.iter().map(|e| Arc::clone(&e.stats)).collect()
    }
}

/// Server-side listeners: one per shard hosted by this process.
///
/// Dropping (or [`TcpServer::shutdown`]) stops the accept loops; open
/// connections are left to their handler threads, which exit when the
/// peer closes or the shard's serve loop is gone.
pub struct TcpServer {
    addrs: Vec<SocketAddr>,
    stop: Arc<AtomicBool>,
    accepts: Vec<JoinHandle<()>>,
    /// One sender per inbox (in address order), so server-local threads
    /// — the replication pollers — can enqueue requests through a
    /// shard's serialized inbox exactly like a remote connection would.
    injectors: Vec<mpsc::Sender<Envelope>>,
}

impl TcpServer {
    /// Bind one listener per address and return the server handle plus
    /// one [`Inbox`] per listener (in address order). Use port `0` for an
    /// ephemeral port; the resolved addresses are available from
    /// [`TcpServer::addrs`].
    pub fn bind(addrs: &[SocketAddr]) -> io::Result<(TcpServer, Vec<Inbox>)> {
        // Bind every listener before spawning anything, so a failed bind
        // leaks no accept threads.
        let mut listeners = Vec::with_capacity(addrs.len());
        let mut local = Vec::with_capacity(addrs.len());
        for addr in addrs {
            let listener = TcpListener::bind(addr)?;
            listener.set_nonblocking(true)?;
            local.push(listener.local_addr()?);
            listeners.push(listener);
        }
        let stop = Arc::new(AtomicBool::new(false));
        let mut inboxes = Vec::with_capacity(addrs.len());
        let mut accepts = Vec::with_capacity(addrs.len());
        let mut injectors = Vec::with_capacity(addrs.len());
        for (i, listener) in listeners.into_iter().enumerate() {
            let (tx, rx) = mpsc::channel();
            inboxes.push(Inbox { rx });
            injectors.push(tx.clone());
            let stop = Arc::clone(&stop);
            let handle = std::thread::Builder::new()
                .name(format!("glint-tcp-accept-{i}"))
                .spawn(move || accept_loop(&listener, &tx, &stop))
                // PANIC-OK: thread spawn fails only on resource
                // exhaustion at process startup; no cleaner recovery
                // exists than aborting the bind.
                .expect("spawn tcp accept loop");
            accepts.push(handle);
        }
        Ok((TcpServer { addrs: local, stop, accepts, injectors }, inboxes))
    }

    /// Local addresses of the listeners, in shard order.
    pub fn addrs(&self) -> &[SocketAddr] {
        &self.addrs
    }

    /// A sender feeding listener `i`'s inbox directly (bypassing TCP).
    /// Requests injected this way are processed by the shard's serve
    /// loop in arrival order, preserving the single-writer model.
    pub(crate) fn injector(&self, i: usize) -> mpsc::Sender<Envelope> {
        self.injectors[i].clone()
    }

    /// Stop accepting new connections and join the accept threads.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        for h in self.accepts.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for TcpServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(listener: &TcpListener, tx: &mpsc::Sender<Envelope>, stop: &AtomicBool) {
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let tx = tx.clone();
                let _ = std::thread::Builder::new()
                    .name("glint-tcp-conn".into())
                    .spawn(move || connection_loop(stream, &tx));
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => std::thread::sleep(ACCEPT_POLL),
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            // Transient accept errors (ECONNABORTED from a client that
            // RST before accept, EMFILE under fd pressure) must not kill
            // the listener for the life of the serve process; back off
            // and keep accepting.
            Err(_) => std::thread::sleep(ACCEPT_POLL),
        }
    }
}

/// One accepted connection: frames are read continuously and forwarded
/// into the shard's inbox, so many requests from this connection can be
/// outstanding at once (the client's pipelining window); a writer thread
/// sends the replies back tagged with each request's correlation id.
/// The envelope hop preserves the single-threaded actor model of the
/// serve loop: many connections, one processor.
fn connection_loop(mut stream: TcpStream, tx: &mpsc::Sender<Envelope>) {
    // BSD-derived platforms (macOS included) hand accepted sockets the
    // listener's O_NONBLOCK flag; reads here must block.
    if stream.set_nonblocking(false).is_err() {
        return;
    }
    let _ = stream.set_nodelay(true);
    let Ok(write_half) = stream.try_clone() else {
        return;
    };
    // Replies are forwarded in request order (the serve loop processes
    // this connection's envelopes FIFO); the correlation tag — not the
    // order — is what the client matches on.
    let (reply_tx, reply_rx) = mpsc::channel::<(u64, mpsc::Receiver<Vec<u8>>)>();
    let writer = std::thread::Builder::new()
        .name("glint-tcp-conn-writer".into())
        .spawn(move || {
            let mut stream = write_half;
            // Bound reply waits and writes so a wedged shard or a peer
            // that stops reading cannot pin this thread forever.
            let _ = stream.set_write_timeout(Some(HANDLER_REPLY_TIMEOUT));
            while let Ok((corr, rx)) = reply_rx.recv() {
                let Ok(reply) = rx.recv_timeout(HANDLER_REPLY_TIMEOUT) else {
                    break;
                };
                if write_tagged_frame(&mut stream, corr, &reply).is_err() {
                    break;
                }
            }
            // Unblock the read half so the reader side exits too.
            let _ = stream.shutdown(Shutdown::Both);
        });
    let Ok(writer) = writer else {
        return;
    };
    loop {
        let (corr, payload) = match read_tagged_frame(&mut stream) {
            Ok(Some(frame)) => frame,
            Ok(None) | Err(_) => break, // peer closed, or framing error
        };
        let (one_tx, one_rx) = mpsc::sync_channel(1);
        if tx.send(Envelope { payload, reply: Some(one_tx) }).is_err() {
            break; // the shard's serve loop has exited
        }
        if reply_tx.send((corr, one_rx)).is_err() {
            break; // the writer gave up on this connection
        }
    }
    drop(reply_tx);
    let _ = writer.join();
}

/// Resolve `host:port` strings (one per shard) into socket addresses.
pub fn resolve_addrs(specs: &[String]) -> Result<Vec<SocketAddr>> {
    specs
        .iter()
        .map(|spec| {
            spec.to_socket_addrs()
                .map_err(|e| Error::Config(format!("cannot resolve {spec:?}: {e}")))?
                .next()
                .ok_or_else(|| Error::Config(format!("{spec:?} resolved to no addresses")))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::respond;

    /// Echo server over an inbox; returns on the b"stop" sentinel.
    fn spawn_echo(inbox: Inbox) -> std::thread::JoinHandle<usize> {
        std::thread::spawn(move || {
            let mut handled = 0;
            while let Some(env) = inbox.recv() {
                handled += 1;
                let stop = env.payload == b"stop";
                respond(&env, env.payload.clone());
                if stop {
                    return handled;
                }
            }
            handled
        })
    }

    fn loopback() -> SocketAddr {
        "127.0.0.1:0".parse().unwrap()
    }

    #[test]
    fn roundtrip_over_loopback() {
        let (mut server, mut inboxes) = TcpServer::bind(&[loopback()]).unwrap();
        let h = spawn_echo(inboxes.remove(0));
        let transport = TcpTransport::connect(server.addrs());
        let ep = transport.endpoint(0);
        for i in 0..50u32 {
            let got = ep.request(i.to_le_bytes().to_vec(), Duration::from_secs(2)).unwrap();
            assert_eq!(got, i.to_le_bytes());
        }
        assert_eq!(ep.stats.requests(), 50);
        assert_eq!(ep.stats.replies(), 50);
        assert_eq!(ep.stats.bytes_sent(), 200);
        ep.request(b"stop".to_vec(), Duration::from_secs(2)).unwrap();
        server.shutdown();
        assert_eq!(h.join().unwrap(), 51);
    }

    #[test]
    fn concurrent_clients_share_one_connection() {
        let (mut server, mut inboxes) = TcpServer::bind(&[loopback()]).unwrap();
        let h = spawn_echo(inboxes.remove(0));
        let transport = TcpTransport::connect(server.addrs());
        std::thread::scope(|scope| {
            for t in 0..8u8 {
                let ep = transport.endpoint(0);
                scope.spawn(move || {
                    for i in 0..20u8 {
                        let msg = vec![t, i];
                        let got = ep.request(msg.clone(), Duration::from_secs(2)).unwrap();
                        assert_eq!(got, msg);
                    }
                });
            }
        });
        let ep = transport.endpoint(0);
        assert_eq!(ep.stats.requests(), 8 * 20);
        ep.request(b"stop".to_vec(), Duration::from_secs(2)).unwrap();
        server.shutdown();
        assert_eq!(h.join().unwrap(), 8 * 20 + 1);
    }

    /// The multiplexing contract itself: two requests outstanding on one
    /// connection whose replies come back in *reverse* order must each
    /// complete with their own response, matched by correlation id.
    #[test]
    fn out_of_order_replies_match_by_correlation_id() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (mut stream, _) = listener.accept().unwrap();
            let first = read_tagged_frame(&mut stream).unwrap().unwrap();
            let second = read_tagged_frame(&mut stream).unwrap().unwrap();
            // Echo both, deliberately last-in-first-out.
            write_tagged_frame(&mut stream, second.0, &second.1).unwrap();
            write_tagged_frame(&mut stream, first.0, &first.1).unwrap();
        });
        let transport = TcpTransport::connect(&[addr]);
        let ep_a = transport.endpoint(0);
        let ep_b = transport.endpoint(0);
        std::thread::scope(|scope| {
            let a = scope
                .spawn(move || ep_a.request(b"alpha".to_vec(), Duration::from_secs(5)).unwrap());
            let b = scope
                .spawn(move || ep_b.request(b"bravo".to_vec(), Duration::from_secs(5)).unwrap());
            assert_eq!(a.join().unwrap(), b"alpha");
            assert_eq!(b.join().unwrap(), b"bravo");
        });
        server.join().unwrap();
    }

    #[test]
    fn unserviced_endpoint_times_out() {
        // Bind a listener whose inbox is never drained: the handler
        // forwards the frame but no reply ever comes, so the client must
        // observe a timeout, not a hang.
        let (mut server, inboxes) = TcpServer::bind(&[loopback()]).unwrap();
        let transport = TcpTransport::connect(server.addrs());
        let ep = transport.endpoint(0);
        let r = ep.request(vec![1, 2, 3], Duration::from_millis(50));
        assert!(r.is_err());
        assert_eq!(ep.stats.timeouts(), 1);
        drop(inboxes);
        server.shutdown();
    }

    #[test]
    fn timed_out_connection_remains_usable() {
        // A slow reply (after the requester gave up) must be dropped by
        // correlation-id mismatch, and the *same* connection must still
        // serve the next request correctly.
        let (mut server, mut inboxes) = TcpServer::bind(&[loopback()]).unwrap();
        let inbox = inboxes.remove(0);
        let h = std::thread::spawn(move || {
            // First request: delay the echo beyond the client timeout.
            let env = inbox.recv().unwrap();
            std::thread::sleep(Duration::from_millis(120));
            respond(&env, env.payload.clone());
            // Second request: echo immediately.
            let env = inbox.recv().unwrap();
            respond(&env, env.payload.clone());
        });
        let transport = TcpTransport::connect(server.addrs());
        let ep = transport.endpoint(0);
        assert!(ep.request(b"slow".to_vec(), Duration::from_millis(30)).is_err());
        let got = ep.request(b"fast".to_vec(), Duration::from_secs(2)).unwrap();
        assert_eq!(got, b"fast");
        h.join().unwrap();
        server.shutdown();
    }

    #[test]
    fn dead_endpoint_is_an_error_not_a_hang() {
        // Bind-then-drop leaves a port with no listener.
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap()
        };
        let transport = TcpTransport::connect(&[addr]);
        let ep = transport.endpoint(0);
        let r = ep.request(vec![9], Duration::from_millis(30));
        assert!(r.is_err());
        assert_eq!(ep.stats.timeouts(), 1);
    }

    #[test]
    fn resolve_addrs_parses_and_rejects() {
        let ok = resolve_addrs(&["127.0.0.1:7000".to_string()]).unwrap();
        assert_eq!(ok[0].port(), 7000);
        assert!(resolve_addrs(&["not an address".to_string()]).is_err());
    }
}
