//! Real TCP transport: length-prefixed frames over `std::net`.
//!
//! The client side ([`TcpTransport`]) keeps a small pool of reusable
//! connections per shard endpoint and dials a fresh connection whenever
//! the pool is empty or a round-trip fails. The server side
//! ([`TcpServer`]) runs one listener per hosted shard with one handler
//! thread per accepted connection; handlers forward decoded frames into
//! the shard's [`Inbox`], so the single-threaded serve loop of
//! [`crate::ps::server`] is shared verbatim with the simulated transport.
//!
//! Delivery semantics are the same **at-most-once** contract the
//! simulated transport models: any dial/write/read failure or timeout is
//! reported as a lost message (`Err(())`), the connection is discarded
//! (a late reply must never desynchronize the framing), and the
//! retry/exactly-once machinery in `ps/client.rs` takes over unchanged.

use std::io;
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::util::error::{Error, Result};

use super::frame::{read_frame, write_frame};
use super::stats::EndpointStats;
use super::{Endpoint, EndpointInner, Envelope, Inbox, Transport};

/// Idle connections kept per endpoint for reuse.
const POOL_CAP: usize = 16;
/// Dial timeout for new connections (further clamped to the request
/// timeout).
const CONNECT_TIMEOUT: Duration = Duration::from_secs(2);
/// How long a server-side connection handler waits for the shard's reply
/// before abandoning the connection.
const HANDLER_REPLY_TIMEOUT: Duration = Duration::from_secs(60);
/// Polling interval of the nonblocking accept loops.
const ACCEPT_POLL: Duration = Duration::from_millis(10);

/// Client half of one shard connection: an address plus a pool of
/// reusable streams. Cheap to clone; clones share the pool.
#[derive(Clone)]
pub(crate) struct TcpEndpoint {
    addr: SocketAddr,
    pool: Arc<Mutex<Vec<TcpStream>>>,
}

impl TcpEndpoint {
    pub(crate) fn new(addr: SocketAddr) -> TcpEndpoint {
        TcpEndpoint { addr, pool: Arc::new(Mutex::new(Vec::new())) }
    }

    fn checkout(&self) -> Option<TcpStream> {
        self.pool.lock().unwrap().pop()
    }

    fn checkin(&self, stream: TcpStream) {
        let mut pool = self.pool.lock().unwrap();
        if pool.len() < POOL_CAP {
            pool.push(stream);
        }
    }

    /// One request/reply round-trip bounded by `timeout` as a whole-call
    /// deadline. Reuses a pooled connection when one is idle, dials
    /// otherwise; reconnects (via the caller's retry) on any error.
    pub(crate) fn roundtrip(
        &self,
        payload: &[u8],
        timeout: Duration,
    ) -> std::result::Result<Vec<u8>, ()> {
        // Duration::ZERO means "no timeout" to the socket API; never pass
        // it through.
        let timeout = timeout.max(Duration::from_millis(1));
        let started = std::time::Instant::now();
        let deadline = started + timeout;
        if let Some(stream) = self.checkout() {
            match self.try_stream(stream, payload, deadline) {
                Ok(reply) => return Ok(reply),
                Err(()) => {
                    // An idle stream going stale usually means the server
                    // restarted or idle connections were reaped — every
                    // other pooled stream is suspect. Flush them all and
                    // fall through to a fresh dial *within this attempt*,
                    // so a poisoned pool cannot consume the caller's
                    // whole retry budget one dead stream at a time.
                    self.pool.lock().unwrap().clear();
                }
            }
        }
        let budget = remaining(deadline).max(Duration::from_millis(1));
        let stream = match TcpStream::connect_timeout(&self.addr, CONNECT_TIMEOUT.min(budget)) {
            Ok(s) => {
                let _ = s.set_nodelay(true);
                s
            }
            Err(_) => {
                // Pace refused dials just enough that the caller's retry
                // loop cannot hot-spin, but capped well below the attempt
                // timeout: ECONNREFUSED is a definitive answer and a dead
                // server must not cost the full back-off schedule (~60s
                // with default PsConfig) to report.
                std::thread::sleep(
                    timeout
                        .saturating_sub(started.elapsed())
                        .min(Duration::from_millis(50)),
                );
                return Err(());
            }
        };
        self.try_stream(stream, payload, deadline)
    }

    /// Write the request and read the reply on one stream under an
    /// absolute deadline; pools the stream again only on success.
    fn try_stream(
        &self,
        mut stream: TcpStream,
        payload: &[u8],
        deadline: std::time::Instant,
    ) -> std::result::Result<Vec<u8>, ()> {
        if stream
            .set_write_timeout(Some(remaining(deadline).max(Duration::from_millis(1))))
            .is_err()
        {
            return Err(());
        }
        if write_frame(&mut stream, payload).is_err() {
            return Err(());
        }
        // The deadline applies to the whole reply, not per syscall: a
        // peer trickling bytes must not extend the attempt indefinitely.
        match read_frame(&mut DeadlineReader { stream: &mut stream, deadline }) {
            Ok(Some(reply)) => {
                self.checkin(stream);
                Ok(reply)
            }
            // EOF, timeout or error: the reply is lost. The stream is
            // dropped, never reused — a reply arriving after a timeout
            // must not be mistaken for the answer to a later request.
            Ok(None) | Err(_) => Err(()),
        }
    }
}

/// Time left until `deadline` (zero if passed).
fn remaining(deadline: std::time::Instant) -> Duration {
    deadline.saturating_duration_since(std::time::Instant::now())
}

/// Enforces an absolute deadline over a stream of reads: before each
/// syscall the socket read timeout is shrunk to the remaining budget, so
/// the *total* read time is bounded even when every individual chunk
/// arrives "in time".
struct DeadlineReader<'a> {
    stream: &'a mut TcpStream,
    deadline: std::time::Instant,
}

impl io::Read for DeadlineReader<'_> {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        let left = remaining(self.deadline);
        if left.is_zero() {
            return Err(io::Error::new(io::ErrorKind::TimedOut, "read deadline exceeded"));
        }
        self.stream.set_read_timeout(Some(left))?;
        self.stream.read(buf)
    }
}

/// Client-side transport connecting to `n` shard servers over TCP.
pub struct TcpTransport {
    endpoints: Vec<Endpoint>,
    addrs: Vec<SocketAddr>,
}

impl TcpTransport {
    /// One pooled endpoint per shard address, in shard order.
    pub fn connect(addrs: &[SocketAddr]) -> TcpTransport {
        let endpoints = addrs
            .iter()
            .map(|&addr| Endpoint {
                inner: EndpointInner::Tcp(TcpEndpoint::new(addr)),
                stats: Arc::new(EndpointStats::default()),
            })
            .collect();
        TcpTransport { endpoints, addrs: addrs.to_vec() }
    }

    /// Shard addresses in shard order.
    pub fn addrs(&self) -> &[SocketAddr] {
        &self.addrs
    }
}

impl Transport for TcpTransport {
    fn shards(&self) -> usize {
        self.endpoints.len()
    }

    fn endpoint(&self, shard: usize) -> Endpoint {
        self.endpoints[shard].clone()
    }

    fn stats(&self) -> Vec<Arc<EndpointStats>> {
        self.endpoints.iter().map(|e| Arc::clone(&e.stats)).collect()
    }
}

/// Server-side listeners: one per shard hosted by this process.
///
/// Dropping (or [`TcpServer::shutdown`]) stops the accept loops; open
/// connections are left to their handler threads, which exit when the
/// peer closes or the shard's serve loop is gone.
pub struct TcpServer {
    addrs: Vec<SocketAddr>,
    stop: Arc<AtomicBool>,
    accepts: Vec<JoinHandle<()>>,
}

impl TcpServer {
    /// Bind one listener per address and return the server handle plus
    /// one [`Inbox`] per listener (in address order). Use port `0` for an
    /// ephemeral port; the resolved addresses are available from
    /// [`TcpServer::addrs`].
    pub fn bind(addrs: &[SocketAddr]) -> io::Result<(TcpServer, Vec<Inbox>)> {
        // Bind every listener before spawning anything, so a failed bind
        // leaks no accept threads.
        let mut listeners = Vec::with_capacity(addrs.len());
        let mut local = Vec::with_capacity(addrs.len());
        for addr in addrs {
            let listener = TcpListener::bind(addr)?;
            listener.set_nonblocking(true)?;
            local.push(listener.local_addr()?);
            listeners.push(listener);
        }
        let stop = Arc::new(AtomicBool::new(false));
        let mut inboxes = Vec::with_capacity(addrs.len());
        let mut accepts = Vec::with_capacity(addrs.len());
        for (i, listener) in listeners.into_iter().enumerate() {
            let (tx, rx) = mpsc::channel();
            inboxes.push(Inbox { rx });
            let stop = Arc::clone(&stop);
            let handle = std::thread::Builder::new()
                .name(format!("glint-tcp-accept-{i}"))
                .spawn(move || accept_loop(&listener, &tx, &stop))
                .expect("spawn tcp accept loop");
            accepts.push(handle);
        }
        Ok((TcpServer { addrs: local, stop, accepts }, inboxes))
    }

    /// Local addresses of the listeners, in shard order.
    pub fn addrs(&self) -> &[SocketAddr] {
        &self.addrs
    }

    /// Stop accepting new connections and join the accept threads.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        for h in self.accepts.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for TcpServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(listener: &TcpListener, tx: &mpsc::Sender<Envelope>, stop: &AtomicBool) {
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let tx = tx.clone();
                let _ = std::thread::Builder::new()
                    .name("glint-tcp-conn".into())
                    .spawn(move || connection_loop(stream, &tx));
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => std::thread::sleep(ACCEPT_POLL),
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            // Transient accept errors (ECONNABORTED from a client that
            // RST before accept, EMFILE under fd pressure) must not kill
            // the listener for the life of the serve process; back off
            // and keep accepting.
            Err(_) => std::thread::sleep(ACCEPT_POLL),
        }
    }
}

/// One request/reply at a time per connection, in frame order. The
/// envelope hop into the shard's inbox preserves the single-threaded
/// actor model of the serve loop: many connections, one processor.
fn connection_loop(mut stream: TcpStream, tx: &mpsc::Sender<Envelope>) {
    // BSD-derived platforms (macOS included) hand accepted sockets the
    // listener's O_NONBLOCK flag; reads here must block.
    if stream.set_nonblocking(false).is_err() {
        return;
    }
    let _ = stream.set_nodelay(true);
    // Bound reply writes so a peer that stops reading cannot pin this
    // handler thread forever on a full send buffer.
    let _ = stream.set_write_timeout(Some(HANDLER_REPLY_TIMEOUT));
    loop {
        let payload = match read_frame(&mut stream) {
            Ok(Some(p)) => p,
            Ok(None) | Err(_) => return, // peer closed, or framing error
        };
        let (reply_tx, reply_rx) = mpsc::sync_channel(1);
        if tx.send(Envelope { payload, reply: Some(reply_tx) }).is_err() {
            return; // the shard's serve loop has exited
        }
        let Ok(reply) = reply_rx.recv_timeout(HANDLER_REPLY_TIMEOUT) else {
            return;
        };
        if write_frame(&mut stream, &reply).is_err() {
            return;
        }
    }
}

/// Resolve `host:port` strings (one per shard) into socket addresses.
pub fn resolve_addrs(specs: &[String]) -> Result<Vec<SocketAddr>> {
    specs
        .iter()
        .map(|spec| {
            spec.to_socket_addrs()
                .map_err(|e| Error::Config(format!("cannot resolve {spec:?}: {e}")))?
                .next()
                .ok_or_else(|| Error::Config(format!("{spec:?} resolved to no addresses")))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::respond;

    /// Echo server over an inbox; returns on the b"stop" sentinel.
    fn spawn_echo(inbox: Inbox) -> std::thread::JoinHandle<usize> {
        std::thread::spawn(move || {
            let mut handled = 0;
            while let Some(env) = inbox.recv() {
                handled += 1;
                let stop = env.payload == b"stop";
                respond(&env, env.payload.clone());
                if stop {
                    return handled;
                }
            }
            handled
        })
    }

    fn loopback() -> SocketAddr {
        "127.0.0.1:0".parse().unwrap()
    }

    #[test]
    fn roundtrip_over_loopback() {
        let (mut server, mut inboxes) = TcpServer::bind(&[loopback()]).unwrap();
        let h = spawn_echo(inboxes.remove(0));
        let transport = TcpTransport::connect(server.addrs());
        let ep = transport.endpoint(0);
        for i in 0..50u32 {
            let got = ep.request(i.to_le_bytes().to_vec(), Duration::from_secs(2)).unwrap();
            assert_eq!(got, i.to_le_bytes());
        }
        assert_eq!(ep.stats.requests(), 50);
        assert_eq!(ep.stats.replies(), 50);
        assert_eq!(ep.stats.bytes_sent(), 200);
        ep.request(b"stop".to_vec(), Duration::from_secs(2)).unwrap();
        server.shutdown();
        assert_eq!(h.join().unwrap(), 51);
    }

    #[test]
    fn concurrent_clients_share_the_pool() {
        let (mut server, mut inboxes) = TcpServer::bind(&[loopback()]).unwrap();
        let h = spawn_echo(inboxes.remove(0));
        let transport = TcpTransport::connect(server.addrs());
        std::thread::scope(|scope| {
            for t in 0..8u8 {
                let ep = transport.endpoint(0);
                scope.spawn(move || {
                    for i in 0..20u8 {
                        let msg = vec![t, i];
                        let got = ep.request(msg.clone(), Duration::from_secs(2)).unwrap();
                        assert_eq!(got, msg);
                    }
                });
            }
        });
        let ep = transport.endpoint(0);
        assert_eq!(ep.stats.requests(), 8 * 20);
        ep.request(b"stop".to_vec(), Duration::from_secs(2)).unwrap();
        server.shutdown();
        assert_eq!(h.join().unwrap(), 8 * 20 + 1);
    }

    #[test]
    fn unserviced_endpoint_times_out() {
        // Bind a listener whose inbox is never drained: the handler
        // forwards the frame but no reply ever comes, so the client must
        // observe a timeout, not a hang.
        let (mut server, inboxes) = TcpServer::bind(&[loopback()]).unwrap();
        let transport = TcpTransport::connect(server.addrs());
        let ep = transport.endpoint(0);
        let r = ep.request(vec![1, 2, 3], Duration::from_millis(50));
        assert!(r.is_err());
        assert_eq!(ep.stats.timeouts(), 1);
        drop(inboxes);
        server.shutdown();
    }

    #[test]
    fn dead_endpoint_is_an_error_not_a_hang() {
        // Bind-then-drop leaves a port with no listener.
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap()
        };
        let transport = TcpTransport::connect(&[addr]);
        let ep = transport.endpoint(0);
        let r = ep.request(vec![9], Duration::from_millis(30));
        assert!(r.is_err());
        assert_eq!(ep.stats.timeouts(), 1);
    }

    #[test]
    fn resolve_addrs_parses_and_rejects() {
        let ok = resolve_addrs(&["127.0.0.1:7000".to_string()]).unwrap();
        assert_eq!(ok[0].port(), 7000);
        assert!(resolve_addrs(&["not an address".to_string()]).is_err());
    }
}
