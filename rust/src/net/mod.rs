//! Message transports connecting parameter-server clients to shards.
//!
//! The paper's parameter server runs on Akka, whose delivery guarantee is
//! **at-most-once**: a message may be lost, and the sender cannot tell a
//! lost message from a slow one. All of Glint's protocol machinery
//! (retrying pulls with exponential back-off, the exactly-once push
//! hand-shake) exists *because* of this semantics, so every transport
//! here exposes the same contract through the [`Transport`] trait:
//!
//! - [`SimTransport`] — in-process delivery to shard inboxes with
//!   configurable fault injection ([`FaultPlan`]): dropped requests,
//!   dropped replies, duplicated deliveries, added latency, periodic
//!   partition windows. The protocol test bed. The same plan drives the
//!   TCP path through the [`chaos`] interposer.
//! - [`tcp::TcpTransport`] — real TCP with correlation-tagged,
//!   length-prefixed frames ([`frame`]): one multiplexed connection per
//!   shard carries any number of concurrently outstanding requests, with
//!   responses matched back to waiters by correlation id (out-of-order
//!   completion is fine) and reconnect-on-error. The multi-process
//!   deployment path; here the *network itself* supplies the
//!   at-most-once behavior (timeouts, dead peers, dropped connections).
//!
//! Requests and replies are fully serialized through [`crate::util::codec`]
//! in both cases, so measured message *sizes* are faithful (the paper
//! reasons about ~2 MB push messages and shuffle-write volumes) and the
//! two transports are wire-compatible.

pub mod chaos;
pub mod frame;
pub mod infer;
pub mod stats;
pub mod tcp;

use std::sync::Arc;
use std::time::Duration;

// Synchronization comes from the sync_shim so the model checker can drive
// the sim transport's channels through explored interleavings (plain std
// re-exports in normal builds).
use crate::util::sync_shim::atomic::{AtomicU64, Ordering};
use crate::util::sync_shim::mpsc::{self, Receiver, RecvTimeoutError, SyncSender, TrySendError};

use crate::util::rng::Pcg64;
use stats::EndpointStats;

/// A request in flight: encoded bytes plus a reply channel.
///
/// Dropping the reply sender simulates a lost response: the server
/// processes the request but the client never hears back.
pub struct Envelope {
    /// Encoded request.
    pub payload: Vec<u8>,
    /// Channel on which the endpoint sends the encoded response, if the
    /// fault plan lets the response through.
    pub reply: Option<SyncSender<Vec<u8>>>,
}

/// Fault-injection plan for a [`SimTransport`].
#[derive(Debug, Clone)]
pub struct FaultPlan {
    /// Probability a request is silently dropped before delivery.
    pub drop_request: f64,
    /// Probability the response is dropped after the server processed the
    /// request (the dangerous case for pushes).
    pub drop_reply: f64,
    /// Probability a delivered request is delivered *twice* (models a
    /// retransmission racing a slow first delivery).
    pub duplicate: f64,
    /// Artificial one-way latency added to each delivery.
    pub latency: Duration,
    /// Periodic partition: out of every `partition_every` sends, the
    /// first `partition_len` are blackholed (request dropped before
    /// delivery). `0` disables. Deterministic in the send counter, so a
    /// partition window replays bit-exactly from the transport seed.
    pub partition_every: u64,
    /// Length of each partition window in sends (see `partition_every`).
    pub partition_len: u64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            drop_request: 0.0,
            drop_reply: 0.0,
            duplicate: 0.0,
            latency: Duration::ZERO,
            partition_every: 0,
            partition_len: 0,
        }
    }
}

impl FaultPlan {
    /// A lossless, zero-latency network.
    pub fn reliable() -> Self {
        FaultPlan::default()
    }

    /// A nasty network for protocol tests.
    pub fn lossy(drop: f64, duplicate: f64) -> Self {
        FaultPlan {
            drop_request: drop,
            drop_reply: drop,
            duplicate,
            ..FaultPlan::default()
        }
    }

    /// True when this plan injects no faults and no latency.
    pub fn is_reliable(&self) -> bool {
        self.drop_request == 0.0
            && self.drop_reply == 0.0
            && self.duplicate == 0.0
            && self.latency.is_zero()
            && self.partition_len == 0
    }

    /// True when send number `n` falls inside a partition window.
    pub fn partitioned(&self, n: u64) -> bool {
        self.partition_every > 0
            && self.partition_len > 0
            && n % self.partition_every < self.partition_len
    }
}

/// A client's view of one shard: `n` shard endpoints plus per-endpoint
/// traffic counters. Implemented by [`SimTransport`] (in-process, fault
/// injectable) and [`tcp::TcpTransport`] (real sockets).
pub trait Transport: Send + Sync {
    /// Number of shard endpoints.
    fn shards(&self) -> usize;

    /// Handle to one shard's endpoint.
    fn endpoint(&self, shard: usize) -> Endpoint;

    /// Per-endpoint stats handles (request counts, bytes, faults).
    fn stats(&self) -> Vec<Arc<EndpointStats>>;

    /// All endpoints, in shard order.
    fn endpoints(&self) -> Vec<Endpoint> {
        (0..self.shards()).map(|s| self.endpoint(s)).collect()
    }
}

/// Sending half of a connection to one endpoint (shard), over whichever
/// backend the transport uses.
#[derive(Clone)]
pub struct Endpoint {
    inner: EndpointInner,
    /// Delivery/traffic counters for this endpoint.
    pub stats: Arc<EndpointStats>,
}

#[derive(Clone)]
enum EndpointInner {
    Sim(SimEndpoint),
    Tcp(tcp::TcpEndpoint),
}

/// Simulated backend: an in-process channel plus the fault plan.
#[derive(Clone)]
struct SimEndpoint {
    tx: mpsc::Sender<Envelope>,
    plan: Arc<FaultPlan>,
    seed: Arc<AtomicU64>,
}

impl SimEndpoint {
    /// Deliver a request according to the fault plan; returns a receiver
    /// for the reply (which may never arrive).
    fn send(&self, payload: Vec<u8>, stats: &EndpointStats) -> Receiver<Vec<u8>> {
        let (reply_tx, reply_rx) = mpsc::sync_channel(2);
        // Each send gets a fresh deterministic stream keyed by the send
        // counter: fault decisions are reproducible for a given transport
        // seed and send ordering.
        let n = self.seed.fetch_add(1, Ordering::Relaxed);
        let mut rng = Pcg64::new(n ^ 0xfa_175);
        stats.record_request(payload.len());

        if !self.plan.latency.is_zero() {
            std::thread::sleep(self.plan.latency);
        }
        if self.plan.partitioned(n) || rng.bernoulli(self.plan.drop_request) {
            stats.record_dropped_request();
            return reply_rx; // envelope never delivered
        }
        let duplicate = rng.bernoulli(self.plan.duplicate);
        let reply = if rng.bernoulli(self.plan.drop_reply) {
            stats.record_dropped_reply();
            None
        } else {
            Some(reply_tx)
        };
        let _ = self.tx.send(Envelope { payload: payload.clone(), reply });
        if duplicate {
            stats.record_duplicate();
            // The duplicate's reply channel is a dead end; the client
            // consumes at most one response anyway.
            let _ = self.tx.send(Envelope { payload, reply: None });
        }
        reply_rx
    }
}

impl Endpoint {
    /// Send and wait for a reply with a timeout. `Ok(bytes)` on success,
    /// `Err(())` on timeout / lost message.
    pub fn request(&self, payload: Vec<u8>, timeout: Duration) -> Result<Vec<u8>, ()> {
        match &self.inner {
            EndpointInner::Sim(sim) => {
                let rx = sim.send(payload, &self.stats);
                match rx.recv_timeout(timeout) {
                    Ok(bytes) => {
                        self.stats.record_reply(bytes.len());
                        Ok(bytes)
                    }
                    Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => {
                        self.stats.record_timeout();
                        Err(())
                    }
                }
            }
            EndpointInner::Tcp(ep) => {
                self.stats.record_request(payload.len());
                match ep.roundtrip(&payload, timeout) {
                    Ok(bytes) => {
                        self.stats.record_reply(bytes.len());
                        Ok(bytes)
                    }
                    Err(()) => {
                        self.stats.record_timeout();
                        Err(())
                    }
                }
            }
        }
    }

    /// Control-plane send that bypasses fault injection (used for
    /// shutdown — modeling an operator channel, not the data path).
    /// Returns `Err(())` if the endpoint's server has already exited.
    pub fn send_reliable(&self, payload: Vec<u8>, timeout: Duration) -> Result<Vec<u8>, ()> {
        match &self.inner {
            EndpointInner::Sim(sim) => {
                let (reply_tx, reply_rx) = mpsc::sync_channel(2);
                if sim.tx.send(Envelope { payload, reply: Some(reply_tx) }).is_err() {
                    return Err(());
                }
                reply_rx.recv_timeout(timeout).map_err(|_| ())
            }
            // Operator traffic skips the chaos interposer (uncounted),
            // exactly as the sim arm skips the fault plan.
            EndpointInner::Tcp(ep) => ep.roundtrip_inner(&payload, timeout, false),
        }
    }
}

/// Receiving half: the shard server's inbox.
pub struct Inbox {
    rx: mpsc::Receiver<Envelope>,
}

impl Inbox {
    /// Build an inbox over a fresh channel, returning the sending half.
    /// Test and model harnesses use this to drive a serve loop directly.
    pub fn channel() -> (mpsc::Sender<Envelope>, Inbox) {
        let (tx, rx) = mpsc::channel();
        (tx, Inbox { rx })
    }

    /// Block for the next envelope; `None` when all senders are gone.
    pub fn recv(&self) -> Option<Envelope> {
        self.rx.recv().ok()
    }

    /// Receive with a timeout (lets server loops check for shutdown).
    pub fn recv_timeout(&self, timeout: Duration) -> Option<Envelope> {
        self.rx.recv_timeout(timeout).ok()
    }
}

/// Reply to an envelope, if its reply path survived fault injection.
pub fn respond(env: &Envelope, bytes: Vec<u8>) {
    if let Some(reply) = &env.reply {
        match reply.try_send(bytes) {
            Ok(()) | Err(TrySendError::Full(_)) | Err(TrySendError::Disconnected(_)) => {}
        }
    }
}

/// An in-process network connecting clients to `n` shard endpoints.
pub struct SimTransport {
    endpoints: Vec<Endpoint>,
}

impl SimTransport {
    /// Create a transport with `shards` endpoints under the given fault
    /// plan and a deterministic seed. Returns the transport (clients keep
    /// it) and one inbox per shard (server threads take them).
    pub fn new(shards: usize, plan: FaultPlan, seed: u64) -> (SimTransport, Vec<Inbox>) {
        let plan = Arc::new(plan);
        let mut endpoints = Vec::with_capacity(shards);
        let mut inboxes = Vec::with_capacity(shards);
        for s in 0..shards {
            let (tx, rx) = mpsc::channel();
            endpoints.push(Endpoint {
                inner: EndpointInner::Sim(SimEndpoint {
                    tx,
                    plan: Arc::clone(&plan),
                    seed: Arc::new(AtomicU64::new(
                        seed.wrapping_mul(0x9e37_79b9).wrapping_add(s as u64) << 20,
                    )),
                }),
                stats: Arc::new(EndpointStats::default()),
            });
            inboxes.push(Inbox { rx });
        }
        (SimTransport { endpoints }, inboxes)
    }
}

impl Transport for SimTransport {
    fn shards(&self) -> usize {
        self.endpoints.len()
    }

    fn endpoint(&self, shard: usize) -> Endpoint {
        self.endpoints[shard].clone()
    }

    fn stats(&self) -> Vec<Arc<EndpointStats>> {
        self.endpoints.iter().map(|e| Arc::clone(&e.stats)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Echo server: replies with the request payload.
    fn spawn_echo(inbox: Inbox) -> std::thread::JoinHandle<usize> {
        std::thread::spawn(move || {
            let mut handled = 0;
            while let Some(env) = inbox.recv() {
                handled += 1;
                let bytes = env.payload.clone();
                respond(&env, bytes);
            }
            handled
        })
    }

    #[test]
    fn reliable_roundtrip() {
        let (net, mut inboxes) = SimTransport::new(1, FaultPlan::reliable(), 1);
        let h = spawn_echo(inboxes.remove(0));
        let ep = net.endpoint(0);
        for i in 0..100u32 {
            let got = ep.request(i.to_le_bytes().to_vec(), Duration::from_secs(1)).unwrap();
            assert_eq!(got, i.to_le_bytes().to_vec());
        }
        drop(net);
        drop(ep);
        assert_eq!(h.join().unwrap(), 100);
    }

    #[test]
    fn dropped_requests_time_out() {
        let plan = FaultPlan { drop_request: 1.0, ..FaultPlan::default() };
        let (net, mut inboxes) = SimTransport::new(1, plan, 2);
        let _h = spawn_echo(inboxes.remove(0));
        let ep = net.endpoint(0);
        let r = ep.request(vec![1, 2, 3], Duration::from_millis(20));
        assert!(r.is_err());
        assert_eq!(ep.stats.dropped_requests(), 1);
    }

    #[test]
    fn dropped_replies_still_process() {
        let plan = FaultPlan { drop_reply: 1.0, ..FaultPlan::default() };
        let (net, mut inboxes) = SimTransport::new(1, plan, 3);
        let h = spawn_echo(inboxes.remove(0));
        let ep = net.endpoint(0);
        let r = ep.request(vec![9], Duration::from_millis(20));
        assert!(r.is_err());
        drop(net);
        drop(ep);
        // The server did process the request even though the reply was lost.
        assert_eq!(h.join().unwrap(), 1);
    }

    #[test]
    fn duplicates_deliver_twice() {
        let plan = FaultPlan { duplicate: 1.0, ..FaultPlan::default() };
        let (net, mut inboxes) = SimTransport::new(1, plan, 4);
        let h = spawn_echo(inboxes.remove(0));
        let ep = net.endpoint(0);
        let r = ep.request(vec![7], Duration::from_millis(100));
        assert!(r.is_ok());
        drop(net);
        drop(ep);
        assert_eq!(h.join().unwrap(), 2);
    }

    #[test]
    fn stats_track_bytes() {
        let (net, mut inboxes) = SimTransport::new(1, FaultPlan::reliable(), 5);
        let _h = spawn_echo(inboxes.remove(0));
        let ep = net.endpoint(0);
        ep.request(vec![0; 128], Duration::from_secs(1)).unwrap();
        assert_eq!(ep.stats.requests(), 1);
        assert_eq!(ep.stats.bytes_sent(), 128);
        assert_eq!(ep.stats.bytes_received(), 128);
    }

    #[test]
    fn multi_shard_isolation() {
        let (net, inboxes) = SimTransport::new(4, FaultPlan::reliable(), 6);
        let handles: Vec<_> = inboxes.into_iter().map(spawn_echo).collect();
        for s in 0..4 {
            let ep = net.endpoint(s);
            ep.request(vec![s as u8], Duration::from_secs(1)).unwrap();
        }
        let eps: Vec<_> = (0..4).map(|s| net.endpoint(s)).collect();
        drop(net);
        drop(eps);
        for h in handles {
            assert_eq!(h.join().unwrap(), 1);
        }
    }

    #[test]
    fn fault_plan_reliability_check() {
        assert!(FaultPlan::reliable().is_reliable());
        assert!(!FaultPlan::lossy(0.1, 0.0).is_reliable());
        assert!(!FaultPlan { latency: Duration::from_millis(1), ..FaultPlan::default() }
            .is_reliable());
        assert!(!FaultPlan { partition_every: 8, partition_len: 2, ..FaultPlan::default() }
            .is_reliable());
    }

    #[test]
    fn partition_windows_blackhole_deterministically() {
        let plan = FaultPlan { partition_every: 4, partition_len: 2, ..FaultPlan::default() };
        // Window shape is a pure function of the send counter.
        assert!(plan.partitioned(0));
        assert!(plan.partitioned(1));
        assert!(!plan.partitioned(2));
        assert!(!plan.partitioned(3));
        assert!(plan.partitioned(4));

        let (net, mut inboxes) = SimTransport::new(1, plan, 7);
        let h = spawn_echo(inboxes.remove(0));
        let ep = net.endpoint(0);
        let mut outcomes = Vec::new();
        for i in 0..8u32 {
            outcomes.push(ep.request(vec![i as u8], Duration::from_millis(20)).is_ok());
        }
        assert_eq!(outcomes, vec![false, false, true, true, false, false, true, true]);
        drop(net);
        drop(ep);
        assert_eq!(h.join().unwrap(), 4);
    }
}
