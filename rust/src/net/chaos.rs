//! Deterministic network chaos for the TCP transport.
//!
//! The sim transport has always had seeded fault injection
//! ([`super::FaultPlan`]); real multi-process deployments had none — a
//! flaky cluster test over TCP was unreproducible by construction. This
//! module closes that gap with an **in-process interposer**: once
//! [`install`]ed, every [`super::tcp::TcpEndpoint`] round-trip in this
//! process consults a process-global [`FaultPlan`] and a per-send
//! counter-keyed RNG (the same forking scheme the sim transport uses), so
//! requests are dropped, duplicated, delayed, or blackholed through
//! partition windows *deterministically in the send ordering* for a given
//! seed.
//!
//! Replay workflow: any test or demo that installs chaos logs a
//! `chaos: plan=... seed=...` line up front. When a run fails, re-running
//! with the same `--chaos-seed`/`--chaos-plan` (or
//! `GLINT_CHAOS_SEED`/`GLINT_CHAOS_PLAN`) reproduces the same fault
//! decisions at the same send offsets. Control-plane traffic sent through
//! [`super::Endpoint::send_reliable`] bypasses the interposer, exactly as
//! it bypasses the sim fault plan.

use std::sync::OnceLock;
use std::time::Duration;

use crate::util::error::{Error, Result};
use crate::util::rng::Pcg64;
use crate::util::sync_shim::atomic::{AtomicU64, Ordering};

use super::FaultPlan;

/// Installed interposer state: the plan, the seed, and the send counter
/// that keys each round-trip's fault decisions.
struct ChaosState {
    plan: FaultPlan,
    seed: u64,
    sends: AtomicU64,
}

static CHAOS: OnceLock<ChaosState> = OnceLock::new();

/// Fault decisions for one TCP round-trip.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Verdict {
    /// Drop the request before it is written (the peer never sees it).
    pub drop_request: bool,
    /// Write the frame twice under distinct correlation ids (models a
    /// retransmission racing a slow first delivery; the server processes
    /// both, the client consumes one reply).
    pub duplicate: bool,
    /// Perform the round-trip but discard the reply (the dangerous case
    /// for pushes: applied server-side, unacknowledged client-side).
    pub drop_reply: bool,
    /// Sleep this long before sending.
    pub delay: Duration,
}

/// Install a process-global chaos plan for the TCP transport. Idempotent:
/// the first install wins and later calls return `false` (so a test
/// binary with several chaos tests cannot silently change plans
/// mid-process). Logs the replay line.
pub fn install(plan: FaultPlan, seed: u64) -> bool {
    let installed = CHAOS
        .set(ChaosState { plan: plan.clone(), seed, sends: AtomicU64::new(0) })
        .is_ok();
    if installed {
        crate::log_info!("chaos: plan=[{}] seed={seed} (replay with --chaos-plan/--chaos-seed)",
            format_plan(&plan));
    }
    installed
}

/// Install from `GLINT_CHAOS_PLAN` / `GLINT_CHAOS_SEED` when set.
/// Returns whether an interposer is active after the call. A present
/// plan with a missing seed defaults to seed `1`.
pub fn install_from_env() -> bool {
    let Ok(spec) = std::env::var("GLINT_CHAOS_PLAN") else {
        return CHAOS.get().is_some();
    };
    let seed = std::env::var("GLINT_CHAOS_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(1);
    match parse_plan(&spec) {
        Ok(plan) => install(plan, seed),
        Err(e) => {
            crate::log_warn!("ignoring GLINT_CHAOS_PLAN: {e}");
            CHAOS.get().is_some()
        }
    }
}

/// True when a chaos plan is installed in this process.
pub fn active() -> bool {
    CHAOS.get().is_some()
}

/// Fault decisions for the next TCP round-trip, or `None` when no chaos
/// is installed (the common case: one branch, no RNG work).
pub(crate) fn verdict() -> Option<Verdict> {
    let state = CHAOS.get()?;
    let n = state.sends.fetch_add(1, Ordering::Relaxed);
    // Same per-send stream forking the sim transport uses, keyed off the
    // installed seed so distinct seeds explore distinct fault schedules.
    let mut rng = Pcg64::new(state.seed.wrapping_mul(0x9e37_79b9).wrapping_add(n) ^ 0xfa_175);
    let plan = &state.plan;
    Some(Verdict {
        drop_request: plan.partitioned(n) || rng.bernoulli(plan.drop_request),
        duplicate: rng.bernoulli(plan.duplicate),
        drop_reply: rng.bernoulli(plan.drop_reply),
        delay: plan.latency,
    })
}

/// Parse a chaos plan spec: comma-separated `key=value` pairs.
///
/// Keys: `drop` (both directions), `drop_req`, `drop_reply`, `dup`
/// (probabilities in `[0,1]`), `delay` (per-send latency, `2ms`/`1s`
/// style), `partition` (`LEN/EVERY` — out of every `EVERY` sends the
/// first `LEN` are blackholed). Example:
/// `drop=0.05,dup=0.02,delay=1ms,partition=40/400`.
pub fn parse_plan(spec: &str) -> Result<FaultPlan> {
    let mut plan = FaultPlan::default();
    for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        let (key, value) = part
            .split_once('=')
            .ok_or_else(|| Error::Config(format!("chaos plan: {part:?} is not key=value")))?;
        let bad = |what: &str| Error::Config(format!("chaos plan: bad {what} in {part:?}"));
        match key {
            "drop" => {
                let p = parse_prob(value).ok_or_else(|| bad("probability"))?;
                plan.drop_request = p;
                plan.drop_reply = p;
            }
            "drop_req" => plan.drop_request = parse_prob(value).ok_or_else(|| bad("probability"))?,
            "drop_reply" => plan.drop_reply = parse_prob(value).ok_or_else(|| bad("probability"))?,
            "dup" => plan.duplicate = parse_prob(value).ok_or_else(|| bad("probability"))?,
            "delay" => plan.latency = parse_duration(value).ok_or_else(|| bad("duration"))?,
            "partition" => {
                let (len, every) = value.split_once('/').ok_or_else(|| bad("LEN/EVERY"))?;
                plan.partition_len = len.parse().map_err(|_| bad("LEN"))?;
                plan.partition_every = every.parse().map_err(|_| bad("EVERY"))?;
                if plan.partition_len > plan.partition_every {
                    return Err(Error::Config(format!(
                        "chaos plan: partition window {len} longer than its period {every}"
                    )));
                }
            }
            _ => return Err(Error::Config(format!("chaos plan: unknown key {key:?}"))),
        }
    }
    Ok(plan)
}

/// Render a plan in the same `key=value` grammar [`parse_plan`] accepts,
/// so the logged replay line can be pasted back into `--chaos-plan`.
pub fn format_plan(plan: &FaultPlan) -> String {
    let mut parts = Vec::new();
    if plan.drop_request > 0.0 {
        parts.push(format!("drop_req={}", plan.drop_request));
    }
    if plan.drop_reply > 0.0 {
        parts.push(format!("drop_reply={}", plan.drop_reply));
    }
    if plan.duplicate > 0.0 {
        parts.push(format!("dup={}", plan.duplicate));
    }
    if !plan.latency.is_zero() {
        parts.push(format!("delay={}us", plan.latency.as_micros()));
    }
    if plan.partition_len > 0 {
        parts.push(format!("partition={}/{}", plan.partition_len, plan.partition_every));
    }
    parts.join(",")
}

fn parse_prob(s: &str) -> Option<f64> {
    let p = s.parse::<f64>().ok()?;
    (0.0..=1.0).contains(&p).then_some(p)
}

/// Parse `10us` / `2ms` / `1s` / bare-milliseconds durations.
pub fn parse_duration(s: &str) -> Option<Duration> {
    let (digits, unit) = match s.find(|c: char| !c.is_ascii_digit()) {
        Some(i) => s.split_at(i),
        None => (s, "ms"),
    };
    let n = digits.parse::<u64>().ok()?;
    match unit {
        "us" => Some(Duration::from_micros(n)),
        "ms" => Some(Duration::from_millis(n)),
        "s" => Some(Duration::from_secs(n)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_specs_parse() {
        let plan = parse_plan("drop=0.05,dup=0.02,delay=1ms,partition=40/400").unwrap();
        assert_eq!(plan.drop_request, 0.05);
        assert_eq!(plan.drop_reply, 0.05);
        assert_eq!(plan.duplicate, 0.02);
        assert_eq!(plan.latency, Duration::from_millis(1));
        assert_eq!(plan.partition_len, 40);
        assert_eq!(plan.partition_every, 400);

        let plan = parse_plan("drop_req=1,drop_reply=0").unwrap();
        assert_eq!(plan.drop_request, 1.0);
        assert_eq!(plan.drop_reply, 0.0);

        assert!(parse_plan("drop=2").is_err());
        assert!(parse_plan("drop").is_err());
        assert!(parse_plan("partition=400/40").is_err());
        assert!(parse_plan("warp=0.5").is_err());
    }

    #[test]
    fn plans_roundtrip_through_format() {
        for spec in ["drop_req=0.1,dup=0.05", "delay=1500us,partition=8/64", ""] {
            let plan = parse_plan(spec).unwrap();
            let reparsed = parse_plan(&format_plan(&plan)).unwrap();
            assert_eq!(format!("{plan:?}"), format!("{reparsed:?}"));
        }
    }

    #[test]
    fn durations_parse() {
        assert_eq!(parse_duration("10us"), Some(Duration::from_micros(10)));
        assert_eq!(parse_duration("2ms"), Some(Duration::from_millis(2)));
        assert_eq!(parse_duration("1s"), Some(Duration::from_secs(1)));
        assert_eq!(parse_duration("7"), Some(Duration::from_millis(7)));
        assert_eq!(parse_duration("7min"), None);
        assert_eq!(parse_duration(""), None);
    }
}
