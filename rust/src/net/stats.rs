//! Per-endpoint traffic counters.
//!
//! These power the Fig. 5 load-balance measurement (requests per machine)
//! and the network-volume columns of the experiment reports. The
//! in-flight / queue-wait counters instrument the asynchronous client
//! dispatchers so pipelining wins show up in the `ps_throughput` bench
//! summary.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Lock-free counters for one endpoint (shard).
#[derive(Debug, Default)]
pub struct EndpointStats {
    requests: AtomicU64,
    replies: AtomicU64,
    bytes_sent: AtomicU64,
    bytes_received: AtomicU64,
    dropped_requests: AtomicU64,
    dropped_replies: AtomicU64,
    duplicates: AtomicU64,
    timeouts: AtomicU64,
    /// Asynchronous operations currently in this shard's window
    /// (submitted, not yet completed).
    in_flight: AtomicU64,
    /// High-water mark of `in_flight`.
    max_in_flight: AtomicU64,
    /// Total time ops spent queued before a dispatcher worker picked
    /// them up, in nanoseconds.
    queue_wait_nanos: AtomicU64,
    /// Ops whose queue wait has been recorded.
    dispatched_ops: AtomicU64,
    /// Times the client's route for this shard failed over to another
    /// replica after repeated delivery failures.
    failovers: AtomicU64,
}

impl EndpointStats {
    /// Record an outgoing request of `bytes` bytes.
    pub fn record_request(&self, bytes: usize) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.bytes_sent.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    /// Record a received reply of `bytes` bytes.
    pub fn record_reply(&self, bytes: usize) {
        self.replies.fetch_add(1, Ordering::Relaxed);
        self.bytes_received.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    /// Record a request dropped by fault injection.
    pub fn record_dropped_request(&self) {
        self.dropped_requests.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a reply dropped by fault injection.
    pub fn record_dropped_reply(&self) {
        self.dropped_replies.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a duplicated delivery.
    pub fn record_duplicate(&self) {
        self.duplicates.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a client-observed timeout.
    pub fn record_timeout(&self) {
        self.timeouts.fetch_add(1, Ordering::Relaxed);
    }

    /// Requests sent to this endpoint.
    pub fn requests(&self) -> u64 {
        self.requests.load(Ordering::Relaxed)
    }

    /// Replies received from this endpoint.
    pub fn replies(&self) -> u64 {
        self.replies.load(Ordering::Relaxed)
    }

    /// Total request bytes.
    pub fn bytes_sent(&self) -> u64 {
        self.bytes_sent.load(Ordering::Relaxed)
    }

    /// Total reply bytes.
    pub fn bytes_received(&self) -> u64 {
        self.bytes_received.load(Ordering::Relaxed)
    }

    /// Requests lost to fault injection.
    pub fn dropped_requests(&self) -> u64 {
        self.dropped_requests.load(Ordering::Relaxed)
    }

    /// Replies lost to fault injection.
    pub fn dropped_replies(&self) -> u64 {
        self.dropped_replies.load(Ordering::Relaxed)
    }

    /// Duplicated deliveries.
    pub fn duplicates(&self) -> u64 {
        self.duplicates.load(Ordering::Relaxed)
    }

    /// Client-observed timeouts.
    pub fn timeouts(&self) -> u64 {
        self.timeouts.load(Ordering::Relaxed)
    }

    /// Record a client-side failover to another replica of this shard.
    pub fn record_failover(&self) {
        self.failovers.fetch_add(1, Ordering::Relaxed);
    }

    /// Route failovers triggered against this shard.
    pub fn failovers(&self) -> u64 {
        self.failovers.load(Ordering::Relaxed)
    }

    /// Record an async op entering this shard's in-flight window.
    pub fn record_op_submitted(&self) {
        let now = self.in_flight.fetch_add(1, Ordering::Relaxed) + 1;
        self.max_in_flight.fetch_max(now, Ordering::Relaxed);
    }

    /// Record an async op leaving the window (completed).
    pub fn record_op_completed(&self) {
        self.in_flight.fetch_sub(1, Ordering::Relaxed);
    }

    /// Record how long an op waited in the dispatcher queue before a
    /// worker picked it up.
    pub fn record_queue_wait(&self, wait: Duration) {
        self.queue_wait_nanos.fetch_add(wait.as_nanos() as u64, Ordering::Relaxed);
        self.dispatched_ops.fetch_add(1, Ordering::Relaxed);
    }

    /// Async ops currently in flight against this shard.
    pub fn in_flight(&self) -> u64 {
        self.in_flight.load(Ordering::Relaxed)
    }

    /// High-water mark of concurrently in-flight async ops.
    pub fn max_in_flight(&self) -> u64 {
        self.max_in_flight.load(Ordering::Relaxed)
    }

    /// Ops dispatched through the async window so far.
    pub fn dispatched_ops(&self) -> u64 {
        self.dispatched_ops.load(Ordering::Relaxed)
    }

    /// Mean queue wait of dispatched ops (zero when none ran).
    pub fn avg_queue_wait(&self) -> Duration {
        let ops = self.dispatched_ops.load(Ordering::Relaxed);
        if ops == 0 {
            return Duration::ZERO;
        }
        Duration::from_nanos(self.queue_wait_nanos.load(Ordering::Relaxed) / ops)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let s = EndpointStats::default();
        s.record_request(100);
        s.record_request(50);
        s.record_reply(25);
        s.record_timeout();
        assert_eq!(s.requests(), 2);
        assert_eq!(s.bytes_sent(), 150);
        assert_eq!(s.replies(), 1);
        assert_eq!(s.bytes_received(), 25);
        assert_eq!(s.timeouts(), 1);
    }

    #[test]
    fn in_flight_window_tracks_depth_and_wait() {
        let s = EndpointStats::default();
        s.record_op_submitted();
        s.record_op_submitted();
        s.record_op_submitted();
        assert_eq!(s.in_flight(), 3);
        s.record_op_completed();
        s.record_op_completed();
        assert_eq!(s.in_flight(), 1);
        assert_eq!(s.max_in_flight(), 3);
        assert_eq!(s.avg_queue_wait(), Duration::ZERO);
        s.record_queue_wait(Duration::from_micros(10));
        s.record_queue_wait(Duration::from_micros(30));
        assert_eq!(s.dispatched_ops(), 2);
        assert_eq!(s.avg_queue_wait(), Duration::from_micros(20));
    }

    #[test]
    fn concurrent_updates() {
        let s = std::sync::Arc::new(EndpointStats::default());
        std::thread::scope(|scope| {
            for _ in 0..8 {
                let s = std::sync::Arc::clone(&s);
                scope.spawn(move || {
                    for _ in 0..1000 {
                        s.record_request(1);
                    }
                });
            }
        });
        assert_eq!(s.requests(), 8000);
        assert_eq!(s.bytes_sent(), 8000);
    }
}
