//! Per-endpoint traffic counters.
//!
//! These power the Fig. 5 load-balance measurement (requests per machine)
//! and the network-volume columns of the experiment reports.

use std::sync::atomic::{AtomicU64, Ordering};

/// Lock-free counters for one endpoint (shard).
#[derive(Debug, Default)]
pub struct EndpointStats {
    requests: AtomicU64,
    replies: AtomicU64,
    bytes_sent: AtomicU64,
    bytes_received: AtomicU64,
    dropped_requests: AtomicU64,
    dropped_replies: AtomicU64,
    duplicates: AtomicU64,
    timeouts: AtomicU64,
}

impl EndpointStats {
    /// Record an outgoing request of `bytes` bytes.
    pub fn record_request(&self, bytes: usize) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.bytes_sent.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    /// Record a received reply of `bytes` bytes.
    pub fn record_reply(&self, bytes: usize) {
        self.replies.fetch_add(1, Ordering::Relaxed);
        self.bytes_received.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    /// Record a request dropped by fault injection.
    pub fn record_dropped_request(&self) {
        self.dropped_requests.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a reply dropped by fault injection.
    pub fn record_dropped_reply(&self) {
        self.dropped_replies.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a duplicated delivery.
    pub fn record_duplicate(&self) {
        self.duplicates.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a client-observed timeout.
    pub fn record_timeout(&self) {
        self.timeouts.fetch_add(1, Ordering::Relaxed);
    }

    /// Requests sent to this endpoint.
    pub fn requests(&self) -> u64 {
        self.requests.load(Ordering::Relaxed)
    }

    /// Replies received from this endpoint.
    pub fn replies(&self) -> u64 {
        self.replies.load(Ordering::Relaxed)
    }

    /// Total request bytes.
    pub fn bytes_sent(&self) -> u64 {
        self.bytes_sent.load(Ordering::Relaxed)
    }

    /// Total reply bytes.
    pub fn bytes_received(&self) -> u64 {
        self.bytes_received.load(Ordering::Relaxed)
    }

    /// Requests lost to fault injection.
    pub fn dropped_requests(&self) -> u64 {
        self.dropped_requests.load(Ordering::Relaxed)
    }

    /// Replies lost to fault injection.
    pub fn dropped_replies(&self) -> u64 {
        self.dropped_replies.load(Ordering::Relaxed)
    }

    /// Duplicated deliveries.
    pub fn duplicates(&self) -> u64 {
        self.duplicates.load(Ordering::Relaxed)
    }

    /// Client-observed timeouts.
    pub fn timeouts(&self) -> u64 {
        self.timeouts.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let s = EndpointStats::default();
        s.record_request(100);
        s.record_request(50);
        s.record_reply(25);
        s.record_timeout();
        assert_eq!(s.requests(), 2);
        assert_eq!(s.bytes_sent(), 150);
        assert_eq!(s.replies(), 1);
        assert_eq!(s.bytes_received(), 25);
        assert_eq!(s.timeouts(), 1);
    }

    #[test]
    fn concurrent_updates() {
        let s = std::sync::Arc::new(EndpointStats::default());
        std::thread::scope(|scope| {
            for _ in 0..8 {
                let s = std::sync::Arc::clone(&s);
                scope.spawn(move || {
                    for _ in 0..1000 {
                        s.record_request(1);
                    }
                });
            }
        });
        assert_eq!(s.requests(), 8000);
        assert_eq!(s.bytes_sent(), 8000);
    }
}
