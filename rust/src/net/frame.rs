//! Length-prefixed framing for byte-stream transports.
//!
//! Every message on a TCP connection is one frame: a 4-byte little-endian
//! payload length followed by the payload bytes. The length prefix is
//! bounded by [`MAX_FRAME_LEN`] so a corrupt or hostile prefix cannot
//! trigger an unbounded allocation; the paper's largest messages (~2 MB
//! push buffers, §3.3) fit with two orders of magnitude to spare.

use std::io::{self, Read, Write};

/// Maximum accepted frame payload (64 MiB).
pub const MAX_FRAME_LEN: usize = 64 * 1024 * 1024;

/// Write one `length + payload` frame and flush the stream.
///
/// Header and payload go out as one buffer: the transports set
/// `TCP_NODELAY`, so separate writes would put the 4-byte header in its
/// own segment on every message.
pub fn write_frame<W: Write>(w: &mut W, payload: &[u8]) -> io::Result<()> {
    if payload.len() > MAX_FRAME_LEN {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("frame of {} bytes exceeds MAX_FRAME_LEN", payload.len()),
        ));
    }
    let mut buf = Vec::with_capacity(4 + payload.len());
    buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    buf.extend_from_slice(payload);
    w.write_all(&buf)?;
    w.flush()
}

/// Read one frame. Returns `Ok(None)` on a clean EOF at a frame boundary
/// (the peer closed the connection); errors on EOF inside a frame, on an
/// oversized length prefix, and on any underlying I/O error (including
/// read timeouts).
pub fn read_frame<R: Read>(r: &mut R) -> io::Result<Option<Vec<u8>>> {
    let mut header = [0u8; 4];
    if !read_header(r, &mut header)? {
        return Ok(None);
    }
    let len = u32::from_le_bytes(header) as usize;
    if len > MAX_FRAME_LEN {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("frame length prefix {len} exceeds MAX_FRAME_LEN"),
        ));
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    Ok(Some(payload))
}

/// Fill the 4-byte header, tolerating partial reads. `Ok(false)` when the
/// stream is already at EOF; an error when EOF lands mid-header.
fn read_header<R: Read>(r: &mut R, header: &mut [u8; 4]) -> io::Result<bool> {
    let mut filled = 0;
    while filled < header.len() {
        match r.read(&mut header[filled..]) {
            Ok(0) => {
                return if filled == 0 {
                    Ok(false)
                } else {
                    Err(io::Error::new(
                        io::ErrorKind::UnexpectedEof,
                        "connection closed inside a frame header",
                    ))
                };
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(true)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    /// A reader that hands out at most one byte per `read` call, to
    /// exercise the partial-read paths.
    struct OneByteReader<R> {
        inner: R,
    }

    impl<R: Read> Read for OneByteReader<R> {
        fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
            let n = buf.len().min(1);
            self.inner.read(&mut buf[..n])
        }
    }

    #[test]
    fn roundtrip_frames() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        write_frame(&mut buf, b"").unwrap();
        write_frame(&mut buf, &[7u8; 1000]).unwrap();
        let mut r = Cursor::new(buf);
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), b"hello");
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), b"");
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), vec![7u8; 1000]);
        assert!(read_frame(&mut r).unwrap().is_none());
    }

    #[test]
    fn partial_reads_reassemble() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"byte at a time").unwrap();
        let mut r = OneByteReader { inner: Cursor::new(buf) };
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), b"byte at a time");
        assert!(read_frame(&mut r).unwrap().is_none());
    }

    #[test]
    fn oversized_length_prefix_rejected() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(MAX_FRAME_LEN as u32 + 1).to_le_bytes());
        buf.extend_from_slice(&[0u8; 16]);
        let err = read_frame(&mut Cursor::new(buf)).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
    }

    #[test]
    fn oversized_payload_refused_on_write() {
        // Don't allocate 64 MiB in a unit test: the length check runs
        // before any byte is written, so a sink that errors is enough to
        // prove the order.
        struct NoWrite;
        impl Write for NoWrite {
            fn write(&mut self, _: &[u8]) -> io::Result<usize> {
                panic!("oversized frame must be rejected before writing");
            }
            fn flush(&mut self) -> io::Result<()> {
                Ok(())
            }
        }
        let payload = vec![0u8; MAX_FRAME_LEN + 1];
        let err = write_frame(&mut NoWrite, &payload).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
    }

    #[test]
    fn eof_inside_header_errors() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"abcdef").unwrap();
        buf.truncate(2); // half a header
        let err = read_frame(&mut Cursor::new(buf)).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);
    }

    #[test]
    fn eof_inside_payload_errors() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"abcdef").unwrap();
        buf.truncate(6); // header + 2 of 6 payload bytes
        assert!(read_frame(&mut Cursor::new(buf)).is_err());
    }

    #[test]
    fn max_len_boundary_accepted() {
        // A frame of exactly MAX_FRAME_LEN must pass the length check;
        // use the prefix alone plus a short read to avoid the allocation
        // cost of a real max-size payload... which read_exact then fails
        // on, proving the prefix itself was accepted.
        let mut buf = Vec::new();
        buf.extend_from_slice(&(MAX_FRAME_LEN as u32).to_le_bytes());
        let err = read_frame(&mut Cursor::new(buf)).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);
    }
}
