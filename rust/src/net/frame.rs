//! Length-prefixed framing for byte-stream transports.
//!
//! Two frame layouts share one connection model:
//!
//! - **plain frames** ([`write_frame`]/[`read_frame`]) — a 4-byte
//!   little-endian payload length followed by the payload bytes. One
//!   request/reply at a time per stream.
//! - **tagged frames** ([`write_tagged_frame`]/[`read_tagged_frame`]) —
//!   the same length prefix followed by an 8-byte little-endian
//!   *correlation id*, then the payload. The correlation id lets many
//!   requests share one connection concurrently: the peer echoes the id
//!   on the reply, and the reader matches responses back to waiters even
//!   when they complete out of order. This is what the multiplexed TCP
//!   transport speaks.
//!
//! The length prefix is bounded by [`MAX_FRAME_LEN`] so a corrupt or
//! hostile prefix cannot trigger an unbounded allocation; the paper's
//! largest messages (~2 MB push buffers, §3.3) fit with two orders of
//! magnitude to spare.

use std::io::{self, Read, Write};

/// Maximum accepted frame payload (64 MiB).
pub const MAX_FRAME_LEN: usize = 64 * 1024 * 1024;

/// Byte length of a tagged-frame header (`u32` length + `u64`
/// correlation id).
pub const TAGGED_HEADER_LEN: usize = 12;

/// Split a tagged-frame header into `(payload_len, correlation_id)`,
/// validating the length prefix. The single place the tagged header
/// layout is decoded — shared by [`read_tagged_frame`] and the
/// timeout-tolerant reader loop in the TCP transport.
pub fn parse_tagged_header(header: &[u8; TAGGED_HEADER_LEN]) -> io::Result<(usize, u64)> {
    let len = u32::from_le_bytes(header[0..4].try_into().expect("4-byte slice")) as usize;
    if len > MAX_FRAME_LEN {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("frame length prefix {len} exceeds MAX_FRAME_LEN"),
        ));
    }
    let corr = u64::from_le_bytes(header[4..12].try_into().expect("8-byte slice"));
    Ok((len, corr))
}

/// Write one `length + payload` frame and flush the stream.
///
/// Header and payload go out as one buffer: the transports set
/// `TCP_NODELAY`, so separate writes would put the 4-byte header in its
/// own segment on every message.
pub fn write_frame<W: Write>(w: &mut W, payload: &[u8]) -> io::Result<()> {
    if payload.len() > MAX_FRAME_LEN {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("frame of {} bytes exceeds MAX_FRAME_LEN", payload.len()),
        ));
    }
    let mut buf = Vec::with_capacity(4 + payload.len());
    buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    buf.extend_from_slice(payload);
    w.write_all(&buf)?;
    w.flush()
}

/// Read one frame. Returns `Ok(None)` on a clean EOF at a frame boundary
/// (the peer closed the connection); errors on EOF inside a frame, on an
/// oversized length prefix, and on any underlying I/O error (including
/// read timeouts).
pub fn read_frame<R: Read>(r: &mut R) -> io::Result<Option<Vec<u8>>> {
    let mut header = [0u8; 4];
    if !read_header(r, &mut header)? {
        return Ok(None);
    }
    let len = u32::from_le_bytes(header) as usize;
    read_payload(r, len).map(Some)
}

/// Write one `length + correlation id + payload` frame and flush the
/// stream, as one buffer (see [`write_frame`] for why).
pub fn write_tagged_frame<W: Write>(w: &mut W, corr: u64, payload: &[u8]) -> io::Result<()> {
    if payload.len() > MAX_FRAME_LEN {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("frame of {} bytes exceeds MAX_FRAME_LEN", payload.len()),
        ));
    }
    let mut buf = Vec::with_capacity(12 + payload.len());
    buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    buf.extend_from_slice(&corr.to_le_bytes());
    buf.extend_from_slice(payload);
    w.write_all(&buf)?;
    w.flush()
}

/// Read one tagged frame: `Ok(Some((correlation_id, payload)))`, or
/// `Ok(None)` on a clean EOF at a frame boundary. Error conditions match
/// [`read_frame`].
pub fn read_tagged_frame<R: Read>(r: &mut R) -> io::Result<Option<(u64, Vec<u8>)>> {
    let mut header = [0u8; TAGGED_HEADER_LEN];
    if !read_header(r, &mut header)? {
        return Ok(None);
    }
    let (len, corr) = parse_tagged_header(&header)?;
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    Ok(Some((corr, payload)))
}

/// Validate the decoded length prefix and read that many payload bytes.
fn read_payload<R: Read>(r: &mut R, len: usize) -> io::Result<Vec<u8>> {
    if len > MAX_FRAME_LEN {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("frame length prefix {len} exceeds MAX_FRAME_LEN"),
        ));
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    Ok(payload)
}

/// Fill a fixed-size header, tolerating partial reads. `Ok(false)` when
/// the stream is already at EOF; an error when EOF lands mid-header.
fn read_header<R: Read>(r: &mut R, header: &mut [u8]) -> io::Result<bool> {
    let mut filled = 0;
    while filled < header.len() {
        match r.read(&mut header[filled..]) {
            Ok(0) => {
                return if filled == 0 {
                    Ok(false)
                } else {
                    Err(io::Error::new(
                        io::ErrorKind::UnexpectedEof,
                        "connection closed inside a frame header",
                    ))
                };
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(true)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    /// A reader that hands out at most one byte per `read` call, to
    /// exercise the partial-read paths.
    struct OneByteReader<R> {
        inner: R,
    }

    impl<R: Read> Read for OneByteReader<R> {
        fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
            let n = buf.len().min(1);
            self.inner.read(&mut buf[..n])
        }
    }

    #[test]
    fn roundtrip_frames() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        write_frame(&mut buf, b"").unwrap();
        write_frame(&mut buf, &[7u8; 1000]).unwrap();
        let mut r = Cursor::new(buf);
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), b"hello");
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), b"");
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), vec![7u8; 1000]);
        assert!(read_frame(&mut r).unwrap().is_none());
    }

    #[test]
    fn partial_reads_reassemble() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"byte at a time").unwrap();
        let mut r = OneByteReader { inner: Cursor::new(buf) };
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), b"byte at a time");
        assert!(read_frame(&mut r).unwrap().is_none());
    }

    #[test]
    fn oversized_length_prefix_rejected() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(MAX_FRAME_LEN as u32 + 1).to_le_bytes());
        buf.extend_from_slice(&[0u8; 16]);
        let err = read_frame(&mut Cursor::new(buf)).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
    }

    #[test]
    fn oversized_payload_refused_on_write() {
        // Don't allocate 64 MiB in a unit test: the length check runs
        // before any byte is written, so a sink that errors is enough to
        // prove the order.
        struct NoWrite;
        impl Write for NoWrite {
            fn write(&mut self, _: &[u8]) -> io::Result<usize> {
                panic!("oversized frame must be rejected before writing");
            }
            fn flush(&mut self) -> io::Result<()> {
                Ok(())
            }
        }
        let payload = vec![0u8; MAX_FRAME_LEN + 1];
        let err = write_frame(&mut NoWrite, &payload).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
    }

    #[test]
    fn eof_inside_header_errors() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"abcdef").unwrap();
        buf.truncate(2); // half a header
        let err = read_frame(&mut Cursor::new(buf)).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);
    }

    #[test]
    fn eof_inside_payload_errors() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"abcdef").unwrap();
        buf.truncate(6); // header + 2 of 6 payload bytes
        assert!(read_frame(&mut Cursor::new(buf)).is_err());
    }

    #[test]
    fn roundtrip_tagged_frames() {
        let mut buf = Vec::new();
        write_tagged_frame(&mut buf, 7, b"hello").unwrap();
        write_tagged_frame(&mut buf, u64::MAX, b"").unwrap();
        write_tagged_frame(&mut buf, 0, &[3u8; 500]).unwrap();
        let mut r = Cursor::new(buf);
        assert_eq!(read_tagged_frame(&mut r).unwrap().unwrap(), (7, b"hello".to_vec()));
        assert_eq!(read_tagged_frame(&mut r).unwrap().unwrap(), (u64::MAX, Vec::new()));
        assert_eq!(read_tagged_frame(&mut r).unwrap().unwrap(), (0, vec![3u8; 500]));
        assert!(read_tagged_frame(&mut r).unwrap().is_none());
    }

    #[test]
    fn tagged_partial_reads_reassemble() {
        let mut buf = Vec::new();
        write_tagged_frame(&mut buf, 0xdead_beef, b"byte at a time").unwrap();
        let mut r = OneByteReader { inner: Cursor::new(buf) };
        let (corr, payload) = read_tagged_frame(&mut r).unwrap().unwrap();
        assert_eq!(corr, 0xdead_beef);
        assert_eq!(payload, b"byte at a time");
        assert!(read_tagged_frame(&mut r).unwrap().is_none());
    }

    #[test]
    fn tagged_oversized_length_prefix_rejected() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(MAX_FRAME_LEN as u32 + 1).to_le_bytes());
        buf.extend_from_slice(&1u64.to_le_bytes());
        buf.extend_from_slice(&[0u8; 16]);
        let err = read_tagged_frame(&mut Cursor::new(buf)).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
    }

    #[test]
    fn tagged_eof_inside_header_errors() {
        let mut buf = Vec::new();
        write_tagged_frame(&mut buf, 9, b"abcdef").unwrap();
        buf.truncate(6); // half the 12-byte header
        let err = read_tagged_frame(&mut Cursor::new(buf)).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);
    }

    #[test]
    fn max_len_boundary_accepted() {
        // A frame of exactly MAX_FRAME_LEN must pass the length check;
        // use the prefix alone plus a short read to avoid the allocation
        // cost of a real max-size payload... which read_exact then fails
        // on, proving the prefix itself was accepted.
        let mut buf = Vec::new();
        buf.extend_from_slice(&(MAX_FRAME_LEN as u32).to_le_bytes());
        let err = read_frame(&mut Cursor::new(buf)).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);
    }
}
