//! Wire messages of the serve-model line protocol.
//!
//! Inference requests and replies ride the same correlation-tagged,
//! length-prefixed frames as the parameter-server data plane
//! ([`super::tcp`]); the payloads here are the frame bodies. Token ids,
//! topics and counts are varint-coded — a typical request is a few
//! bytes per token, and a reply is bounded by `min(len, K)` pairs per
//! document.

use crate::util::codec::{Reader, Writer};
use crate::util::error::{Error, Result};

const Q_INFER: u8 = 1;
const Q_STATS: u8 = 2;
const Q_SHUTDOWN: u8 = 3;

const A_TOPICS: u8 = 1;
const A_STATS: u8 = 2;
const A_OK: u8 = 3;
const A_ERROR: u8 = 4;

/// Client → serving replica.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum InferRequest {
    /// Fold in each document (a token-id list) and return its topics.
    Infer {
        /// One token-id list per document.
        docs: Vec<Vec<u32>>,
    },
    /// Report the replica's cumulative serving counters.
    Stats,
    /// Ask the replica to exit its serve loop.
    Shutdown,
}

/// Serving replica → client.
#[derive(Debug, Clone, PartialEq)]
pub enum InferResponse {
    /// One `(topic, count)` list per requested document, topics
    /// ascending, counts summing to the document's length.
    Topics {
        /// Per-document topic counts, in request order.
        docs: Vec<Vec<(u32, u32)>>,
    },
    /// Cumulative serving counters.
    Stats(ServeStats),
    /// Acknowledged (shutdown).
    Ok,
    /// The replica could not serve the request.
    Error(String),
}

/// Cumulative counters of one serving replica.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServeStats {
    /// Inference requests served.
    pub requests: u64,
    /// Documents answered.
    pub docs: u64,
    /// Documents answered from the fold-in result cache.
    pub cache_hits: u64,
    /// Word rows pulled from the shards.
    pub words_pulled: u64,
    /// Batched sparse pulls issued.
    pub sparse_pulls: u64,
    /// Coalesced batches executed.
    pub batches: u64,
}

impl InferRequest {
    /// Serialize to frame-body bytes.
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        match self {
            InferRequest::Infer { docs } => {
                w.u8(Q_INFER);
                w.usize(docs.len());
                for doc in docs {
                    w.slice_varint_u32(doc);
                }
            }
            InferRequest::Stats => w.u8(Q_STATS),
            InferRequest::Shutdown => w.u8(Q_SHUTDOWN),
        }
        w.into_bytes()
    }

    /// Parse from frame-body bytes.
    pub fn decode(bytes: &[u8]) -> Result<InferRequest> {
        let mut r = Reader::new(bytes);
        let req = match r.u8()? {
            Q_INFER => {
                let n = r.usize()?;
                let mut docs = Vec::with_capacity(n.min(1 << 16));
                for _ in 0..n {
                    docs.push(r.slice_varint_u32()?);
                }
                InferRequest::Infer { docs }
            }
            Q_STATS => InferRequest::Stats,
            Q_SHUTDOWN => InferRequest::Shutdown,
            t => return Err(Error::Decode(format!("unknown infer request tag {t}"))),
        };
        if !r.is_done() {
            return Err(Error::Decode("trailing bytes after infer request".into()));
        }
        Ok(req)
    }
}

impl InferResponse {
    /// Serialize to frame-body bytes.
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        match self {
            InferResponse::Topics { docs } => {
                w.u8(A_TOPICS);
                w.usize(docs.len());
                for pairs in docs {
                    w.usize(pairs.len());
                    for &(t, c) in pairs {
                        w.varint(t as u64);
                        w.varint(c as u64);
                    }
                }
            }
            InferResponse::Stats(s) => {
                w.u8(A_STATS);
                w.varint(s.requests);
                w.varint(s.docs);
                w.varint(s.cache_hits);
                w.varint(s.words_pulled);
                w.varint(s.sparse_pulls);
                w.varint(s.batches);
            }
            InferResponse::Ok => w.u8(A_OK),
            InferResponse::Error(m) => {
                w.u8(A_ERROR);
                w.str(m);
            }
        }
        w.into_bytes()
    }

    /// Parse from frame-body bytes.
    pub fn decode(bytes: &[u8]) -> Result<InferResponse> {
        let mut r = Reader::new(bytes);
        let resp = match r.u8()? {
            A_TOPICS => {
                let n = r.usize()?;
                let mut docs = Vec::with_capacity(n.min(1 << 16));
                for _ in 0..n {
                    let pairs = r.usize()?;
                    let mut doc = Vec::with_capacity(pairs.min(1 << 16));
                    for _ in 0..pairs {
                        let t = varint_u32(&mut r, "topic")?;
                        let c = varint_u32(&mut r, "count")?;
                        doc.push((t, c));
                    }
                    docs.push(doc);
                }
                InferResponse::Topics { docs }
            }
            A_STATS => InferResponse::Stats(ServeStats {
                requests: r.varint()?,
                docs: r.varint()?,
                cache_hits: r.varint()?,
                words_pulled: r.varint()?,
                sparse_pulls: r.varint()?,
                batches: r.varint()?,
            }),
            A_OK => InferResponse::Ok,
            A_ERROR => InferResponse::Error(r.str()?),
            t => return Err(Error::Decode(format!("unknown infer response tag {t}"))),
        };
        if !r.is_done() {
            return Err(Error::Decode("trailing bytes after infer response".into()));
        }
        Ok(resp)
    }
}

/// Varint bounded to u32 (topics and counts are 32-bit on the wire).
fn varint_u32(r: &mut Reader<'_>, what: &str) -> Result<u32> {
    let v = r.varint()?;
    u32::try_from(v).map_err(|_| Error::Decode(format!("{what} out of range: {v}")))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_req(req: &InferRequest) {
        let bytes = req.encode();
        assert_eq!(&InferRequest::decode(&bytes).unwrap(), req);
    }

    fn roundtrip_resp(resp: &InferResponse) {
        let bytes = resp.encode();
        assert_eq!(&InferResponse::decode(&bytes).unwrap(), resp);
    }

    #[test]
    fn requests_roundtrip() {
        roundtrip_req(&InferRequest::Infer { docs: vec![] });
        roundtrip_req(&InferRequest::Infer {
            docs: vec![vec![0, 1, u32::MAX], vec![], vec![42; 300]],
        });
        roundtrip_req(&InferRequest::Stats);
        roundtrip_req(&InferRequest::Shutdown);
    }

    #[test]
    fn responses_roundtrip() {
        roundtrip_resp(&InferResponse::Topics { docs: vec![] });
        roundtrip_resp(&InferResponse::Topics {
            docs: vec![vec![(0, 3), (7, 1), (u32::MAX, 2)], vec![]],
        });
        roundtrip_resp(&InferResponse::Stats(ServeStats {
            requests: 1,
            docs: 2,
            cache_hits: 3,
            words_pulled: u64::MAX,
            sparse_pulls: 5,
            batches: 6,
        }));
        roundtrip_resp(&InferResponse::Ok);
        roundtrip_resp(&InferResponse::Error("shard down".into()));
    }

    #[test]
    fn garbage_and_truncation_are_errors_not_panics() {
        assert!(InferRequest::decode(&[]).is_err());
        assert!(InferRequest::decode(&[0xee]).is_err());
        assert!(InferResponse::decode(&[0xee]).is_err());
        let good = InferRequest::Infer { docs: vec![vec![1, 2, 3]] }.encode();
        for cut in 1..good.len() {
            assert!(InferRequest::decode(&good[..cut]).is_err(), "cut at {cut}");
        }
        let good = InferResponse::Topics { docs: vec![vec![(1, 2)]] }.encode();
        for cut in 1..good.len() {
            assert!(InferResponse::decode(&good[..cut]).is_err(), "cut at {cut}");
        }
    }

    #[test]
    fn trailing_bytes_rejected() {
        let mut bytes = InferRequest::Stats.encode();
        bytes.push(0);
        assert!(InferRequest::decode(&bytes).is_err());
        let mut bytes = InferResponse::Ok.encode();
        bytes.push(9);
        assert!(InferResponse::decode(&bytes).is_err());
    }
}
