//! Shard server: the process that owns a slice of every distributed
//! matrix and serves pull/push requests.
//!
//! # Op-dispatch executor
//!
//! The original seed processed every request on one thread per shard
//! (the Akka actor model of the paper: serialized message processing).
//! Requests are now classified by their operation type and dispatched
//! accordingly:
//!
//! - **Read ops** ([`Request::PullRows`], [`Request::PullSparseRows`],
//!   [`Request::PullTopK`], [`Request::PullColSums`],
//!   [`Request::ShardInfo`]) run concurrently on a small reader pool,
//!   each under that matrix's `RwLock` read guard — many pulls against
//!   the same (or different) matrices overlap freely.
//! - **Write ops** (`CreateMatrix`, `GenUid`, `Push*`, `Forget`) stay
//!   serialized on the shard's inbox thread, exactly as before. The
//!   dedup check → apply → record sequence of a push is therefore never
//!   concurrent with another push, preserving the exactly-once
//!   semantics of §2.4 without any per-uid locking; a push briefly
//!   write-locks its matrix to keep readers consistent.
//!
//! # Bounded dedup window
//!
//! Exactly-once pushes are enforced with a seen-uid record: a
//! `PushCoords`/`PushRows` whose uid was already applied acknowledges
//! without re-applying (paper §2.4, Figure 2). The seed kept those
//! records in an unbounded set, so a client that died between its push
//! ack and the `Forget` leaked an entry forever. The record is now a
//! bounded FIFO window ([`PsConfig::dedup_window`]): when full, the
//! oldest un-forgotten uid is evicted and counted, and the eviction
//! total is reported through [`Response::Info`] so operators can see
//! abandoned hand-shakes. An eviction weakens exactly-once only for a
//! push that is retried *after* its record ages out of the window —
//! with the default 65k-entry window and in-flight counts bounded by
//! `pipeline_depth`, that takes tens of thousands of interleaved
//! pushes, far beyond any retry horizon.
//!
//! # Durability and replication
//!
//! With [`PsConfig::wal_dir`] set, every successfully applied write is
//! also appended to a per-shard write-ahead log ([`crate::wal`]): the
//! inbox thread enqueues the verbatim request bytes and a group-commit
//! thread batches the fsyncs, so hot-path push latency stays flat. On
//! restart the shard replays the log through the same apply path
//! (newest snapshot first, then the committed records after it), and
//! the exactly-once uids it re-records make replay idempotent. `GenUid`
//! is logged too — replay restores the uid counter, so a recovered
//! shard can never re-issue a uid an in-flight retry may still carry.
//!
//! A shard started with [`PsConfig::backup_of`] runs as a **backup**:
//! a poller thread streams its upstream's committed log over the normal
//! transport (`ReplPoll` → `ReplBatch`) and injects `ReplApply` batches
//! into the shard's own inbox, so replicated writes flow through the
//! identical serialized single-writer path. Until promoted
//! ([`Request::Promote`]), data ops are answered with
//! [`Response::Unavailable`] — the retryable signal the client's
//! failover route reacts to.
//!
//! Replication generalizes to a **chain of N replicas**: every standby
//! tails the current head, promotion walks the chain head-ward (the
//! first live backup wins), and a [`Request::ReplSeed`] re-points a
//! standby at a new upstream mid-run — it rebuilds from the upstream's
//! snapshot slice, bumps its replication *generation* (fencing any
//! batch still in flight from the old upstream), and tails the rest of
//! the log through the normal poll path. A planned hand-off
//! ([`Request::Drain`]) flips the head to [`ROLE_DRAINING`]: data ops
//! get the retryable `Unavailable` while replicas finish catching up to
//! the fsynced tip, so the successor takes over having lost nothing —
//! no epoch roll required.

use std::collections::{HashMap, HashSet, VecDeque};
use std::net::SocketAddr;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

// The shard's synchronization — matrix registry locks, the dedup window,
// the reader-pool queue, the replication role/cursor flags — rides the
// sync_shim so the model checker can drive `ShardCore` through explored
// interleavings (`tests/model.rs`, the `shard-*`/`repl-*` models). The
// serve-loop threads and TCP pollers stay on real `std::thread`; only
// the reader pool's workers (`vthread`) become virtual tasks.
use crate::log_warn;
use crate::net::tcp::{resolve_addrs, TcpServer, TcpTransport};
use crate::net::{respond, Envelope, FaultPlan, Inbox, SimTransport, Transport};
use crate::ps::config::{PsConfig, TransportMode};
use crate::ps::messages::{Data, Dtype, Layout, Request, Response, SparseData};
use crate::ps::partition::Partitioner;
use crate::ps::storage::{DenseShard, SparseShard, StorageElement};
use crate::util::error::{Error, Result};
use crate::util::sync_shim::atomic::{AtomicBool, AtomicU64, AtomicU8, Ordering};
use crate::util::sync_shim::thread as vthread;
use crate::util::sync_shim::{mpsc, Mutex, RwLock};
use crate::wal::{ShardWal, WalOptions, WalPayload};

/// Replication role: a regular primary shard.
pub const ROLE_PRIMARY: u8 = 0;
/// Replication role: an un-promoted backup (refuses data ops).
pub const ROLE_BACKUP: u8 = 1;
/// Replication role: a backup promoted to serve as primary.
pub const ROLE_PROMOTED: u8 = 2;
/// Replication role: a primary in planned hand-off — WAL fsynced, data
/// ops refused (retryably) while a backup catches up and takes over.
pub const ROLE_DRAINING: u8 = 3;

/// Log records served per `ReplPoll` reply (bounds reply size).
const REPL_BATCH_MAX: usize = 256;
/// How long a caught-up replication poller sleeps between polls.
const REPL_IDLE_POLL: Duration = Duration::from_millis(20);
/// Back-off after a failed poll (primary unreachable or mid-restart).
const REPL_ERROR_BACKOFF: Duration = Duration::from_millis(200);
/// Per-poll request timeout.
const REPL_POLL_TIMEOUT: Duration = Duration::from_secs(2);
/// Scalar values per snapshot `SnapRows` chunk: bounds record size (and
/// replica apply memory) while keeping per-record overhead negligible.
const SNAP_CHUNK: usize = 1 << 16;

/// Per-shard WAL directory under the configured root.
fn wal_shard_dir(root: &Path, shard: usize) -> PathBuf {
    root.join(format!("shard-{shard:04}"))
}

/// Layout-dispatched storage for one matrix's local slice.
enum Store<T> {
    Dense(DenseShard<T>),
    Sparse(SparseShard<T>),
}

impl<T: StorageElement> Store<T> {
    fn new(layout: Layout, local_rows: u64, cols: u32) -> Store<T> {
        match layout {
            Layout::Dense => Store::Dense(DenseShard::new(local_rows, cols)),
            Layout::Sparse => Store::Sparse(SparseShard::new(local_rows, cols)),
        }
    }

    fn layout(&self) -> Layout {
        match self {
            Store::Dense(_) => Layout::Dense,
            Store::Sparse(_) => Layout::Sparse,
        }
    }

    fn local_rows(&self) -> u64 {
        match self {
            Store::Dense(s) => s.local_rows(),
            Store::Sparse(s) => s.local_rows(),
        }
    }

    fn cols(&self) -> u32 {
        match self {
            Store::Dense(s) => s.cols(),
            Store::Sparse(s) => s.cols(),
        }
    }

    fn bytes(&self) -> usize {
        match self {
            Store::Dense(s) => s.bytes(),
            Store::Sparse(s) => s.bytes(),
        }
    }

    fn read_row(&self, local_row: u64, out: &mut Vec<T>) -> Result<()> {
        match self {
            Store::Dense(s) => s.read_row(local_row, out),
            Store::Sparse(s) => s.read_row(local_row, out),
        }
    }

    fn read_row_sparse(
        &self,
        local_row: u64,
        cols_out: &mut Vec<u32>,
        vals_out: &mut Vec<T>,
    ) -> Result<u32> {
        match self {
            Store::Dense(s) => s.read_row_sparse(local_row, cols_out, vals_out),
            Store::Sparse(s) => s.read_row_sparse(local_row, cols_out, vals_out),
        }
    }

    fn read_row_topk(
        &self,
        local_row: u64,
        k: usize,
        cols_out: &mut Vec<u32>,
        vals_out: &mut Vec<T>,
    ) -> Result<u32> {
        match self {
            Store::Dense(s) => s.read_row_topk(local_row, k, cols_out, vals_out),
            Store::Sparse(s) => s.read_row_topk(local_row, k, cols_out, vals_out),
        }
    }

    fn col_sums(&self, sums: &mut [T]) {
        match self {
            Store::Dense(s) => s.col_sums(sums),
            Store::Sparse(s) => s.col_sums(sums),
        }
    }

    fn add(&mut self, local_row: u64, col: u32, delta: T) -> Result<()> {
        match self {
            Store::Dense(s) => s.add(local_row, col, delta),
            Store::Sparse(s) => s.add(local_row, col, delta),
        }
    }

    fn add_row(&mut self, local_row: u64, deltas: &[T]) -> Result<()> {
        match self {
            Store::Dense(s) => s.add_row(local_row, deltas),
            Store::Sparse(s) => s.add_row(local_row, deltas),
        }
    }
}

/// One matrix's slice on this shard.
enum MatrixSlice {
    I64 { part: Partitioner, store: Store<i64> },
    F32 { part: Partitioner, store: Store<f32> },
}

/// Pull `rows` out of `store` as one dense, concatenated payload.
fn pull_rows_from<T: StorageElement>(
    part: &Partitioner,
    store: &Store<T>,
    rows: &[u64],
) -> Result<Vec<T>> {
    let mut out = Vec::with_capacity(rows.len() * store.cols() as usize);
    for &r in rows {
        store.read_row(part.local_index(r), &mut out)?;
    }
    Ok(out)
}

/// Pull `rows` as `(lens, cols, values)` pair lists; `k = None` returns
/// every non-default pair, `k = Some(n)` the per-row top-n.
fn pull_sparse_from<T: StorageElement>(
    part: &Partitioner,
    store: &Store<T>,
    rows: &[u64],
    k: Option<usize>,
) -> Result<(Vec<u32>, Vec<u32>, Vec<T>)> {
    let mut lens = Vec::with_capacity(rows.len());
    let mut cols = Vec::new();
    let mut vals = Vec::new();
    for &r in rows {
        let local = part.local_index(r);
        let n = match k {
            None => store.read_row_sparse(local, &mut cols, &mut vals)?,
            Some(k) => store.read_row_topk(local, k, &mut cols, &mut vals)?,
        };
        lens.push(n);
    }
    Ok((lens, cols, vals))
}

/// Emit every non-default entry of `store` as chunked `SnapRows`
/// records: absolute values at global `(row, col)` coordinates, so a
/// replay onto a zeroed slice reproduces the state exactly.
fn snap_rows_from<T: StorageElement>(
    part: &Partitioner,
    store: &Store<T>,
    matrix: u32,
    shard: usize,
    wrap: fn(Vec<T>) -> Data,
    out: &mut Vec<WalPayload>,
) {
    let mut rows: Vec<u64> = Vec::new();
    let mut cols: Vec<u32> = Vec::new();
    let mut vals: Vec<T> = Vec::new();
    for local in 0..store.local_rows() {
        let global = part.global_row(shard, local);
        let mut row_cols = Vec::new();
        let mut row_vals = Vec::new();
        if store.read_row_sparse(local, &mut row_cols, &mut row_vals).is_err() {
            continue;
        }
        for (c, v) in row_cols.into_iter().zip(row_vals) {
            rows.push(global);
            cols.push(c);
            vals.push(v);
        }
        if vals.len() >= SNAP_CHUNK {
            out.push(WalPayload::SnapRows {
                matrix,
                rows: std::mem::take(&mut rows),
                cols: std::mem::take(&mut cols),
                values: wrap(std::mem::take(&mut vals)),
            });
        }
    }
    if !vals.is_empty() {
        out.push(WalPayload::SnapRows { matrix, rows, cols, values: wrap(vals) });
    }
}

impl MatrixSlice {
    fn local_rows(&self) -> u64 {
        match self {
            MatrixSlice::I64 { store, .. } => store.local_rows(),
            MatrixSlice::F32 { store, .. } => store.local_rows(),
        }
    }

    fn bytes(&self) -> u64 {
        match self {
            MatrixSlice::I64 { store, .. } => store.bytes() as u64,
            MatrixSlice::F32 { store, .. } => store.bytes() as u64,
        }
    }

    fn shape(&self) -> (u64, u32, Dtype, Layout) {
        match self {
            MatrixSlice::I64 { part, store } => {
                (part.rows, store.cols(), Dtype::I64, store.layout())
            }
            MatrixSlice::F32 { part, store } => {
                (part.rows, store.cols(), Dtype::F32, store.layout())
            }
        }
    }

    fn pull_rows(&self, rows: &[u64]) -> Result<Data> {
        match self {
            MatrixSlice::I64 { part, store } => {
                pull_rows_from(part, store, rows).map(Data::I64)
            }
            MatrixSlice::F32 { part, store } => {
                pull_rows_from(part, store, rows).map(Data::F32)
            }
        }
    }

    fn pull_sparse(&self, rows: &[u64], k: Option<usize>) -> Result<SparseData> {
        match self {
            MatrixSlice::I64 { part, store } => {
                let (lens, cols, vals) = pull_sparse_from(part, store, rows, k)?;
                Ok(SparseData { lens, cols, values: Data::I64(vals) })
            }
            MatrixSlice::F32 { part, store } => {
                let (lens, cols, vals) = pull_sparse_from(part, store, rows, k)?;
                Ok(SparseData { lens, cols, values: Data::F32(vals) })
            }
        }
    }

    fn pull_col_sums(&self) -> Data {
        match self {
            MatrixSlice::I64 { store, .. } => {
                let mut sums = vec![0i64; store.cols() as usize];
                store.col_sums(&mut sums);
                Data::I64(sums)
            }
            MatrixSlice::F32 { store, .. } => {
                let mut sums = vec![0f32; store.cols() as usize];
                store.col_sums(&mut sums);
                Data::F32(sums)
            }
        }
    }

    fn apply_coords(&mut self, rows: &[u64], cols: &[u32], values: &Data) -> Result<()> {
        match (self, values) {
            (MatrixSlice::I64 { part, store }, Data::I64(vals)) => {
                for ((&r, &c), &v) in rows.iter().zip(cols).zip(vals) {
                    store.add(part.local_index(r), c, v)?;
                }
                Ok(())
            }
            (MatrixSlice::F32 { part, store }, Data::F32(vals)) => {
                for ((&r, &c), &v) in rows.iter().zip(cols).zip(vals) {
                    store.add(part.local_index(r), c, v)?;
                }
                Ok(())
            }
            _ => Err(Error::PsRejected("dtype mismatch pushing coords".into())),
        }
    }

    fn apply_rows(&mut self, rows: &[u64], values: &Data) -> Result<()> {
        match (self, values) {
            (MatrixSlice::I64 { part, store }, Data::I64(vals)) => {
                let cols = store.cols() as usize;
                if vals.len() != rows.len() * cols {
                    return Err(Error::PsRejected("row push shape mismatch".into()));
                }
                for (&r, chunk) in rows.iter().zip(vals.chunks_exact(cols)) {
                    store.add_row(part.local_index(r), chunk)?;
                }
                Ok(())
            }
            (MatrixSlice::F32 { part, store }, Data::F32(vals)) => {
                let cols = store.cols() as usize;
                if vals.len() != rows.len() * cols {
                    return Err(Error::PsRejected("row push shape mismatch".into()));
                }
                for (&r, chunk) in rows.iter().zip(vals.chunks_exact(cols)) {
                    store.add_row(part.local_index(r), chunk)?;
                }
                Ok(())
            }
            _ => Err(Error::PsRejected("dtype mismatch pushing rows".into())),
        }
    }

    /// This slice's contents as snapshot records (see [`snap_rows_from`]).
    fn snap_rows(&self, matrix: u32, shard: usize, out: &mut Vec<WalPayload>) {
        match self {
            MatrixSlice::I64 { part, store } => {
                snap_rows_from(part, store, matrix, shard, Data::I64, out)
            }
            MatrixSlice::F32 { part, store } => {
                snap_rows_from(part, store, matrix, shard, Data::F32, out)
            }
        }
    }
}

/// Bounded FIFO record of applied-but-not-forgotten push uids.
struct DedupWindow {
    seen: HashSet<u64>,
    /// Insertion order of un-forgotten uids; may contain stale entries
    /// for uids already forgotten (skipped lazily at eviction time).
    order: VecDeque<u64>,
    /// Maximum `seen` size; `0` means unbounded (the seed's behavior).
    cap: usize,
    evictions: u64,
}

impl DedupWindow {
    fn new(cap: usize) -> DedupWindow {
        DedupWindow { seen: HashSet::new(), order: VecDeque::new(), cap, evictions: 0 }
    }

    fn contains(&self, uid: u64) -> bool {
        self.seen.contains(&uid)
    }

    /// Record an applied uid, evicting the oldest un-forgotten records
    /// once the window overflows.
    fn record(&mut self, uid: u64) {
        if !self.seen.insert(uid) {
            return;
        }
        if self.cap == 0 {
            // Unbounded (the seed's behavior): no eviction order needed.
            return;
        }
        self.order.push_back(uid);
        while self.seen.len() > self.cap {
            match self.order.pop_front() {
                // Stale entries (already forgotten) cost nothing.
                Some(old) => {
                    if self.seen.remove(&old) {
                        self.evictions += 1;
                    }
                }
                None => break,
            }
        }
        // Stale entries (forgotten uids) accumulate in `order` faster
        // than eviction reclaims them in the healthy push→ack→forget
        // workflow (where `seen` never overflows); compact before the
        // queue outgrows the window it serves. Amortized O(1) per push.
        if self.order.len() > self.cap.saturating_mul(2) {
            let seen = &self.seen;
            self.order.retain(|u| seen.contains(u));
        }
    }

    /// Release a uid after the client's ack (phase 3). Its `order`
    /// entry goes stale and is skipped at eviction or compaction time.
    fn forget(&mut self, uid: u64) {
        self.seen.remove(&uid);
    }

    fn pending(&self) -> u64 {
        self.seen.len() as u64
    }

    /// The un-forgotten uids, oldest-first where insertion order is
    /// known: feeding them back through [`DedupWindow::preseed`]
    /// reproduces the same dedup decisions after recovery or a replica
    /// reset. `order` may hold stale duplicates (a uid forgotten and
    /// later re-recorded); the `seen` filter keeps them harmless.
    fn snapshot(&self) -> Vec<u64> {
        if self.cap == 0 {
            return self.seen.iter().copied().collect();
        }
        self.order.iter().copied().filter(|u| self.seen.contains(u)).collect()
    }

    /// Restore recorded uids (recovery / replica reset).
    fn preseed(&mut self, uids: &[u64]) {
        for &uid in uids {
            self.record(uid);
        }
    }
}

/// Shared state of one shard server, lock-partitioned so read ops can
/// run concurrently with each other while pushes stay serialized on the
/// inbox thread.
struct ShardCore {
    shard_id: usize,
    config: PsConfig,
    /// Matrix registry; write-locked only by `CreateMatrix`. Each slice
    /// has its own `RwLock` so pulls of one matrix overlap pushes to
    /// another.
    matrices: RwLock<HashMap<u32, Arc<RwLock<MatrixSlice>>>>,
    dedup: Mutex<DedupWindow>,
    next_uid: AtomicU64,
    /// Write-ahead log, present when [`PsConfig::wal_dir`] is set on a
    /// primary (and opened lazily at promotion time on a backup). Only
    /// the slot is behind the lock; the WAL itself is internally
    /// synchronized.
    wal: RwLock<Option<Arc<ShardWal>>>,
    /// Replication role (`ROLE_*`).
    role: AtomicU8,
    /// Replication: highest upstream WAL sequence applied here.
    repl_applied: AtomicU64,
    /// Replication: the upstream's committed tip at the last apply.
    repl_tip: AtomicU64,
    /// Replication generation, bumped by each `ReplSeed`. A poller batch
    /// fetched under an older generation is rejected by `repl_apply` —
    /// the fence that keeps a zombie upstream's log from overwriting a
    /// freshly seeded replica.
    repl_gen: AtomicU64,
    /// Address the replication poller tails; a `ReplSeed` re-points it.
    repl_upstream: Mutex<Option<String>>,
}

impl ShardCore {
    fn slice(&self, id: u32) -> Result<Arc<RwLock<MatrixSlice>>> {
        self.matrices
            .read()
            .unwrap()
            .get(&id)
            .cloned()
            .ok_or_else(|| Error::PsRejected(format!("unknown matrix {id}")))
    }

    /// Handle a read-only operation (safe to run concurrently).
    fn handle_read(&self, req: &Request) -> Response {
        match req {
            Request::PullRows { id, rows } => self
                .slice(*id)
                .and_then(|m| m.read().unwrap().pull_rows(rows))
                .map_or_else(|e| Response::Error(e.to_string()), Response::Rows),
            Request::PullSparseRows { id, rows } => self
                .slice(*id)
                .and_then(|m| m.read().unwrap().pull_sparse(rows, None))
                .map_or_else(|e| Response::Error(e.to_string()), Response::SparseRows),
            Request::PullTopK { id, rows, k } => self
                .slice(*id)
                .and_then(|m| m.read().unwrap().pull_sparse(rows, Some(*k as usize)))
                .map_or_else(|e| Response::Error(e.to_string()), Response::SparseRows),
            Request::PullColSums { id } => self
                .slice(*id)
                .map(|m| m.read().unwrap().pull_col_sums())
                .map_or_else(|e| Response::Error(e.to_string()), Response::Rows),
            Request::ShardInfo => {
                let reg = self.matrices.read().unwrap();
                let (mut local_rows, mut bytes) = (0u64, 0u64);
                for m in reg.values() {
                    let m = m.read().unwrap();
                    local_rows += m.local_rows();
                    bytes += m.bytes();
                }
                let matrices = reg.len() as u32;
                drop(reg);
                let wal_stats =
                    self.wal.read().unwrap().as_ref().map(|w| w.stats()).unwrap_or_default();
                let repl_applied = self.repl_applied.load(Ordering::Relaxed);
                let repl_lag =
                    self.repl_tip.load(Ordering::Relaxed).saturating_sub(repl_applied);
                let dedup = self.dedup.lock().unwrap();
                Response::Info {
                    shard_id: self.shard_id as u32,
                    shards: self.config.shards as u32,
                    scheme: self.config.scheme,
                    matrices,
                    local_rows,
                    bytes,
                    pending_uids: dedup.pending(),
                    dedup_evictions: dedup.evictions,
                    role: self.role.load(Ordering::Relaxed),
                    wal_records: wal_stats.records,
                    wal_bytes: wal_stats.bytes,
                    wal_commit_batches: wal_stats.commit_batches,
                    repl_applied,
                    repl_lag,
                }
            }
            Request::ReplPoll { from } => match self.wal.read().unwrap().clone() {
                None => Response::Unavailable("shard has no wal to replicate from".into()),
                Some(wal) => match wal.read_from(*from, REPL_BATCH_MAX) {
                    Ok(s) => Response::ReplBatch {
                        reset: s.reset,
                        next: s.next,
                        tip: s.tip,
                        records: s.records,
                    },
                    Err(e) => Response::Error(e.to_string()),
                },
            },
            other => Response::Error(format!("not a read op: {other:?}")),
        }
    }

    /// Handle a state-mutating operation.
    ///
    /// SINGLE-WRITER: must be called from one thread per shard (the
    /// inbox loop): the dedup check → apply → record sequence of a push
    /// is exactly-once only because no second push can interleave with
    /// it.
    fn handle_write(&self, req: Request) -> Response {
        match req {
            Request::CreateMatrix { id, rows, cols, dtype, layout } => {
                self.create(id, rows, cols, dtype, layout)
            }
            Request::GenUid => {
                Response::Uid(self.next_uid.fetch_add(1, Ordering::Relaxed) + 1)
            }
            Request::PushCoords { id, uid, rows, cols, values } => {
                if self.dedup.lock().unwrap().contains(uid) {
                    return Response::PushAck { fresh: false };
                }
                if rows.len() != cols.len() || rows.len() != values.len() {
                    return Response::Error(format!(
                        "coord push length mismatch: {} rows, {} cols, {} values",
                        rows.len(),
                        cols.len(),
                        values.len()
                    ));
                }
                let result = self
                    .slice(id)
                    .and_then(|m| m.write().unwrap().apply_coords(&rows, &cols, &values));
                match result {
                    Ok(()) => {
                        self.dedup.lock().unwrap().record(uid);
                        self.note_issued_uid(uid);
                        Response::PushAck { fresh: true }
                    }
                    Err(e) => Response::Error(e.to_string()),
                }
            }
            Request::PushRows { id, uid, rows, values } => {
                if self.dedup.lock().unwrap().contains(uid) {
                    return Response::PushAck { fresh: false };
                }
                let result =
                    self.slice(id).and_then(|m| m.write().unwrap().apply_rows(&rows, &values));
                match result {
                    Ok(()) => {
                        self.dedup.lock().unwrap().record(uid);
                        self.note_issued_uid(uid);
                        Response::PushAck { fresh: true }
                    }
                    Err(e) => Response::Error(e.to_string()),
                }
            }
            Request::Forget { uid } => {
                self.dedup.lock().unwrap().forget(uid);
                Response::Ok
            }
            Request::DeleteMatrix { matrix } => {
                // Idempotent: deleting an unknown (or already-deleted)
                // id is a no-op, so coordinator retries are safe.
                self.matrices.write().unwrap().remove(&matrix);
                Response::Ok
            }
            Request::Promote => self.promote(),
            Request::ReplApply { gen, reset, tip, records } => {
                self.repl_apply(gen, reset, tip, &records)
            }
            Request::ReplSeed { upstream, tip, records } => {
                self.repl_seed(&upstream, tip, &records)
            }
            Request::Drain => self.drain(),
            Request::Shutdown => Response::Ok,
            other => Response::Error(format!("not a write op: {other:?}")),
        }
    }

    fn create(&self, id: u32, rows: u64, cols: u32, dtype: Dtype, layout: Layout) -> Response {
        let mut reg = self.matrices.write().unwrap();
        // Idempotent: re-creating the same id with the same shape is a
        // no-op (a retried CreateMatrix must not wipe data).
        if let Some(existing) = reg.get(&id) {
            return if existing.read().unwrap().shape() == (rows, cols, dtype, layout) {
                Response::Ok
            } else {
                Response::Error(format!("matrix {id} already exists with different shape"))
            };
        }
        let part = Partitioner::new(rows, self.config.shards, self.config.scheme);
        let local = part.rows_on_shard(self.shard_id);
        let slice = match dtype {
            Dtype::I64 => MatrixSlice::I64 { part, store: Store::new(layout, local, cols) },
            Dtype::F32 => MatrixSlice::F32 { part, store: Store::new(layout, local, cols) },
        };
        reg.insert(id, Arc::new(RwLock::new(slice)));
        Response::Ok
    }

    /// Replay and replication hand this shard uids issued by a previous
    /// life; bump the counter past them so it never re-issues one.
    /// Guarded by the shard tag in the top bits — foreign uids (tests,
    /// other shards) must not blow the counter up.
    fn note_issued_uid(&self, uid: u64) {
        if uid >> 48 == self.shard_id as u64 {
            self.next_uid.fetch_max(uid + 1, Ordering::Relaxed);
        }
    }

    /// Apply a write, appending it to the WAL when it both should be
    /// logged and actually mutated state. `log` is false on the replay
    /// and replication paths, whose records are already in a log.
    fn apply_write(&self, req: Request, log: bool) -> Response {
        let encoded = if log && should_log(&req) && self.wal.read().unwrap().is_some() {
            Some(req.encode())
        } else {
            None
        };
        let resp = self.handle_write(req);
        if let Some(bytes) = encoded {
            if write_succeeded(&resp) {
                let wal = self.wal.read().unwrap().clone();
                if let Some(wal) = wal {
                    wal.append(&WalPayload::Write(bytes));
                    self.maybe_compact(&wal);
                }
            }
        }
        resp
    }

    /// Fold the shard state into a snapshot segment once enough sealed
    /// log segments pile up. Runs on the single writer thread, so the
    /// captured state is consistent with everything logged before it.
    fn maybe_compact(&self, wal: &ShardWal) {
        if wal.sealed_segments() < self.config.wal_compact_after.max(1) {
            return;
        }
        let payloads = self.snapshot_payloads();
        if let Err(e) = wal.compact(&payloads) {
            log_warn!("shard {}: wal compaction failed: {e}", self.shard_id);
        }
    }

    /// Role gate: an un-promoted backup accepts only replication
    /// traffic, introspection and control ops; a draining primary still
    /// feeds its replicas (`ReplPoll`) and answers introspection but
    /// refuses new data ops. Gated requests get
    /// [`Response::Unavailable`], which the client's courier treats as
    /// a retryable failover signal (unlike a hard `Error`).
    fn gate(&self, req: &Request) -> Option<Response> {
        match self.role.load(Ordering::Relaxed) {
            ROLE_BACKUP => match req {
                Request::ShardInfo
                | Request::ReplApply { .. }
                | Request::ReplSeed { .. }
                | Request::Promote
                | Request::Shutdown => None,
                _ => Some(Response::Unavailable(format!(
                    "shard {} is an un-promoted backup",
                    self.shard_id
                ))),
            },
            ROLE_DRAINING => match req {
                Request::ShardInfo
                | Request::ReplPoll { .. }
                | Request::Drain
                | Request::Shutdown => None,
                _ => Some(Response::Unavailable(format!(
                    "shard {} is draining",
                    self.shard_id
                ))),
            },
            _ => None,
        }
    }

    /// Promote this backup to serve as primary. Idempotent. A promoted
    /// backup with a configured `wal_dir` opens its own WAL and folds
    /// the replicated in-memory state (the authority now — whatever a
    /// previous life logged in that directory is superseded) into a
    /// snapshot, so the shard stays durable after the role flip.
    fn promote(&self) -> Response {
        if self.role.load(Ordering::Relaxed) != ROLE_BACKUP {
            return Response::Ok;
        }
        self.role.store(ROLE_PROMOTED, Ordering::SeqCst);
        if self.wal.read().unwrap().is_none() {
            if let Some(dir) = self.config.wal_dir.clone() {
                let path = wal_shard_dir(&dir, self.shard_id);
                match ShardWal::open(&path, self.shard_id as u32, self.wal_options()) {
                    Ok((wal, _stale)) => {
                        let wal = Arc::new(wal);
                        // Continue the replication sequence domain: the
                        // snapshot below then lands at `upto =
                        // repl_applied`, so a `ReplPoll` from any
                        // standby cursor reaches it (a fresh log would
                        // compact at `upto = 0`, invisible to `from >=
                        // 1`), and survivors already at the frontier
                        // keep tailing without a reset.
                        let applied = self.repl_applied.load(Ordering::Acquire);
                        if applied > 0 {
                            wal.adopt_frontier(applied);
                        }
                        *self.wal.write().unwrap() = Some(Arc::clone(&wal));
                        if let Err(e) = wal.compact(&self.snapshot_payloads()) {
                            log_warn!(
                                "shard {}: snapshot after promotion failed: {e}",
                                self.shard_id
                            );
                        }
                    }
                    // Unlike at startup this is remote-triggered mid-run;
                    // serving non-durably beats refusing the promotion.
                    Err(e) => log_warn!(
                        "shard {}: cannot open wal after promotion ({e}); continuing \
                         without durability",
                        self.shard_id
                    ),
                }
            }
        }
        Response::Ok
    }

    /// Apply a replicated batch. Only a backup accepts this: a promoted
    /// replica is the authority and a zombie poller must not overwrite
    /// it. A batch fetched under a stale replication generation (the
    /// poller read it from the *previous* upstream before a `ReplSeed`
    /// re-pointed this shard) is rejected for the same reason — its
    /// sequence numbers belong to a log this replica no longer follows.
    /// Re-delivered records are skipped by sequence; the writes inside
    /// flow through the normal dedup path, so re-application is safe
    /// even across a `reset`.
    fn repl_apply(&self, gen: u64, reset: bool, tip: u64, records: &[(u64, Vec<u8>)]) -> Response {
        if self.role.load(Ordering::Relaxed) != ROLE_BACKUP {
            return Response::Error("not a backup".into());
        }
        if gen != self.repl_gen.load(Ordering::SeqCst) {
            return Response::Error(format!(
                "stale replication generation {gen} (shard is at {})",
                self.repl_gen.load(Ordering::SeqCst)
            ));
        }
        if reset {
            self.matrices.write().unwrap().clear();
            *self.dedup.lock().unwrap() = DedupWindow::new(self.config.dedup_window);
            self.repl_applied.store(0, Ordering::Relaxed);
            self.next_uid.store((self.shard_id as u64) << 48, Ordering::Relaxed);
        }
        let mut applied = self.repl_applied.load(Ordering::Relaxed);
        for (seq, bytes) in records {
            // A snapshot's records all carry the same sequence, so the
            // skip applies only to non-reset (streamed) batches.
            if !reset && *seq <= applied {
                continue;
            }
            self.apply_logged(*seq, bytes);
            applied = applied.max(*seq);
        }
        self.repl_applied.store(applied, Ordering::Relaxed);
        self.repl_tip.store(tip.max(applied), Ordering::Relaxed);
        Response::Ok
    }

    /// Rebuild this backup from an upstream's snapshot slice and
    /// re-point its poller — how the coordinator attaches a standby
    /// behind a freshly promoted head without pausing training. The seed
    /// carries the upstream's snapshot at some sequence `S ≤ tip`; the
    /// reset apply leaves `repl_applied == S`, so the poller's next
    /// cursor (`S + 1`) tails the remaining log through the normal
    /// `ReplPoll` path.
    ///
    /// SINGLE-WRITER: runs on the inbox thread like every write, so the
    /// generation bump here is ordered before any later `ReplApply` —
    /// a batch the poller fetched from the *old* upstream carries the
    /// old generation and is fenced off instead of corrupting the seed.
    fn repl_seed(&self, upstream: &str, tip: u64, records: &[(u64, Vec<u8>)]) -> Response {
        if self.role.load(Ordering::Relaxed) != ROLE_BACKUP {
            return Response::Error("not a backup".into());
        }
        let gen = self.repl_gen.fetch_add(1, Ordering::SeqCst) + 1;
        if !upstream.is_empty() {
            *self.repl_upstream.lock().unwrap() = Some(upstream.to_string());
        }
        self.repl_apply(gen, true, tip, records)
    }

    /// Planned hand-off: flip to [`ROLE_DRAINING`] (data ops get the
    /// retryable `Unavailable`), fsync the WAL, and report the committed
    /// tip. Because this runs on the single writer thread, every write
    /// acked before it is already appended — `tip` covers the entire
    /// commit window, and a backup whose `repl_applied` reaches `tip`
    /// holds everything, so the subsequent promotion loses nothing and
    /// needs no epoch roll. Idempotent.
    fn drain(&self) -> Response {
        if self.role.load(Ordering::Relaxed) == ROLE_BACKUP {
            return Response::Error("cannot drain an un-promoted backup".into());
        }
        let Some(wal) = self.wal.read().unwrap().clone() else {
            return Response::Error(
                "drain needs a wal-backed shard: without a log there is no feed for a \
                 backup to catch up on"
                    .into(),
            );
        };
        self.role.store(ROLE_DRAINING, Ordering::SeqCst);
        wal.sync();
        Response::Drained { tip: wal.committed() }
    }

    /// Apply one WAL record (recovery replay or replication): `Write`
    /// records re-run the original request, `Snap*` records rebuild
    /// state directly. Failures are logged and skipped — recovery must
    /// salvage everything applicable rather than refuse to start.
    fn apply_logged(&self, seq: u64, bytes: &[u8]) {
        match WalPayload::decode(bytes) {
            Ok(WalPayload::Write(req)) => match Request::decode(&req) {
                Ok(req) => {
                    if let Response::Error(e) = self.handle_write(req) {
                        log_warn!(
                            "shard {}: wal record {seq} failed to re-apply: {e}",
                            self.shard_id
                        );
                    }
                }
                Err(e) => log_warn!(
                    "shard {}: wal record {seq} is undecodable: {e}",
                    self.shard_id
                ),
            },
            Ok(snap) => self.apply_snap(snap),
            Err(e) => {
                log_warn!("shard {}: wal record {seq} is undecodable: {e}", self.shard_id)
            }
        }
    }

    /// Apply one snapshot record. Snapshots are only ever applied to an
    /// empty registry (fresh recovery or just-reset replica), so the
    /// absolute `SnapRows` values land on zeroed state and the additive
    /// apply reproduces them exactly.
    fn apply_snap(&self, snap: WalPayload) {
        match snap {
            WalPayload::Write(_) => {} // not a snapshot record
            WalPayload::SnapMatrix { id, rows, cols, dtype, layout } => {
                if let Response::Error(e) = self.create(id, rows, cols, dtype, layout) {
                    log_warn!("shard {}: snapshot matrix {id} rejected: {e}", self.shard_id);
                }
            }
            WalPayload::SnapRows { matrix, rows, cols, values } => {
                let res = self
                    .slice(matrix)
                    .and_then(|m| m.write().unwrap().apply_coords(&rows, &cols, &values));
                if let Err(e) = res {
                    log_warn!(
                        "shard {}: snapshot rows for matrix {matrix} rejected: {e}",
                        self.shard_id
                    );
                }
            }
            WalPayload::SnapDedup { uids } => self.dedup.lock().unwrap().preseed(&uids),
            WalPayload::SnapNextUid(v) => {
                self.next_uid.fetch_max(v, Ordering::Relaxed);
            }
        }
    }

    /// The full shard state as snapshot records, terminal marker last.
    ///
    /// SINGLE-WRITER: must run on the shard's one writer thread so
    /// nothing mutates underneath the capture.
    fn snapshot_payloads(&self) -> Vec<WalPayload> {
        let reg = self.matrices.read().unwrap();
        let mut ids: Vec<u32> = reg.keys().copied().collect();
        ids.sort_unstable();
        let mut payloads = Vec::new();
        for id in ids {
            let slice = reg[&id].read().unwrap();
            let (rows, cols, dtype, layout) = slice.shape();
            payloads.push(WalPayload::SnapMatrix { id, rows, cols, dtype, layout });
            slice.snap_rows(id, self.shard_id, &mut payloads);
        }
        drop(reg);
        payloads.push(WalPayload::SnapDedup { uids: self.dedup.lock().unwrap().snapshot() });
        payloads.push(WalPayload::SnapNextUid(self.next_uid.load(Ordering::Relaxed)));
        payloads
    }

    /// Open the WAL at `path`, replay whatever a previous life left
    /// behind through the live apply path, then arm it for appends. A
    /// WAL that cannot open is fatal: silently running non-durable when
    /// durability was asked for would be worse than refusing to start.
    fn recover(&self, path: &Path) {
        let (wal, replay) = ShardWal::open(path, self.shard_id as u32, self.wal_options())
            .unwrap_or_else(|e| {
                panic!(
                    "shard {}: cannot open wal at {}: {e}",
                    self.shard_id,
                    path.display()
                )
            });
        for (seq, bytes) in &replay {
            self.apply_logged(*seq, bytes);
        }
        *self.wal.write().unwrap() = Some(Arc::new(wal));
    }

    fn wal_options(&self) -> WalOptions {
        WalOptions {
            segment_bytes: self.config.wal_segment_bytes,
            commit_window: self.config.wal_commit_window,
            compact_after: self.config.wal_compact_after,
            ..WalOptions::default()
        }
    }
}

/// True for write ops that mutate durable state and therefore go to the
/// WAL. `GenUid` is included — replaying it restores the uid counter —
/// while `Promote`/`ReplApply` are control-plane and never logged.
fn should_log(req: &Request) -> bool {
    matches!(
        req,
        Request::CreateMatrix { .. }
            | Request::GenUid
            | Request::PushCoords { .. }
            | Request::PushRows { .. }
            | Request::Forget { .. }
            | Request::DeleteMatrix { .. }
    )
}

/// True when the response proves the write actually mutated state. A
/// deduplicated push (`fresh: false`) changed nothing — its original
/// application is already in the log — and errors log nothing.
fn write_succeeded(resp: &Response) -> bool {
    matches!(resp, Response::Ok | Response::Uid(_) | Response::PushAck { fresh: true })
}

/// True for operations that only read shard state and may run on the
/// concurrent reader pool.
fn is_read_op(req: &Request) -> bool {
    matches!(
        req,
        Request::PullRows { .. }
            | Request::PullSparseRows { .. }
            | Request::PullTopK { .. }
            | Request::PullColSums { .. }
            | Request::ReplPoll { .. }
            | Request::ShardInfo
    )
}

/// State of one shard server. Cheap handle over the lock-partitioned
/// core; [`ShardState::handle`] processes any request inline (the
/// single-threaded path used by tests and embedded servers), while
/// [`serve`] dispatches reads onto a concurrent pool.
pub struct ShardState {
    core: Arc<ShardCore>,
}

impl ShardState {
    /// Fresh state for shard `shard_id`. A primary with a configured
    /// `wal_dir` recovers from (and then appends to) its write-ahead
    /// log; a backup (`backup_of` set) starts empty and refuses data
    /// ops until promoted — its state arrives by replication.
    pub fn new(shard_id: usize, config: PsConfig) -> ShardState {
        let dedup_window = config.dedup_window;
        let is_backup = config.backup_of.is_some();
        let upstream = config
            .backup_of
            .as_ref()
            .and_then(|primaries| primaries.get(shard_id))
            .filter(|addr| !addr.is_empty())
            .cloned();
        let core = Arc::new(ShardCore {
            shard_id,
            config,
            matrices: RwLock::new(HashMap::new()),
            dedup: Mutex::new(DedupWindow::new(dedup_window)),
            // Uids carry the shard id in the top bits so they are
            // unique across shards (useful in traces); dedup is
            // per-shard anyway.
            next_uid: AtomicU64::new((shard_id as u64) << 48),
            wal: RwLock::new(None),
            role: AtomicU8::new(if is_backup { ROLE_BACKUP } else { ROLE_PRIMARY }),
            repl_applied: AtomicU64::new(0),
            repl_tip: AtomicU64::new(0),
            repl_gen: AtomicU64::new(0),
            repl_upstream: Mutex::new(upstream),
        });
        if !is_backup {
            if let Some(dir) = core.config.wal_dir.clone() {
                core.recover(&wal_shard_dir(&dir, shard_id));
            }
        }
        ShardState { core }
    }

    /// Handle one decoded request inline.
    pub fn handle(&mut self, req: Request) -> Response {
        if let Some(resp) = self.core.gate(&req) {
            return resp;
        }
        if is_read_op(&req) {
            self.core.handle_read(&req)
        } else {
            self.core.apply_write(req, true)
        }
    }

    /// A shareable read-only handle over this shard's core, for callers
    /// that run read ops concurrently with the owning thread's writes
    /// (the model-checker tests drive the reader/writer interleavings
    /// through this).
    pub fn reader(&self) -> ShardReader {
        ShardReader { core: Arc::clone(&self.core) }
    }

    /// Start a concurrent reader pool over this shard's core (the same
    /// executor [`serve`] uses). Exposed so tests — the model suite in
    /// particular — can drive the pool directly with crafted envelopes.
    pub fn start_read_pool(&self, threads: usize) -> ReadPool {
        ReadPool::start(Arc::clone(&self.core), threads)
    }
}

/// Cloneable read-only view of one shard (see [`ShardState::reader`]).
#[derive(Clone)]
pub struct ShardReader {
    core: Arc<ShardCore>,
}

impl ShardReader {
    /// Handle one read-only request. Safe to call from any thread,
    /// concurrently with the owner's writes.
    pub fn handle_read(&self, req: &Request) -> Response {
        self.core.handle_read(req)
    }
}

/// Concurrent executor for read ops: a fixed pool of reader threads
/// draining a shared queue. Dropping the pool closes the queue and
/// joins the workers after they finish (and respond to) whatever is
/// still queued.
///
/// Public only for the test surface ([`ShardState::start_read_pool`]);
/// production servers get one implicitly through [`serve`]. The workers
/// spawn through the sync_shim, so under the model checker they become
/// virtual tasks whose interleavings are explored.
pub struct ReadPool {
    tx: Option<mpsc::Sender<(Envelope, Request)>>,
    workers: Vec<vthread::JoinHandle<()>>,
}

impl ReadPool {
    fn start(core: Arc<ShardCore>, threads: usize) -> ReadPool {
        let (tx, rx) = mpsc::channel::<(Envelope, Request)>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads.max(1))
            .map(|i| {
                let core = Arc::clone(&core);
                let rx = Arc::clone(&rx);
                vthread::Builder::new()
                    .name(format!("glint-shard-{}-read-{i}", core.shard_id))
                    .spawn(move || loop {
                        let item = rx.lock().unwrap().recv();
                        match item {
                            Ok((env, req)) => {
                                respond(&env, core.handle_read(&req).encode());
                            }
                            Err(_) => return,
                        }
                    })
                    // PANIC-OK: reader-pool spawn fails only on resource
                    // exhaustion while bringing the shard up.
                    .expect("spawn shard reader")
            })
            .collect();
        ReadPool { tx: Some(tx), workers }
    }

    /// Enqueue one read op; some pool worker will `respond` on the
    /// envelope's reply channel.
    pub fn submit(&self, env: Envelope, req: Request) {
        if let Some(tx) = &self.tx {
            let _ = tx.send((env, req));
        }
    }
}

impl Drop for ReadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Event loop for one shard server thread: write ops inline (serialized
/// — the exactly-once invariant), read ops onto the reader pool.
fn serve(state: ShardState, inbox: Inbox) {
    let readers = ReadPool::start(Arc::clone(&state.core), state.core.config.read_concurrency);
    while let Some(env) = inbox.recv() {
        match Request::decode(&env.payload) {
            Ok(Request::Shutdown) => {
                respond(&env, Response::Ok.encode());
                return; // drops the pool: queued reads drain first
            }
            Ok(req) => {
                if let Some(resp) = state.core.gate(&req) {
                    respond(&env, resp.encode());
                } else if is_read_op(&req) {
                    readers.submit(env, req);
                } else {
                    respond(&env, state.core.apply_write(req, true).encode());
                }
            }
            Err(e) => respond(&env, Response::Error(e.to_string()).encode()),
        }
    }
}

/// Spawn one serve-loop thread per inbox, for shards numbered from
/// `first_shard` upward. Also returns the shard cores so the caller
/// can attach server-local machinery (replication pollers).
fn spawn_serve_threads(
    config: &PsConfig,
    first_shard: usize,
    inboxes: Vec<Inbox>,
) -> (Vec<JoinHandle<()>>, Vec<Arc<ShardCore>>) {
    let mut handles = Vec::with_capacity(inboxes.len());
    let mut cores = Vec::with_capacity(inboxes.len());
    for (i, inbox) in inboxes.into_iter().enumerate() {
        let shard_id = first_shard + i;
        let state = ShardState::new(shard_id, config.clone());
        cores.push(Arc::clone(&state.core));
        handles.push(
            std::thread::Builder::new()
                .name(format!("glint-shard-{shard_id}"))
                .spawn(move || serve(state, inbox))
                // PANIC-OK: serve-thread spawn fails only on resource
                // exhaustion at server startup.
                .expect("spawn shard server"),
        );
    }
    (handles, cores)
}

/// A running group of shard servers plus the transport connecting to
/// them. Owns the server threads; dropping the group shuts them down.
pub struct ServerGroup {
    transport: Arc<dyn Transport>,
    config: PsConfig,
    handles: Vec<JoinHandle<()>>,
    /// Listener handles when the group runs over TCP loopback.
    tcp: Option<TcpServer>,
}

impl ServerGroup {
    /// Start `config.shards` shard servers over the transport selected
    /// by `config.transport`:
    ///
    /// - [`TransportMode::Sim`] — in-process inboxes under `plan`;
    /// - [`TransportMode::TcpLoopback`] — real TCP listeners on
    ///   `127.0.0.1` ephemeral ports (the fault plan does not apply: the
    ///   network itself supplies the at-most-once behavior);
    /// - [`TransportMode::Connect`] — not startable: the servers live in
    ///   other processes (use [`TcpShardServer`] there).
    pub fn start(config: PsConfig, plan: FaultPlan, seed: u64) -> ServerGroup {
        match config.transport {
            TransportMode::Sim => {
                let (transport, inboxes) = SimTransport::new(config.shards, plan, seed);
                let (handles, _cores) = spawn_serve_threads(&config, 0, inboxes);
                ServerGroup { transport: Arc::new(transport), config, handles, tcp: None }
            }
            TransportMode::TcpLoopback => {
                if !plan.is_reliable() {
                    log_warn!(
                        "the TCP transport ignores the sim fault plan; install the chaos \
                         interposer (net::chaos) or --chaos-plan for TCP fault injection"
                    );
                }
                // PANIC-OK: a constant loopback address always parses.
                let want: Vec<SocketAddr> =
                    vec!["127.0.0.1:0".parse().unwrap(); config.shards];
                let (server, inboxes) =
                    // PANIC-OK: an in-process loopback group that cannot
                    // bind has no caller-visible fallback.
                    TcpServer::bind(&want).expect("bind loopback tcp listeners");
                let transport = TcpTransport::connect(server.addrs());
                let (handles, _cores) = spawn_serve_threads(&config, 0, inboxes);
                ServerGroup {
                    transport: Arc::new(transport),
                    config,
                    handles,
                    tcp: Some(server),
                }
            }
            TransportMode::Connect(_) => panic!(
                "ServerGroup::start cannot run in Connect mode: the shard servers live in \
                 other processes (run `glint-lda serve` there and connect a client instead)"
            ),
        }
    }

    /// The transport clients should connect through.
    pub fn transport(&self) -> Arc<dyn Transport> {
        Arc::clone(&self.transport)
    }

    /// Deployment config.
    pub fn config(&self) -> &PsConfig {
        &self.config
    }

    /// Gracefully stop all shard threads.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        for s in 0..self.transport.shards() {
            let ep = self.transport.endpoint(s);
            // Control-plane channel: bypasses fault injection so the stop
            // signal always lands (or errors if the shard already exited).
            let _ = ep
                .send_reliable(Request::Shutdown.encode(), std::time::Duration::from_secs(5));
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        if let Some(mut server) = self.tcp.take() {
            server.shutdown();
        }
    }
}

impl Drop for ServerGroup {
    fn drop(&mut self) {
        if !self.handles.is_empty() {
            self.shutdown_inner();
        }
    }
}

/// Standalone TCP shard servers for multi-process deployments: the
/// `glint-lda serve` half of a `serve` / `train --connect` pair.
///
/// Hosts shards `first_shard .. first_shard + addrs.len()` of a
/// `config.shards`-shard deployment, one listener per shard. Each serve
/// loop exits when it receives a [`Request::Shutdown`] (e.g. from
/// [`crate::ps::client::PsClient::shutdown_servers`]).
///
/// With [`PsConfig::backup_of`] set, every hosted shard runs as a
/// backup replica: a poller thread per shard streams the corresponding
/// primary's committed WAL and injects the batches into the shard's
/// inbox (see [`repl_poll_loop`]).
pub struct TcpShardServer {
    server: TcpServer,
    handles: Vec<JoinHandle<()>>,
    /// Replication pollers (backup mode only).
    pollers: Vec<JoinHandle<()>>,
    /// Tells the pollers to exit at shutdown time.
    stop: Arc<AtomicBool>,
}

impl TcpShardServer {
    /// Bind listeners and start the serve loops. Use port `0` to bind
    /// ephemeral ports and read them back from [`TcpShardServer::addrs`].
    pub fn bind(
        config: PsConfig,
        first_shard: usize,
        addrs: &[SocketAddr],
    ) -> Result<TcpShardServer> {
        if addrs.is_empty() {
            return Err(crate::util::error::Error::Config(
                "serve needs at least one bind address".into(),
            ));
        }
        if first_shard + addrs.len() > config.shards {
            return Err(crate::util::error::Error::Config(format!(
                "shards {first_shard}..{} exceed the {}-shard deployment",
                first_shard + addrs.len(),
                config.shards
            )));
        }
        let primary_addrs = match &config.backup_of {
            None => None,
            Some(primaries) => {
                if primaries.len() != config.shards {
                    return Err(Error::Config(format!(
                        "--backup-of needs one primary address per shard ({}), got {}",
                        config.shards,
                        primaries.len()
                    )));
                }
                Some(resolve_addrs(primaries)?)
            }
        };
        let (server, inboxes) = TcpServer::bind(addrs)?;
        let (handles, cores) = spawn_serve_threads(&config, first_shard, inboxes);
        let stop = Arc::new(AtomicBool::new(false));
        let mut pollers = Vec::new();
        if primary_addrs.is_some() {
            for (i, core) in cores.iter().enumerate() {
                let shard = first_shard + i;
                let injector = server.injector(i);
                let core = Arc::clone(core);
                let stop = Arc::clone(&stop);
                pollers.push(
                    std::thread::Builder::new()
                        .name(format!("glint-repl-{shard}"))
                        .spawn(move || repl_poll_loop(&core, &injector, &stop))
                        // PANIC-OK: poller spawn fails only on resource
                        // exhaustion at server startup.
                        .expect("spawn replication poller"),
                );
            }
        }
        Ok(TcpShardServer { server, handles, pollers, stop })
    }

    /// Local listener addresses, in shard order.
    pub fn addrs(&self) -> &[SocketAddr] {
        self.server.addrs()
    }

    /// Block until every hosted shard has been told to shut down, then
    /// stop the pollers and accept loops.
    pub fn join(mut self) {
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        self.stop.store(true, Ordering::SeqCst);
        for p in self.pollers.drain(..) {
            let _ = p.join();
        }
        self.server.shutdown();
    }
}

/// Replication poller for one backup shard: pull committed WAL records
/// from the current upstream and inject the batches into the shard's
/// own inbox, so they apply through the same serialized single-writer
/// path as live traffic. The upstream address is re-read every
/// iteration — a `ReplSeed` re-points the shard mid-run and the poller
/// re-dials — and every batch is tagged with the replication generation
/// it was fetched under, so a batch from a superseded upstream is
/// rejected by the apply handler instead of corrupting the seed. Exits
/// when the server stops or the shard is promoted (the upstream's feed
/// is no longer the authority then).
fn repl_poll_loop(
    core: &Arc<ShardCore>,
    injector: &mpsc::Sender<Envelope>,
    stop: &Arc<AtomicBool>,
) {
    // (address, endpoint) of the current upstream connection.
    let mut conn: Option<(String, crate::net::Endpoint)> = None;
    while !stop.load(Ordering::SeqCst) {
        if core.role.load(Ordering::Relaxed) != ROLE_BACKUP {
            return;
        }
        let Some(upstream) = core.repl_upstream.lock().unwrap().clone() else {
            std::thread::sleep(REPL_ERROR_BACKOFF);
            continue;
        };
        if conn.as_ref().map_or(true, |(addr, _)| *addr != upstream) {
            match resolve_addrs(std::slice::from_ref(&upstream)) {
                Ok(addrs) => {
                    conn = Some((upstream.clone(), TcpTransport::connect(&addrs).endpoint(0)));
                }
                Err(e) => {
                    log_warn!(
                        "shard {}: bad replication upstream {upstream:?}: {e}",
                        core.shard_id
                    );
                    std::thread::sleep(REPL_ERROR_BACKOFF);
                    continue;
                }
            }
        }
        // PANIC-OK: `conn` was just installed above when absent.
        let ep = &conn.as_ref().expect("upstream connection installed").1;
        // Sample the generation *before* the poll: if a ReplSeed lands
        // in between, this batch carries a stale generation and the
        // single-writer apply path rejects it.
        let gen = core.repl_gen.load(Ordering::SeqCst);
        let from = core.repl_applied.load(Ordering::Relaxed) + 1;
        let reply = match ep.request(Request::ReplPoll { from }.encode(), REPL_POLL_TIMEOUT) {
            Ok(bytes) => Response::decode(&bytes),
            Err(()) => {
                std::thread::sleep(REPL_ERROR_BACKOFF);
                continue;
            }
        };
        match reply {
            Ok(Response::ReplBatch { reset, next: _, tip, records }) => {
                if records.is_empty() && !reset {
                    // Caught up; note the tip and idle briefly (only if
                    // no seed re-pointed us mid-poll — a superseded
                    // upstream's tip would fake lag).
                    if core.repl_gen.load(Ordering::SeqCst) == gen {
                        let applied = core.repl_applied.load(Ordering::Relaxed);
                        core.repl_tip.store(tip.max(applied), Ordering::Relaxed);
                    }
                    std::thread::sleep(REPL_IDLE_POLL);
                    continue;
                }
                let apply = Request::ReplApply { gen, reset, tip, records }.encode();
                let (reply_tx, reply_rx) = mpsc::sync_channel(1);
                if injector.send(Envelope { payload: apply, reply: Some(reply_tx) }).is_err() {
                    return; // the serve loop is gone
                }
                // Wait for the apply so `repl_applied` has advanced
                // before the next poll computes its cursor.
                let _ = reply_rx.recv_timeout(REPL_POLL_TIMEOUT);
            }
            // Transient states (upstream restarting without its WAL yet,
            // a draining or just-promoted head, decode noise) all take
            // the same back-off.
            Ok(_) | Err(_) => std::thread::sleep(REPL_ERROR_BACKOFF),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state() -> ShardState {
        // Single shard so every row is local.
        ShardState::new(0, PsConfig::with_shards(1))
    }

    fn create(rows: u64, cols: u32, dtype: Dtype, layout: Layout) -> Request {
        Request::CreateMatrix { id: 1, rows, cols, dtype, layout }
    }

    #[test]
    fn create_pull_push_cycle() {
        for layout in [Layout::Dense, Layout::Sparse] {
            let mut s = state();
            assert_eq!(s.handle(create(4, 3, Dtype::I64, layout)), Response::Ok);
            let uid = match s.handle(Request::GenUid) {
                Response::Uid(u) => u,
                r => panic!("want uid, got {r:?}"),
            };
            assert_eq!(
                s.handle(Request::PushCoords {
                    id: 1,
                    uid,
                    rows: vec![0, 0, 3],
                    cols: vec![0, 1, 2],
                    values: Data::I64(vec![5, 7, -2]),
                }),
                Response::PushAck { fresh: true }
            );
            match s.handle(Request::PullRows { id: 1, rows: vec![0, 3] }) {
                Response::Rows(Data::I64(v)) => assert_eq!(v, vec![5, 7, 0, 0, 0, -2]),
                r => panic!("unexpected {r:?}"),
            }
        }
    }

    #[test]
    fn duplicate_push_not_reapplied() {
        let mut s = state();
        s.handle(create(1, 1, Dtype::I64, Layout::Dense));
        let push = Request::PushCoords {
            id: 1,
            uid: 7,
            rows: vec![0],
            cols: vec![0],
            values: Data::I64(vec![10]),
        };
        assert_eq!(s.handle(push.clone()), Response::PushAck { fresh: true });
        assert_eq!(s.handle(push.clone()), Response::PushAck { fresh: false });
        assert_eq!(s.handle(push), Response::PushAck { fresh: false });
        match s.handle(Request::PullRows { id: 1, rows: vec![0] }) {
            Response::Rows(Data::I64(v)) => assert_eq!(v, vec![10]),
            r => panic!("unexpected {r:?}"),
        }
    }

    #[test]
    fn forget_releases_uid() {
        let mut s = state();
        s.handle(create(1, 1, Dtype::I64, Layout::Dense));
        let push = Request::PushCoords {
            id: 1,
            uid: 9,
            rows: vec![0],
            cols: vec![0],
            values: Data::I64(vec![1]),
        };
        s.handle(push.clone());
        match s.handle(Request::ShardInfo) {
            Response::Info { pending_uids, .. } => assert_eq!(pending_uids, 1),
            r => panic!("unexpected {r:?}"),
        }
        assert_eq!(s.handle(Request::Forget { uid: 9 }), Response::Ok);
        assert_eq!(s.handle(Request::Forget { uid: 9 }), Response::Ok); // idempotent
        match s.handle(Request::ShardInfo) {
            Response::Info { pending_uids, .. } => assert_eq!(pending_uids, 0),
            r => panic!("unexpected {r:?}"),
        }
    }

    #[test]
    fn recreate_same_shape_is_idempotent() {
        let mut s = state();
        let create = create(2, 2, Dtype::I64, Layout::Sparse);
        s.handle(create.clone());
        s.handle(Request::PushCoords {
            id: 1,
            uid: 1,
            rows: vec![1],
            cols: vec![1],
            values: Data::I64(vec![4]),
        });
        // Retried create must not wipe the data.
        assert_eq!(s.handle(create), Response::Ok);
        match s.handle(Request::PullRows { id: 1, rows: vec![1] }) {
            Response::Rows(Data::I64(v)) => assert_eq!(v, vec![0, 4]),
            r => panic!("unexpected {r:?}"),
        }
    }

    #[test]
    fn recreate_different_shape_or_layout_rejected() {
        let mut s = state();
        s.handle(create(2, 2, Dtype::I64, Layout::Dense));
        match s.handle(Request::CreateMatrix {
            id: 1,
            rows: 3,
            cols: 2,
            dtype: Dtype::I64,
            layout: Layout::Dense,
        }) {
            Response::Error(_) => {}
            r => panic!("unexpected {r:?}"),
        }
        match s.handle(Request::CreateMatrix {
            id: 1,
            rows: 2,
            cols: 2,
            dtype: Dtype::I64,
            layout: Layout::Sparse,
        }) {
            Response::Error(_) => {}
            r => panic!("unexpected {r:?}"),
        }
    }

    #[test]
    fn errors_for_unknown_matrix_and_mismatch() {
        let mut s = state();
        match s.handle(Request::PullRows { id: 99, rows: vec![0] }) {
            Response::Error(m) => assert!(m.contains("unknown")),
            r => panic!("unexpected {r:?}"),
        }
        match s.handle(Request::PullColSums { id: 99 }) {
            Response::Error(m) => assert!(m.contains("unknown")),
            r => panic!("unexpected {r:?}"),
        }
        s.handle(create(1, 1, Dtype::I64, Layout::Dense));
        match s.handle(Request::PushCoords {
            id: 1,
            uid: 1,
            rows: vec![0],
            cols: vec![0],
            values: Data::F32(vec![1.0]),
        }) {
            Response::Error(m) => assert!(m.contains("dtype")),
            r => panic!("unexpected {r:?}"),
        }
    }

    #[test]
    fn failed_push_does_not_consume_uid() {
        let mut s = state();
        s.handle(create(1, 1, Dtype::I64, Layout::Dense));
        // Out-of-bounds column: rejected, uid stays unused, so a corrected
        // retry under the same uid can still apply.
        match s.handle(Request::PushCoords {
            id: 1,
            uid: 5,
            rows: vec![0],
            cols: vec![10],
            values: Data::I64(vec![1]),
        }) {
            Response::Error(_) => {}
            r => panic!("unexpected {r:?}"),
        }
        assert_eq!(
            s.handle(Request::PushCoords {
                id: 1,
                uid: 5,
                rows: vec![0],
                cols: vec![0],
                values: Data::I64(vec![1]),
            }),
            Response::PushAck { fresh: true }
        );
    }

    #[test]
    fn sparse_pull_and_topk_and_col_sums() {
        let mut s = state();
        s.handle(create(4, 8, Dtype::I64, Layout::Sparse));
        s.handle(Request::PushCoords {
            id: 1,
            uid: 1,
            rows: vec![0, 0, 2, 2, 2],
            cols: vec![3, 5, 1, 4, 6],
            values: Data::I64(vec![9, 2, 1, 8, 8]),
        });
        match s.handle(Request::PullSparseRows { id: 1, rows: vec![0, 1, 2] }) {
            Response::SparseRows(d) => {
                assert_eq!(d.lens, vec![2, 0, 3]);
                assert_eq!(d.cols, vec![3, 5, 1, 4, 6]);
                assert_eq!(d.values, Data::I64(vec![9, 2, 1, 8, 8]));
            }
            r => panic!("unexpected {r:?}"),
        }
        match s.handle(Request::PullTopK { id: 1, rows: vec![2], k: 2 }) {
            Response::SparseRows(d) => {
                assert_eq!(d.lens, vec![2]);
                // Value ties break by ascending column.
                assert_eq!(d.cols, vec![4, 6]);
                assert_eq!(d.values, Data::I64(vec![8, 8]));
            }
            r => panic!("unexpected {r:?}"),
        }
        match s.handle(Request::PullColSums { id: 1 }) {
            Response::Rows(Data::I64(v)) => {
                assert_eq!(v, vec![0, 1, 0, 9, 8, 2, 8, 0]);
            }
            r => panic!("unexpected {r:?}"),
        }
    }

    #[test]
    fn dedup_window_evicts_oldest_and_reports() {
        let cfg = PsConfig { dedup_window: 4, ..PsConfig::with_shards(1) };
        let mut s = ShardState::new(0, cfg);
        s.handle(Request::CreateMatrix {
            id: 1,
            rows: 1,
            cols: 1,
            dtype: Dtype::I64,
            layout: Layout::Dense,
        });
        // Six un-forgotten pushes through a 4-entry window: the two
        // oldest records must be evicted.
        for uid in 1..=6u64 {
            let resp = s.handle(Request::PushCoords {
                id: 1,
                uid,
                rows: vec![0],
                cols: vec![0],
                values: Data::I64(vec![1]),
            });
            assert_eq!(resp, Response::PushAck { fresh: true });
        }
        match s.handle(Request::ShardInfo) {
            Response::Info { pending_uids, dedup_evictions, .. } => {
                assert_eq!(pending_uids, 4);
                assert_eq!(dedup_evictions, 2);
            }
            r => panic!("unexpected {r:?}"),
        }
        // An evicted uid re-applies (the documented weakening)...
        assert_eq!(
            s.handle(Request::PushCoords {
                id: 1,
                uid: 1,
                rows: vec![0],
                cols: vec![0],
                values: Data::I64(vec![1]),
            }),
            Response::PushAck { fresh: true }
        );
        // ...while a uid still inside the window deduplicates.
        assert_eq!(
            s.handle(Request::PushCoords {
                id: 1,
                uid: 6,
                rows: vec![0],
                cols: vec![0],
                values: Data::I64(vec![1]),
            }),
            Response::PushAck { fresh: false }
        );
    }

    #[test]
    fn dedup_order_queue_is_compacted_in_healthy_workflow() {
        // Healthy push→ack→forget cycles never overflow `seen`, so the
        // eviction loop alone would let the order queue grow by one
        // entry per push forever; compaction must keep it bounded.
        let mut w = DedupWindow::new(8);
        for uid in 0..10_000u64 {
            assert!(!w.contains(uid));
            w.record(uid);
            w.forget(uid);
        }
        assert!(w.order.len() <= 16, "order queue grew to {}", w.order.len());
        assert_eq!(w.evictions, 0);
        assert_eq!(w.pending(), 0);
    }

    fn tmp(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("glint-shard-wal-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn wal_cfg(dir: &std::path::Path) -> PsConfig {
        PsConfig { wal_dir: Some(dir.to_path_buf()), ..PsConfig::with_shards(1) }
    }

    #[test]
    fn wal_recovery_restores_counts_dedup_and_uid_counter() {
        let dir = tmp("recover");
        let uid;
        {
            let mut s = ShardState::new(0, wal_cfg(&dir));
            s.handle(create(4, 3, Dtype::I64, Layout::Dense));
            uid = match s.handle(Request::GenUid) {
                Response::Uid(u) => u,
                r => panic!("want uid, got {r:?}"),
            };
            s.handle(Request::PushCoords {
                id: 1,
                uid,
                rows: vec![0, 3],
                cols: vec![1, 2],
                values: Data::I64(vec![5, -2]),
            });
            // A completed hand-shake: applied, acked, forgotten.
            s.handle(Request::PushCoords {
                id: 1,
                uid: uid + 1000,
                rows: vec![0],
                cols: vec![0],
                values: Data::I64(vec![7]),
            });
            s.handle(Request::Forget { uid: uid + 1000 });
        }
        let mut s = ShardState::new(0, wal_cfg(&dir));
        match s.handle(Request::PullRows { id: 1, rows: vec![0, 3] }) {
            Response::Rows(Data::I64(v)) => assert_eq!(v, vec![7, 5, 0, 0, 0, -2]),
            r => panic!("unexpected {r:?}"),
        }
        // The un-forgotten uid still deduplicates after recovery...
        assert_eq!(
            s.handle(Request::PushCoords {
                id: 1,
                uid,
                rows: vec![0],
                cols: vec![0],
                values: Data::I64(vec![1]),
            }),
            Response::PushAck { fresh: false }
        );
        // ...and fresh uids continue past everything issued before.
        match s.handle(Request::GenUid) {
            Response::Uid(u) => assert!(u > uid + 1000, "uid {u} re-issued"),
            r => panic!("unexpected {r:?}"),
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn wal_recovery_after_compaction_uses_the_snapshot() {
        let dir = tmp("compacted");
        let cfg = PsConfig {
            wal_dir: Some(dir.clone()),
            wal_segment_bytes: 256,
            wal_compact_after: 1,
            ..PsConfig::with_shards(1)
        };
        {
            let mut s = ShardState::new(0, cfg.clone());
            s.handle(create(8, 4, Dtype::I64, Layout::Sparse));
            for i in 0..200u64 {
                let resp = s.handle(Request::PushCoords {
                    id: 1,
                    uid: i + 1,
                    rows: vec![i % 8],
                    cols: vec![(i % 4) as u32],
                    values: Data::I64(vec![1]),
                });
                assert_eq!(resp, Response::PushAck { fresh: true });
            }
            // Tiny segments + compact_after 1: state has been folded
            // into a snapshot (and log bytes reclaimed) along the way.
            let wal = s.core.wal.read().unwrap().clone().unwrap();
            assert!(wal.stats().bytes > 0);
        }
        let mut s = ShardState::new(0, cfg);
        match s.handle(Request::PullColSums { id: 1 }) {
            Response::Rows(Data::I64(v)) => assert_eq!(v.iter().sum::<i64>(), 200),
            r => panic!("unexpected {r:?}"),
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn deleted_matrix_stays_deleted_after_recovery() {
        let dir = tmp("delete");
        {
            let mut s = ShardState::new(0, wal_cfg(&dir));
            s.handle(create(2, 2, Dtype::I64, Layout::Dense));
            s.handle(Request::PushCoords {
                id: 1,
                uid: 1,
                rows: vec![0],
                cols: vec![0],
                values: Data::I64(vec![3]),
            });
            s.handle(Request::DeleteMatrix { matrix: 1 });
        }
        let mut s = ShardState::new(0, wal_cfg(&dir));
        match s.handle(Request::PullRows { id: 1, rows: vec![0] }) {
            Response::Error(m) => assert!(m.contains("unknown")),
            r => panic!("unexpected {r:?}"),
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn delete_matrix_drops_state_and_is_idempotent() {
        let mut s = state();
        s.handle(create(2, 2, Dtype::I64, Layout::Dense));
        s.handle(Request::PushCoords {
            id: 1,
            uid: 1,
            rows: vec![0],
            cols: vec![0],
            values: Data::I64(vec![9]),
        });
        assert_eq!(s.handle(Request::DeleteMatrix { matrix: 1 }), Response::Ok);
        match s.handle(Request::PullRows { id: 1, rows: vec![0] }) {
            Response::Error(m) => assert!(m.contains("unknown")),
            r => panic!("unexpected {r:?}"),
        }
        assert_eq!(s.handle(Request::DeleteMatrix { matrix: 1 }), Response::Ok);
        // Re-creating after a delete starts from zeroed state.
        assert_eq!(s.handle(create(2, 2, Dtype::I64, Layout::Dense)), Response::Ok);
        match s.handle(Request::PullRows { id: 1, rows: vec![0] }) {
            Response::Rows(Data::I64(v)) => assert_eq!(v, vec![0, 0]),
            r => panic!("unexpected {r:?}"),
        }
    }

    #[test]
    fn backup_refuses_data_ops_until_promoted() {
        let cfg = PsConfig { backup_of: Some(vec![]), ..PsConfig::with_shards(1) };
        let mut s = ShardState::new(0, cfg);
        match s.handle(create(2, 2, Dtype::I64, Layout::Dense)) {
            Response::Unavailable(_) => {}
            r => panic!("unexpected {r:?}"),
        }
        match s.handle(Request::ShardInfo) {
            Response::Info { role, .. } => assert_eq!(role, ROLE_BACKUP),
            r => panic!("unexpected {r:?}"),
        }
        assert_eq!(s.handle(Request::Promote), Response::Ok);
        assert_eq!(s.handle(Request::Promote), Response::Ok); // idempotent
        assert_eq!(s.handle(create(2, 2, Dtype::I64, Layout::Dense)), Response::Ok);
        match s.handle(Request::ShardInfo) {
            Response::Info { role, .. } => assert_eq!(role, ROLE_PROMOTED),
            r => panic!("unexpected {r:?}"),
        }
    }

    #[test]
    fn replication_batches_rebuild_a_backup_exactly() {
        let dir = tmp("repl");
        let mut primary = ShardState::new(0, wal_cfg(&dir));
        primary.handle(create(6, 3, Dtype::I64, Layout::Dense));
        for i in 0..40u64 {
            primary.handle(Request::PushCoords {
                id: 1,
                uid: i + 1,
                rows: vec![i % 6],
                cols: vec![i as u32 % 3],
                values: Data::I64(vec![2]),
            });
        }
        let wal = primary.core.wal.read().unwrap().clone().unwrap();
        wal.sync();

        let backup_cfg = PsConfig { backup_of: Some(vec![]), ..PsConfig::with_shards(1) };
        let mut backup = ShardState::new(0, backup_cfg);
        let mut cursor = 1u64;
        loop {
            let slice = wal.read_from(cursor, 7).unwrap();
            let done = slice.records.is_empty();
            cursor = slice.next;
            let resp = backup.handle(Request::ReplApply {
                gen: 0,
                reset: slice.reset,
                tip: slice.tip,
                records: slice.records,
            });
            assert_eq!(resp, Response::Ok);
            if done {
                break;
            }
        }
        // Redelivering an old batch is a no-op (sequence skip + dedup).
        let slice = wal.read_from(1, 7).unwrap();
        assert_eq!(
            backup.handle(Request::ReplApply {
                gen: 0,
                reset: slice.reset,
                tip: slice.tip,
                records: slice.records,
            }),
            Response::Ok
        );
        match backup.handle(Request::ShardInfo) {
            Response::Info { repl_applied, .. } => assert_eq!(repl_applied, 41),
            r => panic!("unexpected {r:?}"),
        }
        assert_eq!(backup.handle(Request::Promote), Response::Ok);
        let want = match primary.handle(Request::PullColSums { id: 1 }) {
            Response::Rows(d) => d,
            r => panic!("unexpected {r:?}"),
        };
        let got = match backup.handle(Request::PullColSums { id: 1 }) {
            Response::Rows(d) => d,
            r => panic!("unexpected {r:?}"),
        };
        assert_eq!(got, want);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn drain_freezes_writes_but_keeps_feeding_replicas() {
        let dir = tmp("drain");
        let mut s = ShardState::new(0, wal_cfg(&dir));
        s.handle(create(2, 2, Dtype::I64, Layout::Dense));
        s.handle(Request::PushCoords {
            id: 1,
            uid: 1,
            rows: vec![0],
            cols: vec![0],
            values: Data::I64(vec![3]),
        });
        let tip = match s.handle(Request::Drain) {
            Response::Drained { tip } => tip,
            r => panic!("unexpected {r:?}"),
        };
        assert_eq!(tip, 2); // create + push, both committed
        // Idempotent: a second drain reports the same frozen tip.
        assert_eq!(s.handle(Request::Drain), Response::Drained { tip });
        match s.handle(Request::ShardInfo) {
            Response::Info { role, .. } => assert_eq!(role, ROLE_DRAINING),
            r => panic!("unexpected {r:?}"),
        }
        // New data ops get the retryable Unavailable...
        match s.handle(Request::PushCoords {
            id: 1,
            uid: 2,
            rows: vec![0],
            cols: vec![0],
            values: Data::I64(vec![1]),
        }) {
            Response::Unavailable(_) => {}
            r => panic!("unexpected {r:?}"),
        }
        // ...while a catching-up replica can still poll the full window.
        match s.handle(Request::ReplPoll { from: 1 }) {
            Response::ReplBatch { tip: t, records, .. } => {
                assert_eq!(t, tip);
                assert!(!records.is_empty());
            }
            r => panic!("unexpected {r:?}"),
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn drain_rejects_wal_less_and_backup_shards() {
        // No WAL: there is no log for a successor to tail.
        let mut plain = state();
        match plain.handle(Request::Drain) {
            Response::Error(e) => assert!(e.contains("wal"), "{e}"),
            r => panic!("unexpected {r:?}"),
        }
        // An un-promoted backup is gated like any non-replication op.
        let cfg = PsConfig { backup_of: Some(vec![]), ..PsConfig::with_shards(1) };
        let mut backup = ShardState::new(0, cfg);
        match backup.handle(Request::Drain) {
            Response::Unavailable(_) => {}
            r => panic!("unexpected {r:?}"),
        }
    }

    #[test]
    fn repl_seed_repoints_a_backup_and_fences_stale_batches() {
        let dir = tmp("reseed");
        let mut primary = ShardState::new(0, wal_cfg(&dir));
        primary.handle(create(4, 2, Dtype::I64, Layout::Dense));
        for i in 0..10u64 {
            primary.handle(Request::PushCoords {
                id: 1,
                uid: i + 1,
                rows: vec![i % 4],
                cols: vec![i as u32 % 2],
                values: Data::I64(vec![1]),
            });
        }
        let wal = primary.core.wal.read().unwrap().clone().unwrap();
        wal.sync();
        let tip = wal.committed();
        let slice = wal.read_from(1, 1024).unwrap();

        let backup_cfg = PsConfig { backup_of: Some(vec![]), ..PsConfig::with_shards(1) };
        let mut backup = ShardState::new(0, backup_cfg);
        // Generation 0 batches apply until a seed bumps the fence.
        assert_eq!(
            backup.handle(Request::ReplApply { gen: 0, reset: false, tip: 0, records: vec![] }),
            Response::Ok
        );
        assert_eq!(
            backup.handle(Request::ReplSeed {
                upstream: "10.0.0.9:7070".into(),
                tip,
                records: slice.records,
            }),
            Response::Ok
        );
        assert_eq!(
            backup.core.repl_upstream.lock().unwrap().as_deref(),
            Some("10.0.0.9:7070")
        );
        match backup.handle(Request::ShardInfo) {
            Response::Info { repl_applied, .. } => assert_eq!(repl_applied, tip),
            r => panic!("unexpected {r:?}"),
        }
        // A batch the poller fetched from the *old* upstream (generation
        // 0) lands after the seed: fenced off instead of applied.
        match backup.handle(Request::ReplApply {
            gen: 0,
            reset: false,
            tip: tip + 5,
            records: vec![],
        }) {
            Response::Error(e) => assert!(e.contains("stale replication generation"), "{e}"),
            r => panic!("unexpected {r:?}"),
        }
        // The new generation streams normally.
        assert_eq!(
            backup.handle(Request::ReplApply { gen: 1, reset: false, tip, records: vec![] }),
            Response::Ok
        );
        // The seeded replica promotes into an exact copy of the source.
        assert_eq!(backup.handle(Request::Promote), Response::Ok);
        let want = match primary.handle(Request::PullColSums { id: 1 }) {
            Response::Rows(d) => d,
            r => panic!("unexpected {r:?}"),
        };
        let got = match backup.handle(Request::PullColSums { id: 1 }) {
            Response::Rows(d) => d,
            r => panic!("unexpected {r:?}"),
        };
        assert_eq!(got, want);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn forgotten_uids_do_not_count_as_evictions() {
        let cfg = PsConfig { dedup_window: 2, ..PsConfig::with_shards(1) };
        let mut s = ShardState::new(0, cfg);
        s.handle(Request::CreateMatrix {
            id: 1,
            rows: 1,
            cols: 1,
            dtype: Dtype::I64,
            layout: Layout::Dense,
        });
        // Full hand-shakes: push then forget, many times over a tiny
        // window. Nothing is abandoned, so nothing may count as evicted.
        for uid in 1..=10u64 {
            s.handle(Request::PushCoords {
                id: 1,
                uid,
                rows: vec![0],
                cols: vec![0],
                values: Data::I64(vec![1]),
            });
            s.handle(Request::Forget { uid });
        }
        match s.handle(Request::ShardInfo) {
            Response::Info { pending_uids, dedup_evictions, .. } => {
                assert_eq!(pending_uids, 0);
                assert_eq!(dedup_evictions, 0);
            }
            r => panic!("unexpected {r:?}"),
        }
    }
}
