//! Shard server: the process that owns a slice of every distributed
//! matrix and serves pull/push requests.
//!
//! Each shard runs a single-threaded event loop over its inbox (the Akka
//! actor model of the original: one actor per partial matrix, serialized
//! message processing). Exactly-once pushes are enforced with a
//! seen-uid set: a `PushCoords`/`PushRows` whose uid was already applied
//! acknowledges without re-applying (paper §2.4, Figure 2).

use std::collections::{HashMap, HashSet};
use std::net::SocketAddr;
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::log_warn;
use crate::net::tcp::{TcpServer, TcpTransport};
use crate::net::{respond, FaultPlan, Inbox, SimTransport, Transport};
use crate::ps::config::{PsConfig, TransportMode};
use crate::ps::messages::{Data, Dtype, Request, Response};
use crate::ps::partition::Partitioner;
use crate::ps::storage::DenseShard;
use crate::util::error::Result;

/// One matrix's slice on this shard.
enum MatrixSlice {
    I64 { part: Partitioner, shard: DenseShard<i64> },
    F32 { part: Partitioner, shard: DenseShard<f32> },
}

impl MatrixSlice {
    fn local_rows(&self) -> u64 {
        match self {
            MatrixSlice::I64 { shard, .. } => shard.local_rows(),
            MatrixSlice::F32 { shard, .. } => shard.local_rows(),
        }
    }

    fn bytes(&self) -> u64 {
        match self {
            MatrixSlice::I64 { shard, .. } => shard.bytes() as u64,
            MatrixSlice::F32 { shard, .. } => shard.bytes() as u64,
        }
    }
}

/// State of one shard server.
pub struct ShardState {
    shard_id: usize,
    config: PsConfig,
    matrices: HashMap<u32, MatrixSlice>,
    /// Applied-but-not-forgotten push ids (exactly-once dedup set).
    seen_uids: HashSet<u64>,
    next_uid: u64,
}

impl ShardState {
    /// Fresh state for shard `shard_id`.
    pub fn new(shard_id: usize, config: PsConfig) -> ShardState {
        ShardState {
            shard_id,
            config,
            matrices: HashMap::new(),
            seen_uids: HashSet::new(),
            // Uids carry the shard id in the top bits so they are unique
            // across shards (useful in traces); dedup is per-shard anyway.
            next_uid: (shard_id as u64) << 48,
        }
    }

    /// Handle one decoded request.
    pub fn handle(&mut self, req: Request) -> Response {
        match req {
            Request::CreateMatrix { id, rows, cols, dtype } => {
                self.create(id, rows, cols, dtype)
            }
            Request::PullRows { id, rows } => self.pull_rows(id, &rows),
            Request::GenUid => {
                self.next_uid += 1;
                Response::Uid(self.next_uid)
            }
            Request::PushCoords { id, uid, rows, cols, values } => {
                if self.seen_uids.contains(&uid) {
                    return Response::PushAck { fresh: false };
                }
                match self.apply_coords(id, &rows, &cols, &values) {
                    Ok(()) => {
                        self.seen_uids.insert(uid);
                        Response::PushAck { fresh: true }
                    }
                    Err(e) => Response::Error(e.to_string()),
                }
            }
            Request::PushRows { id, uid, rows, values } => {
                if self.seen_uids.contains(&uid) {
                    return Response::PushAck { fresh: false };
                }
                match self.apply_rows(id, &rows, &values) {
                    Ok(()) => {
                        self.seen_uids.insert(uid);
                        Response::PushAck { fresh: true }
                    }
                    Err(e) => Response::Error(e.to_string()),
                }
            }
            Request::Forget { uid } => {
                self.seen_uids.remove(&uid);
                Response::Ok
            }
            Request::ShardInfo => Response::Info {
                shard_id: self.shard_id as u32,
                shards: self.config.shards as u32,
                scheme: self.config.scheme,
                matrices: self.matrices.len() as u32,
                local_rows: self.matrices.values().map(|m| m.local_rows()).sum(),
                bytes: self.matrices.values().map(|m| m.bytes()).sum(),
                pending_uids: self.seen_uids.len() as u64,
            },
            Request::Shutdown => Response::Ok,
        }
    }

    fn create(&mut self, id: u32, rows: u64, cols: u32, dtype: Dtype) -> Response {
        // Idempotent: re-creating the same id with the same shape is a
        // no-op (a retried CreateMatrix must not wipe data).
        if let Some(existing) = self.matrices.get(&id) {
            let (erows, ecols, edtype) = match existing {
                MatrixSlice::I64 { part, shard } => (part.rows, shard.cols(), Dtype::I64),
                MatrixSlice::F32 { part, shard } => (part.rows, shard.cols(), Dtype::F32),
            };
            return if (erows, ecols, edtype) == (rows, cols, dtype) {
                Response::Ok
            } else {
                Response::Error(format!("matrix {id} already exists with different shape"))
            };
        }
        let part = Partitioner::new(rows, self.config.shards, self.config.scheme);
        let local = part.rows_on_shard(self.shard_id);
        let slice = match dtype {
            Dtype::I64 => MatrixSlice::I64 { part, shard: DenseShard::new(local, cols) },
            Dtype::F32 => MatrixSlice::F32 { part, shard: DenseShard::new(local, cols) },
        };
        self.matrices.insert(id, slice);
        Response::Ok
    }

    fn pull_rows(&self, id: u32, rows: &[u64]) -> Response {
        let Some(slice) = self.matrices.get(&id) else {
            return Response::Error(format!("unknown matrix {id}"));
        };
        let result: Result<Data> = match slice {
            MatrixSlice::I64 { part, shard } => {
                let mut out = Vec::with_capacity(rows.len() * shard.cols() as usize);
                rows.iter()
                    .try_for_each(|&r| shard.read_row(part.local_index(r), &mut out))
                    .map(|()| Data::I64(out))
            }
            MatrixSlice::F32 { part, shard } => {
                let mut out = Vec::with_capacity(rows.len() * shard.cols() as usize);
                rows.iter()
                    .try_for_each(|&r| shard.read_row(part.local_index(r), &mut out))
                    .map(|()| Data::F32(out))
            }
        };
        match result {
            Ok(data) => Response::Rows(data),
            Err(e) => Response::Error(e.to_string()),
        }
    }

    fn apply_coords(&mut self, id: u32, rows: &[u64], cols: &[u32], values: &Data) -> Result<()> {
        if rows.len() != cols.len() || rows.len() != values.len() {
            return Err(crate::util::error::Error::PsRejected(format!(
                "coord push length mismatch: {} rows, {} cols, {} values",
                rows.len(),
                cols.len(),
                values.len()
            )));
        }
        let slice = self.matrices.get_mut(&id).ok_or_else(|| {
            crate::util::error::Error::PsRejected(format!("unknown matrix {id}"))
        })?;
        match (slice, values) {
            (MatrixSlice::I64 { part, shard }, Data::I64(vals)) => {
                for ((&r, &c), &v) in rows.iter().zip(cols).zip(vals) {
                    shard.add(part.local_index(r), c, v)?;
                }
                Ok(())
            }
            (MatrixSlice::F32 { part, shard }, Data::F32(vals)) => {
                for ((&r, &c), &v) in rows.iter().zip(cols).zip(vals) {
                    shard.add(part.local_index(r), c, v)?;
                }
                Ok(())
            }
            _ => Err(crate::util::error::Error::PsRejected(format!(
                "dtype mismatch pushing to matrix {id}"
            ))),
        }
    }

    fn apply_rows(&mut self, id: u32, rows: &[u64], values: &Data) -> Result<()> {
        let slice = self.matrices.get_mut(&id).ok_or_else(|| {
            crate::util::error::Error::PsRejected(format!("unknown matrix {id}"))
        })?;
        match (slice, values) {
            (MatrixSlice::I64 { part, shard }, Data::I64(vals)) => {
                let cols = shard.cols() as usize;
                if vals.len() != rows.len() * cols {
                    return Err(crate::util::error::Error::PsRejected(
                        "row push shape mismatch".into(),
                    ));
                }
                for (&r, chunk) in rows.iter().zip(vals.chunks_exact(cols)) {
                    shard.add_row(part.local_index(r), chunk)?;
                }
                Ok(())
            }
            (MatrixSlice::F32 { part, shard }, Data::F32(vals)) => {
                let cols = shard.cols() as usize;
                if vals.len() != rows.len() * cols {
                    return Err(crate::util::error::Error::PsRejected(
                        "row push shape mismatch".into(),
                    ));
                }
                for (&r, chunk) in rows.iter().zip(vals.chunks_exact(cols)) {
                    shard.add_row(part.local_index(r), chunk)?;
                }
                Ok(())
            }
            _ => Err(crate::util::error::Error::PsRejected(format!(
                "dtype mismatch pushing to matrix {id}"
            ))),
        }
    }
}

/// Event loop for one shard server thread.
fn serve(mut state: ShardState, inbox: Inbox) {
    while let Some(env) = inbox.recv() {
        let resp = match Request::decode(&env.payload) {
            Ok(Request::Shutdown) => {
                respond(&env, Response::Ok.encode());
                return;
            }
            Ok(req) => state.handle(req),
            Err(e) => Response::Error(e.to_string()),
        };
        respond(&env, resp.encode());
    }
}

/// Spawn one serve-loop thread per inbox, for shards numbered from
/// `first_shard` upward.
fn spawn_serve_threads(
    config: &PsConfig,
    first_shard: usize,
    inboxes: Vec<Inbox>,
) -> Vec<JoinHandle<()>> {
    inboxes
        .into_iter()
        .enumerate()
        .map(|(i, inbox)| {
            let shard_id = first_shard + i;
            let state = ShardState::new(shard_id, config.clone());
            std::thread::Builder::new()
                .name(format!("glint-shard-{shard_id}"))
                .spawn(move || serve(state, inbox))
                .expect("spawn shard server")
        })
        .collect()
}

/// A running group of shard servers plus the transport connecting to
/// them. Owns the server threads; dropping the group shuts them down.
pub struct ServerGroup {
    transport: Arc<dyn Transport>,
    config: PsConfig,
    handles: Vec<JoinHandle<()>>,
    /// Listener handles when the group runs over TCP loopback.
    tcp: Option<TcpServer>,
}

impl ServerGroup {
    /// Start `config.shards` shard servers over the transport selected
    /// by `config.transport`:
    ///
    /// - [`TransportMode::Sim`] — in-process inboxes under `plan`;
    /// - [`TransportMode::TcpLoopback`] — real TCP listeners on
    ///   `127.0.0.1` ephemeral ports (the fault plan does not apply: the
    ///   network itself supplies the at-most-once behavior);
    /// - [`TransportMode::Connect`] — not startable: the servers live in
    ///   other processes (use [`TcpShardServer`] there).
    pub fn start(config: PsConfig, plan: FaultPlan, seed: u64) -> ServerGroup {
        match config.transport {
            TransportMode::Sim => {
                let (transport, inboxes) = SimTransport::new(config.shards, plan, seed);
                let handles = spawn_serve_threads(&config, 0, inboxes);
                ServerGroup { transport: Arc::new(transport), config, handles, tcp: None }
            }
            TransportMode::TcpLoopback => {
                if !plan.is_reliable() {
                    log_warn!(
                        "fault injection is sim-only; the TCP transport ignores the fault plan"
                    );
                }
                let want: Vec<SocketAddr> =
                    vec!["127.0.0.1:0".parse().unwrap(); config.shards];
                let (server, inboxes) =
                    TcpServer::bind(&want).expect("bind loopback tcp listeners");
                let transport = TcpTransport::connect(server.addrs());
                let handles = spawn_serve_threads(&config, 0, inboxes);
                ServerGroup {
                    transport: Arc::new(transport),
                    config,
                    handles,
                    tcp: Some(server),
                }
            }
            TransportMode::Connect(_) => panic!(
                "ServerGroup::start cannot run in Connect mode: the shard servers live in \
                 other processes (run `glint-lda serve` there and connect a client instead)"
            ),
        }
    }

    /// The transport clients should connect through.
    pub fn transport(&self) -> Arc<dyn Transport> {
        Arc::clone(&self.transport)
    }

    /// Deployment config.
    pub fn config(&self) -> &PsConfig {
        &self.config
    }

    /// Gracefully stop all shard threads.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        for s in 0..self.transport.shards() {
            let ep = self.transport.endpoint(s);
            // Control-plane channel: bypasses fault injection so the stop
            // signal always lands (or errors if the shard already exited).
            let _ = ep
                .send_reliable(Request::Shutdown.encode(), std::time::Duration::from_secs(5));
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        if let Some(mut server) = self.tcp.take() {
            server.shutdown();
        }
    }
}

impl Drop for ServerGroup {
    fn drop(&mut self) {
        if !self.handles.is_empty() {
            self.shutdown_inner();
        }
    }
}

/// Standalone TCP shard servers for multi-process deployments: the
/// `glint-lda serve` half of a `serve` / `train --connect` pair.
///
/// Hosts shards `first_shard .. first_shard + addrs.len()` of a
/// `config.shards`-shard deployment, one listener per shard. Each serve
/// loop exits when it receives a [`Request::Shutdown`] (e.g. from
/// [`crate::ps::client::PsClient::shutdown_servers`]).
pub struct TcpShardServer {
    server: TcpServer,
    handles: Vec<JoinHandle<()>>,
}

impl TcpShardServer {
    /// Bind listeners and start the serve loops. Use port `0` to bind
    /// ephemeral ports and read them back from [`TcpShardServer::addrs`].
    pub fn bind(
        config: PsConfig,
        first_shard: usize,
        addrs: &[SocketAddr],
    ) -> Result<TcpShardServer> {
        if addrs.is_empty() {
            return Err(crate::util::error::Error::Config(
                "serve needs at least one bind address".into(),
            ));
        }
        if first_shard + addrs.len() > config.shards {
            return Err(crate::util::error::Error::Config(format!(
                "shards {first_shard}..{} exceed the {}-shard deployment",
                first_shard + addrs.len(),
                config.shards
            )));
        }
        let (server, inboxes) = TcpServer::bind(addrs)?;
        let handles = spawn_serve_threads(&config, first_shard, inboxes);
        Ok(TcpShardServer { server, handles })
    }

    /// Local listener addresses, in shard order.
    pub fn addrs(&self) -> &[SocketAddr] {
        self.server.addrs()
    }

    /// Block until every hosted shard has been told to shut down, then
    /// stop accepting connections.
    pub fn join(mut self) {
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        self.server.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state() -> ShardState {
        // Single shard so every row is local.
        ShardState::new(0, PsConfig::with_shards(1))
    }

    #[test]
    fn create_pull_push_cycle() {
        let mut s = state();
        assert_eq!(
            s.handle(Request::CreateMatrix { id: 1, rows: 4, cols: 3, dtype: Dtype::I64 }),
            Response::Ok
        );
        let uid = match s.handle(Request::GenUid) {
            Response::Uid(u) => u,
            r => panic!("want uid, got {r:?}"),
        };
        assert_eq!(
            s.handle(Request::PushCoords {
                id: 1,
                uid,
                rows: vec![0, 0, 3],
                cols: vec![0, 1, 2],
                values: Data::I64(vec![5, 7, -2]),
            }),
            Response::PushAck { fresh: true }
        );
        match s.handle(Request::PullRows { id: 1, rows: vec![0, 3] }) {
            Response::Rows(Data::I64(v)) => assert_eq!(v, vec![5, 7, 0, 0, 0, -2]),
            r => panic!("unexpected {r:?}"),
        }
    }

    #[test]
    fn duplicate_push_not_reapplied() {
        let mut s = state();
        s.handle(Request::CreateMatrix { id: 1, rows: 1, cols: 1, dtype: Dtype::I64 });
        let push = Request::PushCoords {
            id: 1,
            uid: 7,
            rows: vec![0],
            cols: vec![0],
            values: Data::I64(vec![10]),
        };
        assert_eq!(s.handle(push.clone()), Response::PushAck { fresh: true });
        assert_eq!(s.handle(push.clone()), Response::PushAck { fresh: false });
        assert_eq!(s.handle(push), Response::PushAck { fresh: false });
        match s.handle(Request::PullRows { id: 1, rows: vec![0] }) {
            Response::Rows(Data::I64(v)) => assert_eq!(v, vec![10]),
            r => panic!("unexpected {r:?}"),
        }
    }

    #[test]
    fn forget_releases_uid() {
        let mut s = state();
        s.handle(Request::CreateMatrix { id: 1, rows: 1, cols: 1, dtype: Dtype::I64 });
        let push = Request::PushCoords {
            id: 1,
            uid: 9,
            rows: vec![0],
            cols: vec![0],
            values: Data::I64(vec![1]),
        };
        s.handle(push.clone());
        match s.handle(Request::ShardInfo) {
            Response::Info { pending_uids, .. } => assert_eq!(pending_uids, 1),
            r => panic!("unexpected {r:?}"),
        }
        assert_eq!(s.handle(Request::Forget { uid: 9 }), Response::Ok);
        assert_eq!(s.handle(Request::Forget { uid: 9 }), Response::Ok); // idempotent
        match s.handle(Request::ShardInfo) {
            Response::Info { pending_uids, .. } => assert_eq!(pending_uids, 0),
            r => panic!("unexpected {r:?}"),
        }
    }

    #[test]
    fn recreate_same_shape_is_idempotent() {
        let mut s = state();
        let create = Request::CreateMatrix { id: 1, rows: 2, cols: 2, dtype: Dtype::I64 };
        s.handle(create.clone());
        s.handle(Request::PushCoords {
            id: 1,
            uid: 1,
            rows: vec![1],
            cols: vec![1],
            values: Data::I64(vec![4]),
        });
        // Retried create must not wipe the data.
        assert_eq!(s.handle(create), Response::Ok);
        match s.handle(Request::PullRows { id: 1, rows: vec![1] }) {
            Response::Rows(Data::I64(v)) => assert_eq!(v, vec![0, 4]),
            r => panic!("unexpected {r:?}"),
        }
    }

    #[test]
    fn recreate_different_shape_rejected() {
        let mut s = state();
        s.handle(Request::CreateMatrix { id: 1, rows: 2, cols: 2, dtype: Dtype::I64 });
        match s.handle(Request::CreateMatrix { id: 1, rows: 3, cols: 2, dtype: Dtype::I64 }) {
            Response::Error(_) => {}
            r => panic!("unexpected {r:?}"),
        }
    }

    #[test]
    fn errors_for_unknown_matrix_and_mismatch() {
        let mut s = state();
        match s.handle(Request::PullRows { id: 99, rows: vec![0] }) {
            Response::Error(m) => assert!(m.contains("unknown")),
            r => panic!("unexpected {r:?}"),
        }
        s.handle(Request::CreateMatrix { id: 1, rows: 1, cols: 1, dtype: Dtype::I64 });
        match s.handle(Request::PushCoords {
            id: 1,
            uid: 1,
            rows: vec![0],
            cols: vec![0],
            values: Data::F32(vec![1.0]),
        }) {
            Response::Error(m) => assert!(m.contains("dtype")),
            r => panic!("unexpected {r:?}"),
        }
    }

    #[test]
    fn failed_push_does_not_consume_uid() {
        let mut s = state();
        s.handle(Request::CreateMatrix { id: 1, rows: 1, cols: 1, dtype: Dtype::I64 });
        // Out-of-bounds column: rejected, uid stays unused, so a corrected
        // retry under the same uid can still apply.
        match s.handle(Request::PushCoords {
            id: 1,
            uid: 5,
            rows: vec![0],
            cols: vec![10],
            values: Data::I64(vec![1]),
        }) {
            Response::Error(_) => {}
            r => panic!("unexpected {r:?}"),
        }
        assert_eq!(
            s.handle(Request::PushCoords {
                id: 1,
                uid: 5,
                rows: vec![0],
                cols: vec![0],
                values: Data::I64(vec![1]),
            }),
            Response::PushAck { fresh: true }
        );
    }
}
